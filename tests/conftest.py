"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the real
device count (the dry-run is the only 512-device context).  Tests that need
a small multi-device mesh force 8 host devices via a subprocess-safe env
check in pytest.ini instead; locally we use whatever is available and skip
mesh-shape-dependent tests when devices are insufficient.
"""
import os

# allow an 8-device CPU mesh for sharding tests without touching the
# dry-run's 512-device setting (tests run in their own process)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.distributed.sharding import make_mesh  # noqa: E402


@pytest.fixture(scope="session")
def mesh():
    n = len(jax.devices())
    if n < 8:
        pytest.skip("needs 8 host devices")
    return make_mesh((2, 4), ("data", "model"))


@pytest.fixture(scope="session")
def mesh1d():
    n = len(jax.devices())
    if n < 4:
        pytest.skip("needs 4 host devices")
    return make_mesh((1, 4), ("data", "model"))


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
