"""Chaos-hardened serving: fault injection, retry/breaker/brown-out
degradation, guardrails (OOB validation, score scrub), and mid-serving
checkpoint recovery."""
import numpy as np
import pytest

from repro.runtime.fault_tolerance import FailureInjector
from repro.serving import (ArrivalConfig, BreakerConfig, ClosedLoopSource,
                           DegradationController, FaultConfig,
                           FaultInjectingExecutor, FixedBatcher,
                           FixedServiceModel, LadderConfig, LoadConfig,
                           OpenLoopSource, Request, RetryPolicy,
                           RuntimeConfig, ServingRuntime, SimulatedExecutor,
                           TransientServingFailure, corrupt_store)
from repro.serving.degradation import RUNGS, CircuitBreaker


# ---------------------------------------------------------------------------
# Injection vocabulary
# ---------------------------------------------------------------------------


def test_failure_injector_fires_scheduled_once_and_chaos_reproducibly():
    inj = FailureInjector(fail_at_steps=(2, 5))
    hits = [s for s in range(10) if inj.fires(s)]
    assert hits == [2, 5]
    assert not inj.fires(2)              # once each: retries must not loop
    a = FailureInjector(fail_prob=0.3, seed=7)
    b = FailureInjector(fail_prob=0.3, seed=7)
    pat_a = [a.fires(s) for s in range(200)]
    pat_b = [b.fires(s) for s in range(200)]
    assert pat_a == pat_b and any(pat_a) and not all(pat_a)
    assert not FailureInjector().armed
    assert FailureInjector(fail_prob=0.1).armed


def test_fault_executor_straggler_multiplies_and_transient_raises():
    model = FixedServiceModel(base_s=1e-3, per_row_s=0.0)
    fex = FaultInjectingExecutor(
        SimulatedExecutor(model),
        FaultConfig(straggler_at=(1,), straggler_factor=8.0,
                    transient_at=(3,), stall_at=(0,), stall_s=0.5))
    from repro.serving import Bucket
    bucket = Bucket(4, 4)
    base = fex.run_batch(bucket, {})                 # step 0: clean
    assert fex.run_batch(bucket, {}) == pytest.approx(8.0 * base)
    fex.run_batch(bucket, {})                        # step 2: clean
    with pytest.raises(TransientServingFailure):
        fex.run_batch(bucket, {})                    # step 3
    assert fex.observe({}) == pytest.approx(0.5)     # injected stall
    assert fex.report()["straggler"] == 1
    assert fex.report()["transient"] == 1


def test_fault_executor_corruption_copies_batch():
    """A retry of a corrupted micro-batch must see the ORIGINAL data (the
    re-read from the healthy feature store), so corruption may never
    mutate the caller's arrays in place."""
    model = FixedServiceModel(base_s=1e-3, per_row_s=0.0)
    fex = FaultInjectingExecutor(
        SimulatedExecutor(model),
        FaultConfig(corrupt_oob_at=(0,), corrupt_nan_at=(0,)))
    from repro.serving import Bucket
    idx = np.zeros((4, 2, 3), np.int32)
    dense = np.ones((4, 8), np.float32)
    fex.run_batch(Bucket(4, 4), {"indices": idx, "dense": dense})
    assert (idx == 0).all() and np.isfinite(dense).all()
    assert fex.corrupted_batches == [0]


def test_transient_burst_persists_across_attempts():
    model = FixedServiceModel(base_s=1e-3, per_row_s=0.0)
    fex = FaultInjectingExecutor(
        SimulatedExecutor(model),
        FaultConfig(transient_at=(0,), transient_runs=3))
    from repro.serving import Bucket
    for _ in range(3):
        with pytest.raises(TransientServingFailure):
            fex.run_batch(Bucket(4, 4), {})
    fex.run_batch(Bucket(4, 4), {})      # burst spent: healthy again
    assert fex.report()["transient"] == 3


# ---------------------------------------------------------------------------
# Circuit breaker + ladder (virtual clock, no runtime)
# ---------------------------------------------------------------------------


def test_circuit_breaker_trips_cools_down_and_probes():
    br = CircuitBreaker(BreakerConfig(trip_after=3, cooldown_s=1.0))
    for _ in range(3):
        assert br.allow(0.0)
        br.record_failure(0.0)
    assert br.state == "open" and br.trips == 1
    assert not br.allow(0.5)                   # cooling down: fail fast
    assert br.allow(1.0)                       # half-open probe admitted
    br.record_failure(1.0)                     # probe fails -> reopen
    assert br.state == "open" and not br.allow(1.5)
    assert br.allow(2.0)
    br.record_success()                        # probe succeeds -> closed
    assert br.state == "closed" and br.allow(2.1)


def test_ladder_steps_down_under_pressure_and_recovers_with_hysteresis():
    ctrl = DegradationController(
        ladder=LadderConfig(alpha=0.5, step_down_at=0.6, step_up_at=0.2,
                            min_dwell_batches=2))
    t = 0.0
    for _ in range(4):
        ctrl.on_batch_done(t, ok=False)
        t += 0.01
    assert ctrl.rung >= 1                      # stepped down under failures
    down = len(ctrl.transitions)
    rung_peak = ctrl.rung
    for _ in range(20):
        ctrl.on_batch_done(t, ok=True)
        t += 0.01
    assert ctrl.rung == 0                      # recovered all the way up
    ups = len(ctrl.transitions) - down
    assert ups == rung_peak                    # one recorded move per rung
    # hysteresis: dwell gate means moves never alternate on single batches
    times = [tr["t"] for tr in ctrl.transitions]
    assert all(b >= a for a, b in zip(times, times[1:]))
    rep = ctrl.report()
    assert rep["rung"] == "full" and rep["n_transitions"] == len(times)


def test_ladder_shed_rung_tightens_and_restores_admission():
    ctrl = DegradationController(
        ladder=LadderConfig(alpha=1.0, step_down_at=0.5, step_up_at=0.1,
                            min_dwell_batches=1, shed_capacity=2))
    from repro.serving import AdmissionQueue
    q = AdmissionQueue(100)
    ctrl.bind_queue(q)
    t = 0.0
    while ctrl.rung_label != "shed":
        ctrl.on_batch_done(t, ok=False)
        t += 0.01
    assert q.capacity == 2
    while ctrl.rung_label != "full":
        ctrl.on_batch_done(t, ok=True)
        t += 0.01
    assert q.capacity == 100
    assert [tr["from"] for tr in ctrl.transitions[:4]] == list(RUNGS[:4])


# ---------------------------------------------------------------------------
# Runtime integration (simulated executor): retry, fail-once accounting,
# closed-loop release, shed-everything overload
# ---------------------------------------------------------------------------


def _sim_runtime(fault_cfg, retry=None, breaker=None, queue_capacity=4096):
    model = FixedServiceModel(base_s=4e-3, per_row_s=0.0)
    ctrl = DegradationController(retry=retry, breaker=breaker)
    fex = FaultInjectingExecutor(SimulatedExecutor(model), fault_cfg)
    rt = ServingRuntime(
        fex, FixedBatcher(batch=4, pooling=4),
        padder=lambda reqs, bucket: {"n": len(reqs)},
        cfg=RuntimeConfig(observe_every=0, replan_every=0,
                          queue_capacity=queue_capacity),
        service_model=model, controller=ctrl)
    return rt, ctrl, fex


def _reqs(n, rate=1000.0, slo=0.05):
    times = np.arange(n) / rate
    return [Request(rid=i, arrival_s=float(times[i]),
                    deadline_s=float(times[i]) + slo, features={}, pooling=4)
            for i in range(n)]


def test_retry_recovers_transient_and_counts_retries():
    rt, ctrl, fex = _sim_runtime(FaultConfig(transient_at=(0,)))
    s = rt.run(OpenLoopSource(_reqs(8)))
    assert s["served"] == 8 and s["failed"] == 0
    assert s["retries"] == 1 and s["availability"] == 1.0
    assert s["failed_batches"] == 0


def test_retry_exhausted_requests_fail_once_in_slo_metrics():
    # a 3-attempt burst on the first micro-batch exhausts the default
    # 3-attempt retry budget: that batch's requests fail exactly once
    rt, ctrl, fex = _sim_runtime(FaultConfig(transient_at=(0,),
                                             transient_runs=3))
    s = rt.run(OpenLoopSource(_reqs(8)))
    assert s["failed"] == 4 and s["served"] == 4
    assert s["failed_batches"] == 1
    # failed requests are SLO violations exactly once: 4 failed + 0 of the
    # served 4 violated over 8 completed
    assert s["slo_violation_rate"] == pytest.approx(4 / 8)
    assert s["availability"] == pytest.approx(0.5)
    assert s["retries"] == 2            # two scheduled re-attempts, both lost
    assert s["goodput_qps"] <= s["qps"]


def test_breaker_failfast_then_recovery_serves_tail():
    # 8 consecutive failing attempts trip the 4-failure breaker mid-burst;
    # requests arriving while it is open fail fast (never reach the
    # executor), and the stream's tail is served after the cooldown
    rt, ctrl, fex = _sim_runtime(
        FaultConfig(transient_at=(0,), transient_runs=8),
        breaker=BreakerConfig(trip_after=4, cooldown_s=0.01))
    s = rt.run(OpenLoopSource(_reqs(40, rate=400.0)))
    assert s["failed_fast"] > 0
    assert ctrl.breaker.trips >= 1
    assert s["served"] > 0                       # recovered: tail healthy
    assert s["served"] + s["failed"] == 40       # nothing lost or doubled
    deg = s["degradation"]
    assert deg["breaker_trips"] == ctrl.breaker.trips


def test_closed_loop_users_released_on_failed_and_dropped_requests():
    # the first batch fails after exhausting its retry budget: if failure
    # did not release the issuing users the closed loop would starve and
    # the run would end short of n_requests
    rt, ctrl, fex = _sim_runtime(FaultConfig(transient_at=(0,),
                                             transient_runs=3))
    factory = lambda rid, user, t: Request(   # noqa: E731
        rid=rid, arrival_s=t, deadline_s=t + 0.05, features={}, pooling=4)
    src = ClosedLoopSource(n_users=4, n_requests=24, factory=factory,
                           think_time_s=0.001)
    s = rt.run(src)
    assert s["served"] + s["failed"] == 24
    assert s["failed"] > 0 and s["served"] > 0


def test_shed_everything_overload_summary_stays_finite():
    """All-shed regime (satellite: empty-window metrics guards): capacity 4
    with a same-instant burst far beyond it — most requests drop, and every
    summary rate must come back finite, never divide-by-zero."""
    model = FixedServiceModel(base_s=4e-3, per_row_s=0.0)
    rt = ServingRuntime(
        SimulatedExecutor(model), FixedBatcher(batch=4, pooling=4),
        padder=lambda reqs, bucket: {"n": len(reqs)},
        cfg=RuntimeConfig(observe_every=0, replan_every=0, queue_capacity=4),
        service_model=model)
    reqs = [Request(rid=i, arrival_s=0.0, deadline_s=0.05, features={},
                    pooling=4) for i in range(64)]
    s = rt.run(OpenLoopSource(reqs))
    assert s["dropped"] > 0
    assert s["served"] + s["dropped"] == 64
    for k in ("qps", "goodput_qps", "availability", "slo_violation_rate"):
        assert np.isfinite(s[k]), k


def test_metrics_guards_empty_window_and_nonfinite_samples():
    from repro.serving import LatencyHistogram, ServingMetrics
    m = ServingMetrics()
    s = m.summary()                      # zero requests, zero duration
    assert s["qps"] == 0.0 and s["goodput_qps"] == 0.0
    assert s["availability"] == 1.0 and s["slo_violation_rate"] == 0.0
    h = LatencyHistogram()
    h.record(float("nan"))
    h.record(float("inf"))
    h.record(1e-3)
    assert len(h) == 1 and h.nonfinite == 2
    assert np.isfinite(h.percentiles_ms()["p99_ms"])
    # a failed request that never started must not poison percentiles
    m2 = ServingMetrics()
    r = Request(rid=0, arrival_s=0.0, deadline_s=0.1, features={})
    m2.record_failure(r)
    assert m2.failed == 1 and len(m2.latency) == 0
    s2 = m2.summary()
    assert s2["availability"] == 0.0 and s2["slo_violation_rate"] == 1.0


def test_request_failed_is_never_slo_ok():
    r = Request(rid=0, arrival_s=0.0, deadline_s=10.0, features={})
    r.start_s = r.finish_s = 0.1
    assert r.slo_ok
    r.failed = True
    assert not r.slo_ok


def test_admission_queue_set_capacity_never_evicts():
    from repro.serving import AdmissionQueue
    q = AdmissionQueue(4)
    for i in range(4):
        assert q.offer(_reqs(4)[i])
    q.set_capacity(2)                    # shrink below current depth
    assert len(q) == 4                   # admitted requests survive
    assert not q.offer(_reqs(5)[4])      # but new offers shed
    q.set_capacity(8)
    assert q.offer(_reqs(6)[5])
    with pytest.raises(ValueError):
        q.set_capacity(0)


# ---------------------------------------------------------------------------
# Engine/binding guardrails + degraded rungs on a real mesh
# ---------------------------------------------------------------------------


def _dlrm_batch(cfg, B=8, seed=0):
    rng = np.random.default_rng(seed)
    return {"dense": rng.normal(size=(B, cfg.n_dense)).astype(np.float32),
            "indices": rng.integers(0, cfg.emb_num,
                                    (B, cfg.n_tables, cfg.pooling)
                                    ).astype(np.int32)}


@pytest.fixture(scope="module")
def rmc1():
    from repro.configs import get_config, reduced
    return reduced(get_config("rmc1"))


def test_validate_ids_raises_host_side_on_oob(mesh, rmc1):
    from repro.serving import bind_model
    binding = bind_model(rmc1, mesh, validate_ids=True)
    batch = _dlrm_batch(rmc1)
    with mesh:
        binding.execute(batch)                         # valid ids: fine
        bad = dict(batch)
        bad["indices"] = batch["indices"].copy()
        bad["indices"][0, 0, 0] = 2 ** 31 - 2
        with pytest.raises(ValueError, match="out-of-range"):
            binding.execute(bad)


def test_degraded_rungs_bitexact_and_hot_only_finite(mesh, rmc1):
    """The ladder's bit-exactness contract, test-pinned: split_fe and
    no_dedup (and shed's datapath twin hot_only aside) must produce
    bitwise-identical scores to full; hot_only/shed stay finite and
    well-shaped (scores may change — cold rows are zero-filled)."""
    from repro.serving import bind_model
    binding = bind_model(rmc1, mesh, dedup="on", front_end="fused",
                         degraded_variants=True)
    assert set(binding.modes()) == set(RUNGS)
    batch = _dlrm_batch(rmc1)
    out = {}
    with mesh:
        for rung in RUNGS:
            binding.set_mode(rung)
            out[rung] = np.asarray(binding.execute(batch))
    binding.set_mode("full")
    np.testing.assert_array_equal(out["full"], out["split_fe"])
    np.testing.assert_array_equal(out["full"], out["no_dedup"])
    np.testing.assert_array_equal(out["hot_only"], out["shed"])
    for rung in RUNGS:
        assert out[rung].shape == out["full"].shape
        assert np.isfinite(out[rung]).all()


def test_set_mode_unknown_rung_falls_back_to_full(mesh, rmc1):
    from repro.serving import bind_model
    binding = bind_model(rmc1, mesh)          # no variants built
    binding.set_mode("hot_only")
    assert binding.active == "full"


def test_scrub_and_checkpoint_restore_heal_corrupted_store(mesh, rmc1,
                                                           tmp_path):
    """Corrupted hot tier -> NaN scores -> scrub zero-fills with poisoned
    accounting -> restore() reloads the checkpoint -> scores bit-equal the
    healthy baseline, all without retracing the serve step."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.serving import bind_model
    binding = bind_model(rmc1, mesh, scrub_scores=True)
    batch = _dlrm_batch(rmc1)
    dp = max(1, binding.engine.axes.dp_size(binding.engine.mesh))
    with mesh:
        # promote the batch's pages into the hot tier so corruption lands
        # on rows the lookup actually reads
        binding.observe(batch)
        binding.replan()
        healthy = np.asarray(binding.execute(batch))
        assert binding.last_poisoned == 0
        binding.reset_plan_stats()
        binding.attach_checkpointer(Checkpointer(str(tmp_path)),
                                    save_now=True)
        # explicit mode="nan": this scenario heals through the NaN score
        # scrub; finite flips are the checksum scrubber's territory
        # (test_integrity.py)
        n_bad = corrupt_store(binding, frac=1.0, seed=1, mode="nan")
        assert n_bad > 0
        poisoned = np.asarray(binding.execute(batch))
        assert binding.last_poisoned > 0 and binding.poisoned_batches == 1
        assert np.isfinite(poisoned).all()          # scrubbed, not NaN
        binding.restore()
        healed = np.asarray(binding.execute(batch))
    assert binding.restores == 1
    np.testing.assert_array_equal(healed, healthy)
    assert binding.engine.plan_stats()["traces"] == 0   # no retrace


def test_heal_replays_wal_for_post_snapshot_updates(mesh, rmc1, tmp_path):
    """The heal scenario above, extended with streaming updates: deltas
    applied AFTER the snapshot exist only in the write-ahead log, so a
    checkpoint reload alone would serve stale rows.  restore() must chase
    the snapshot with a WAL replay and land bit-exactly on the
    post-update scores — still without retracing the serve step."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.checkpoint.wal import WriteAheadLog
    from repro.serving import bind_model
    binding = bind_model(rmc1, mesh, storage="int8")
    batch = _dlrm_batch(rmc1)
    rng = np.random.default_rng(8)
    total = int(binding.engine.cfg.total_rows)
    with mesh:
        binding.observe(batch)
        binding.replan()
        binding.attach_wal(WriteAheadLog(str(tmp_path / "u.wal")))
        binding.attach_checkpointer(Checkpointer(str(tmp_path / "ck")),
                                    save_now=True)
        stale = np.asarray(binding.execute(batch))  # pre-update scores
        for _ in range(2):
            binding.apply_deltas(
                rng.integers(0, total, 32),
                rng.normal(size=(32, rmc1.emb_dim)).astype(np.float32))
        fresh = np.asarray(binding.execute(batch))  # post-update scores
        assert not np.array_equal(stale, fresh)     # updates visible
        binding.reset_plan_stats()
        assert corrupt_store(binding, frac=1.0, seed=4, mode="nan") > 0
        binding.restore()
        healed = np.asarray(binding.execute(batch))
    assert binding.restores == 1
    np.testing.assert_array_equal(healed, fresh)    # not the stale snapshot
    assert binding.update_seq == 2
    assert binding.engine.plan_stats()["traces"] == 0


# ---------------------------------------------------------------------------
# Shard loss -> elastic re-mesh (degraded-mesh serving)
# ---------------------------------------------------------------------------


def test_shard_loss_persists_until_remesh():
    """Unlike a transient, shard loss keeps failing every attempt until
    the executor is told the dead shard left the mesh (on_remesh)."""
    from repro.serving import Bucket, ShardLossFailure
    model = FixedServiceModel(base_s=1e-3, per_row_s=0.0)
    fex = FaultInjectingExecutor(
        SimulatedExecutor(model),
        FaultConfig(shard_loss_at=(1,), shard_loss_shard=3))
    bucket = Bucket(4, 4)
    fex.run_batch(bucket, {})                 # step 0: healthy
    for _ in range(3):                        # persistent, not one-shot
        with pytest.raises(ShardLossFailure) as ei:
            fex.run_batch(bucket, {})
        assert ei.value.shard == 3
    assert fex.lost_shard == 3
    fex.on_remesh({})                         # the dead shard left the mesh
    fex.run_batch(bucket, {})                 # healthy again
    assert fex.lost_shard is None
    assert fex.report()["shard_loss"] == 3
    assert isinstance(ShardLossFailure("x", 0), TransientServingFailure)


def test_shard_loss_spares_replicated_only_rungs():
    """hot_only/shed run zero cross-shard work (replicated hot tier only),
    so a dead cold shard is invisible to them — the ladder can limp, but
    only a re-mesh recovers full quality."""
    from repro.serving import Bucket, ShardLossFailure

    class _Binding:
        active = "hot_only"

    class _Inner:
        binding = _Binding()

        def run_batch(self, bucket, batch):
            return 1e-3

    fex = FaultInjectingExecutor(
        _Inner(), FaultConfig(shard_loss_at=(0,), shard_loss_shard=1))
    bucket = Bucket(4, 4)
    fex.run_batch(bucket, {})            # fires, but hot_only passes through
    assert fex.lost_shard == 1           # ...the shard is still dead
    assert fex.report()["shard_loss"] == 0
    fex.inner.binding.active = "full"    # back on the cross-shard datapath
    with pytest.raises(ShardLossFailure):
        fex.run_batch(bucket, {})


def test_controller_shard_attribution_escalates_and_transient_clears():
    """The persistent/transient distinguisher: only a *consecutive*
    same-shard failure streak escalates to remesh; an interleaved
    non-attributed transient breaks the evidence chain (a genuinely flaky
    fabric does not blame one shard consistently)."""
    from repro.serving import ShardLossFailure

    class _Binding:
        can_remesh = True
        checkpointer = None

        def set_mode(self, label):
            pass

    ctrl = DegradationController(binding=_Binding(),
                                 ladder=LadderConfig(remesh_after=3))
    for _ in range(2):
        ctrl.on_attempt_failure(0.0, ShardLossFailure("x", shard=2))
    assert not ctrl.wants_remesh
    ctrl.on_attempt_failure(0.0, TransientServingFailure("flaky"))
    assert ctrl.suspect_shard is None          # chain broken
    for _ in range(3):
        ctrl.on_attempt_failure(0.0, ShardLossFailure("x", shard=2))
    assert ctrl.wants_remesh and ctrl.suspect_shard == 2
    ctrl.note_remeshed(0.0, {"to_mesh": {"data": 2, "model": 2}})
    assert not ctrl.wants_remesh
    assert ctrl.remeshes == 1 and ctrl.pressure == 0.0
    assert ctrl.breaker.state == "closed"
    rep = ctrl.report()
    assert rep["remeshes"] == 1 and rep["suspect_shard"] is None
    assert rep["remesh_events"][0]["shard"] == 2


def test_watchdog_trips_surface_in_summary_and_feed_controller():
    """One spiked micro-batch trips the service-time watchdog; the trip
    lands in the runtime summary and bumps the controller's pressure
    (half-weight: slow-but-correct is pressure, not failure)."""
    from repro.runtime.fault_tolerance import StragglerWatchdog

    class SpikyExecutor:
        def __init__(self):
            self.n = 0

        def run_batch(self, bucket, batch):
            self.n += 1
            return 0.1 if self.n == 10 else 0.004

        def observe(self, batch):
            return 0.0

        def replan(self):
            return 0.0

    ctrl = DegradationController()
    wd = StragglerWatchdog(threshold=4.0, warmup=2)
    rt = ServingRuntime(SpikyExecutor(), FixedBatcher(batch=4, pooling=4),
                        padder=lambda reqs, bucket: {"n": len(reqs)},
                        cfg=RuntimeConfig(observe_every=0, replan_every=0),
                        controller=ctrl, watchdog=wd)
    s = rt.run(OpenLoopSource(_reqs(64)))
    assert s["watchdog"]["trips"] == 1
    assert s["watchdog"]["events"][0]["dt"] == pytest.approx(0.1)
    assert ctrl.straggler_trips == 1
    assert s["degradation"]["straggler_trips"] == 1
    assert ctrl.pressure > 0.0


def test_binding_checkpoint_mesh_mismatch_routes_to_elastic(mesh, rmc1,
                                                            tmp_path):
    """A checkpoint written under tp=4 must refuse an in-place restore on
    a tp=2 binding — loudly, naming the elastic path — instead of
    silently mis-placing shards."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.distributed.sharding import make_mesh
    from repro.serving import bind_model
    binding = bind_model(rmc1, mesh, storage="int8")
    with mesh:
        binding.attach_checkpointer(Checkpointer(str(tmp_path)),
                                    save_now=True)
    extra = binding.checkpointer.extra()
    assert extra["n_shards"] == 4
    assert extra["mesh"] == {"data": 2, "model": 4}
    assert extra["storage"] == "int8"
    m2 = make_mesh((4, 2), ("data", "model"))
    other = bind_model(rmc1, m2, storage="int8")
    other.attach_checkpointer(Checkpointer(str(tmp_path)), save_now=False)
    with pytest.raises(ValueError, match="elastic"):
        other.restore()
    # same mesh but mismatched storage fails loudly too
    other32 = bind_model(rmc1, mesh, storage="fp32")
    other32.attach_checkpointer(Checkpointer(str(tmp_path)), save_now=False)
    with pytest.raises(ValueError, match="storage"):
        other32.restore()


def test_serving_survives_shard_loss_with_elastic_remesh(mesh, rmc1):
    """The degraded-mesh tentpole end to end, on the full feature stack
    (int8 cold tier + dedup + fused front end): a tp shard dies
    mid-serving, the controller attributes the same-shard streak and
    escalates to remesh, the runtime re-meshes onto the survivors
    (tp 4 -> 2 under prefer_tp=2 with the bucket-granule constraint),
    re-warms every rung, re-attempts the stranded micro-batch —
    availability holds, zero steady-state retraces across BOTH sides of
    the re-mesh, the front end re-resolves fused_tp at the survivor tp,
    and the recovered engine serves scores bit-identical to a fresh
    engine packed onto the same degraded mesh."""
    import jax
    from repro.runtime.fault_tolerance import StragglerWatchdog
    from repro.serving import (BatcherConfig, BindingExecutor, Bucket,
                               DynamicBatcher, bind_model,
                               dummy_request_factory, make_padder,
                               request_stream)
    binding = bind_model(rmc1, mesh, storage="int8", dedup="on",
                         front_end="fused", degraded_variants=True,
                         scrub_scores=True, elastic=True, prefer_tp=2)
    bat = BatcherConfig(batch_sizes=(8, 16), poolings=(rmc1.pooling,))
    ctrl = DegradationController(
        binding=binding, retry=RetryPolicy(max_attempts=3),
        breaker=BreakerConfig(trip_after=6, cooldown_s=0.02),
        ladder=LadderConfig(min_dwell_batches=4, remesh_after=3))
    inner = BindingExecutor(binding)
    fex = FaultInjectingExecutor(
        inner, FaultConfig(seed=13, shard_loss_at=(2,)),
        idx_key=binding.idx_key)
    wd = StragglerWatchdog(threshold=4.0, warmup=4)
    rt = ServingRuntime(inner, DynamicBatcher(bat), make_padder(rmc1),
                        RuntimeConfig(observe_every=4, replan_every=8),
                        controller=ctrl, watchdog=wd)
    factory = dummy_request_factory(rmc1, storage="int8")
    load = LoadConfig(n_requests=96,
                      arrival=ArrivalConfig(rate_qps=400.0, seed=2),
                      slo_ms=500.0, seed=2, storage="int8", dedup="on",
                      front_end="fused")
    with mesh:
        for rung in binding.modes():
            binding.set_mode(rung)
            rt.warmup(factory)
        binding.set_mode("full")
        rt.executor = fex
        binding.reset_plan_stats()
        s = rt.run(OpenLoopSource(request_stream(rmc1, load)))

        # gates — the trace ledger FIRST: probe batches below are fresh
        # jit signatures and would pollute a later read
        assert binding.plan_stats()["traces"] == 0
        assert s["served"] + s["failed"] == 96
        assert s["availability"] >= 0.99
        assert binding.remeshes == 1
        rec = s["remesh"]
        assert rec["lost_shard"] == 3              # highest tp index died
        assert rec["from_mesh"] == {"data": 2, "model": 4}
        assert rec["to_mesh"] == {"data": 2, "model": 2}
        assert dict(binding.engine.mesh.shape) == {"data": 2, "model": 2}
        assert rec["mttr_s"] > 0.0
        assert fex.report()["shard_loss"] >= 3     # the attribution streak
        assert fex.lost_shard is None              # on_remesh cleared it
        assert s["degradation"]["remeshes"] == 1
        fe_recs = [r for r in
                   binding.engine.plan_stats().get("front_end", {}).values()
                   if r["requested"] == "fused"]
        assert fe_recs and all(r["resolved"] == "fused_tp" and r["tp"] == 2
                               for r in fe_recs)

        # bit-exactness: recovered binding vs a fresh engine packed onto
        # the same survivor mesh from the same logical triple + page table
        codes, values, scales = binding.engine.export_state(binding.state)
        fresh = bind_model(rmc1, binding.engine.mesh, storage="int8",
                           dedup="on", front_end="fused")
        fresh.params = binding.params
        fresh.state = fresh.engine.pack_state(
            codes, values, scales, table=binding.state.page_table,
            counts=np.asarray(jax.device_get(binding.state.counts)))
        padder = make_padder(rmc1)
        for b in bat.batch_sizes:
            bucket = Bucket(b, rmc1.pooling)
            probe = padder([factory(i, bucket.pooling)
                            for i in range(bucket.batch)], bucket)
            np.testing.assert_array_equal(
                np.asarray(jax.device_get(binding.execute(probe))),
                np.asarray(jax.device_get(fresh.execute(probe))))


def test_fault_injected_serving_run_end_to_end(mesh, rmc1):
    """Transient chaos + controller over a real binding: every request is
    accounted, availability holds, retries happen, and the plan cache
    keeps the zero-steady-retrace contract under injected faults."""
    from repro.serving import (BindingExecutor, DynamicBatcher,
                               BatcherConfig, bind_model,
                               dummy_request_factory, make_padder,
                               request_stream)
    binding = bind_model(rmc1, mesh, degraded_variants=True,
                         scrub_scores=True)
    bat = BatcherConfig(batch_sizes=(8, 16), poolings=(rmc1.pooling,))
    ctrl = DegradationController(
        binding=binding, breaker=BreakerConfig(trip_after=5,
                                               cooldown_s=0.02),
        ladder=LadderConfig(min_dwell_batches=4))
    inner = BindingExecutor(binding)
    fex = FaultInjectingExecutor(
        inner, FaultConfig(transient_at=(1,), transient_prob=0.02, seed=5))
    rt = ServingRuntime(inner, DynamicBatcher(bat), make_padder(rmc1),
                        RuntimeConfig(observe_every=4, replan_every=8),
                        controller=ctrl)
    load = LoadConfig(n_requests=48,
                      arrival=ArrivalConfig(rate_qps=400.0, seed=2),
                      slo_ms=200.0, seed=2)
    with mesh:
        # warm every rung through the clean executor (faults must never
        # fire during compile), then arm injection for the measured run
        for rung in binding.modes():
            binding.set_mode(rung)
            rt.warmup(dummy_request_factory(rmc1))
        binding.set_mode("full")
        rt.executor = fex
        binding.reset_plan_stats()
        s = rt.run(OpenLoopSource(request_stream(rmc1, load)))
    assert s["served"] + s["failed"] == 48
    assert s["availability"] >= 0.99
    assert s["retries"] >= 1
    assert binding.plan_stats()["traces"] == 0
    assert s["degradation"]["rung"] in RUNGS
