"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (pool requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.configs import base as cfgs
from repro.models import dlrm as dlrm_mod
from repro.models import gnn as gnn_mod
from repro.models import params as prm
from repro.models import recsys as rec_mod
from repro.models import transformer as tfm
from repro.optim.optimizers import adafactor, adam, rowwise_adagrad

LM_ARCHS = ["llama3.2-3b", "granite-moe-1b-a400m", "deepseek-v3-671b",
            "deepseek-67b", "nemotron-4-340b"]
REC_ARCHS = ["sasrec", "autoint", "dcn-v2", "bst"]


def _finite(x):
    return bool(jnp.isfinite(jnp.asarray(x, jnp.float32)).all())


def _rec_batch(cfg, B, kind, rng):
    it = cfg.interaction
    b = {}
    if it in ("self-attn-seq", "transformer-seq"):
        V = cfg.vocab_sizes[0]
        b["seq"] = jnp.asarray(rng.integers(0, V, (B, cfg.seq_len)), jnp.int32)
        if it == "transformer-seq":
            b["dense"] = jnp.asarray(rng.normal(size=(B, cfg.n_dense)),
                                     jnp.float32)
        if kind == "train" and it == "self-attn-seq":
            b["pos"] = jnp.asarray(rng.integers(0, V, (B, cfg.seq_len)),
                                   jnp.int32)
            b["neg"] = jnp.asarray(rng.integers(0, V, (B, cfg.seq_len)),
                                   jnp.int32)
        else:
            b["target"] = jnp.asarray(rng.integers(0, V, (B,)), jnp.int32)
            if kind == "train":
                b["labels"] = jnp.asarray(rng.integers(0, 2, B), jnp.int32)
    else:
        fields = np.stack([rng.integers(0, v, B) for v in cfg.vocab_sizes], 1)
        b["fields"] = jnp.asarray(fields, jnp.int32)
        if cfg.n_dense:
            b["dense"] = jnp.asarray(rng.normal(size=(B, cfg.n_dense)),
                                     jnp.float32)
        if kind == "train":
            b["labels"] = jnp.asarray(rng.integers(0, 2, B), jnp.int32)
    return b


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_and_decode(arch, mesh, rng):
    cfg = reduced(get_config(arch))
    params = prm.initialize(tfm.model_specs(cfg, mesh), jax.random.PRNGKey(0))
    opt = adafactor(1e-2)
    ostate = opt.init(params)
    B, S = 4, 16
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    step = tfm.make_train_step(cfg, mesh, opt)
    with mesh:
        p2, o2, m = jax.jit(step)(params, ostate, batch)
        assert _finite(m["loss"]) and float(m["loss"]) > 0
        # decode
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             tfm.cache_specs(cfg, mesh, batch=B, seq=S))
        logits, cache2 = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(p, c, t, pos, cfg, mesh)
        )(params, cache, batch["tokens"][:, :1], jnp.asarray(0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert _finite(logits)
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_loss_decreases(arch, mesh, rng):
    cfg = reduced(get_config(arch))
    params = prm.initialize(tfm.model_specs(cfg, mesh), jax.random.PRNGKey(0))
    opt = adafactor(3e-2)
    ostate = opt.init(params)
    B, S = 4, 16
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 50, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 50, (B, S)), jnp.int32),
    }
    step = jax.jit(tfm.make_train_step(cfg, mesh, opt))
    with mesh:
        losses = []
        for _ in range(8):
            params, ostate, m = step(params, ostate, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", REC_ARCHS)
def test_rec_smoke_train_serve_retrieval(arch, mesh, rng):
    cfg = reduced(get_config(arch))
    engine, offs = rec_mod.build_engine(cfg, mesh)
    params = prm.initialize(rec_mod.model_specs(cfg, mesh),
                            jax.random.PRNGKey(0))
    state = engine.init_state(jax.random.PRNGKey(1))
    opt, eopt = adam(1e-3), rowwise_adagrad(1e-2)
    ostate = opt.init(params)
    eostate = eopt.init({"cold": state.cold, "hot": state.hot})
    B = 16
    with mesh:
        step = jax.jit(rec_mod.make_train_step(cfg, engine, offs, mesh, opt,
                                               eopt))
        b = _rec_batch(cfg, B, "train", rng)
        p2, s2, o2, eo2, m = step(params, state, ostate, eostate, b)
        assert _finite(m["loss"])
        # embedding rows actually updated
        assert not np.allclose(np.asarray(s2.cold), np.asarray(state.cold))

        serve = jax.jit(rec_mod.make_serve_step(cfg, engine, offs, mesh))
        bs = _rec_batch(cfg, B, "serve", rng)
        pr = serve(params, state, bs)
        assert pr.shape == (B,) and _finite(pr)
        assert float(pr.min()) >= 0.0 and float(pr.max()) <= 1.0

        ret = jax.jit(rec_mod.make_retrieval_step(cfg, engine, offs, mesh))
        br = {k: v[:1] for k, v in _rec_batch(cfg, B, "serve", rng).items()
              if k != "target"}
        br["cand_ids"] = jnp.asarray(
            rng.integers(0, cfg.vocab_sizes[0], (64,)), jnp.int32)
        sc = ret(params, state, br)
        assert sc.shape == (64,) and _finite(sc)


@pytest.mark.parametrize("name", ["rmc1", "rmc2", "rmc3", "rmc4"])
def test_dlrm_smoke(name, mesh, rng):
    cfg = reduced(get_config(name))
    engine, offs = dlrm_mod.build_engine(cfg, mesh)
    params = prm.initialize(dlrm_mod.model_specs(cfg, mesh),
                            jax.random.PRNGKey(0))
    state = engine.init_state(jax.random.PRNGKey(1))
    opt, eopt = adam(1e-3), rowwise_adagrad(1e-2)
    ostate = opt.init(params)
    eostate = eopt.init({"cold": state.cold, "hot": state.hot})
    B = 16
    batch = {
        "dense": jnp.asarray(rng.normal(size=(B, cfg.n_dense)), jnp.float32),
        "indices": (jnp.asarray(rng.integers(
            0, cfg.emb_num, (B, cfg.n_tables, cfg.pooling)), jnp.int32)
            + jnp.asarray(offs, jnp.int32)[None, :, None]),
        "labels": jnp.asarray(rng.integers(0, 2, B), jnp.int32),
    }
    with mesh:
        step = jax.jit(dlrm_mod.make_train_step(cfg, engine, mesh, opt, eopt))
        p2, s2, o2, eo2, m = step(params, state, ostate, eostate, batch)
        assert _finite(m["loss"])
        serve = jax.jit(dlrm_mod.make_serve_step(cfg, engine, mesh))
        pr = serve(params, state, batch)
    assert pr.shape == (B,) and _finite(pr)


def test_gnn_smoke_all_regimes(mesh, rng):
    cfg = reduced(get_config("graphsage-reddit"))
    N, E, F = 32, 64, 16
    params = prm.initialize(gnn_mod.model_specs(cfg, F), jax.random.PRNGKey(0))
    opt = adam(1e-2)
    ostate = opt.init(params)
    feats = jnp.asarray(rng.normal(size=(N, F)), jnp.float32)
    with mesh:
        # full
        step = jax.jit(gnn_mod.make_train_step(cfg, mesh, opt, "full"))
        batch = {"feats": feats,
                 "edges": jnp.asarray(rng.integers(0, N, (E, 2)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.n_classes, N),
                                       jnp.int32)}
        p2, o2, m = step(params, ostate, batch)
        assert _finite(m["loss"])
        # minibatch
        B, f1, f2 = 8, 3, 2
        mb = {"feats": feats,
              "roots": jnp.asarray(rng.integers(0, N, B), jnp.int32),
              "hop1": jnp.asarray(rng.integers(0, N, (B, f1)), jnp.int32),
              "hop2": jnp.asarray(rng.integers(0, N, (B, f1, f2)), jnp.int32),
              "labels": jnp.asarray(rng.integers(0, cfg.n_classes, B),
                                    jnp.int32)}
        step2 = jax.jit(gnn_mod.make_train_step(cfg, mesh, opt, "minibatch"))
        p3, o3, m2 = step2(params, ostate, mb)
        assert _finite(m2["loss"])
        # molecule
        G, n, Em = 8, 10, 20
        mol = {"feats": jnp.asarray(rng.normal(size=(G, n, F)), jnp.float32),
               "edges": jnp.asarray(rng.integers(0, n, (G, Em, 2)), jnp.int32),
               "labels": jnp.asarray(rng.integers(0, cfg.n_classes, G),
                                     jnp.int32)}
        step3 = jax.jit(gnn_mod.make_train_step(cfg, mesh, opt, "molecule"))
        p4, o4, m3 = step3(params, ostate, mol)
        assert _finite(m3["loss"])


def test_gnn_pad_edges_inert(mesh, rng):
    cfg = reduced(get_config("graphsage-reddit"))
    N, E, F = 32, 64, 16
    params = prm.initialize(gnn_mod.model_specs(cfg, F), jax.random.PRNGKey(0))
    feats = jnp.asarray(rng.normal(size=(N, F)), jnp.float32)
    edges = jnp.asarray(rng.integers(0, N, (E, 2)), jnp.int32)
    pad = jnp.asarray([[-1, 0]] * 8, jnp.int32)
    with mesh:
        f = jax.jit(lambda p, x, e: gnn_mod.full_forward(p, x, e, cfg, mesh))
        a = f(params, feats, edges)
        b = f(params, feats, jnp.concatenate([edges, pad]))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_all_archs_registered():
    archs = list_archs()
    assert len(archs) == 10
    for a in archs:
        cfg = get_config(a)
        assert cfg.shapes()


def test_iter_cells_counts():
    from repro.configs import iter_cells
    cells = iter_cells()
    assert len(cells) == 40
    skips = [c for c in cells if c[2]]
    # long_500k skipped for the 5 pure full-attention LM archs
    assert len(skips) == 5


def test_dlrm_front_end_fused_matches_split_bitwise(rng):
    """The whole DLRM serve step (bottom MLP -> lookup -> interaction ->
    top MLP) produces bit-identical scores with front_end fused vs split
    on the replicated/dp-sharded mesh, for both SLS impls."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    from repro.distributed.sharding import make_mesh
    mesh_dp = make_mesh((8, 1), ("data", "model"))
    cfg = reduced(get_config("rmc1"))
    engine, offs = dlrm_mod.build_engine(cfg, mesh_dp)
    params = prm.initialize(dlrm_mod.model_specs(cfg, mesh_dp),
                            jax.random.PRNGKey(0))
    state = engine.init_state(jax.random.PRNGKey(1))
    B = 16
    batch = {
        "dense": jnp.asarray(rng.normal(size=(B, cfg.n_dense)), jnp.float32),
        "indices": (jnp.asarray(rng.integers(
            0, cfg.emb_num, (B, cfg.n_tables, cfg.pooling)), jnp.int32)
            + jnp.asarray(offs, jnp.int32)[None, :, None]),
    }
    with mesh_dp:
        outs = {}
        for impl in ("jnp", "pallas"):
            for fe in ("split", "fused"):
                step = jax.jit(dlrm_mod.make_serve_step(
                    cfg, engine, mesh_dp, impl=impl, interaction_impl=impl,
                    front_end=fe))
                outs[(impl, fe)] = np.asarray(step(params, state, batch))
    base = outs[("jnp", "split")]
    for k, v in outs.items():
        np.testing.assert_array_equal(base, v, err_msg=str(k))
    recs = [r for r in engine.plan_stats()["front_end"].values()
            if r["requested"] == "fused"]
    assert recs and all(r["resolved"] == "fused" for r in recs)
