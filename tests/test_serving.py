"""repro.serving behaviour: deterministic coalescing replay, exact bucket
padding, bounded admission, arrival processes, metrics, and an end-to-end
zero-steady-retrace serving run on a real engine."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.pifs import engine_for_tables
from repro.serving import (AdmissionQueue, ArrivalConfig, BatcherConfig,
                           Bucket, DynamicBatcher, FixedBatcher,
                           FixedServiceModel, Flush, LatencyHistogram,
                           LoadConfig, OpenLoopSource, Request,
                           RuntimeConfig, ServingRuntime, SimulatedExecutor,
                           Wait, arrival_times, pad_pooled_indices)


def _req(rid, t, slo=0.05, pooling=4):
    return Request(rid=rid, arrival_s=t, deadline_s=t + slo, features={},
                   pooling=pooling)


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("process", ["poisson", "bursty", "uniform"])
def test_arrival_process_deterministic_and_calibrated(process):
    # short burst dwells so the MMPP cycles many times within the sample
    # (the time-averaged rate only converges across many state cycles)
    cfg = ArrivalConfig(rate_qps=500.0, process=process, seed=3,
                        mean_burst_s=0.02)
    a = arrival_times(cfg, 4000)
    b = arrival_times(cfg, 4000)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) >= 0)
    mean_rate = 4000 / a[-1]
    assert 0.8 * 500 < mean_rate < 1.2 * 500   # time-averaged rate holds
    if process != "uniform":
        c = arrival_times(dataclasses.replace(cfg, seed=4), 4000)
        assert not np.array_equal(a, c)


def test_bursty_config_validates():
    with pytest.raises(ValueError):
        ArrivalConfig(rate_qps=100, process="bursty", burst_factor=8,
                      burst_fraction=0.2)   # 8 * 0.2 >= 1: base rate <= 0


# ---------------------------------------------------------------------------
# Admission queue
# ---------------------------------------------------------------------------


def test_admission_queue_bounds_and_sheds():
    q = AdmissionQueue(capacity=2)
    rs = [_req(i, 0.0) for i in range(4)]
    assert q.offer(rs[0]) and q.offer(rs[1])
    assert not q.offer(rs[2])                 # full: shed, don't grow
    assert (q.offered, q.dropped, len(q)) == (3, 1, 2)
    assert [r.rid for r in q.pop_n(2)] == [0, 1]
    with pytest.raises(ValueError):
        q.pop_n(1)


# ---------------------------------------------------------------------------
# Batcher decisions
# ---------------------------------------------------------------------------

BAT = BatcherConfig(batch_sizes=(4, 8, 16), poolings=(4, 8),
                    safety_ms=1.0, max_wait_ms=10.0)
SVC = FixedServiceModel(base_s=4e-3, per_row_s=2.5e-4)


def test_full_bucket_flushes_immediately():
    b = DynamicBatcher(BAT)
    d = b.decide(0.0, [_req(i, 0.0) for i in range(20)], 0.001, SVC)
    assert isinstance(d, Flush) and d.count == 16
    assert d.bucket == Bucket(16, 4)


def test_pooling_level_picks_smallest_adequate():
    b = DynamicBatcher(BAT)
    d = b.decide(1.0, [_req(0, 0.0, pooling=3), _req(1, 0.0, pooling=7)],
                 None, SVC)
    assert isinstance(d, Flush) and d.bucket == Bucket(4, 8)
    with pytest.raises(ValueError):
        b.decide(1.0, [_req(0, 0.0, pooling=99)], None, SVC)


def test_waits_then_deadline_flushes():
    b = DynamicBatcher(BAT)
    head = _req(0, 0.0, slo=0.05)
    d = b.decide(0.0, [head], next_arrival=1.0, service=SVC)
    assert isinstance(d, Wait)
    # eager cap: head.arrival + 10ms (well before deadline-driven time)
    assert d.until == pytest.approx(0.010)
    d2 = b.decide(d.until, [head], next_arrival=1.0, service=SVC)
    assert isinstance(d2, Flush) and d2.count == 1 and d2.bucket.batch == 4


def test_high_load_suppresses_eager_flush():
    """Arrival-rate estimate from queue stamps disables the max_wait cap
    when small-batch flushing would saturate (the stability guard)."""
    b = DynamicBatcher(BAT)
    # 6 requests in 12 ms -> 500/s; est(4-bucket) = 5ms -> util 0.63 > 0.5
    reqs = [_req(i, 0.002 * i, slo=0.10) for i in range(6)]
    now = 0.012
    d = b.decide(now, reqs, next_arrival=0.014, service=SVC)
    assert isinstance(d, Wait)      # past max_wait, but deadline still far
    # same queue at a trickle rate flushes eagerly at the cap
    slow = [_req(i, 0.04 * i, slo=1.0) for i in range(6)]
    d2 = b.decide(0.25, slow, next_arrival=0.3, service=SVC)
    assert isinstance(d2, Flush)


def test_fixed_batcher_waits_then_drains():
    fb = FixedBatcher(batch=8, pooling=4)
    reqs = [_req(i, 0.0) for i in range(3)]
    d = fb.decide(0.0, reqs, next_arrival=0.5, service=SVC)
    assert isinstance(d, Wait) and d.until == 0.5
    d2 = fb.decide(0.5, reqs, next_arrival=None, service=SVC)   # stream end
    assert isinstance(d2, Flush) and d2.count == 3
    d3 = fb.decide(0.0, [_req(i, 0.0) for i in range(9)], 0.5, SVC)
    assert isinstance(d3, Flush) and d3.count == 8


# ---------------------------------------------------------------------------
# Deterministic replay: the coalescing decision sequence is pinned
# ---------------------------------------------------------------------------


def _replay_requests():
    times = arrival_times(ArrivalConfig(rate_qps=200.0, seed=11), 32)
    pool_cycle = (2, 4, 4, 8)
    return [_req(i, float(times[i]), slo=0.04,
                 pooling=pool_cycle[i % len(pool_cycle)])
            for i in range(32)]


def _run_replay():
    model = FixedServiceModel(base_s=4e-3, per_row_s=2.5e-4)
    rt = ServingRuntime(
        SimulatedExecutor(model), DynamicBatcher(BAT),
        padder=lambda reqs, bucket: {"n": len(reqs)},
        cfg=RuntimeConfig(observe_every=0, replan_every=0),
        service_model=model)
    summary = rt.run(OpenLoopSource(_replay_requests()))
    trace = [(b.bucket.batch, b.bucket.pooling, b.n_real, round(b.t, 5))
             for b in rt.metrics.batches]
    return trace, summary

# generated once from the fixed seed above; any change to the coalescing
# policy, the arrival stream, or the service model shows up here
PINNED_REPLAY = [
    (4, 4, 3, 0.01038),
    (4, 8, 3, 0.022),
    (4, 8, 4, 0.03818),
    (4, 8, 2, 0.05151),
    (4, 4, 1, 0.06392),
    (16, 8, 10, 0.08929),
    (4, 8, 3, 0.10169),
    (8, 8, 6, 0.11424),
]


def test_deterministic_replay_pins_coalescing():
    t1, s1 = _run_replay()
    t2, s2 = _run_replay()
    assert t1 == t2                          # exact replay
    assert s1["served"] == 32 and s1["dropped"] == 0
    assert t1[:len(PINNED_REPLAY)] == PINNED_REPLAY
    assert s1["p99_ms"] == s2["p99_ms"]


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def test_latency_histogram_percentiles_match_numpy():
    h = LatencyHistogram()
    rng = np.random.default_rng(0)
    xs = rng.lognormal(-4.0, 1.0, 500)       # seconds
    for x in xs:
        h.record(float(x))
    p = h.percentiles_ms()
    assert p["p50_ms"] == pytest.approx(np.percentile(xs * 1e3, 50))
    assert p["p99.9_ms"] == pytest.approx(np.percentile(xs * 1e3, 99.9))
    exp = h.export()
    assert sum(exp["counts"]) == 500
    assert len(exp["bin_lo_ms"]) == len(exp["bin_hi_ms"]) == len(exp["counts"])
    # sparse bins: a bimodal sample keeps its true (non-widened) intervals
    h2 = LatencyHistogram()
    h2.record(1e-3)
    h2.record(0.1)
    exp2 = h2.export()
    assert exp2["counts"] == [1, 1]
    assert exp2["bin_hi_ms"][0] < 2.0 and exp2["bin_lo_ms"][1] > 90.0


# ---------------------------------------------------------------------------
# Engine-level: exact padding and end-to-end serving
# ---------------------------------------------------------------------------


def test_bucket_padding_is_exact(mesh):
    """Padding a variable-pooling request into a shape bucket (repeat-first
    -id at weight 0, replicate-row-0 on the batch axis) must be bit-exact
    vs the unpadded per-request lookup."""
    engine, offs = engine_for_tables([512, 512], dim=8, mesh=mesh,
                                     hot_fraction=0.1)
    state = engine.init_state(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    reqs = []
    for i, pooling in enumerate((1, 3, 4, 5, 2)):
        ids = rng.integers(0, 512, (2, pooling)) + offs[:, None]
        reqs.append(Request(rid=i, arrival_s=0.0, deadline_s=1.0,
                            features={"indices": ids.astype(np.int32)},
                            pooling=pooling))
    bucket = Bucket(8, 6)
    idx, w = pad_pooled_indices(reqs, bucket)
    with mesh:
        padded = np.asarray(engine.lookup(
            state, jax.numpy.asarray(idx), weights=jax.numpy.asarray(w)))
        for i, r in enumerate(reqs):
            ref = np.asarray(engine.lookup(
                state, jax.numpy.asarray(r.features["indices"][None]),
                dp_shard=False))[0]
            np.testing.assert_array_equal(padded[i], ref)


@pytest.mark.parametrize("storage", ["fp32", "int8"])
def test_loadgen_offsets_match_engine_offsets(mesh, storage):
    """Regression for the offset mirror: the DLRM request factories derive
    global-row offsets from synth._padded_rows, which must track the
    engine's storage-dependent page rounding exactly — int8 pages hold 4x
    the rows, so the padding boundary (and every table>=1 offset) moves.
    A divergence here serves garbage embeddings with no error."""
    from repro.configs import get_config, reduced
    from repro.data.synth import _padded_rows
    from repro.models import dlrm as dlrm_mod

    cfg = reduced(get_config("rmc1"))
    engine, offs = dlrm_mod.build_engine(cfg, mesh, storage=storage)
    mirrored = np.arange(cfg.n_tables, dtype=np.int64) * _padded_rows(
        cfg, storage=storage)
    np.testing.assert_array_equal(offs, mirrored)


def test_observe_with_pad_weights_counts_only_real_lookups(mesh):
    """The profiler must not rank pages by padding artifacts: weight-0
    entries (pooling pad + replicated batch-pad rows) contribute nothing."""
    engine, offs = engine_for_tables([512, 512], dim=8, mesh=mesh,
                                     hot_fraction=0.1)
    state = engine.init_state(jax.random.PRNGKey(0))
    reqs = [Request(rid=0, arrival_s=0.0, deadline_s=1.0,
                    features={"indices": (np.full((2, 3), 9)
                                          + offs[:, None]).astype(np.int32)},
                    pooling=3)]
    bucket = Bucket(4, 8)
    idx, w = pad_pooled_indices(reqs, bucket)
    with mesh:
        new = engine.observe(state, jax.numpy.asarray(idx),
                             weights=jax.numpy.asarray(w))
    # one request, 2 bags x 3 real lookups = 6 counted accesses; the other
    # 4*2*8 - 6 padded slots are weight-0 and invisible
    assert float(np.asarray(new.counts).sum()) == 6.0


def test_end_to_end_serving_zero_steady_retraces(mesh):
    from repro.configs import get_config, reduced
    from repro.launch.serve import serve_offered_load
    cfg = reduced(get_config("rmc1"))
    load = LoadConfig(
        n_requests=48,
        arrival=ArrivalConfig(rate_qps=400.0, seed=2),
        slo_ms=200.0, seed=2)
    out = serve_offered_load(cfg, mesh, load, batch_sizes=(8, 16),
                             runtime_cfg=RuntimeConfig(observe_every=2,
                                                       replan_every=2))
    assert out["served"] == 48 and out["dropped"] == 0
    assert out["steady_traces"] == 0          # the plan-cache contract
    assert out["replans"] >= 1                # maintenance actually folded in
    assert 0.0 < out["batch_occupancy_mean"] <= 1.0
    assert out["qps"] > 0 and out["p99_ms"] >= out["p50_ms"]
    # the observe-cadence dedup probe attributes bytes per shape bucket
    assert out["dedup_factors"], "no bucket was ever observed"
    for rec in out["dedup_factors"].values():
        assert rec["batches"] >= 1
        assert rec["entries"] >= rec["unique_rows"] > 0
        assert rec["factor"] >= 1.0


@pytest.mark.parametrize("dedup", ["on", "auto"])
def test_end_to_end_serving_dedup_matches_off(mesh, dedup):
    """Identical request stream served with dedup off vs on/auto: scores
    are produced by bit-exact lookups, so the serving summary's served /
    dropped / retrace accounting must be identical and the dedup'd run
    must keep the zero-steady-retrace contract ('auto' freezes its
    per-bucket decision at warmup and never retraces afterwards)."""
    from repro.configs import get_config, reduced
    from repro.launch.serve import serve_offered_load
    cfg = reduced(get_config("rmc1"))

    def run(knob):
        load = LoadConfig(
            n_requests=32, arrival=ArrivalConfig(rate_qps=400.0, seed=3),
            slo_ms=200.0, seed=3, dedup=knob)
        return serve_offered_load(
            cfg, mesh, load, batch_sizes=(8, 16),
            runtime_cfg=RuntimeConfig(observe_every=2, replan_every=4))

    base = run("off")
    out = run(dedup)
    assert out["served"] == base["served"] == 32
    assert out["steady_traces"] == 0
    assert out["dedup_factors"].keys() == base["dedup_factors"].keys()


def test_serving_auto_dedup_resolves_from_primed_histogram(mesh):
    """serve_offered_load(dedup='auto') must not be inert: the profiler is
    primed with a prefix of the live stream before the post-warmup plan
    rebuild, so per-bucket 'auto' resolutions see the real (zipfian)
    traffic skew instead of freezing against the empty-histogram uniform
    prior at first warmup."""
    from repro.configs import get_config, reduced
    from repro.launch.serve import build_serving
    from repro.serving import (OpenLoopSource, dummy_request_factory,
                               prime_dedup_auto, request_stream)
    cfg = reduced(get_config("rmc1"))
    load = LoadConfig(n_requests=64,
                      arrival=ArrivalConfig(rate_qps=400.0, seed=5),
                      slo_ms=200.0, seed=5, dedup="auto")
    runtime, binding = build_serving(cfg, mesh, dedup="auto",
                                     batch_sizes=(8, 16))
    with mesh:
        runtime.warmup(dummy_request_factory(cfg))
        cold = binding.plan_stats().get("dedup", {})
        # first warmup ran before any traffic: uniform prior, all off
        assert cold and all(not r["resolved"] for r in cold.values())
        reqs = request_stream(cfg, load)
        assert prime_dedup_auto(binding, reqs) > 0
        runtime.warmup(dummy_request_factory(cfg))
        binding.reset_plan_stats()
        runtime.run(OpenLoopSource(reqs))
    stats = binding.plan_stats()
    recs = stats["dedup"]
    # rebuilt against the primed histogram: the skewed stream must flip
    # at least one bucket on, with the expected factor on record
    assert any(r["resolved"] for r in recs.values())
    assert all(r["expected_factor"] is not None for r in recs.values())
    assert stats["traces"] == 0           # the rebuilds were pre-steady


def test_end_to_end_serving_front_end_fused_matches_split():
    """Identical request stream served with front_end fused vs split on the
    replicated/dp-sharded mesh (where fusion actually resolves fused):
    lookups are bit-exact, so the serving accounting must be identical,
    the fused run must keep the zero-steady-retrace contract, and
    plan_stats() must confirm every interact plan resolved fused."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    from repro.configs import get_config, reduced
    from repro.distributed.sharding import make_mesh
    from repro.launch.serve import serve_offered_load
    cfg = reduced(get_config("rmc1"))
    mesh_dp = make_mesh((8, 1), ("data", "model"))

    outs = {}
    for fe in ("split", "fused"):
        load = LoadConfig(
            n_requests=32, arrival=ArrivalConfig(rate_qps=400.0, seed=4),
            slo_ms=200.0, seed=4, front_end=fe)
        outs[fe] = serve_offered_load(
            cfg, mesh_dp, load, impl="pallas", batch_sizes=(8, 16),
            runtime_cfg=RuntimeConfig(observe_every=2, replan_every=4))
    assert outs["fused"]["served"] == outs["split"]["served"] == 32
    assert outs["fused"]["steady_traces"] == 0


def test_bind_model_front_end_resolution(mesh):
    """bind_model threads front_end through to the DLRM serve step; on the
    tp-sharded session mesh the engine records the fused_tp resolution
    (partial-pool -> psum the pooled tile -> resume)."""
    from repro.configs import get_config, reduced
    from repro.serving import bind_model
    cfg = reduced(get_config("rmc1"))
    binding = bind_model(cfg, mesh, front_end="fused")
    B, T, L = 8, cfg.n_tables, cfg.pooling
    rng = np.random.default_rng(0)
    batch = {"dense": rng.normal(size=(B, cfg.n_dense)).astype(np.float32),
             "indices": rng.integers(0, cfg.emb_num, (B, T, L)
                                     ).astype(np.int32)}
    with mesh:
        scores = np.asarray(binding.execute(batch))
    assert scores.shape == (B,) and np.isfinite(scores).all()
    recs = [r for r in binding.plan_stats()["front_end"].values()
            if r["requested"] == "fused"]
    assert recs and recs[0]["resolved"] == "fused_tp"   # tp=4 mesh
    assert recs[0]["tp"] == 4 and "psum" in recs[0]["reason"]
