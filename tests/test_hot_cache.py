"""Buffer-policy coverage for core/hot_cache.py (paper Fig. 15 mechanism):
on a skewed production-like trace the paper's HTR buffer must capture at
least as much as recency policies — HTR >= LRU >= FIFO — plus the
degenerate-capacity edge cases the simulator must survive."""
import numpy as np
import pytest

from repro.core.hot_cache import (AccessProfiler, FIFOCache, LRUCache,
                                  make_policy)
from repro.data.traces import TraceConfig, TraceGenerator

POLICIES = ("htr", "lru", "fifo")


def _zipf_keys(n_accesses: int = 24576, n_rows: int = 4096,
               seed: int = 0) -> np.ndarray:
    """Stationary zipfian key stream (drift off: this probes steady-state
    capture, not adaptation)."""
    gen = TraceGenerator(TraceConfig(
        n_rows=n_rows, n_tables=1, pooling=8, batch=n_accesses // 8,
        distribution="zipfian", drift_per_batch=0.0, seed=seed))
    return gen.next_batch().reshape(-1)


def test_policy_hit_rate_ordering_on_zipfian():
    keys = _zipf_keys()
    rates = {name: make_policy(name, capacity=256).run(keys)
             for name in POLICIES}
    # frequency ranking beats recency beats pure insertion order on a
    # skewed stationary trace (the reason the paper's switch buffer is HTR)
    assert rates["htr"] >= rates["lru"] >= rates["fifo"]
    assert rates["htr"] > 0.15          # capturing something real
    assert all(0.0 <= r <= 1.0 for r in rates.values())


def test_policy_ordering_across_seeds():
    for seed in (1, 2):
        keys = _zipf_keys(n_accesses=16384, seed=seed)
        rates = {n: make_policy(n, 128).run(keys) for n in POLICIES}
        assert rates["htr"] >= rates["lru"] >= rates["fifo"]


def test_capacity_one():
    keys = [1, 1, 2, 2, 2, 1]
    for name in POLICIES:
        p = make_policy(name, capacity=1)
        hr = p.run(keys)
        assert 0.0 <= hr <= 1.0
        assert p.accesses == len(keys)
        assert p.hits == round(hr * len(keys))
    # recency policies at capacity 1 hit exactly on adjacent repeats
    assert LRUCache(1).run(keys) == pytest.approx(3 / 6)
    assert FIFOCache(1).run(keys) == pytest.approx(3 / 6)


def test_capacity_at_least_key_space_only_cold_misses():
    keys = _zipf_keys(n_accesses=4096, n_rows=64)
    unique = len(np.unique(keys))
    for name in POLICIES:
        p = make_policy(name, capacity=128)      # capacity > n_rows
        p.run(keys)
        # nothing is ever evicted: every miss is a cold (first-touch) miss
        assert p.hits == p.accesses - unique, name


def test_make_policy_rejects_unknown():
    with pytest.raises(ValueError):
        make_policy("arc", 16)


def test_access_profiler_hottest_tracks_frequency():
    prof = AccessProfiler(n_items=100, decay=1.0)
    rng = np.random.default_rng(0)
    items = np.concatenate([np.repeat(7, 50), np.repeat(3, 30),
                            rng.integers(10, 100, 40)])
    prof.observe(items)
    top2 = list(prof.hottest(2))
    assert top2[0] == 7 and top2[1] == 3
