"""Dry-run cell builders: every cell must trace (eval_shape) on a small
mesh — catches spec/shape/sharding-structure errors without the compile
cost of the full 512-device dry-run."""
import jax
import pytest

from repro.configs import get_config, iter_cells
from repro.launch.cells import build_cell

SAMPLE = [
    ("llama3.2-3b", "train_4k"),
    ("llama3.2-3b", "decode_32k"),
    ("granite-moe-1b-a400m", "prefill_32k"),
    ("sasrec", "train_batch"),
    ("autoint", "serve_p99"),
    ("dcn-v2", "retrieval_cand"),
    ("bst", "serve_bulk"),
    ("graphsage-reddit", "full_graph_sm"),
    ("graphsage-reddit", "molecule"),
]


@pytest.mark.parametrize("arch,shape", SAMPLE)
def test_cell_traces(arch, shape, mesh):
    cell = build_cell(arch, shape, mesh)
    with mesh:
        out = jax.eval_shape(cell.fn, *cell.abstract_args)
    assert out is not None
    assert cell.model_flops > 0
    # sharding trees align with the abstract args structurally
    for a, s in zip(cell.abstract_args, cell.in_shardings):
        jax.tree.map(lambda x, y: None, a, s,
                     is_leaf=lambda z: hasattr(z, "shape")
                     or hasattr(z, "spec"))


def test_every_cell_buildable(mesh):
    """All 40 logical cells must at least construct (no lowering)."""
    built = 0
    for arch, shape, skip in iter_cells():
        if skip:
            continue
        cell = build_cell(arch, shape, mesh)
        assert cell.abstract_args and cell.in_shardings
        built += 1
    assert built == 35
