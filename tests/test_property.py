"""Hypothesis property tests on system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: see requirements-dev.txt

from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import quant
from repro.core import sls as sls_ops
from repro.core.hot_cache import FIFOCache, HTRCache, LRUCache
from repro.core.paging import (PagingConfig, initial_page_table, locate,
                               placement_gather_indices)
from repro.core.planner import PlannerConfig, plan
from repro.data.traces import TraceConfig, TraceGenerator
from repro.kernels import ref
from repro.launch.hlo_stats import summarize
from repro.optim.optimizers import adafactor, adam, rowwise_adagrad

SETTINGS = dict(deadline=None, max_examples=25,
                suppress_health_check=[HealthCheck.too_slow])


@given(total_rows=st.integers(64, 4096), dim=st.sampled_from([8, 16, 64]),
       n_shards=st.sampled_from([2, 4, 8]),
       hot_fraction=st.floats(0.0, 0.3))
@settings(**SETTINGS)
def test_paging_locate_is_total_and_unique(total_rows, dim, n_shards,
                                           hot_fraction):
    """Every row maps to exactly one (shard, slot) and no two rows collide."""
    cfg = PagingConfig(total_rows=total_rows, dim=dim, n_shards=n_shards,
                       hot_fraction=hot_fraction)
    table = initial_page_table(cfg)
    rows = jnp.arange(cfg.padded_rows)
    shard, local, is_hot = locate(cfg, table, rows)
    shard, local, is_hot = (np.asarray(shard), np.asarray(local),
                            np.asarray(is_hot))
    # addresses are unique within each tier
    cold = ~is_hot
    addr = shard[cold] * cfg.rows_per_shard + local[cold]
    assert len(np.unique(addr)) == cold.sum()
    assert (local[cold] < cfg.rows_per_shard).all()


@given(n_pages=st.integers(8, 256), n_shards=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 10))
@settings(**SETTINGS)
def test_planner_output_is_valid_placement(n_pages, n_shards, seed):
    cfg = PagingConfig(total_rows=n_pages * 16, dim=64, n_shards=n_shards,
                       page_bytes=64 * 16 * 4, hot_fraction=0.05)
    assert cfg.num_pages == n_pages
    table = initial_page_table(cfg)
    rng = np.random.default_rng(seed)
    counts = rng.random(n_pages) * 100
    new, stats = plan(cfg, table, counts, PlannerConfig())
    shard = np.asarray(new.page_to_shard)
    slot = np.asarray(new.page_to_slot)
    assert ((shard >= -1) & (shard < n_shards)).all()
    # no two pages share a (shard, slot)
    cold = shard >= 0
    key = shard[cold].astype(np.int64) * (slot.max() + 1) + slot[cold]
    assert len(np.unique(key)) == cold.sum()
    assert (slot[cold] < cfg.pages_per_shard).all()
    hot = shard == -1
    assert hot.sum() <= cfg.hot_pages


@given(seed=st.integers(0, 20))
@settings(**SETTINGS)
def test_migration_gather_preserves_content(seed):
    """placement_gather_indices must move every live page's rows intact."""
    cfg = PagingConfig(total_rows=256, dim=8, n_shards=4, page_bytes=8 * 4 * 4,
                       hot_fraction=0.1)
    rng = np.random.default_rng(seed)
    old = initial_page_table(cfg)
    counts = rng.random(cfg.num_pages)
    new, _ = plan(cfg, old, counts, PlannerConfig())
    cold_src, hot_src = placement_gather_indices(cfg, old, new)
    # simulate: storage cells hold their global flat address
    old_cold = np.arange(cfg.cold_rows_total, dtype=np.int64)
    old_hot = np.arange(cfg.hot_rows, dtype=np.int64) + cfg.cold_rows_total
    combined = np.concatenate([old_cold, old_hot])
    new_cold = combined[cold_src]
    new_hot = combined[hot_src]

    ps = cfg.page_size
    o_shard = np.asarray(old.page_to_shard)
    o_slot = np.asarray(old.page_to_slot)
    n_shard = np.asarray(new.page_to_shard)
    n_slot = np.asarray(new.page_to_slot)
    for p in range(cfg.num_pages):
        src0 = (cfg.cold_rows_total + o_slot[p] * ps if o_shard[p] == -1
                else o_shard[p] * cfg.rows_per_shard + o_slot[p] * ps)
        if n_shard[p] == -1:
            got = new_hot[n_slot[p] * ps:(n_slot[p] + 1) * ps]
        else:
            base = n_shard[p] * cfg.rows_per_shard + n_slot[p] * ps
            got = new_cold[base: base + ps]
        assert (got == np.arange(src0, src0 + ps)).all(), f"page {p}"


@given(n_pages=st.integers(1, 16), ps=st.sampled_from([1, 4, 16]),
       D=st.sampled_from([4, 16]), mag=st.floats(1e-4, 1e3),
       seed=st.integers(0, 20))
@settings(**SETTINGS)
def test_quantize_roundtrip_error_bound(n_pages, ps, D, mag, seed):
    """Per-page int8 round trip: |x - dequant(quant(x))| <= scale/2 per
    element, all-zero pages round-trip exactly, and re-quantizing the
    dequantized values with the same scales recovers the codes bit-for-bit
    (the idempotency the engine's exact migration invariance rests on).
    ps=1 covers single-row pages."""
    rng = np.random.default_rng(seed)
    pages = (rng.normal(size=(n_pages, ps, D)) * mag).astype(np.float32)
    pages[0] = 0.0                              # all-zero page edge case
    pages = jnp.asarray(pages)
    q, scales = quant.quantize_pages(pages)
    deq = quant.dequantize_pages(q, scales)
    s = np.asarray(scales)
    # per-page scale correctness: amax/127, or 1.0 for all-zero pages
    amax = np.abs(np.asarray(pages)).max(axis=(1, 2))
    np.testing.assert_allclose(
        s, np.where(amax > 0, amax / quant.QMAX, 1.0), rtol=1e-7)
    assert s[0] == 1.0
    # error bound (tiny slack for the fp32 divide's rounding)
    err = np.abs(np.asarray(deq) - np.asarray(pages))
    bound = (s * 0.5 * (1 + 1e-5) + 1e-30)[:, None, None]
    assert (err <= bound).all()
    np.testing.assert_array_equal(np.asarray(deq)[0], 0.0)
    # idempotency
    q2 = quant.quantize_rows(deq, scales[:, None, None])
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))


@given(B=st.integers(1, 8), L=st.integers(1, 8), V=st.integers(4, 128),
       D=st.sampled_from([4, 16]))
@settings(**SETTINGS)
def test_sls_permutation_invariance(B, L, V, D):
    """SLS is order-invariant within a bag (commutative accumulation) —
    the out-of-order engine's correctness condition (paper §IV-A5)."""
    rng = np.random.default_rng(B + L + V)
    table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    idx = rng.integers(0, V, (B, L))
    perm = np.stack([rng.permutation(L) for _ in range(B)])
    idx_p = np.take_along_axis(idx, perm, axis=1)
    a = ref.sls_ref(table, jnp.asarray(idx, jnp.int32))
    b = ref.sls_ref(table, jnp.asarray(idx_p, jnp.int32))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# Gather-once duplicate coalescing (dedup) — bit-exactness properties
# ---------------------------------------------------------------------------

_DEDUP_ENGINES: dict = {}     # storage -> (engine, state); one lookup shape
_DEDUP_SHAPE = (8, 2, 4)      # fixed across examples => plans cache, no
#                               per-example retraces blow up the runtime


def _dedup_engine(storage, mesh):
    if storage not in _DEDUP_ENGINES:
        from repro.core.pifs import engine_for_tables
        eng, _ = engine_for_tables([500, 300], dim=16, mesh=mesh,
                                   hot_fraction=0.06, storage=storage)
        state = eng.init_state(jax.random.PRNGKey(0))
        _DEDUP_ENGINES[storage] = (eng, state)
    return _DEDUP_ENGINES[storage]


@given(data=st.data(),
       mode=st.sampled_from(["pifs", "pond", "beacon"]),
       combine=st.sampled_from(["psum", "psum_scatter"]),
       storage=st.sampled_from(["fp32", "int8"]),
       impl=st.sampled_from(["jnp", "pallas"]),
       weighted=st.booleans(),
       extreme=st.sampled_from(["random", "all_dup", "all_unique"]))
@settings(deadline=None, max_examples=20,
          suppress_health_check=list(HealthCheck))
def test_dedup_lookup_bit_exact(mesh, data, mode, combine, storage, impl,
                                weighted, extreme):
    """dedup=on must equal dedup=off **bit-for-bit** across every
    (impl, mode, combine, storage, weighted) datapath, including the
    all-duplicate and all-unique index extremes: the coalesced stage
    changes where rows are gathered from, never the accumulate order."""
    eng, state = _dedup_engine(storage, mesh)
    B, G, L = _DEDUP_SHAPE
    if extreme == "all_dup":
        row = data.draw(st.integers(0, 499))
        idx = np.full(_DEDUP_SHAPE, row, np.int32)
    elif extreme == "all_unique":
        start = data.draw(st.integers(0, 499 - B * G * L))
        idx = (np.arange(B * G * L, dtype=np.int32) + start
               ).reshape(_DEDUP_SHAPE)
    else:
        seed = data.draw(st.integers(0, 2 ** 16))
        idx = np.random.default_rng(seed).integers(
            0, 500, _DEDUP_SHAPE).astype(np.int32)
    idx = jnp.asarray(idx)
    w = None
    if weighted:
        wseed = data.draw(st.integers(0, 2 ** 16))
        w = jnp.asarray(np.random.default_rng(wseed).random(
            _DEDUP_SHAPE).astype(np.float32))
    with mesh:
        off = eng.lookup(state, idx, weights=w, mode=mode, combine=combine,
                         impl=impl, dedup="off")
        on = eng.lookup(state, idx, weights=w, mode=mode, combine=combine,
                        impl=impl, dedup="on")
    np.testing.assert_array_equal(np.asarray(off), np.asarray(on))


@given(B=st.integers(1, 6), L=st.integers(1, 8), cap=st.integers(0, 64),
       quantized=st.booleans(), weighted=st.booleans(),
       seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_dedup_capacity_overflow_falls_back_exact(B, L, cap, quantized,
                                                  weighted, seed):
    """A staging capacity smaller than the padded worst case (B*L) must
    fall back to the non-dedup path — bit-exactly, for both impls and both
    storage dtypes (the fallback is the same code path dedup is pinned
    against, so correctness never depends on the capacity check)."""
    rng = np.random.default_rng(seed)
    V, D = 64, 16
    if quantized:
        table = jnp.asarray(rng.integers(-127, 128, (V, D)), jnp.int8)
        row_scale = rng.uniform(1e-4, 2e-2, V).astype(np.float32)
    else:
        table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
        row_scale = None
    idx = jnp.asarray(rng.integers(0, V // 2, (B, L)), jnp.int32)
    owned = jnp.asarray(rng.random((B, L)) < 0.6)
    w = (jnp.asarray(rng.random((B, L)).astype(np.float32))
         if weighted else None)
    scales = None if row_scale is None else jnp.asarray(row_scale)[idx]
    kw = dict(weights=w, scales=scales,
              out_dtype=jnp.float32 if quantized else None)
    for impl in ("jnp", "pallas"):
        base = sls_ops.masked_partial_sls_dense(
            table, idx, owned, impl=impl, dedup=False, **kw)
        capped = sls_ops.masked_partial_sls_dense(
            table, idx, owned, impl=impl, dedup=True, dedup_capacity=cap,
            **kw)
        full = sls_ops.masked_partial_sls_dense(
            table, idx, owned, impl=impl, dedup=True, **kw)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(capped))
        np.testing.assert_array_equal(np.asarray(base), np.asarray(full))


@given(cap=st.integers(1, 64), n=st.integers(1, 500), seed=st.integers(0, 5))
@settings(**SETTINGS)
def test_cache_policies_bounded_and_sane(cap, n, seed):
    rng = np.random.default_rng(seed)
    keys = rng.zipf(1.2, n) % 100
    for cls in (LRUCache, FIFOCache, HTRCache):
        c = cls(cap)
        hr = c.run(keys.tolist())
        assert 0.0 <= hr <= 1.0
        if cap >= 100:  # cache bigger than key space: everything after
            assert c.hits >= n - 100  # first touch must hit


@given(dist=st.sampled_from(["zipfian", "normal", "uniform", "random"]),
       seed=st.integers(0, 5))
@settings(**SETTINGS)
def test_trace_generator_in_range(dist, seed):
    cfg = TraceConfig(n_rows=1000, n_tables=2, pooling=4, batch=32,
                      distribution=dist, seed=seed)
    g = TraceGenerator(cfg)
    b = g.next_batch()
    assert b.shape == (32, 2, 4)
    assert b.min() >= 0 and b.max() < 1000


@given(shape=st.sampled_from([(4,), (8, 16), (16, 8, 4), (256, 256)]),
       opt_name=st.sampled_from(["adam", "adafactor", "rowwise"]))
@settings(**SETTINGS)
def test_optimizers_decrease_quadratic(shape, opt_name):
    """Any optimizer must make progress on a convex quadratic."""
    opt = {"adam": lambda: adam(1e-1),
           "adafactor": lambda: adafactor(1e-1),
           "rowwise": lambda: rowwise_adagrad(5e-1)}[opt_name]()
    target = jnp.asarray(np.random.default_rng(0).normal(size=shape),
                         jnp.float32)
    params = {"w": jnp.zeros(shape, jnp.float32)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(30):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < 0.5 * l0


def test_hlo_stats_loop_multiplier():
    hlo = """
%body (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %p = (s32[], f32[4,8]) parameter(0)
  %a = f32[4,8] get-tuple-element(%p), index=1
  %w = f32[8,8] constant(0)
  %d = f32[4,8] dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[4,8]) tuple(%i, %d)
}
%cond (p: (s32[], f32[4,8])) -> pred[] {
  %p = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (x: f32[4,8]) -> f32[4,8] {
  %x = f32[4,8] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[4,8]) tuple(%z, %x)
  %w = (s32[], f32[4,8]) while(%init), condition=%cond, body=%body
  ROOT %o = f32[4,8] get-tuple-element(%w), index=1
}
"""
    s = summarize(hlo)
    # dot = 2*4*8*8 = 512 flops x 7 iterations
    assert s.flops == 512 * 7


# ---------------------------------------------------------------------------
# Fused front end (SLS -> dot-interaction) — bit-exactness properties
# ---------------------------------------------------------------------------

_FE_ENGINES: dict = {}        # storage -> (engine, state); dp-only mesh
_FE_SHAPE = (8, 2, 4)         # fixed across examples => plans cache


def _fe_engine(storage):
    """Engine on the replicated/dp-sharded (8, 1) mesh — the config where
    ``front_end='fused'`` resolves fused."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    if storage not in _FE_ENGINES:
        from repro.core.pifs import engine_for_tables
        from repro.distributed.sharding import make_mesh
        mesh = make_mesh((8, 1), ("data", "model"))
        eng, _ = engine_for_tables([500, 300], dim=16, mesh=mesh,
                                   hot_fraction=0.06, storage=storage)
        state = eng.init_state(jax.random.PRNGKey(0))
        _FE_ENGINES[storage] = (eng, state, mesh)
    return _FE_ENGINES[storage]


@given(data=st.data(),
       storage=st.sampled_from(["fp32", "int8"]),
       impl=st.sampled_from(["jnp", "pallas"]),
       combine=st.sampled_from(["psum", "psum_scatter"]),
       dedup=st.sampled_from(["off", "on"]),
       weighted=st.booleans())
@settings(deadline=None, max_examples=20,
          suppress_health_check=list(HealthCheck))
def test_front_end_fused_equals_split_bit_exact(data, storage, impl, combine,
                                                dedup, weighted):
    """front_end='fused' must equal 'split' **bit-for-bit** across every
    (impl, storage, dedup, weighted, combine) datapath, and both must
    equal the oracle composition (engine.lookup -> concat -> interaction
    ref): the fused kernel changes where the pooled features *live*
    (VMEM), never what is accumulated or in which order."""
    eng, state, mesh = _fe_engine(storage)
    B, G, L = _FE_SHAPE
    seed = data.draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, 500, _FE_SHAPE).astype(np.int32))
    x = jnp.asarray(rng.normal(size=(B, eng.cfg.dim)).astype(np.float32))
    w = (jnp.asarray(rng.random(_FE_SHAPE).astype(np.float32))
         if weighted else None)
    with mesh:
        split = eng.lookup_interact(state, idx, x, weights=w, impl=impl,
                                    combine=combine, dedup=dedup,
                                    front_end="split")
        fused = eng.lookup_interact(state, idx, x, weights=w, impl=impl,
                                    combine=combine, dedup=dedup,
                                    front_end="fused")
        pooled = eng.lookup(state, idx, weights=w, impl="jnp", dedup="off")
        feats = jnp.concatenate([x[:, None, :], pooled], axis=1)
        want = ref.dot_interaction_ref(feats)
    np.testing.assert_array_equal(np.asarray(split), np.asarray(fused))
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))
    recs = [r for r in eng.plan_stats()["front_end"].values()
            if r["requested"] == "fused"]
    assert recs and all(r["resolved"] == "fused" for r in recs)


def _fe_tp_engine(mesh_shape, storage):
    """Engine on a tp-sharded mesh — the config where ``front_end='fused'``
    resolves fused_tp (partial-pool -> psum -> resume)."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    key = (mesh_shape, storage)
    if key not in _FE_ENGINES:
        from repro.core.pifs import engine_for_tables
        from repro.distributed.sharding import make_mesh
        mesh = make_mesh(mesh_shape, ("data", "model"))
        eng, _ = engine_for_tables([500, 300], dim=16, mesh=mesh,
                                   hot_fraction=0.06, storage=storage)
        state = eng.init_state(jax.random.PRNGKey(0))
        _FE_ENGINES[key] = (eng, state, mesh)
    return _FE_ENGINES[key]


@given(data=st.data(),
       mesh_shape=st.sampled_from([(4, 2), (2, 4)]),
       storage=st.sampled_from(["fp32", "int8"]),
       impl=st.sampled_from(["jnp", "pallas"]),
       combine=st.sampled_from(["psum", "psum_scatter"]),
       dedup=st.sampled_from(["off", "on"]),
       mode=st.sampled_from(["pifs", "pond", "beacon"]),
       weighted=st.booleans())
@settings(deadline=None, max_examples=20,
          suppress_health_check=list(HealthCheck))
def test_front_end_fused_tp_equals_split(data, mesh_shape, storage, impl,
                                         combine, dedup, mode, weighted):
    """On tp-sharded meshes 'fused' resolves **fused_tp**: each shard
    partial-pools its (B, F, D) cold tile, only that small tile is psum'd
    (never raw rows), and phase 3 resumes on the reduced tile.  For
    pifs/beacon this must equal 'split' bit-for-bit across every
    (storage, dedup, weighted, combine) datapath — both paths psum fixed
    l-order cold partials in the same deterministic mesh order.  Pond
    requesting fusion pools its cold partials *before* the hot/cold add,
    so it equals the fixed l-order split composition (the pifs split
    result) bitwise and its own segment-sum split to tolerance."""
    eng, state, mesh = _fe_tp_engine(mesh_shape, storage)
    B, G, L = _FE_SHAPE
    seed = data.draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, 500, _FE_SHAPE).astype(np.int32))
    x = jnp.asarray(rng.normal(size=(B, eng.cfg.dim)).astype(np.float32))
    w = (jnp.asarray(rng.random(_FE_SHAPE).astype(np.float32))
         if weighted else None)
    with mesh:
        split = eng.lookup_interact(state, idx, x, weights=w, impl=impl,
                                    combine=combine, dedup=dedup, mode=mode,
                                    front_end="split")
        fused = eng.lookup_interact(state, idx, x, weights=w, impl=impl,
                                    combine=combine, dedup=dedup, mode=mode,
                                    front_end="fused")
        if mode == "pond":
            fixed = eng.lookup_interact(state, idx, x, weights=w, impl=impl,
                                        combine=combine, dedup=dedup,
                                        mode="pifs", front_end="split")
            np.testing.assert_array_equal(np.asarray(fused),
                                          np.asarray(fixed))
            np.testing.assert_allclose(np.asarray(fused), np.asarray(split),
                                       rtol=1e-5, atol=1e-5)
        else:
            np.testing.assert_array_equal(np.asarray(split),
                                          np.asarray(fused))
    recs = [r for r in eng.plan_stats()["front_end"].values()
            if r["requested"] == "fused"]
    assert recs and all(r["resolved"] == "fused_tp" for r in recs)
