"""Streaming embedding updates: coalesce/chunk determinism, WAL
durability, delta application vs a dense reference, requant-demote
exactness, and the serving-runtime integration (staleness accounting,
zero steady-state retraces)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.wal import WriteAheadLog
from repro.core.paging import HOT_SHARD
from repro.core.pifs import engine_for_tables
from repro.core.updates import (PAD_ROW, DriftTracker, UpdateConfig,
                                chunk_delta_batch, coalesce_deltas,
                                demote_table)
from repro.serving import (ArrivalConfig, BindingExecutor, DynamicBatcher,
                           BatcherConfig, LoadConfig, OpenLoopSource,
                           RuntimeConfig, ServingRuntime, StreamingUpdater,
                           UpdateBatch, bind_model, corrupt_store,
                           dummy_request_factory, make_padder,
                           request_stream, update_stream)


# ---------------------------------------------------------------------------
# Host control plane: coalesce, chunk, drift tracking, demote placement
# ---------------------------------------------------------------------------


def test_coalesce_sums_duplicates_drops_pads_and_is_idempotent():
    rows = np.array([5, 2, 5, PAD_ROW, 2, 9], np.int64)
    d = np.arange(6 * 3, dtype=np.float32).reshape(6, 3)
    r, out = coalesce_deltas(rows, d)
    np.testing.assert_array_equal(r, [2, 5, 9])
    np.testing.assert_array_equal(out[0], d[1] + d[4])
    np.testing.assert_array_equal(out[1], d[0] + d[2])
    np.testing.assert_array_equal(out[2], d[5])
    # re-coalescing a coalesced batch is the identity — the property WAL
    # replay leans on (live path and replay path see identical arrays)
    r2, out2 = coalesce_deltas(r, out)
    np.testing.assert_array_equal(r, r2)
    np.testing.assert_array_equal(out, out2)
    assert r.dtype == np.int32 and out.dtype == np.float32


def test_chunk_delta_batch_fixed_shape_and_lossless():
    rows = np.arange(10, dtype=np.int32)
    d = np.random.default_rng(0).normal(size=(10, 4)).astype(np.float32)
    chunks = list(chunk_delta_batch(rows, d, capacity=4))
    assert len(chunks) == 3
    for cr, cd in chunks:
        assert cr.shape == (4,) and cd.shape == (4, 4)
        assert cr.dtype == np.int32 and cd.dtype == np.float32
    got_rows = np.concatenate([c[0] for c in chunks])
    got_d = np.concatenate([c[1] for c in chunks])
    real = got_rows != PAD_ROW
    np.testing.assert_array_equal(got_rows[real], rows)
    np.testing.assert_array_equal(got_d[real], d)
    assert (got_d[~real] == 0).all()
    # empty batch yields nothing — no caller pays a pointless device
    # apply (the warmup path builds its own all-pad batch)
    empty = list(chunk_delta_batch(np.empty(0, np.int32),
                                   np.empty((0, 4), np.float32), 4))
    assert empty == []
    with pytest.raises(ValueError):
        list(chunk_delta_batch(rows, d, 0))


def _paging_cfg():
    from repro.core.paging import PagingConfig
    return PagingConfig(total_rows=256, dim=8, n_shards=4, page_bytes=256,
                        hot_fraction=0.25)


def test_drift_tracker_guard_threshold_and_cap():
    from repro.core.paging import initial_page_table
    cfg = _paging_cfg()
    table = initial_page_table(cfg)
    shard = np.asarray(table.page_to_shard).copy()
    shard[:8] = HOT_SHARD                       # pages 0..7 hot-resident
    table = dataclasses.replace(table, page_to_shard=shard)
    tr = DriftTracker(cfg)
    ps = cfg.page_size
    # page p gets drift mass ~ p (page 0 none, page 7 most)
    for p in range(1, 8):
        tr.update(np.full(p, p * ps), np.ones((p, cfg.dim), np.float32))
    counts = np.zeros(cfg.num_pages)
    counts[6] = 100.0                            # page 6 is traffic-hot
    counts[7] = 90.0                             # page 7 second-hottest
    ucfg = UpdateConfig(drift_threshold=cfg.dim * 2.0, max_demotions=2,
                        hotness_guard=0.25)      # guards top 2 of 8
    cand = tr.demote_candidates(table, counts, ucfg)
    # 6 and 7 are guarded despite max drift; 5 and 4 lead the rest;
    # pages 0-1 sit below the (inclusive) threshold; cap keeps it to two
    np.testing.assert_array_equal(cand, [5, 4])
    tr.note_requantized(cand)
    assert tr.demote_candidates(table, counts, ucfg).tolist() == [3, 2]
    assert tr.demote_candidates(
        table, counts, dataclasses.replace(ucfg, max_demotions=0)).size == 0


def test_demote_table_deterministic_least_loaded_and_validates():
    from repro.core.paging import initial_page_table
    cfg = _paging_cfg()
    table = initial_page_table(cfg)
    shard = np.asarray(table.page_to_shard).copy()
    hot_pages = np.nonzero(shard == HOT_SHARD)[0]
    if hot_pages.size < 2:
        shard[:2] = HOT_SHARD
        table = dataclasses.replace(table, page_to_shard=shard)
        hot_pages = np.asarray([0, 1])
    counts = np.ones(cfg.num_pages)
    a = demote_table(cfg, table, counts, hot_pages[:2])
    b = demote_table(cfg, table, counts, hot_pages[:2])
    np.testing.assert_array_equal(np.asarray(a.page_to_shard),
                                  np.asarray(b.page_to_shard))
    np.testing.assert_array_equal(np.asarray(a.page_to_slot),
                                  np.asarray(b.page_to_slot))
    sh = np.asarray(a.page_to_shard)
    assert (sh[hot_pages[:2]] >= 0).all()
    # untouched pages keep their placement exactly
    others = np.setdiff1d(np.arange(cfg.num_pages), hot_pages[:2])
    np.testing.assert_array_equal(sh[others],
                                  np.asarray(table.page_to_shard)[others])
    with pytest.raises(ValueError):
        demote_table(cfg, a, counts, hot_pages[:1])   # already cold


# ---------------------------------------------------------------------------
# Write-ahead log
# ---------------------------------------------------------------------------


def test_wal_roundtrip_truncate_and_reopen(tmp_path):
    path = str(tmp_path / "u.wal")
    wal = WriteAheadLog(path)
    assert len(wal) == 0
    batches = []
    rng = np.random.default_rng(3)
    for seq in (1, 2, 3):
        r = rng.integers(0, 100, 5).astype(np.int32)
        d = rng.normal(size=(5, 4)).astype(np.float32)
        wal.append(seq, r, d)
        batches.append((seq, r, d))
    got = list(wal.replay())
    assert [g[0] for g in got] == [1, 2, 3]
    for (s, r, d), (gs, gr, gd) in zip(batches, got):
        np.testing.assert_array_equal(r, gr)
        np.testing.assert_array_equal(d, gd)
    # a fresh handle on the same file sees the same records
    assert len(WriteAheadLog(path)) == 3
    wal.truncate()
    assert len(wal) == 0 and list(wal.replay()) == []
    assert len(WriteAheadLog(path)) == 0


def test_wal_torn_tail_is_silent_but_corruption_raises(tmp_path):
    path = str(tmp_path / "u.wal")
    wal = WriteAheadLog(path)
    r = np.arange(4, dtype=np.int32)
    d = np.ones((4, 2), np.float32)
    wal.append(1, r, d)
    wal.append(2, r, d)
    # torn tail (crash mid-append): drop the last 7 bytes — record 2
    # vanishes silently, record 1 survives
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 7)
    got = list(WriteAheadLog(path).replay())
    assert [g[0] for g in got] == [1]
    # bit-flip inside a *complete* record: that is corruption, not a torn
    # write — replay must refuse rather than apply garbage
    wal2 = WriteAheadLog(str(tmp_path / "v.wal"))
    wal2.append(1, r, d)
    with open(wal2.path, "r+b") as f:
        f.seek(-3, os.SEEK_END)
        byte = f.read(1)
        f.seek(-3, os.SEEK_END)
        f.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(IOError):
        list(WriteAheadLog(wal2.path).replay())
    # and a file that is not a WAL at all is rejected up front
    bad = tmp_path / "w.wal"
    bad.write_bytes(b"NOTAWAL!" + b"\x00" * 32)
    with pytest.raises(IOError):
        WriteAheadLog(str(bad))


def test_wal_reopen_truncates_torn_tail_so_recovery_appends_survive(
        tmp_path):
    """The crash-recovery scenario the WAL exists for: a torn tail must
    be cut on reopen, or records appended after recovery land behind the
    garbage bytes and replay silently drops them."""
    path = str(tmp_path / "u.wal")
    wal = WriteAheadLog(path)
    r = np.arange(4, dtype=np.int32)
    d = np.ones((4, 2), np.float32)
    wal.append(1, r, d)
    wal.append(2, r, d)
    with open(path, "r+b") as f:                 # crash mid-append of 2
        f.truncate(os.path.getsize(path) - 7)
    recovered = WriteAheadLog(path)
    assert len(recovered) == 1                   # record 2 was torn away
    recovered.append(5, r + 10, d * 2.0)         # post-recovery append
    got = list(WriteAheadLog(path).replay())
    assert [g[0] for g in got] == [1, 5]         # nothing silently lost
    np.testing.assert_array_equal(got[1][1], r + 10)
    np.testing.assert_array_equal(got[1][2], d * 2.0)


# ---------------------------------------------------------------------------
# Engine apply path vs dense reference (both storages)
# ---------------------------------------------------------------------------


def _promoted_engine(mesh, storage):
    eng, offs = engine_for_tables([160, 96], dim=16, mesh=mesh,
                                  hot_fraction=0.15, storage=storage)
    state = eng.init_state(jax.random.PRNGKey(0))
    idx = jnp.tile(jnp.arange(8, dtype=jnp.int32).reshape(1, 1, 8),
                   (8, 1, 1))
    with mesh:
        for _ in range(4):
            state = eng.observe(state, idx)
        state, stats = eng.plan_and_migrate(state)
    assert stats["hot_pages"] > 0
    return eng, state


def _apply_ref(eng, state, rows, deltas):
    """Dense host reference: hot/fp32 rows add exactly; int8 cold rows
    round-trip the quantized domain under the page's carried scale."""
    dense = np.asarray(eng.to_dense(state)).copy()
    shard = np.asarray(state.page_to_shard)
    scales = np.asarray(state.page_scales)
    ps = eng.cfg.page_size
    r, d = coalesce_deltas(rows, deltas)
    for row, dd in zip(r.tolist(), d):
        pg = row // ps
        if eng.cfg.storage == "fp32" or shard[pg] == HOT_SHARD:
            dense[row] = dense[row] + dd
        else:
            s = scales[pg]
            q = np.clip(np.round((dense[row] + dd) / s), -127, 127)
            dense[row] = q.astype(np.float32) * s
    return dense


@pytest.mark.parametrize("storage", ["fp32", "int8"])
def test_apply_deltas_matches_dense_reference(mesh, storage):
    eng, state = _promoted_engine(mesh, storage)
    rng = np.random.default_rng(1)
    rows = rng.integers(0, 256, 48).astype(np.int64)
    deltas = rng.normal(size=(48, 16)).astype(np.float32) * 0.1
    want = _apply_ref(eng, state, rows, deltas)
    r, d = coalesce_deltas(rows, deltas)
    with mesh:
        new = state
        for cr, cd in chunk_delta_batch(r, d, capacity=32):
            new = eng.apply_deltas(new, jnp.asarray(cr), jnp.asarray(cd))
        got = np.asarray(eng.to_dense(new))
    np.testing.assert_array_equal(got, want)      # bit-exact, both tiers
    # untouched rows are bit-identical to the original store
    before = np.asarray(eng.to_dense(state))
    untouched = np.setdiff1d(np.arange(256), r)
    np.testing.assert_array_equal(got[untouched], before[untouched])


@pytest.mark.parametrize("storage", ["fp32", "int8"])
def test_apply_deltas_all_pad_is_bitwise_noop(mesh, storage):
    eng, state = _promoted_engine(mesh, storage)
    rows = jnp.full((32,), PAD_ROW, jnp.int32)
    deltas = jnp.zeros((32, 16), jnp.float32)
    with mesh:
        new = eng.apply_deltas(state, rows, deltas)
        for a, b in ((state.cold, new.cold), (state.hot, new.hot)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_apply_deltas_zero_scale_page_keeps_codes(mesh):
    """A zero carried scale (representable in a hand-built or restored
    state, never emitted by quant.page_scales) must not divide: the
    page's codes stay untouched instead of collapsing to ±127/NaN."""
    eng, state = _promoted_engine(mesh, "int8")
    shard = np.asarray(state.page_to_shard)
    cold_pages = np.nonzero(shard != HOT_SHARD)[0]
    pg = int(cold_pages[0])
    scales = np.asarray(state.page_scales).copy()
    scales[pg] = 0.0
    state0 = dataclasses.replace(state, page_scales=jnp.asarray(scales))
    ps = eng.cfg.page_size
    rows = jnp.asarray([pg * ps], jnp.int32)
    deltas = jnp.ones((1, 16), jnp.float32)
    with mesh:
        new = eng.apply_deltas(state0, rows, deltas)
        np.testing.assert_array_equal(np.asarray(state0.cold),
                                      np.asarray(new.cold))


def test_apply_deltas_is_placement_invariant(mesh):
    """The same deltas applied before and after a migration land on the
    same logical rows (fp32: identical dense view regardless of tier)."""
    eng, offs = engine_for_tables([160, 96], dim=16, mesh=mesh,
                                  hot_fraction=0.15, storage="fp32")
    state = eng.init_state(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    rows = jnp.asarray(rng.integers(0, 256, 24).astype(np.int32))
    deltas = jnp.asarray(rng.normal(size=(24, 16)).astype(np.float32))
    idx = jnp.tile(jnp.arange(8, dtype=jnp.int32).reshape(1, 1, 8), (8, 1, 1))
    with mesh:
        plain = eng.apply_deltas(state, rows, deltas)
        st = eng.observe(state, idx)
        st2, _ = eng.plan_and_migrate(st)
        moved = eng.apply_deltas(st2, rows, deltas)
        np.testing.assert_array_equal(np.asarray(eng.to_dense(plain)),
                                      np.asarray(eng.to_dense(moved)))


def test_apply_deltas_rejects_bad_shapes_and_oob_rows(mesh):
    eng, state = _promoted_engine(mesh, "fp32")
    with mesh:
        with pytest.raises(ValueError):
            eng.apply_deltas(state, jnp.zeros((4,), jnp.int32),
                             jnp.zeros((5, 16), jnp.float32))
        with pytest.raises(ValueError):
            eng.apply_deltas(state, jnp.zeros((4,), jnp.int32),
                             jnp.zeros((4, 8), jnp.float32))
        with pytest.raises(ValueError):
            eng.apply_deltas(
                state, jnp.asarray([10 ** 6], jnp.int32),
                jnp.zeros((1, 16), jnp.float32))


# ---------------------------------------------------------------------------
# Requant-demote: the snap is the demote->promote round trip, bit-for-bit
# ---------------------------------------------------------------------------


def _roundtrip_vs_snap(mesh, storage, deltas_seed):
    """apply d1 -> (demote -> promote) -> apply d2 must equal
    apply d1 -> fused snap -> apply d2, bit-for-bit on the dense view."""
    eng, state = _promoted_engine(mesh, storage)
    table = state.page_table
    hot_pages = np.nonzero(
        np.asarray(table.page_to_shard) == HOT_SHARD)[0]
    rng = np.random.default_rng(deltas_seed)
    ps = eng.cfg.page_size
    # deltas aimed at the hot pages (plus some cold traffic)
    rows = np.concatenate([
        rng.choice(hot_pages) * ps + rng.integers(0, ps, 8)
        for _ in range(3)] + [rng.integers(0, 256, 8)]).astype(np.int64)
    d1 = rng.normal(size=(rows.size, 16)).astype(np.float32) * 0.2
    d2 = rng.normal(size=(rows.size, 16)).astype(np.float32) * 0.2
    counts = np.asarray(jax.device_get(state.counts))
    demoted = demote_table(eng.cfg, table, counts, hot_pages)
    jr = jnp.asarray(rows.astype(np.int32))
    with mesh:
        # path A: demote the hot pages to cold, then promote them back
        a = eng.apply_deltas(state, jr, jnp.asarray(d1))
        a = eng.migrate(a, demoted, count_decay=1.0)
        a = eng.migrate(a, table, count_decay=1.0)
        a = eng.apply_deltas(a, jr, jnp.asarray(d2))
        # path B: fused in-place requant snap of the same pages
        b = eng.apply_deltas(state, jr, jnp.asarray(d1))
        b = eng.requant_hot_pages(b, jnp.asarray(hot_pages, jnp.int32))
        b = eng.apply_deltas(b, jr, jnp.asarray(d2))
        np.testing.assert_array_equal(np.asarray(a.hot), np.asarray(b.hot))
        np.testing.assert_array_equal(np.asarray(eng.to_dense(a)),
                                      np.asarray(eng.to_dense(b)))


@pytest.mark.parametrize("storage", ["fp32", "int8"])
def test_demote_promote_roundtrip_equals_fused_snap(mesh, storage):
    _roundtrip_vs_snap(mesh, storage, deltas_seed=7)


def test_demote_promote_vs_snap_property(mesh):
    """Property form of the round-trip identity (hypothesis drives the
    delta content; the deterministic test above keeps coverage when the
    dependency is absent locally — CI fails loudly if it is missing)."""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @given(seed=st.integers(0, 10 ** 6))
    @settings(deadline=None, max_examples=8,
              suppress_health_check=list(HealthCheck))
    def prop(seed):
        _roundtrip_vs_snap(mesh, "int8", deltas_seed=seed)

    prop()


# ---------------------------------------------------------------------------
# Binding + WAL + runtime integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def rmc1():
    from repro.configs import get_config, reduced
    return reduced(get_config("rmc1"))


def test_binding_apply_logs_and_replay_restores_bitwise(mesh, rmc1,
                                                        tmp_path):
    """The ISSUE's durability contract: updates applied after a snapshot
    live only in the WAL; corrupt_store + restore() must replay them and
    reproduce the exact post-update EngineState and lookups."""
    binding = bind_model(rmc1, mesh, storage="int8")
    batch = {"dense": np.zeros((8, rmc1.n_dense), np.float32),
             "indices": np.tile(np.arange(rmc1.pooling, dtype=np.int32),
                                (8, rmc1.n_tables, 1))}
    rng = np.random.default_rng(5)
    with mesh:
        binding.observe(batch)
        binding.replan()
        wal = WriteAheadLog(str(tmp_path / "u.wal"))
        binding.attach_wal(wal)
        binding.attach_checkpointer(Checkpointer(str(tmp_path / "ck")),
                                    save_now=True)
        total = int(binding.engine.cfg.total_rows)
        for _ in range(3):
            rows = rng.integers(0, total, 40)
            deltas = rng.normal(size=(40, rmc1.emb_dim)
                                ).astype(np.float32) * 0.05
            binding.apply_deltas(rows, deltas)
        assert binding.update_seq == 3 and len(wal) == 3
        end = binding.execute(batch)
        end_scores = np.asarray(end)
        leaves = [np.asarray(jax.device_get(x)) for x in
                  (binding.state.cold, binding.state.hot,
                   binding.state.page_scales)]
        binding.engine.reset_plan_stats()
        corrupt_store(binding, frac=1.0, seed=2)
        binding.restore()                       # checkpoint + WAL replay
        healed = [np.asarray(jax.device_get(x)) for x in
                  (binding.state.cold, binding.state.hot,
                   binding.state.page_scales)]
        healed_scores = np.asarray(binding.execute(batch))
    for a, b in zip(leaves, healed):
        np.testing.assert_array_equal(a, b)     # bit-identical state
    np.testing.assert_array_equal(end_scores, healed_scores)
    assert binding.update_seq == 3              # replay restored the seq
    # replay reuses the compiled apply plan: no retrace on the heal path
    assert binding.engine.plan_stats()["traces"] == 0


def test_snapshot_truncates_wal_and_replay_skips_committed(mesh, rmc1,
                                                          tmp_path):
    binding = bind_model(rmc1, mesh, storage="fp32")
    rng = np.random.default_rng(9)
    total = int(binding.engine.cfg.total_rows)
    with mesh:
        wal = WriteAheadLog(str(tmp_path / "u.wal"))
        binding.attach_wal(wal)
        binding.attach_checkpointer(Checkpointer(str(tmp_path / "ck")),
                                    save_now=True)
        binding.apply_deltas(rng.integers(0, total, 8),
                             rng.normal(size=(8, rmc1.emb_dim)
                                        ).astype(np.float32))
        assert len(wal) == 1
        binding.snapshot()                      # commits seq 1, truncates
        assert len(wal) == 0
        binding.apply_deltas(rng.integers(0, total, 8),
                             rng.normal(size=(8, rmc1.emb_dim)
                                        ).astype(np.float32))
        want = np.asarray(jax.device_get(binding.state.cold))
        corrupt_store(binding, frac=1.0, seed=1)
        binding.restore()
        got = np.asarray(jax.device_get(binding.state.cold))
    np.testing.assert_array_equal(want, got)
    assert binding.update_seq == 2


def test_update_stream_is_deterministic_and_respects_offsets(rmc1):
    load = LoadConfig(n_requests=64,
                      arrival=ArrivalConfig(rate_qps=500.0), seed=4,
                      update_qps=1000.0, update_batch=16)
    a = update_stream(rmc1, load)
    b = update_stream(rmc1, load)
    assert len(a) == len(b) > 0
    for x, y in zip(a, b):
        assert x.seq == y.seq and x.t_gen == y.t_gen
        np.testing.assert_array_equal(x.rows, y.rows)
        np.testing.assert_array_equal(x.deltas, y.deltas)
    assert all(x.rows.shape == (16,) for x in a)
    assert all((x.rows >= 0).all() for x in a)
    ts = [x.t_gen for x in a]
    assert ts == sorted(ts) and ts[0] > 0
    # zero-rate stream is empty, not an error
    assert update_stream(rmc1, dataclasses.replace(load,
                                                   update_qps=0.0)) == []


def test_streaming_updater_runtime_integration(mesh, rmc1, tmp_path):
    """Full loop: open-loop serving + concurrent update stream.  Applied
    between micro-batches, staleness sampled every boundary, maintenance
    recorded, zero steady-state retraces (apply plan warmed up front)."""
    binding = bind_model(rmc1, mesh, storage="int8")
    load = LoadConfig(n_requests=48,
                      arrival=ArrivalConfig(rate_qps=400.0, seed=2),
                      slo_ms=200.0, seed=2, storage="int8",
                      update_qps=600.0, update_batch=16)
    bat = BatcherConfig(batch_sizes=(8, 16), poolings=(rmc1.pooling,))
    rt = ServingRuntime(BindingExecutor(binding), DynamicBatcher(bat),
                        make_padder(rmc1),
                        RuntimeConfig(observe_every=4, replan_every=8))
    wal = WriteAheadLog(str(tmp_path / "u.wal"))
    updater = StreamingUpdater(
        binding, update_stream(rmc1, load),
        UpdateConfig(capacity=32), wal=wal)
    rt.updater = updater
    with mesh:
        rt.warmup(dummy_request_factory(rmc1, storage="int8"))
        updater.warmup()
        binding.reset_plan_stats()
        s = rt.run(OpenLoopSource(request_stream(rmc1, load)))
    rep = updater.report()
    assert rep["applied_batches"] > 0
    assert rep["applied_batches"] + rep["pending_batches"] == \
        rep["generated_batches"]
    assert rep["wal_records"] == rep["applied_batches"]
    assert s["maintenance_calls"].get("updates", 0) >= 1
    assert s["staleness"]["samples"] == s["batches"]
    assert s["staleness"]["rows_behind_p99"] >= 0.0
    assert binding.plan_stats()["traces"] == 0  # the contract under test
    assert s["served"] == 48


def test_staleness_summary_shape_and_legacy_absence():
    from repro.serving import ServingMetrics
    m = ServingMetrics()
    assert "staleness" not in m.summary()       # legacy summary untouched
    m.record_staleness(10.0, 0.5)
    m.record_staleness(0.0, 0.0)
    st = m.summary()["staleness"]
    assert st["samples"] == 2
    assert st["rows_behind_max"] == 10.0
    assert st["seconds_behind_p99"] == pytest.approx(
        np.percentile([0.5, 0.0], 99))


def test_requant_demote_refuses_wal_without_checkpointer(mesh, rmc1,
                                                         tmp_path):
    """Demotions are not WAL-representable, so every demote must fence
    with a WAL-truncating snapshot — running one with a WAL attached but
    no checkpointer to snapshot into would leave un-fenced pre-demote
    deltas in the log, and must refuse loudly."""
    binding = bind_model(rmc1, mesh, storage="int8")
    wal = WriteAheadLog(str(tmp_path / "u.wal"))
    upd = StreamingUpdater(binding, [], UpdateConfig(capacity=8), wal=wal)
    with pytest.raises(RuntimeError, match="checkpointer"):
        upd.requant_demote()


def test_updater_drain_and_apply_every_gate(mesh, rmc1):
    binding = bind_model(rmc1, mesh, storage="fp32")
    rng = np.random.default_rng(0)
    total = int(binding.engine.cfg.total_rows)
    batches = [UpdateBatch(seq=i + 1, t_gen=0.1 * (i + 1),
                           rows=rng.integers(0, total, 8),
                           deltas=rng.normal(size=(8, rmc1.emb_dim)
                                             ).astype(np.float32))
               for i in range(4)]
    upd = StreamingUpdater(binding, batches,
                           UpdateConfig(capacity=16, apply_every=2))
    with mesh:
        upd.warmup()
        from repro.serving import ServingMetrics
        m = ServingMetrics()
        assert upd.on_batch(0.15, m) == 0.0     # gated boundary: no drain
        assert upd.applied_batches == 0
        assert len(m.staleness_rows) == 1       # but staleness sampled
        assert m.staleness_rows[0] == 8.0
        dt = upd.on_batch(0.25, m)              # 2nd boundary: drains 1-2
        assert dt > 0.0 and upd.applied_batches == 2
        assert upd.drain() == 2                 # flush the not-yet-due tail
    assert upd.applied_batches == 4 and len(upd.pending) == 0
