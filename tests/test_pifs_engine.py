"""PIFS engine behaviour: mode equivalence, placement invariance, planner
balance, migration correctness — the paper's system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sls as sls_ops
from repro.core.paging import PagingConfig, initial_page_table
from repro.core.pifs import PIFSEmbeddingEngine, engine_for_tables
from repro.core.planner import PlannerConfig, plan, shard_loads


@pytest.fixture()
def engine(mesh):
    eng, offs = engine_for_tables([500, 300], dim=16, mesh=mesh,
                                  hot_fraction=0.06)
    return eng


@pytest.fixture()
def engine_q(mesh):
    """int8 cold tier with per-page scales (the tiered-precision store)."""
    eng, offs = engine_for_tables([500, 300], dim=16, mesh=mesh,
                                  hot_fraction=0.06, storage="int8")
    return eng


def _ref_lookup(eng, state, idx):
    dense = eng.to_dense(state)
    B, G, L = idx.shape
    flat = idx.reshape(B * G, L)
    return sls_ops.sls_dense_ref(dense, flat).reshape(B, G, -1)


def test_modes_agree_with_dense_reference(engine, mesh):
    state = engine.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    want = _ref_lookup(engine, state, idx)
    with mesh:
        for mode in ("pifs", "pond", "beacon"):
            got = engine.lookup(state, idx, mode=mode)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)


def test_weighted_lookup(engine, mesh):
    state = engine.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(1), (4, 2, 4), 0, 500
                             ).astype(jnp.int32)
    w = jax.random.uniform(jax.random.PRNGKey(2), (4, 2, 4))
    dense = engine.to_dense(state)
    want = sls_ops.sls_dense_ref(dense, idx.reshape(8, 4), w.reshape(8, 4)
                                 ).reshape(4, 2, 16)
    with mesh:
        got = engine.lookup(state, idx, weights=w, mode="pifs")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_placement_invariance_under_migration(engine, mesh):
    """The planner may move pages at any time; lookups must not change —
    including across *repeated* migrations on already-sharded state (a
    regression for the GSPMD-inferred migrate gather, which corrupted the
    store on the second call)."""
    state = engine.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    with mesh:
        before = np.asarray(engine.lookup(state, idx))
        st = engine.observe(state, idx)
        st2, stats = engine.plan_and_migrate(st)
        after = np.asarray(engine.lookup(st2, idx))
        # second cycle with a different hot set: demotions + promotions on
        # state whose storage is now tp-sharded by the first migration
        st3 = engine.observe(st2, (idx * 7 + 3) % 500)
        st4, _ = engine.plan_and_migrate(st3)
        after2 = np.asarray(engine.lookup(st4, idx))
    assert stats["hot_pages"] > 0
    np.testing.assert_allclose(before, after, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(before, after2, rtol=1e-5, atol=1e-5)


def test_hot_pages_become_local(engine, mesh):
    """Pages hammered by the trace must be promoted to the hot tier."""
    state = engine.init_state(jax.random.PRNGKey(0))
    hot_rows = jnp.asarray([[ [0, 1, 2, 3] ]], jnp.int32)  # page 0
    with mesh:
        st = state
        for _ in range(5):
            st = engine.observe(st, jnp.tile(hot_rows, (8, 1, 1)))
        st2, stats = engine.plan_and_migrate(st)
    shard0 = int(np.asarray(st2.page_to_shard)[0])
    assert shard0 == -1  # HOT_SHARD


def test_gradients_flow_through_lookup(engine, mesh):
    state = engine.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(1), (4, 2, 4), 0, 500
                             ).astype(jnp.int32)

    def loss(cold, hot):
        st = dataclasses.replace(state, cold=cold, hot=hot)
        return engine.lookup(st, idx).sum()

    with mesh:
        gc, gh = jax.grad(loss, argnums=(0, 1))(state.cold, state.hot)
    # every accessed row contributes gradient 1 per accessed element
    total = float(np.asarray(gc).sum() + np.asarray(gh).sum())
    assert total == pytest.approx(4 * 2 * 4 * 16, rel=1e-3)


def test_planner_balances_loads():
    cfg = PagingConfig(total_rows=4096, dim=16, n_shards=4, hot_fraction=0.02)
    table = initial_page_table(cfg)
    rng = np.random.default_rng(0)
    counts = rng.zipf(1.3, cfg.num_pages).astype(np.float64)
    new_table, stats = plan(cfg, table, counts, PlannerConfig())
    assert stats["load_std_after"] <= stats["load_std_before"] + 1e-9
    # LPT bound: max load <= mean + heaviest single item (pages are atomic)
    loads = shard_loads(cfg, new_table, counts)
    hot = np.asarray(new_table.page_to_shard) == -1
    heaviest_cold = counts[~hot].max()
    assert loads.max() <= loads.mean() + heaviest_cold + 1e-9


def test_planner_sticky_when_balanced():
    cfg = PagingConfig(total_rows=4096, dim=16, n_shards=4, hot_fraction=0.02)
    table = initial_page_table(cfg)
    counts = np.ones(cfg.num_pages)
    new_table, stats = plan(cfg, table, counts, PlannerConfig())
    # uniform traffic: nothing needs to move except hot promotions
    assert stats["moved_fraction"] < 0.1


@pytest.mark.parametrize("mode", ["pifs", "pond", "beacon"])
@pytest.mark.parametrize("combine", ["psum", "psum_scatter"])
def test_pallas_impl_agrees_with_jnp_exactly(engine, mesh, mode, combine):
    """The kernel datapath must match the jnp path bit-for-bit in fp32:
    both accumulate in the same fixed l-order (impl-invariance)."""
    state = engine.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    w = jax.random.uniform(jax.random.PRNGKey(2), (8, 2, 4))
    with mesh:
        a = engine.lookup(state, idx, mode=mode, combine=combine, impl="jnp")
        b = engine.lookup(state, idx, mode=mode, combine=combine,
                          impl="pallas")
        aw = engine.lookup(state, idx, weights=w, mode=mode, combine=combine,
                           impl="jnp")
        bw = engine.lookup(state, idx, weights=w, mode=mode, combine=combine,
                           impl="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(aw), np.asarray(bw))
    # and it is still the right answer
    want = _ref_lookup(engine, state, idx)
    np.testing.assert_allclose(np.asarray(b), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_lookup_plan_cache_compiles_once(engine, mesh, impl):
    """Repeated lookups of one signature must trace/compile exactly once;
    new signatures add exactly one plan each."""
    state = engine.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    engine.reset_plan_stats()
    with mesh:
        outs = [np.asarray(engine.lookup(state, idx, impl=impl))
                for _ in range(5)]
    stats = engine.plan_stats()
    assert stats == {"plans": 1, "traces": 1, "calls": 5}
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])
    # a different shape is a new plan — but still exactly one more trace
    idx2 = idx[:, :, :2]
    with mesh:
        engine.lookup(state, idx2, impl=impl)
        engine.lookup(state, idx2, impl=impl)
    assert engine.plan_stats() == {"plans": 2, "traces": 2, "calls": 7}
    # weighted lookups and mode changes key separate plans
    w = jax.random.uniform(jax.random.PRNGKey(2), (8, 2, 4))
    with mesh:
        engine.lookup(state, idx, weights=w, impl=impl)
        engine.lookup(state, idx, mode="pond", impl=impl)
    assert engine.plan_stats()["plans"] == 4
    assert engine.plan_stats()["traces"] == 4


# ---------------------------------------------------------------------------
# Tiered-precision store (storage='int8')
# ---------------------------------------------------------------------------


def test_quantized_lookup_matches_dequantized_oracle(engine_q, mesh):
    """Every mode/impl must agree with the dequantized dense reference
    (to_dense is the effective table: int8 codes * per-page scales)."""
    state = engine_q.init_state(jax.random.PRNGKey(0))
    assert state.cold.dtype == jnp.int8
    assert state.page_scales.shape == (engine_q.cfg.num_pages,)
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    want = _ref_lookup(engine_q, state, idx)
    with mesh:
        for mode in ("pifs", "pond", "beacon"):
            got = engine_q.lookup(state, idx, mode=mode)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)


def test_quantized_lookup_tracks_fp32_within_error_bound(mesh):
    """int8 vs fp32 lookups on the same dense table differ by at most the
    summed per-entry half-scale quantization error."""
    eng32, _ = engine_for_tables([500, 300], dim=16, mesh=mesh,
                                 hot_fraction=0.06)
    eng8, _ = engine_for_tables([500, 300], dim=16, mesh=mesh,
                                hot_fraction=0.06, storage="int8")
    dense = jax.random.normal(jax.random.PRNGKey(0), (800, 16)) * 0.05
    s32 = eng32.from_dense(dense)
    s8 = eng8.from_dense(dense)
    L = 4
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, L), 0, 500
                             ).astype(jnp.int32)
    with mesh:
        a = np.asarray(eng32.lookup(s32, idx))
        b = np.asarray(eng8.lookup(s8, idx))
    bound = L * float(np.asarray(s8.page_scales).max()) * 0.5 * 1.01
    assert np.abs(a - b).max() <= bound


@pytest.mark.parametrize("mode", ["pifs", "pond"])
def test_quantized_pallas_impl_agrees_with_jnp_exactly(engine_q, mesh, mode):
    """Fused dequant must not break impl-invariance: both datapaths scale
    each gathered row then accumulate in the same fixed l-order."""
    state = engine_q.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    w = jax.random.uniform(jax.random.PRNGKey(2), (8, 2, 4))
    with mesh:
        a = engine_q.lookup(state, idx, mode=mode, impl="jnp")
        b = engine_q.lookup(state, idx, mode=mode, impl="pallas")
        aw = engine_q.lookup(state, idx, weights=w, mode=mode, impl="jnp")
        bw = engine_q.lookup(state, idx, weights=w, mode=mode, impl="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(aw), np.asarray(bw))


def test_quantized_placement_invariance_is_exact(engine_q, mesh):
    """Migration is *bit-exact* in the quantized domain: cold->cold moves
    codes and their (global, per-page) scales verbatim, promotion stores
    exactly q*scale in fp32, and demotion re-quantizes with the carried
    scale, recovering the codes — through multiple observe/replan cycles
    with hot-set churn (promotions AND demotions)."""
    state = engine_q.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    w = jax.random.uniform(jax.random.PRNGKey(2), (8, 2, 4))
    with mesh:
        st = state
        before = np.asarray(engine_q.lookup(st, idx))
        before_w = np.asarray(engine_q.lookup(st, idx, weights=w))
        promoted = 0
        for cycle in range(3):
            hammer = idx if cycle % 2 == 0 else (idx * 7 + 3) % 500
            st = engine_q.observe(st, hammer)
            st, stats = engine_q.plan_and_migrate(st)
            promoted = max(promoted, stats["hot_pages"])
            after = np.asarray(engine_q.lookup(st, idx))
            after_w = np.asarray(engine_q.lookup(st, idx, weights=w))
            np.testing.assert_array_equal(before, after)
            np.testing.assert_array_equal(before_w, after_w)
        # scales never move: they are global per-page metadata
        np.testing.assert_array_equal(np.asarray(state.page_scales),
                                      np.asarray(st.page_scales))
    assert promoted > 0


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_quantized_plan_cache_compiles_once(engine_q, mesh, impl):
    """storage='int8' signatures share the plan-cache contract: one trace
    per signature, zero steady-state retraces."""
    state = engine_q.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    engine_q.reset_plan_stats()
    with mesh:
        outs = [np.asarray(engine_q.lookup(state, idx, impl=impl))
                for _ in range(5)]
    assert engine_q.plan_stats() == {"plans": 1, "traces": 1, "calls": 5}
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


def test_quantized_roundtrip_through_from_to_dense(engine_q, mesh):
    """to_dense(from_dense(x)) is exactly the quantize->dequantize of x for
    the all-cold initial placement."""
    from repro.core import quant
    c = engine_q.cfg
    dense = jax.random.normal(jax.random.PRNGKey(3), (c.padded_rows, c.dim))
    state = engine_q.from_dense(dense)
    got = np.asarray(engine_q.to_dense(state))
    q, scales = quant.quantize_pages(
        dense.reshape(c.num_pages, c.page_size, c.dim))
    want = np.asarray(quant.dequantize_pages(q, scales)).reshape(
        c.padded_rows, c.dim)
    np.testing.assert_array_equal(got, want)


def test_engine_address_space_must_fit_int32(mesh):
    """Regression: engine_for_tables returns int64 offsets and model code
    downcasts the summed global index to int32 — construction must refuse
    address spaces where that cast would silently truncate."""
    with pytest.raises(ValueError, match="int32"):
        engine_for_tables([2 ** 31], dim=16, mesh=mesh)
    # int8 packs 4x the rows per page but the row *count* is what must fit
    with pytest.raises(ValueError, match="int32"):
        engine_for_tables([2 ** 30, 2 ** 30, 2 ** 30], dim=16, mesh=mesh,
                          storage="int8")
    # just under the bound constructs fine (no arrays are allocated)
    eng, offs = engine_for_tables([2 ** 30], dim=16, mesh=mesh)
    assert offs.dtype == np.int64


# ---------------------------------------------------------------------------
# Gather-once duplicate coalescing (dedup knob)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_dedup_lookup_bit_exact_pinned(engine, engine_q, mesh, impl):
    """dedup=on equals dedup=off bit-for-bit: the coalesced stage changes
    the gather (each unique owned row fetched/dequantized once), never the
    fixed-l accumulate order — pinned here for fp32 and int8 storage,
    weighted and unweighted (the hypothesis sweep covers the rest)."""
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 16), 0, 300
                             ).astype(jnp.int32)   # small range => many dups
    w = jax.random.uniform(jax.random.PRNGKey(2), (8, 2, 16))
    for eng in (engine, engine_q):
        state = eng.init_state(jax.random.PRNGKey(0))
        with mesh:
            a = eng.lookup(state, idx, impl=impl, dedup="off")
            b = eng.lookup(state, idx, impl=impl, dedup="on")
            aw = eng.lookup(state, idx, weights=w, impl=impl, dedup="off")
            bw = eng.lookup(state, idx, weights=w, impl=impl, dedup="on")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(aw), np.asarray(bw))


def test_dedup_grows_plan_cache_key(engine, mesh):
    """The requested dedup knob is part of the lookup-plan signature: each
    distinct value keys its own plan (one trace each), repeated calls hit
    the cache, and plan_stats() reports the resolution records — but only
    when a dedup-requesting plan exists (off-only callers see the exact
    legacy stats shape)."""
    state = engine.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    engine.reset_plan_stats(clear_plans=True)
    with mesh:
        engine.lookup(state, idx, dedup="off")
        engine.lookup(state, idx, dedup="off")
    stats = engine.plan_stats()
    assert stats == {"plans": 1, "traces": 1, "calls": 2}  # no "dedup" key
    with mesh:
        engine.lookup(state, idx, dedup="on")
        engine.lookup(state, idx, dedup="on")
        engine.lookup(state, idx, dedup="auto")
    stats = engine.plan_stats()
    assert (stats["plans"], stats["traces"], stats["calls"]) == (3, 3, 5)
    recs = stats["dedup"]
    assert len(recs) == 2         # the 'on' and 'auto' plans
    by_req = {r["requested"]: r for r in recs.values()}
    assert by_req["on"]["resolved"] is True
    assert by_req["on"]["measured_factor"] > 1.0
    # zero histogram => uniform prior => essentially duplicate-free => off
    assert by_req["auto"]["resolved"] is False
    assert by_req["auto"]["expected_factor"] is not None


def test_dedup_auto_no_retrace_across_observe_replan(engine, mesh):
    """dedup='auto' freezes its per-plan decision at first build (the cache
    key carries the *requested* knob), so observe/replan cycles — which
    change the histogram the decision came from — never retrace, and
    results stay placement-invariant."""
    state = engine.init_state(jax.random.PRNGKey(0))
    # hammer a narrow id range so the histogram is skewed when 'auto' looks
    hot_idx = (jax.random.randint(jax.random.PRNGKey(1), (8, 2, 16), 0, 64)
               ).astype(jnp.int32)
    engine.reset_plan_stats(clear_plans=True)
    with mesh:
        state = engine.observe(state, hot_idx)
        before = np.asarray(engine.lookup(state, hot_idx, dedup="auto"))
        for _ in range(2):
            state = engine.observe(state, hot_idx)
            state, _stats = engine.plan_and_migrate(state)
            after = np.asarray(engine.lookup(state, hot_idx, dedup="auto"))
            np.testing.assert_allclose(before, after, rtol=1e-5, atol=1e-5)
    stats = engine.plan_stats()
    # exactly one lookup trace (the observe histogram plan is separate)
    assert stats["traces"] == 1 and len(stats["dedup"]) == 1
    rec = next(iter(stats["dedup"].values()))
    # 64 hot rows hammered by 256 entries: auto must have turned dedup on
    assert rec["requested"] == "auto" and rec["resolved"] is True
    assert rec["expected_factor"] >= engine.dedup_auto_threshold


def test_dedup_on_capacity_fallback_is_exact(mesh):
    """dedup='on' with a staging budget smaller than the signature's
    worst case resolves to the non-dedup datapath — recorded in the plan
    stats, bit-exact by construction."""
    eng, _ = engine_for_tables([500, 300], dim=16, mesh=mesh,
                               hot_fraction=0.06)
    eng.dedup_staging_bytes = 64          # far below (8*2*4) * 16 * 4
    base, _ = engine_for_tables([500, 300], dim=16, mesh=mesh,
                                hot_fraction=0.06)
    s1 = eng.init_state(jax.random.PRNGKey(0))
    s2 = base.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    with mesh:
        got = np.asarray(eng.lookup(s1, idx, dedup="on"))
        want = np.asarray(base.lookup(s2, idx, dedup="off"))
    np.testing.assert_array_equal(got, want)
    rec = next(iter(eng.plan_stats()["dedup"].values()))
    assert rec == {**rec, "requested": "on", "resolved": False,
                   "capacity_ok": False}


def test_dedup_engine_default_and_validation(mesh):
    """engine_for_tables threads the engine-wide dedup default; bad knob
    values fail loudly at construction and lookup."""
    eng, _ = engine_for_tables([500, 300], dim=16, mesh=mesh, dedup="on")
    assert eng.default_dedup == "on"
    state = eng.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    with mesh:
        eng.lookup(state, idx)            # default knob = 'on'
    assert next(iter(eng.plan_stats()["dedup"].values()))["resolved"] is True
    with pytest.raises(ValueError, match="dedup"):
        engine_for_tables([500], dim=16, mesh=mesh, dedup="sometimes")
    with mesh, pytest.raises(ValueError, match="dedup"):
        eng.lookup(state, idx, dedup="bogus")


def test_dedup_factor_counts_weighted_entries(engine, mesh):
    """The measured duplicate factor replays the per-(dp-group, shard)
    uniques the dedup'd datapath gathers, and weight-0 (serving pad)
    entries are excluded from the entry count."""
    state = engine.init_state(jax.random.PRNGKey(0))
    idx = jnp.asarray(np.full((8, 2, 4), 17, np.int32))
    d = engine.dedup_factor(state, idx)
    # one row, hammered by every entry, owned by one shard per dp group
    assert d["entries"] == 8 * 2 * 4
    assert d["unique_rows"] == 2          # dp=2 groups gather it once each
    assert d["factor"] == pytest.approx(32.0)
    w = np.zeros((8, 2, 4), np.float32)
    w[0, 0, 0] = 1.0
    dw = engine.dedup_factor(state, idx, weights=w)
    assert dw["entries"] == 1 and dw["unique_rows"] == 1


def test_psum_scatter_combine(engine, mesh):
    state = engine.init_state(jax.random.PRNGKey(0))
    # bags per device must divide tp=4: B=8 over dp=2 -> 4 local x G=2 = 8 bags
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    want = _ref_lookup(engine, state, idx)
    with mesh:
        got = engine.lookup(state, idx, mode="pifs", combine="psum_scatter")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Fused front end (lookup_interact): resolution, plan cache, stability
# ---------------------------------------------------------------------------


@pytest.fixture()
def mesh_dp():
    """Replicated/dp-sharded mesh — the config where fusion resolves fused."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    from repro.distributed.sharding import make_mesh
    return make_mesh((8, 1), ("data", "model"))


@pytest.fixture()
def engine_dp(mesh_dp):
    eng, offs = engine_for_tables([500, 300], dim=16, mesh=mesh_dp,
                                  hot_fraction=0.06)
    return eng


def _fe_args(engine, seed=1):
    state = engine.init_state(jax.random.PRNGKey(0))
    idx = jax.random.randint(jax.random.PRNGKey(seed), (8, 2, 4), 0, 500
                             ).astype(jnp.int32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, engine.cfg.dim))
    return state, idx, x


def test_front_end_matches_lookup_plus_interaction(engine_dp, mesh_dp):
    """lookup_interact == lookup -> concat -> dot_interaction oracle, and
    fused == split bitwise, on the dp-only mesh."""
    from repro.kernels import ref as kernel_ref
    state, idx, x = _fe_args(engine_dp)
    with mesh_dp:
        pooled = engine_dp.lookup(state, idx)
        want = np.asarray(kernel_ref.dot_interaction_ref(
            jnp.concatenate([x[:, None, :], pooled], axis=1)))
        for impl in ("jnp", "pallas"):
            s = np.asarray(engine_dp.lookup_interact(
                state, idx, x, impl=impl, front_end="split"))
            f = np.asarray(engine_dp.lookup_interact(
                state, idx, x, impl=impl, front_end="fused"))
            np.testing.assert_array_equal(s, f)
            np.testing.assert_array_equal(f, want)


def test_front_end_grows_plan_cache_key(engine_dp, mesh_dp):
    """front_end is part of the interact-plan signature: each knob value
    keys its own plan (one trace each), repeated calls hit the cache, and
    plan_stats() grows a 'front_end' entry with the resolution records —
    interact plans never collide with lookup plans."""
    state, idx, x = _fe_args(engine_dp)
    engine_dp.reset_plan_stats(clear_plans=True)
    with mesh_dp:
        engine_dp.lookup_interact(state, idx, x, front_end="split")
        engine_dp.lookup_interact(state, idx, x, front_end="split")
        engine_dp.lookup_interact(state, idx, x, front_end="fused")
        engine_dp.lookup_interact(state, idx, x, front_end="fused")
    stats = engine_dp.plan_stats()
    assert (stats["plans"], stats["traces"], stats["calls"]) == (2, 2, 4)
    recs = stats["front_end"]
    assert len(recs) == 2
    by_req = {r["requested"]: r for r in recs.values()}
    assert by_req["split"]["resolved"] == "split"
    assert by_req["fused"]["resolved"] == "fused"
    assert all(label.startswith("interact:") for label in recs)
    with mesh_dp:
        engine_dp.lookup(state, idx)          # lookup plan is a distinct key
    assert engine_dp.plan_stats()["plans"] == 3


def test_front_end_tp_resolves_fused_tp_and_is_recorded(engine, mesh):
    """tp-sharded masked partials resolve 'fused_tp': each shard partial-
    pools its (B, F, D) cold tile, the psum lands between the partial-pool
    and resume kernels, and the result stays bit-exact vs split (both
    paths psum fixed-l-order cold partials in the same mesh order).  The
    resolution record distinguishes fused_tp from a split fallback so
    benches can assert the datapath they time."""
    state, idx, x = _fe_args(engine)
    with mesh:
        for impl in ("jnp", "pallas"):
            s = np.asarray(engine.lookup_interact(state, idx, x, impl=impl,
                                                  front_end="split"))
            f = np.asarray(engine.lookup_interact(state, idx, x, impl=impl,
                                                  front_end="fused"))
            np.testing.assert_array_equal(s, f)
    recs = [r for r in engine.plan_stats()["front_end"].values()
            if r["requested"] == "fused"]
    assert recs and all(r["resolved"] == "fused_tp" for r in recs)
    assert "psum" in recs[0]["reason"]
    assert recs[0]["tp"] == 4
    split_recs = [r for r in engine.plan_stats()["front_end"].values()
                  if r["requested"] == "split"]
    assert split_recs and all(r["resolved"] == "split" for r in split_recs)


def test_front_end_pond_resolves_fused_tp(engine_dp, mesh_dp):
    """pond requesting fusion opts into pooling its cold partials before
    the hot/cold add (partial-pool -> psum -> resume): the knob resolves
    'fused_tp' even on the dp-only mesh, and the result equals the fixed
    l-order split composition (the pifs split path) bitwise — pond-split's
    own segment-sum order only agrees to tolerance."""
    state, idx, x = _fe_args(engine_dp)
    with mesh_dp:
        pifs_split = np.asarray(engine_dp.lookup_interact(
            state, idx, x, mode="pifs", front_end="split"))
        pond_split = np.asarray(engine_dp.lookup_interact(
            state, idx, x, mode="pond", front_end="split"))
        pond_fused = np.asarray(engine_dp.lookup_interact(
            state, idx, x, mode="pond", front_end="fused"))
    np.testing.assert_array_equal(pond_fused, pifs_split)
    np.testing.assert_allclose(pond_fused, pond_split, rtol=1e-5, atol=1e-5)
    recs = [r for r in engine_dp.plan_stats()["front_end"].values()
            if r["requested"] == "fused"]
    assert recs and recs[0]["resolved"] == "fused_tp"
    assert "pool" in recs[0]["reason"]


def test_front_end_tp_no_retrace_and_quantized(mesh):
    """fused_tp on the (2, 4) mesh: int8 cold tier + dedup + weights stay
    bit-exact vs split, and steady state holds zero retraces across
    observe/replan cycles (the serving contract under tp)."""
    eng, _ = engine_for_tables([500, 300], dim=16, mesh=mesh,
                               hot_fraction=0.06, storage="int8")
    state, idx, x = _fe_args(eng)
    w = jax.random.uniform(jax.random.PRNGKey(5), (8, 2, 4))
    with mesh:
        for impl in ("jnp", "pallas"):
            for dedup in ("off", "on"):
                s = np.asarray(eng.lookup_interact(
                    state, idx, x, weights=w, impl=impl, dedup=dedup,
                    front_end="split"))
                f = np.asarray(eng.lookup_interact(
                    state, idx, x, weights=w, impl=impl, dedup=dedup,
                    front_end="fused"))
                np.testing.assert_array_equal(s, f)
        warm = eng.plan_stats()["traces"]
        for _ in range(3):
            state = eng.observe(state, idx)
            state, _ = eng.plan_and_migrate(state)
            f = np.asarray(eng.lookup_interact(
                state, idx, x, weights=w, impl="pallas", front_end="fused"))
            s = np.asarray(eng.lookup_interact(
                state, idx, x, weights=w, impl="pallas", front_end="split"))
            np.testing.assert_array_equal(f, s)
    assert eng.plan_stats()["traces"] == warm


def test_front_end_no_retrace_across_observe_replan(engine_dp, mesh_dp):
    """Zero steady-state retraces across observe/replan cycles with
    front_end='fused' (the serving contract), and lookups stay bit-stable
    against their own split shadow after every migration."""
    state, idx, x = _fe_args(engine_dp)
    with mesh_dp:
        engine_dp.lookup_interact(state, idx, x, impl="pallas",
                                  front_end="fused")
        engine_dp.lookup_interact(state, idx, x, impl="pallas",
                                  front_end="split")
        warm = engine_dp.plan_stats()["traces"]
        for _ in range(3):
            state = engine_dp.observe(state, idx)
            state, _ = engine_dp.plan_and_migrate(state)
            f = np.asarray(engine_dp.lookup_interact(
                state, idx, x, impl="pallas", front_end="fused"))
            s = np.asarray(engine_dp.lookup_interact(
                state, idx, x, impl="pallas", front_end="split"))
            np.testing.assert_array_equal(f, s)
    assert engine_dp.plan_stats()["traces"] == warm


def test_front_end_validation(engine_dp, mesh_dp):
    state, idx, x = _fe_args(engine_dp)
    with pytest.raises(ValueError, match="front_end"):
        engine_dp.lookup_interact(state, idx, x, front_end="bogus")
    with pytest.raises(ValueError, match="dense_feature"):
        engine_dp.lookup_interact(state, idx, x[:, :4], front_end="fused")


def test_front_end_quantized_bit_exact(mesh_dp):
    """int8 cold tier through the fused front end: fused == split bitwise
    (the per-row dequant rides the same VMEM staging)."""
    eng, _ = engine_for_tables([500, 300], dim=16, mesh=mesh_dp,
                               hot_fraction=0.06, storage="int8")
    state, idx, x = _fe_args(eng)
    w = jax.random.uniform(jax.random.PRNGKey(5), (8, 2, 4))
    with mesh_dp:
        for impl in ("jnp", "pallas"):
            for dedup in ("off", "on"):
                s = np.asarray(eng.lookup_interact(
                    state, idx, x, weights=w, impl=impl, dedup=dedup,
                    front_end="split"))
                f = np.asarray(eng.lookup_interact(
                    state, idx, x, weights=w, impl=impl, dedup=dedup,
                    front_end="fused"))
                np.testing.assert_array_equal(s, f)
