"""simlab behaviour: the paper's mechanisms must hold directionally for any
reasonable trace (these are the claims the reproduction rests on)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.traces import TraceConfig, TraceGenerator, flatten_trace
from repro.simlab.devices import CostParams, HardwareParams
from repro.simlab.simulator import (ALL_SYSTEMS, make_system, pifs,
                                    e2e_speedup, simulate)
from repro.simlab.tco import gpu_tco, pifs_tco, power_area_table


@pytest.fixture(scope="module")
def trace():
    model = get_config("rmc4")
    cfg = TraceConfig(n_rows=model.emb_num, n_tables=8, pooling=8,
                      batch=256, seed=0)
    g = TraceGenerator(cfg)
    arr = np.stack([g.next_batch() for _ in range(6)])
    return flatten_trace(arr.reshape(-1, 8, 8), model.emb_num), model


def _run(trace, model, sys, hw=None, **kw):
    hw = hw or HardwareParams()
    return simulate(trace, model.emb_dim, model.pooling, sys, hw,
                    n_rows_total=model.emb_num * model.n_tables, **kw)


def test_system_ordering_matches_paper(trace):
    """pond slowest, pifs fastest, beacon between pond_pm and pifs."""
    flat, model = trace
    hw = HardwareParams()
    t = {n: _run(flat, model, make_system(n, hw)).total_us
         for n in ALL_SYSTEMS}
    assert t["pifs"] < t["recnmp"] < t["beacon"] < t["pond"]
    assert t["pifs"] < t["pond_pm"] <= t["pond"] * 1.05


def test_more_devices_help_pifs_not_pond(trace):
    flat, model = trace
    hw = HardwareParams()
    p4 = _run(flat, model, make_system("pifs", hw), n_devices=4).total_us
    p16 = _run(flat, model, make_system("pifs", hw), n_devices=16).total_us
    assert p16 <= p4 * 1.03  # pc-bound: more devices never hurt
    q4 = _run(flat, model, make_system("pond", hw), n_devices=4).total_us
    q16 = _run(flat, model, make_system("pond", hw), n_devices=16).total_us
    assert q16 > q4  # congestion makes host-centric WORSE with fan-out


def test_buffer_and_pm_both_help(trace):
    flat, model = trace
    hw = HardwareParams()
    full = _run(flat, model, pifs(hw)).total_us
    no_buf = _run(flat, model, pifs(hw, buffer_kb=0)).total_us
    no_pm = _run(flat, model, pifs(hw, pm=False)).total_us
    assert full <= no_buf
    assert full <= no_pm


def test_ooo_gain_bounded(trace):
    flat, model = trace
    hw = HardwareParams()
    with_ooo = _run(flat, model, pifs(hw, ooo=True)).total_us
    without = _run(flat, model, pifs(hw, ooo=False)).total_us
    assert 1.0 <= without / with_ooo <= 1.08   # paper: <= 7.3%


def test_line_migration_cheaper_5x(trace):
    flat, model = trace
    hw = HardwareParams()
    line = _run(flat, model, pifs(hw, migration_granularity="line"))
    page = _run(flat, model, pifs(hw, migration_granularity="page"))
    assert page.migration_cost_us / line.migration_cost_us == pytest.approx(
        5.1, rel=1e-6)


def test_uniform_trace_balances_devices():
    model = get_config("rmc4")
    cfg = TraceConfig(n_rows=model.emb_num, n_tables=8, pooling=8,
                      batch=256, distribution="uniform", seed=0)
    g = TraceGenerator(cfg)
    arr = np.stack([g.next_batch() for _ in range(4)])
    flat = flatten_trace(arr.reshape(-1, 8, 8), model.emb_num)
    r = _run(flat, model, make_system("pifs", HardwareParams()))
    assert r.device_imbalance < 1.25


def test_e2e_speedup_amdahl():
    assert e2e_speedup(4.0, 1.0) == pytest.approx(4.0)
    assert e2e_speedup(4.0, 0.0) == pytest.approx(1.0)
    assert 1.0 < e2e_speedup(4.0, 0.5) < 4.0


def test_tco_pifs_cheaper_than_gpu():
    for mem in (256.0, 2048.0):
        p = pifs_tco(mem)
        g = gpu_tco(mem, n_gpus=1)
        assert g.total > p.total
    pa = power_area_table()
    assert pa["power_ratio"] == pytest.approx(2.72, abs=0.05)
    assert pa["area_ratio"] == pytest.approx(2.02, abs=0.05)


def test_drift_reduces_pm_capture():
    """Hot-set drift must make profiled placement less effective — the
    mechanism behind the paper's PM gains being modest."""
    model = get_config("rmc4")

    def capture(drift):
        cfg = TraceConfig(n_rows=model.emb_num, n_tables=8, pooling=8,
                          batch=256, drift_per_batch=drift, seed=0)
        g = TraceGenerator(cfg)
        arr = np.stack([g.next_batch() for _ in range(6)])
        flat = flatten_trace(arr.reshape(-1, 8, 8), model.emb_num)
        return _run(flat, model,
                    make_system("pifs", HardwareParams())).frac_local_access

    assert capture(0.0) > capture(0.4) + 0.05
