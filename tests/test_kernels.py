"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.interaction import dot_interaction_pallas
from repro.kernels.sls import sls_pallas


@pytest.mark.parametrize("B,L,V,D", [
    (4, 2, 64, 16),
    (8, 8, 256, 64),
    (16, 4, 1024, 128),
    (3, 5, 100, 32),          # non-power-of-two
    (1, 1, 8, 16),            # degenerate
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sls_kernel_matches_ref(B, L, V, D, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(B * L + V))
    table = jax.random.normal(k1, (V, D), dtype)
    idx = jax.random.randint(k2, (B, L), 0, V).astype(jnp.int32)
    out = sls_pallas(table, idx, interpret=True)
    want = ref.sls_ref(table, idx)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,L,V,D", [(8, 8, 256, 64), (4, 3, 64, 16)])
def test_sls_kernel_weighted(B, L, V, D):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    table = jax.random.normal(k1, (V, D))
    idx = jax.random.randint(k2, (B, L), 0, V).astype(jnp.int32)
    w = jax.random.uniform(k3, (B, L))
    out = sls_pallas(table, idx, w, interpret=True)
    want = ref.sls_ref(table, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("B,L,V,D,block_l", [
    (8, 8, 256, 64, 8),       # exact tiling: L == block_l
    (8, 8, 256, 64, 3),       # tail tile: L % block_l = 2
    (4, 9, 128, 32, 4),       # tail tile of 1
    (2, 5, 64, 24, 16),       # block_l > L (clamped to one tile)
    (3, 7, 100, 130, 4),      # odd D, non-128-multiple
    (4, 6, 64, 16, 1),        # degenerate one-row tiles
])
@pytest.mark.parametrize("weighted", [False, True])
def test_masked_sls_kernel_matches_ref(B, L, V, D, block_l, weighted):
    """Masked-partial kernel vs oracle across blocking edge cases."""
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(B * L + D), 4)
    table = jax.random.normal(k1, (V, D))
    idx = jax.random.randint(k2, (B, L), 0, V).astype(jnp.int32)
    owned = jax.random.bernoulli(k3, 0.5, (B, L))
    w = jax.random.uniform(k4, (B, L)) if weighted else None
    out = ops.masked_sls(table, idx, owned, w, interpret=True,
                         block_l=block_l)
    want = ref.masked_sls_ref(table, idx, owned, w)
    assert out.shape == (B, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("block_l", [1, 3, 8, 16])
def test_sls_kernel_bag_tiling_invariant(block_l):
    """Pooling result must not depend on the tile size (fixed l order)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    table = jax.random.normal(k1, (128, 48))
    idx = jax.random.randint(k2, (6, 11), 0, 128).astype(jnp.int32)
    w = jax.random.uniform(k3, (6, 11))
    base = ops.sls(table, idx, w, interpret=True, block_l=11)
    out = ops.sls(table, idx, w, interpret=True, block_l=block_l)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


def test_masked_sls_empty_and_full_masks():
    """Empty bags (all entries masked out) pool to exactly zero."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    table = jax.random.normal(k1, (64, 32))
    idx = jax.random.randint(k2, (4, 6), 0, 64).astype(jnp.int32)
    none = jnp.zeros((4, 6), bool)
    out = ops.masked_sls(table, idx, none, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((4, 32)))
    # mask rows 2..: only a *sub-bag* survives
    part = jnp.asarray([[True] * 2 + [False] * 4] * 4)
    out2 = ops.masked_sls(table, idx, part, interpret=True)
    want = ref.sls_ref(table, idx[:, :2])
    np.testing.assert_allclose(np.asarray(out2), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # all-owned mask degenerates to plain SLS
    out3 = ops.masked_sls(table, idx, jnp.ones((4, 6), bool), interpret=True)
    np.testing.assert_array_equal(np.asarray(out3),
                                  np.asarray(ops.sls(table, idx,
                                                     interpret=True)))


@pytest.mark.parametrize("B,L,V,D,block_l", [
    (8, 8, 256, 64, 8),       # exact tiling
    (8, 8, 256, 64, 3),       # tail tile
    (4, 9, 128, 32, 4),       # tail tile of 1
    (3, 7, 100, 130, 4),      # odd D, non-128-multiple
])
@pytest.mark.parametrize("weighted", [False, True])
def test_masked_sls_quant_kernel_matches_oracle_bitwise(B, L, V, D, block_l,
                                                        weighted):
    """int8 table + per-entry dequant scales: the kernel's fused dequant
    must match the fixed-l-order quantized oracle bit-for-bit in fp32."""
    k1, k2, k3, k4, k5 = jax.random.split(jax.random.PRNGKey(B + L + D), 5)
    table_q = jax.random.randint(k1, (V, D), -127, 128).astype(jnp.int8)
    idx = jax.random.randint(k2, (B, L), 0, V).astype(jnp.int32)
    owned = jax.random.bernoulli(k3, 0.5, (B, L))
    scales = jax.random.uniform(k4, (B, L), minval=1e-4, maxval=2e-2)
    w = jax.random.uniform(k5, (B, L)) if weighted else None
    out = ops.masked_sls(table_q, idx, owned, w, scales=scales,
                         interpret=True, block_l=block_l)
    want = ref.masked_sls_quant_ref(table_q, idx, owned, scales, w)
    assert out.dtype == jnp.float32 and out.shape == (B, D)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_masked_sls_quant_jnp_dispatch_matches_oracle():
    """The jnp fallback (ops.masked_sls impl='jnp') dequantizes with the
    same semantics as the quantized oracle (sum order may differ)."""
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(11), 4)
    table_q = jax.random.randint(k1, (64, 16), -127, 128).astype(jnp.int8)
    idx = jax.random.randint(k2, (4, 6), 0, 64).astype(jnp.int32)
    owned = jax.random.bernoulli(k3, 0.5, (4, 6))
    scales = jax.random.uniform(k4, (4, 6), minval=1e-4, maxval=1e-2)
    a = ops.masked_sls(table_q, idx, owned, scales=scales, impl="jnp")
    want = ref.masked_sls_quant_ref(table_q, idx, owned, scales)
    np.testing.assert_allclose(np.asarray(a), np.asarray(want), rtol=1e-6,
                               atol=1e-7)
    # empty mask still pools to exactly zero through the dequant path
    none = jnp.zeros((4, 6), bool)
    z = ops.masked_sls(table_q, idx, none, scales=scales, interpret=True)
    np.testing.assert_array_equal(np.asarray(z), np.zeros((4, 16)))


@pytest.mark.parametrize("B,L,V,D,block_l", [
    (8, 8, 256, 64, 8),       # exact tiling
    (8, 8, 256, 64, 3),       # tail tile
    (4, 9, 128, 32, 4),       # tail tile of 1
    (3, 7, 100, 130, 4),      # odd D, non-128-multiple
])
@pytest.mark.parametrize("weighted", [False, True])
def test_masked_sls_dedup_kernel_bit_exact(B, L, V, D, block_l, weighted):
    """The two-phase gather-once kernel must match (a) its staging oracle
    and (b) the non-dedup kernel **bit-for-bit**: the dedup stage changes
    the gather, never the fixed-l accumulate order."""
    from repro.core.sls import dedup_plan
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(B * L + D), 4)
    table = jax.random.normal(k1, (V, D))
    idx = jax.random.randint(k2, (B, L), 0, V // 4).astype(jnp.int32)  # dups
    owned = jax.random.bernoulli(k3, 0.5, (B, L))
    w = jax.random.uniform(k4, (B, L)) if weighted else None
    plan = dedup_plan(idx, owned)
    out = ops.masked_sls_dedup(table, plan, owned, w, interpret=True,
                               block_l=block_l)
    base = ops.masked_sls(table, idx, owned, w, interpret=True,
                          block_l=block_l)
    want = ref.masked_sls_dedup_ref(table, plan.unique_rows, plan.slots,
                                    owned, w)
    assert out.shape == (B, D)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("weighted", [False, True])
def test_masked_sls_dedup_quant_kernel_bit_exact(weighted):
    """int8 table: the per-unique-row fused dequant sees the same operands
    as the non-dedup kernel's per-entry dequant — bitwise equal, and both
    match the fixed-l-order quantized oracle."""
    from repro.core.sls import dedup_plan
    B, L, V, D = 6, 9, 128, 32
    k1, k2, k3, k4, k5 = jax.random.split(jax.random.PRNGKey(3), 5)
    table_q = jax.random.randint(k1, (V, D), -127, 128).astype(jnp.int8)
    idx = jax.random.randint(k2, (B, L), 0, V // 4).astype(jnp.int32)
    owned = jax.random.bernoulli(k3, 0.5, (B, L))
    # per-entry scales must be a function of the row (page scales are)
    row_scale = jax.random.uniform(k4, (V,), minval=1e-4, maxval=2e-2)
    scales = row_scale[idx]
    w = jax.random.uniform(k5, (B, L)) if weighted else None
    plan = dedup_plan(idx, owned, scales)
    out = ops.masked_sls_dedup(table_q, plan, owned, w, interpret=True,
                               block_l=4)
    base = ops.masked_sls(table_q, idx, owned, w, scales=scales,
                          interpret=True, block_l=4)
    want = ref.masked_sls_quant_ref(table_q, idx, owned, scales, w)
    assert out.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_masked_sls_dedup_extremes():
    """All-duplicate bags collapse to one staging row; all-unique bags
    degrade gracefully to one DMA per entry; a fully-masked batch pools to
    exactly zero (the sentinel staging slot never contributes)."""
    from repro.core.sls import dedup_plan
    B, L, V, D = 4, 6, 64, 16
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    table = jax.random.normal(k1, (V, D))
    w = jax.random.uniform(k2, (B, L))
    all_dup = jnp.full((B, L), 7, jnp.int32)
    all_unique = jnp.arange(B * L, dtype=jnp.int32).reshape(B, L)
    ones = jnp.ones((B, L), bool)
    for idx, owned in [(all_dup, ones), (all_unique, ones),
                       (all_dup, jnp.zeros((B, L), bool))]:
        plan = dedup_plan(idx, owned)
        out = ops.masked_sls_dedup(table, plan, owned, w, interpret=True)
        base = ops.masked_sls(table, idx, owned, w, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(base))
    assert int(dedup_plan(all_dup, ones).n_unique) == 1
    assert int(dedup_plan(all_unique, ones).n_unique) == B * L
    assert int(dedup_plan(all_dup, jnp.zeros((B, L), bool)).n_unique) == 0


def test_dedup_plan_invariants():
    """Plan structure: slots route every owned entry to a staging slot
    holding exactly its row; non-owned entries route to the sentinel run;
    padded capacity beyond n_slots stays sentinel."""
    from repro.core.sls import DEDUP_SENTINEL, dedup_plan
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, 10, (5, 7)), jnp.int32)
    owned = jnp.asarray(rng.random((5, 7)) < 0.6)
    plan = dedup_plan(idx, owned)
    uniq = np.asarray(plan.unique_rows)
    slots = np.asarray(plan.slots)
    n_slots, n_unique = int(plan.n_slots), int(plan.n_unique)
    o = np.asarray(owned)
    routed = uniq[slots]
    np.testing.assert_array_equal(routed[o], np.asarray(idx)[o])
    assert (routed[~o] == DEDUP_SENTINEL).all()
    assert (slots < n_slots).all()
    assert n_unique == len(np.unique(np.asarray(idx)[o]))
    assert (uniq[n_slots:] == DEDUP_SENTINEL).all()


def test_sls_zero_length_bags():
    table = jnp.ones((8, 16))
    idx = jnp.zeros((4, 0), jnp.int32)
    out = ops.sls(table, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.zeros((4, 16)))
    outm = ops.masked_sls(table, idx, jnp.zeros((4, 0), bool), interpret=True)
    np.testing.assert_array_equal(np.asarray(outm), np.zeros((4, 16)))


@pytest.mark.parametrize("D", [16, 100, 130])
def test_sls_lane_padding_is_transparent(D):
    """Forcing 128-lane padding must not change results or shapes."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(D), 3)
    table = jax.random.normal(k1, (64, D))
    idx = jax.random.randint(k2, (5, 4), 0, 64).astype(jnp.int32)
    owned = jax.random.bernoulli(k3, 0.7, (5, 4))
    padded = ops.pad_to_lanes(table, pad_lanes=True)
    assert padded.shape[1] % ops.LANES == 0 or D % ops.LANES == 0
    a = ops.masked_sls(table, idx, owned, interpret=True, pad_lanes=True)
    b = ops.masked_sls(table, idx, owned, interpret=True, pad_lanes=False)
    assert a.shape == b.shape == (5, D)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Fused front end: SLS -> dot-interaction in one kernel
# ---------------------------------------------------------------------------


def _fe_inputs(B, G, L, V, D, weighted, quantized, hot_rows=32, seed=0):
    """Random two-tier inputs: every entry is cold-owned, hot, or neither
    (the sharded-engine reality); hot local rows stay in range."""
    ks = jax.random.split(jax.random.PRNGKey(seed + B + G + L + D), 7)
    hot = jax.random.normal(ks[1], (hot_rows, D))
    rows = jax.random.randint(ks[2], (B, G, L), 0, min(V, hot_rows)
                              ).astype(jnp.int32)
    if quantized:
        cold = jax.random.randint(ks[0], (V, D), -127, 128).astype(jnp.int8)
        # page-aligned scale addressing: duplicates of a row share its
        # page's scale (the dedup contract), so derive scales per *row*
        row_scales = jax.random.uniform(ks[5], (V,), minval=1e-4,
                                        maxval=2e-2)
        scales = row_scales[rows]
    else:
        cold = jax.random.normal(ks[0], (V, D))
        scales = None
    tier = jax.random.randint(ks[3], (B, G, L), 0, 3)   # 0=cold 1=hot 2=none
    owned, is_hot = tier == 0, tier == 1
    x = jax.random.normal(ks[4], (B, D))
    w = jax.random.uniform(ks[6], (B, G, L)) if weighted else None
    return cold, hot, x, rows, owned, is_hot, w, scales


@pytest.mark.parametrize("B,G,L,D,block_l,block_b", [
    (8, 2, 8, 16, 8, 4),       # exact tiling
    (8, 4, 7, 32, 3, 8),       # F=5 not a multiple of the sublane tile;
    #                            tail pooling tile
    (4, 2, 5, 16, 4, 32),      # B < block_b (batch tile clamps to B)
    (6, 3, 4, 24, 8, 4),       # odd D, B not a multiple of block_b
    (1, 2, 1, 16, 8, 128),     # degenerate batch
])
@pytest.mark.parametrize("weighted", [False, True])
def test_fused_front_end_kernel_bit_exact(B, G, L, D, block_l, block_b,
                                          weighted):
    """The fused SLS -> interaction kernel must match the split-pipeline
    oracle (fixed-l-order per-tier SLS -> add -> concat -> interaction)
    bit-for-bit in fp32."""
    cold, hot, x, rows, owned, is_hot, w, _ = _fe_inputs(
        B, G, L, 128, D, weighted, quantized=False)
    out = ops.fused_front_end(cold, hot, x, rows, owned, is_hot, w,
                              interpret=True, block_l=block_l,
                              block_b=block_b)
    want = ref.fused_front_end_ref(cold, hot, x, rows, owned, is_hot, w)
    F = G + 1
    assert out.shape == (B, F * (F - 1) // 2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("dedup", [False, True])
def test_fused_front_end_quant_kernel_bit_exact(weighted, dedup):
    """int8 cold tier: the fused kernel's per-row dequant (per-entry or
    gather-once) matches the quantized split oracle bit-for-bit."""
    from repro.core import sls as core_sls
    B, G, L, V, D = 6, 2, 5, 96, 16
    cold, hot, x, rows, owned, is_hot, w, scales = _fe_inputs(
        B, G, L, V, D, weighted, quantized=True)
    plans = None
    if dedup:
        nb = B * G
        cp = core_sls.dedup_plan(rows.reshape(nb, L), owned.reshape(nb, L),
                                 scales.reshape(nb, L))
        hp = core_sls.dedup_plan(rows.reshape(nb, L), is_hot.reshape(nb, L))
        plans = (cp._replace(slots=cp.slots.reshape(B, G, L)),
                 hp._replace(slots=hp.slots.reshape(B, G, L)))
    out = ops.fused_front_end(cold, hot, x, rows, owned, is_hot, w,
                              scales=scales, dedup_plans=plans,
                              interpret=True, block_l=3, block_b=2)
    want = ref.fused_front_end_ref(cold, hot, x, rows, owned, is_hot, w,
                                   scales=scales)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("extreme", ["all_cold", "all_hot", "none"])
def test_fused_front_end_mask_extremes(extreme):
    """Degenerate tier masks: everything cold, everything hot, or nothing
    owned (the pooled features are then all-zero and the interaction is
    x-only) — all bit-exact against the oracle."""
    B, G, L, V, D = 4, 3, 6, 64, 16
    cold, hot, x, rows, _, _, _, _ = _fe_inputs(B, G, L, V, D, False, False)
    full = jnp.ones((B, G, L), bool)
    empty = jnp.zeros((B, G, L), bool)
    owned, is_hot = {"all_cold": (full, empty), "all_hot": (empty, full),
                     "none": (empty, empty)}[extreme]
    out = ops.fused_front_end(cold, hot, x, rows, owned, is_hot,
                              interpret=True, block_l=4, block_b=4)
    want = ref.fused_front_end_ref(cold, hot, x, rows, owned, is_hot)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_fused_front_end_dedup_matches_nondedup_bitwise():
    """The gather-once fused variant only changes where rows come from —
    identical output bits to the per-entry-DMA fused kernel."""
    from repro.core import sls as core_sls
    B, G, L, V, D = 8, 2, 6, 64, 16
    cold, hot, x, rows, owned, is_hot, w, _ = _fe_inputs(
        B, G, L, V, D, True, False)
    a = core_sls.fused_front_end_dense(cold, hot, x, rows, owned, is_hot, w,
                                       impl="pallas", interpret=True,
                                       dedup=False)
    b = core_sls.fused_front_end_dense(cold, hot, x, rows, owned, is_hot, w,
                                       impl="pallas", interpret=True,
                                       dedup=True)
    c = core_sls.fused_front_end_dense(cold, hot, x, rows, owned, is_hot, w,
                                       impl="jnp")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_fused_front_end_lane_padding_is_transparent():
    """D=24 is not lane-aligned: padding the three dense operands must not
    change any output bit (zero lanes add exact +0 to every pairwise dot)."""
    B, G, L, V, D = 4, 2, 5, 64, 24
    cold, hot, x, rows, owned, is_hot, w, _ = _fe_inputs(
        B, G, L, V, D, True, False)
    a = ops.fused_front_end(cold, hot, x, rows, owned, is_hot, w,
                            interpret=True, pad_lanes=True)
    b = ops.fused_front_end(cold, hot, x, rows, owned, is_hot, w,
                            interpret=True, pad_lanes=False)
    assert a.shape == b.shape
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Tensor-parallel fused front end: partial-pool + resume kernel halves
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,G,L,D,block_l,block_b", [
    (8, 2, 8, 16, 8, 4),       # exact tiling
    (8, 4, 7, 32, 3, 8),       # F=5 not a multiple of the sublane tile
    (4, 2, 5, 16, 4, 32),      # B < block_b (batch tile clamps to B)
    (6, 3, 4, 24, 8, 4),       # odd D, B not a multiple of block_b
    (1, 2, 1, 16, 8, 128),     # degenerate batch
])
@pytest.mark.parametrize("weighted", [False, True])
def test_fused_partial_pool_resume_bit_exact(B, G, L, D, block_l, block_b,
                                             weighted):
    """Splitting the fused kernel at the phase-2/3 seam must be free:
    partial-pool -> resume equals the one-kernel fused front end (and the
    split-composition oracle) bit-for-bit, and the tiles themselves match
    the partial-pool oracle — cold row 0 zero, x riding the hot tile."""
    cold, hot, x, rows, owned, is_hot, w, _ = _fe_inputs(
        B, G, L, 128, D, weighted, quantized=False)
    pc, ph = ops.fused_partial_pool(cold, hot, x, rows, owned, is_hot, w,
                                    interpret=True, block_l=block_l,
                                    block_b=block_b)
    rc, rh = ref.fused_partial_pool_ref(cold, hot, x, rows, owned, is_hot, w)
    F = G + 1
    assert pc.shape == ph.shape == (B, F, D)
    np.testing.assert_array_equal(np.asarray(pc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(ph), np.asarray(rh))
    assert not np.asarray(pc)[:, 0, :].any()      # psum-safe cold row 0
    out = ops.fused_resume(pc, ph, interpret=True, block_b=block_b)
    want = ref.fused_front_end_ref(cold, hot, x, rows, owned, is_hot, w)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("dedup", [False, True])
def test_fused_partial_pool_quant_dedup_bit_exact(weighted, dedup):
    """int8 cold tier through the partial-pool half (per-entry or
    gather-once dequant staging): resume of the tiles matches the
    quantized fused oracle bit-for-bit."""
    from repro.core import sls as core_sls
    B, G, L, V, D = 6, 2, 5, 96, 16
    cold, hot, x, rows, owned, is_hot, w, scales = _fe_inputs(
        B, G, L, V, D, weighted, quantized=True)
    pc, ph = core_sls.fused_partial_pool_dense(
        cold, hot, x, rows, owned, is_hot, w, scales=scales, impl="pallas",
        interpret=True, block_l=3, block_b=2, dedup=dedup)
    out = core_sls.fused_resume_dense(pc, ph, impl="pallas", interpret=True,
                                      block_b=2)
    want = ref.fused_front_end_ref(cold, hot, x, rows, owned, is_hot, w,
                                   scales=scales)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_fused_partial_pool_simulated_psum_bit_exact():
    """The tp contract, single-host: split the cold ownership across two
    simulated shards, partial-pool each, sum the cold tiles (the psum),
    resume — bit-identical to the same two-shard composition through the
    oracle.  (Each shard keeps fixed l-order over *its* rows; the split
    path under tp masks identically, which is why engine-level fused_tp
    == split holds bitwise.)  The hot tile comes from one shard only —
    replicated, never reduced."""
    B, G, L, V, D = 8, 3, 6, 64, 16
    cold, hot, x, rows, owned, is_hot, w, _ = _fe_inputs(
        B, G, L, V, D, True, False)
    shard0 = owned & (rows % 2 == 0)
    shard1 = owned & (rows % 2 == 1)
    no_hot = jnp.zeros_like(is_hot)
    c0, h0 = ops.fused_partial_pool(cold, hot, x, rows, shard0, is_hot, w,
                                    interpret=True)
    c1, _ = ops.fused_partial_pool(cold, hot, x, rows, shard1, no_hot, w,
                                   interpret=True)
    out = ops.fused_resume(c0 + c1, h0, interpret=True)   # psum, then resume
    rc0, rh0 = ref.fused_partial_pool_ref(cold, hot, x, rows, shard0,
                                          is_hot, w)
    rc1, _ = ref.fused_partial_pool_ref(cold, hot, x, rows, shard1,
                                        no_hot, w)
    want = ref.fused_resume_ref(rc0 + rc1, rh0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    # and the reduced tile is the full-ownership pool up to reorder only
    full_c, _ = ref.fused_partial_pool_ref(cold, hot, x, rows, owned,
                                           is_hot, w)
    np.testing.assert_allclose(np.asarray(c0 + c1), np.asarray(full_c),
                               rtol=1e-5, atol=1e-6)


def test_fused_partial_pool_lane_padding_is_transparent():
    """D=24 is not lane-aligned: the partial tiles are sliced back to D
    (the collective must ship exactly B*F*D elements) and the resume
    re-pads — no output bit changes anywhere in the composition."""
    B, G, L, V, D = 4, 2, 5, 64, 24
    cold, hot, x, rows, owned, is_hot, w, _ = _fe_inputs(
        B, G, L, V, D, True, False)
    pc_a, ph_a = ops.fused_partial_pool(cold, hot, x, rows, owned, is_hot, w,
                                        interpret=True, pad_lanes=True)
    pc_b, ph_b = ops.fused_partial_pool(cold, hot, x, rows, owned, is_hot, w,
                                        interpret=True, pad_lanes=False)
    assert pc_a.shape == pc_b.shape == (B, G + 1, D)
    np.testing.assert_array_equal(np.asarray(pc_a), np.asarray(pc_b))
    np.testing.assert_array_equal(np.asarray(ph_a), np.asarray(ph_b))
    a = ops.fused_resume(pc_a, ph_a, interpret=True, pad_lanes=True)
    b = ops.fused_resume(pc_b, ph_b, interpret=True, pad_lanes=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_interaction_interpret_default_detects_backend():
    """dot_interaction_pallas defaulted interpret=True forever — on a CPU
    container the None default must resolve to the interpreter (and on TPU
    it would resolve to compiled; here we can only pin the off-TPU leg and
    that an explicit override still threads through)."""
    feats = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 16))
    want = ref.dot_interaction_ref(feats)
    out_default = dot_interaction_pallas(feats)              # None -> detect
    out_forced = dot_interaction_pallas(feats, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_default),
                                  np.asarray(out_forced))
    np.testing.assert_allclose(np.asarray(out_default), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    out_ops = ops.dot_interaction(feats, impl="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(out_ops),
                                  np.asarray(out_default))


@pytest.mark.parametrize("B,F,D", [
    (8, 4, 16), (16, 8, 32), (128, 27, 16), (32, 9, 64),
])
@pytest.mark.parametrize("self_int", [False, True])
def test_interaction_kernel_matches_ref(B, F, D, self_int):
    feats = jax.random.normal(jax.random.PRNGKey(F), (B, F, D))
    out = ops.dot_interaction(feats, self_interaction=self_int,
                              impl="pallas", interpret=True)
    want = ref.dot_interaction_ref(feats, self_interaction=self_int)
    assert out.shape == want.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_interaction_output_size():
    B, F, D = 4, 6, 8
    feats = jnp.ones((B, F, D))
    out = ref.dot_interaction_ref(feats)
    assert out.shape == (B, F * (F - 1) // 2)
    out2 = ref.dot_interaction_ref(feats, self_interaction=True)
    assert out2.shape == (B, F * (F + 1) // 2)
