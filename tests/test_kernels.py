"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.interaction import dot_interaction_pallas
from repro.kernels.sls import sls_pallas


@pytest.mark.parametrize("B,L,V,D", [
    (4, 2, 64, 16),
    (8, 8, 256, 64),
    (16, 4, 1024, 128),
    (3, 5, 100, 32),          # non-power-of-two
    (1, 1, 8, 16),            # degenerate
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sls_kernel_matches_ref(B, L, V, D, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(B * L + V))
    table = jax.random.normal(k1, (V, D), dtype)
    idx = jax.random.randint(k2, (B, L), 0, V).astype(jnp.int32)
    out = sls_pallas(table, idx, interpret=True)
    want = ref.sls_ref(table, idx)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,L,V,D", [(8, 8, 256, 64), (4, 3, 64, 16)])
def test_sls_kernel_weighted(B, L, V, D):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    table = jax.random.normal(k1, (V, D))
    idx = jax.random.randint(k2, (B, L), 0, V).astype(jnp.int32)
    w = jax.random.uniform(k3, (B, L))
    out = sls_pallas(table, idx, w, interpret=True)
    want = ref.sls_ref(table, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("B,F,D", [
    (8, 4, 16), (16, 8, 32), (128, 27, 16), (32, 9, 64),
])
@pytest.mark.parametrize("self_int", [False, True])
def test_interaction_kernel_matches_ref(B, F, D, self_int):
    feats = jax.random.normal(jax.random.PRNGKey(F), (B, F, D))
    out = ops.dot_interaction(feats, self_interaction=self_int,
                              impl="pallas", interpret=True)
    want = ref.dot_interaction_ref(feats, self_interaction=self_int)
    assert out.shape == want.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_interaction_output_size():
    B, F, D = 4, 6, 8
    feats = jnp.ones((B, F, D))
    out = ref.dot_interaction_ref(feats)
    assert out.shape == (B, F * (F - 1) // 2)
    out2 = ref.dot_interaction_ref(feats, self_interaction=True)
    assert out2.shape == (B, F * (F + 1) // 2)
