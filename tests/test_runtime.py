"""Fault tolerance, checkpointing, elasticity, data pipeline."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.pifs import engine_for_tables
from repro.data.pipeline import Prefetcher
from repro.data.synth import lm_batches
from repro.distributed.sharding import make_mesh, shard_map
from repro.optim.compression import compressed_psum, init_error_feedback
from repro.runtime.elastic import remesh_engine, scale_plan, validate_mesh_for
from repro.runtime.fault_tolerance import (FailureInjector, SimulatedFailure,
                                           StragglerWatchdog, run_resilient)


# ---------------------------------------------------------------------------
# Checkpointer
# ---------------------------------------------------------------------------


def _state():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,)), "step": jnp.asarray(7)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    s = _state()
    ck.save(3, s, blocking=True)
    r = ck.restore(s)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ck.save(step, _state(), blocking=True)
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_ignores_partial_tmp(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(), blocking=True)
    # simulate a crash mid-write: orphan tmp dir without manifest
    os.makedirs(tmp_path / "step_000000000002.tmp")
    with open(tmp_path / "step_000000000002.tmp" / "leaf_000000.npy", "w"):
        pass
    assert ck.latest_step() == 1
    ck.restore(_state())  # must not raise


def test_checkpoint_bitflip_corruption_detected_on_restore(tmp_path):
    # flip one byte in a leaf's data region (past the .npy header, so
    # shape/dtype still parse): the per-leaf CRC must catch it loudly
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(), blocking=True)
    leaf = tmp_path / "step_000000000001" / "leaf_000000.npy"
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF
    leaf.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="checksum mismatch"):
        ck.restore(_state())
    ck.restore(_state(), validate=False)  # explicit opt-out still loads


def test_checkpoint_structure_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(), blocking=True)
    with pytest.raises(ValueError):
        ck.restore({"different": jnp.zeros(3)})


def test_checkpoint_roundtrips_quantized_engine_state(tmp_path, mesh):
    """Elastic restore must not drop quantization state: the int8 codes and
    the page_scales leaf round-trip bit-for-bit, and a restored state
    serves bit-identical lookups."""
    eng, _ = engine_for_tables([300, 200], dim=16, mesh=mesh,
                               hot_fraction=0.1, storage="int8")
    state = eng.init_state(jax.random.PRNGKey(0))
    idx = jnp.asarray(np.arange(64).reshape(8, 2, 4) % 300, jnp.int32)
    with mesh:
        st = eng.observe(state, idx)
        st, _ = eng.plan_and_migrate(st)       # a non-trivial placement
        before = np.asarray(eng.lookup(st, idx))
    ck = Checkpointer(str(tmp_path))
    ck.save(1, st, blocking=True)
    restored = ck.restore(st, shardings=eng.state_shardings())
    assert restored.cold.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(restored.page_scales),
                                  np.asarray(st.page_scales))
    np.testing.assert_array_equal(np.asarray(restored.cold),
                                  np.asarray(st.cold))
    with mesh:
        after = np.asarray(eng.lookup(restored, idx))
    np.testing.assert_array_equal(before, after)


def test_checkpoint_storage_mode_mismatch_raises(tmp_path, mesh):
    """Restoring a quantized state into an fp32-storage engine's structure
    must fail loudly (dtype guard), not silently misinterpret codes."""
    eng8, _ = engine_for_tables([300, 200], dim=16, mesh=mesh,
                                hot_fraction=0.1, storage="int8")
    st8 = eng8.init_state(jax.random.PRNGKey(0))
    ck = Checkpointer(str(tmp_path))
    ck.save(1, st8, blocking=True)
    eng32, _ = engine_for_tables([300, 200], dim=16, mesh=mesh,
                                 hot_fraction=0.1)
    st32 = eng32.init_state(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="dtype|shape"):
        ck.restore(st32)


def test_checkpoint_elastic_restore_across_meshes(tmp_path):
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices")
    from jax.sharding import NamedSharding, PartitionSpec as P
    m1 = make_mesh((2, 4), ("data", "model"))
    m2 = make_mesh((4, 2), ("data", "model"))
    ck = Checkpointer(str(tmp_path))
    x = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                       NamedSharding(m1, P("model", None)))
    ck.save(1, {"x": x}, blocking=True)
    r = ck.restore({"x": x},
                   shardings={"x": NamedSharding(m2, P("model", None))})
    np.testing.assert_array_equal(np.asarray(r["x"]), np.asarray(x))
    assert r["x"].sharding.mesh.shape["model"] == 2


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------


def test_run_resilient_survives_failures(tmp_path):
    ck = Checkpointer(str(tmp_path))
    calls = []

    def step(s, batch):
        calls.append(int(s["i"]))
        return {"i": s["i"] + 1}, {"loss": 1.0}

    inj = FailureInjector(fail_at_steps=(4, 11))
    rep = run_resilient(step, {"i": jnp.asarray(0)}, lambda i: None, 15, ck,
                        ckpt_every=5, injector=inj)
    assert rep.steps_done == 15
    assert rep.restarts == 2
    final = ck.restore({"i": jnp.asarray(0)})
    assert int(final["i"]) == 15


def test_run_resilient_gives_up_after_max_restarts(tmp_path):
    ck = Checkpointer(str(tmp_path))

    def step(s, batch):
        return s, {}

    # fails at step 0 forever (checkpoint never advances past it)
    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            raise SimulatedFailure("always")

    with pytest.raises(SimulatedFailure):
        run_resilient(step, {"i": jnp.asarray(0)}, lambda i: None, 5, ck,
                      injector=AlwaysFail(), max_restarts=3)


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(alpha=0.5, threshold=2.0, warmup=2)
    for i in range(6):
        wd.observe(i, 0.10)
    assert wd.observe(6, 0.50)       # 5x the EWMA -> straggler
    assert len(wd.events) == 1
    # the straggler must not poison the baseline
    assert wd.ewma < 0.2


# ---------------------------------------------------------------------------
# Elasticity
# ---------------------------------------------------------------------------


def test_scale_plan_prefers_tp():
    assert scale_plan(256) == ((16, 16), ("data", "model"))
    assert scale_plan(192) == ((12, 16), ("data", "model"))
    assert scale_plan(24, prefer_tp=16) == ((3, 8), ("data", "model"))


def test_scale_plan_edge_cases():
    # odd survivor counts (a shard loss rarely leaves a power of two),
    # prime counts (tp collapses to 1), non-power-of-two prefer_tp
    assert scale_plan(6, prefer_tp=4) == ((3, 2), ("data", "model"))
    assert scale_plan(7, prefer_tp=16) == ((7, 1), ("data", "model"))
    assert scale_plan(10, prefer_tp=12) == ((10, 1), ("data", "model"))
    assert scale_plan(9, prefer_tp=6) == ((3, 3), ("data", "model"))
    assert scale_plan(1) == ((1, 1), ("data", "model"))


def test_scale_plan_batch_granule_shrinks_used_devices():
    """Serving constraint: dp shards bucket-shaped micro-batches, so dp
    must divide the bucket batch granule.  6 survivors against
    power-of-two buckets idles devices rather than building a mesh the
    serve step cannot shard over."""
    assert scale_plan(6, prefer_tp=2,
                      batch_granule=8) == ((2, 2), ("data", "model"))
    assert scale_plan(6, prefer_tp=4,
                      batch_granule=8) == ((1, 4), ("data", "model"))
    # already-compatible plans are untouched by the constraint
    assert scale_plan(8, prefer_tp=2,
                      batch_granule=8) == ((4, 2), ("data", "model"))


def test_validate_mesh_divisibility():
    validate_mesh_for((16, 16), ("data", "model"),
                      {"data": 256, "model": 4096})
    with pytest.raises(ValueError):
        validate_mesh_for((16, 16), ("data", "model"), {"model": 100})


def test_remesh_engine_preserves_table(mesh):
    """Scale tp 4 -> 2: every row must survive the re-shard byte-for-byte."""
    m2 = make_mesh((4, 2), ("data", "model"))
    eng, _ = engine_for_tables([200], dim=8, mesh=mesh, hot_fraction=0.05)
    state = eng.init_state(jax.random.PRNGKey(0))
    dense_before = np.asarray(eng.to_dense(state))
    eng2, state2 = remesh_engine(eng, m2, state)
    dense_after = np.asarray(eng2.to_dense(state2))
    np.testing.assert_allclose(dense_before, dense_after, rtol=0, atol=0)
    assert eng2.cfg.n_shards == 2


@pytest.mark.parametrize("storage", ["fp32", "int8"])
def test_remesh_roundtrip_bitwise_identity(mesh, storage):
    """tp 4 -> 2 -> 4: the logical (codes, values, scales) triple is
    bitwise the identity after the round trip.  For int8 that means the
    re-mesh moved cold pages in the *quantized* domain — codes and the
    carried per-page scales verbatim — never through a dequantize /
    requantize cycle (which would drift one code per trip)."""
    m2 = make_mesh((4, 2), ("data", "model"))
    eng, _ = engine_for_tables([300, 200], dim=16, mesh=mesh,
                               hot_fraction=0.1, storage=storage)
    state = eng.init_state(jax.random.PRNGKey(0))
    idx = jnp.asarray(np.arange(64).reshape(8, 2, 4) % 300, jnp.int32)
    with mesh:
        state = eng.observe(state, idx)
        state, _ = eng.plan_and_migrate(state)     # non-trivial placement
    before = [np.asarray(jax.device_get(x))
              for x in eng.export_state(state)]
    eng2, st2 = remesh_engine(eng, m2, state)
    eng3, st3 = remesh_engine(eng2, mesh, st2)
    after = [np.asarray(jax.device_get(x))
             for x in eng3.export_state(st3)]
    for a, b in zip(before, after):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)
    if storage == "int8":
        assert st3.cold.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(st3.page_scales),
                                      np.asarray(state.page_scales))


@pytest.mark.parametrize("storage", ["fp32", "int8"])
@pytest.mark.parametrize("target", [(4, 2), (8, 1)])
def test_remesh_lookup_matches_fresh_engine(mesh, storage, target):
    """Property sweep {storage} x {tp 4 -> 2, tp -> 1 collapse}: a
    re-meshed engine must be indistinguishable from a fresh engine on the
    target mesh packed from the same logical triple and the same page
    table — lookups bit-equal."""
    mt = make_mesh(target, ("data", "model"))
    eng, _ = engine_for_tables([300, 200], dim=16, mesh=mesh,
                               hot_fraction=0.1, storage=storage)
    state = eng.init_state(jax.random.PRNGKey(1))
    idx = jnp.asarray((np.arange(96).reshape(8, 3, 4) * 7) % 500,
                      jnp.int32)
    with mesh:
        state = eng.observe(state, idx)
        state, _ = eng.plan_and_migrate(state)
    codes, values, scales = eng.export_state(state)
    eng2, st2 = remesh_engine(eng, mt, state)
    fresh, _ = engine_for_tables([300, 200], dim=16, mesh=mt,
                                 hot_fraction=0.1, storage=storage)
    fresh_state = fresh.pack_state(
        codes, values, scales, table=st2.page_table,
        counts=np.asarray(jax.device_get(state.counts)))
    with mt:
        a = np.asarray(eng2.lookup(st2, idx))
        b = np.asarray(fresh.lookup(fresh_state, idx))
    np.testing.assert_array_equal(a, b)
    assert eng2.cfg.n_shards == target[1]


# ---------------------------------------------------------------------------
# Pipeline + compression
# ---------------------------------------------------------------------------


def test_prefetcher_preserves_order():
    it = iter(range(20))
    pf = Prefetcher(({"x": np.asarray([i])} for i in range(20)), depth=4)
    got = [int(b["x"][0]) for b in pf]
    assert got == list(range(20))


def test_compressed_psum_bf16_and_int8(mesh):
    from jax.sharding import PartitionSpec as P
    g = {"w": jnp.linspace(-1, 1, 64).reshape(8, 8)}

    def block(gl):
        red_none, _ = compressed_psum(gl, ("data",), "none")
        red_bf16, _ = compressed_psum(gl, ("data",), "bf16")
        red_int8, _ = compressed_psum(gl, ("data",), "int8",
                                      error_fb=jax.tree.map(jnp.zeros_like, gl))
        return red_none, red_bf16, red_int8

    with mesh:
        f = shard_map(block, mesh=mesh,
                          in_specs=({"w": P()},),
                          out_specs=({"w": P()},) * 3, check_vma=False)
        none, bf16, int8 = f(g)
    want = np.asarray(g["w"]) * 2  # data axis size 2
    np.testing.assert_allclose(np.asarray(none["w"]), want, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(bf16["w"]), want, rtol=2e-2,
                               atol=2e-2)
    np.testing.assert_allclose(np.asarray(int8["w"]), want, rtol=0.1,
                               atol=0.1)


def test_lm_data_learnable_structure():
    from repro.configs import get_config, reduced
    cfg = reduced(get_config("llama3.2-3b"))
    b = next(lm_batches(cfg, 8, 32, 1))
    # ~25% of positions copy t-2: verify the injected structure exists
    toks = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
    rep = (toks[:, 2:] == toks[:, :-2]).mean()
    assert rep > 0.15
