"""Silent-corruption detection + page-granular self-healing.

Pins the per-page checksum ledger (``repro.core.integrity``): host/device
checksum bit-identity, incremental consistency across every mutation path
(delta apply, replan migration, requant snaps, elastic re-mesh — the
hypothesis sweep interleaves them randomly), detection of finite bit
flips the NaN score scrub is structurally blind to, and the snapshot +
WAL-replay repair path restoring the store bit-identically to a
never-corrupted engine.  Plus the serving-seam accounting contract:
scrub wall time is maintenance, never service latency.
"""
import os

import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.wal import WriteAheadLog
from repro.core.integrity import (PageChecksumLedger, fetch_snapshot_page,
                                  page_checksum_host)
from repro.core.paging import HOT_SHARD
from repro.serving import (DegradationController, FixedBatcher,
                           FixedServiceModel, OpenLoopSource, Request,
                           RuntimeConfig, ScrubConfig, ScrubController,
                           ServingMetrics, ServingRuntime,
                           SimulatedExecutor, bind_model, corrupt_store,
                           flip_store_bits)


@pytest.fixture(scope="module")
def rmc1():
    from repro.configs import get_config, reduced
    return reduced(get_config("rmc1"))


def _dlrm_batch(cfg, B=8, seed=0):
    rng = np.random.default_rng(seed)
    return {"dense": rng.normal(size=(B, cfg.n_dense)).astype(np.float32),
            "indices": rng.integers(0, cfg.emb_num,
                                    (B, cfg.n_tables, cfg.pooling)
                                    ).astype(np.int32)}


def _promote_hot(binding, cfg, seed=0):
    """Observe a skewed stream and replan so some pages land hot."""
    dp = max(1, binding.engine.axes.dp_size(binding.engine.mesh))
    idx = _dlrm_batch(cfg, B=8, seed=seed)["indices"] % 64
    binding.observe({binding.idx_key:
                     np.broadcast_to(idx[None], (dp,) + idx.shape)})
    binding.replan()
    p2s = np.asarray(binding.state.page_to_shard)
    return np.nonzero(p2s == HOT_SHARD)[0]


def _page_rows_host(binding, page):
    """A page's native-domain rows + scale pulled from host copies of the
    live leaves — the independent reference the ledger must agree with."""
    eng = binding.engine
    ps = eng.cfg.page_size
    p2s = np.asarray(binding.state.page_to_shard)
    p2slot = np.asarray(binding.state.page_to_slot)
    scale = float(np.asarray(binding.state.page_scales)[page])
    if p2s[page] == HOT_SHARD:
        hot = np.asarray(binding.state.hot)
        slot = int(p2slot[page])
        return hot[slot * ps:(slot + 1) * ps], scale
    cold = np.asarray(binding.state.cold)
    start = int(p2s[page]) * eng.cfg.rows_per_shard + int(p2slot[page]) * ps
    return cold[start:start + ps], scale


def _state_leaves(binding):
    st = binding.state
    return [np.asarray(x) for x in (st.cold, st.hot, st.page_scales,
                                    st.page_to_shard, st.page_to_slot)]


# ---------------------------------------------------------------------------
# Checksum definition: host twin == device reduction, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("storage", ["fp32", "int8"])
def test_host_checksum_matches_device_both_tiers(mesh, rmc1, storage):
    binding = bind_model(rmc1, mesh, storage=storage)
    with mesh:
        hot_pages = _promote_hot(binding, rmc1)
        assert hot_pages.size > 0
        binding.attach_integrity()
        ledger = binding.integrity
        # every legitimate path updated the ledger (here: build time), so
        # a full audit is clean
        assert ledger.verify(binding.state).size == 0
        for page in range(binding.engine.cfg.num_pages):
            rows, scale = _page_rows_host(binding, page)
            assert page_checksum_host(rows, scale) == \
                int(ledger.checksums[page]), f"page {page}"


def test_host_checksum_rejects_unsupported_dtype():
    with pytest.raises(TypeError, match="int8 codes or fp32"):
        page_checksum_host(np.zeros((4, 4), np.float64), 1.0)


def test_checksum_position_weighted_catches_row_swap():
    """The Fletcher s2 term: swapped rows change the checksum even though
    the lane *sum* is identical — a sum-only checksum would miss it."""
    rows = np.arange(32, dtype=np.float32).reshape(8, 4)
    swapped = rows.copy()
    swapped[[0, 1]] = swapped[[1, 0]]
    assert page_checksum_host(rows, 1.0) != page_checksum_host(swapped, 1.0)
    # while the unweighted lane sums agree
    assert rows.view(np.uint32).sum() == swapped.view(np.uint32).sum()


# ---------------------------------------------------------------------------
# Detection: finite flips are invisible to the score scrub, caught by audit
# ---------------------------------------------------------------------------


def test_finite_flip_evades_score_scrub_but_not_ledger(mesh, rmc1):
    binding = bind_model(rmc1, mesh, scrub_scores=True)
    batch = _dlrm_batch(rmc1)
    with mesh:
        _promote_hot(binding, rmc1)
        binding.attach_integrity()
        flipped = flip_store_bits(binding, n_rows=3, seed=11, tier="both")
        scores = np.asarray(binding.execute(batch))
        # wrong-but-finite scores sail through the NaN/Inf scrub
        assert np.isfinite(scores).all()
        assert binding.last_poisoned == 0 and binding.poisoned_rows == 0
        # ...while one checksum audit names exactly the flipped pages
        bad = binding.integrity.verify(binding.state)
        assert sorted(int(p) for p in bad) == flipped


def test_corrupt_store_finite_mode_and_mode_validation(mesh, rmc1):
    binding = bind_model(rmc1, mesh, scrub_scores=True)
    batch = _dlrm_batch(rmc1)
    with mesh:
        hot_pages = _promote_hot(binding, rmc1)
        binding.attach_integrity()
        with pytest.raises(ValueError, match="unknown corrupt_store mode"):
            corrupt_store(binding, frac=0.5, seed=2, mode="bogus")
        n = corrupt_store(binding, frac=0.5, seed=2, mode="finite")
        assert n > 0
        assert np.isfinite(np.asarray(binding.state.hot)).all()
        scores = np.asarray(binding.execute(batch))
        assert np.isfinite(scores).all() and binding.last_poisoned == 0
        bad = binding.integrity.verify(binding.state)
        assert bad.size > 0
        assert set(int(p) for p in bad) <= set(int(p) for p in hot_pages)


def test_scrub_controller_requires_armed_ledger(mesh, rmc1):
    binding = bind_model(rmc1, mesh)
    with pytest.raises(RuntimeError, match="attach_integrity"):
        ScrubController(binding)


# ---------------------------------------------------------------------------
# Rotating window: full coverage within one sweep, detection bounded by it
# ---------------------------------------------------------------------------


def test_rotating_window_detects_within_one_sweep(mesh, rmc1):
    binding = bind_model(rmc1, mesh)
    with mesh:
        _promote_hot(binding, rmc1)
        binding.attach_integrity()
        n = int(binding.engine.cfg.num_pages)
        k = max(1, n // 4)
        scrub = ScrubController(binding,
                                ScrubConfig(pages_per_cycle=k, repair=False))
        flipped = flip_store_bits(binding, n_rows=3, seed=5, tier="both")
        m = ServingMetrics()
        sweep = -(-n // k)
        for _ in range(sweep):
            scrub.on_batch(0.0, m)
        rep = scrub.report()
        assert rep["sweep_cycles"] == sweep
        assert rep["coverage"] == 1.0 and rep["pages_audited"] == sweep * k
        # every flipped page found inside the first full sweep, and (no
        # repair path armed) left quarantined
        assert sorted(rep["detections"]) == flipped
        assert all(c <= sweep for c in rep["detections"].values())
        assert rep["quarantined"] == flipped and rep["pages_repaired"] == 0
        s = m.summary()
        assert s["scrub"]["cycles"] == sweep
        assert s["scrub"]["pages_detected"] == len(flipped)
        assert s["scrub"]["pages_repaired"] == 0
    # runs without a scrubber keep the exact legacy summary shape
    assert "scrub" not in ServingMetrics().summary()


# ---------------------------------------------------------------------------
# Repair: snapshot page + filtered WAL replay == never-corrupted, bitwise
# ---------------------------------------------------------------------------


def _arm_full(binding, cfg, tmp_path):
    """Hot tier + ledger + snapshot (with ledger) + a WAL-logged delta
    tail past the snapshot touching every page."""
    _promote_hot(binding, cfg)
    binding.attach_integrity()
    binding.attach_wal(WriteAheadLog(os.path.join(str(tmp_path), "t.wal")))
    binding.attach_checkpointer(Checkpointer(str(tmp_path)), save_now=True)
    eng = binding.engine
    n_pages, ps, d = eng.cfg.num_pages, eng.cfg.page_size, eng.cfg.dim
    rng = np.random.default_rng(23)
    rows = (np.arange(n_pages, dtype=np.int64) * ps
            + rng.integers(0, ps, size=n_pages))
    deltas = (1e-3 * rng.standard_normal((n_pages, d))).astype(np.float32)
    binding.apply_deltas(rows, deltas)
    assert len(binding.wal) > 0


@pytest.mark.parametrize("storage", ["fp32", "int8"])
def test_repair_restores_bit_identical_state(mesh, rmc1, storage, tmp_path):
    binding = bind_model(rmc1, mesh, storage=storage)
    batch = _dlrm_batch(rmc1)
    with mesh:
        _arm_full(binding, rmc1, tmp_path)
        truth_scores = np.asarray(binding.execute(batch))
        truth_leaves = _state_leaves(binding)
        n = int(binding.engine.cfg.num_pages)
        scrub = ScrubController(binding, ScrubConfig(pages_per_cycle=n))
        scrub.warmup()
        # warmup compiles through all-pad windows/pages: state untouched
        for a, b in zip(truth_leaves, _state_leaves(binding)):
            np.testing.assert_array_equal(a, b)
        flipped = flip_store_bits(binding, n_rows=3, seed=7, tier="both")
        scrub.on_batch(0.0)                     # one full-store audit
        rep = scrub.report()
        assert sorted(rep["detections"]) == flipped
        assert rep["pages_repaired"] == len(flipped)
        assert rep["quarantined"] == []
        # every repair replayed the WAL tail (one record per page landed
        # after the snapshot) and clocked a positive MTTR
        assert all(r["wal_batches"] >= 1 and r["mttr_s"] > 0.0
                   for r in rep["repairs"])
        for a, b in zip(truth_leaves, _state_leaves(binding)):
            np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(truth_scores,
                                      np.asarray(binding.execute(batch)))
        assert binding.integrity.verify(binding.state).size == 0


def test_repaired_equals_fresh_property_over_flip_seeds(mesh, rmc1,
                                                        tmp_path):
    """Repaired-equals-fresh as a property: any seeded flip pattern, once
    scrubbed, leaves the store bitwise equal to the never-corrupted
    truth — so successive rounds always start from the same state."""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    binding = bind_model(rmc1, mesh, storage="int8")
    with mesh:
        _arm_full(binding, rmc1, tmp_path)
        truth_leaves = _state_leaves(binding)
    n = int(binding.engine.cfg.num_pages)

    @settings(deadline=None, max_examples=8,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 2 ** 16), n_rows=st.integers(1, 4),
           tier=st.sampled_from(["hot", "cold", "both"]))
    def prop(seed, n_rows, tier):
        with mesh:
            flipped = flip_store_bits(binding, n_rows=n_rows, seed=seed,
                                      tier=tier)
            scrub = ScrubController(binding,
                                    ScrubConfig(pages_per_cycle=n))
            scrub.on_batch(0.0)
            rep = scrub.report()
            assert sorted(rep["detections"]) == flipped
            assert rep["pages_repaired"] == len(flipped)
            for a, b in zip(truth_leaves, _state_leaves(binding)):
                np.testing.assert_array_equal(a, b)

    prop()


# ---------------------------------------------------------------------------
# Invariance: the ledger tracks every legitimate mutation path
# ---------------------------------------------------------------------------

_prop_bindings: dict = {}


def _shared_binding(rmc1, mesh, storage):
    if storage not in _prop_bindings:
        b = bind_model(rmc1, mesh, storage=storage)
        with mesh:
            _promote_hot(b, rmc1)
            b.attach_integrity()
        _prop_bindings[storage] = b
    return _prop_bindings[storage]


@pytest.mark.parametrize("storage", ["fp32", "int8"])
def test_ledger_invariant_under_interleaved_mutations(mesh, rmc1, storage):
    """Hypothesis sweep: random interleavings of delta application,
    observe/replan migration, and hot-page requant snaps accumulate on a
    shared live binding — after every op the full audit must be clean
    (every mutation path kept the ledger consistent incrementally)."""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    binding = _shared_binding(rmc1, mesh, storage)
    eng = binding.engine
    total, d = eng.cfg.total_rows, eng.cfg.dim

    def apply_op(rng):
        rows = rng.integers(0, total, size=16).astype(np.int64)
        deltas = (1e-3 * rng.standard_normal((16, d))).astype(np.float32)
        binding.apply_deltas(rows, deltas)

    def migrate_op(rng):
        dp = max(1, eng.axes.dp_size(eng.mesh))
        idx = rng.integers(0, rmc1.emb_num,
                           (8, rmc1.n_tables, rmc1.pooling)
                           ).astype(np.int32) % int(rng.integers(32, 256))
        binding.observe({binding.idx_key:
                         np.broadcast_to(idx[None], (dp,) + idx.shape)})
        binding.replan()

    def requant_op(rng):
        p2s = np.asarray(binding.state.page_to_shard)
        hot = np.nonzero(p2s == HOT_SHARD)[0]
        if hot.size:
            binding.requant_hot_pages(hot[:2].astype(np.int32))

    ops = {"apply": apply_op, "migrate": migrate_op, "requant": requant_op}

    @settings(deadline=None, max_examples=6,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seq=st.lists(st.sampled_from(sorted(ops)), min_size=1,
                        max_size=4),
           seed=st.integers(0, 2 ** 16))
    def prop(seq, seed):
        rng = np.random.default_rng(seed)
        with mesh:
            for name in seq:
                ops[name](rng)
                assert binding.integrity.verify(binding.state).size == 0, \
                    f"ledger diverged after {name} in {seq}"

    prop()


@pytest.mark.parametrize("storage", ["fp32", "int8"])
def test_ledger_survives_elastic_remesh(mesh, rmc1, storage):
    """Interleave a mid-sequence re-mesh with the other mutation paths:
    page geometry is shard-count-invariant, so the ledger carries across
    the survivor mesh verbatim (tier-flipped pages recomputed) and stays
    consistent for mutations on the new mesh."""
    binding = bind_model(rmc1, mesh, storage=storage, elastic=True,
                         prefer_tp=2)
    eng_cfg = binding.engine.cfg
    rng = np.random.default_rng(3)
    with mesh:
        _promote_hot(binding, rmc1)
        binding.attach_integrity()
        before = binding.integrity.checksums.copy()
        rows = rng.integers(0, eng_cfg.total_rows, size=16).astype(np.int64)
        deltas = (1e-3 * rng.standard_normal(
            (16, eng_cfg.dim))).astype(np.float32)
        binding.apply_deltas(rows, deltas)
        assert binding.integrity.verify(binding.state).size == 0

        old_p2s = np.asarray(binding.state.page_to_shard)
        binding.remesh(lost_shard=3)
        assert dict(binding.engine.mesh.shape)["model"] == 2
        # rebind carried the ledger onto the re-meshed engine...
        assert binding.integrity.engine is binding.engine
        assert binding.integrity.verify(binding.state).size == 0
        # ...and pages that kept their tier kept their checksum verbatim
        new_p2s = np.asarray(binding.state.page_to_shard)
        kept = ((old_p2s == HOT_SHARD) == (new_p2s == HOT_SHARD))
        touched = np.unique(rows // eng_cfg.page_size)
        stable = np.setdiff1d(np.nonzero(kept)[0], touched)
        np.testing.assert_array_equal(binding.integrity.checksums[stable],
                                      before[stable])

        # the survivor mesh keeps the invariant under further mutations
        binding.apply_deltas(rows, deltas)
        assert binding.integrity.verify(binding.state).size == 0
        # and a flip on the survivor mesh is still detected
        flipped = flip_store_bits(binding, n_rows=2, seed=9, tier="cold")
        bad = binding.integrity.verify(binding.state)
        assert sorted(int(p) for p in bad) == flipped


def test_ledger_rebind_rejects_geometry_change(mesh, rmc1):
    binding = bind_model(rmc1, mesh)
    with mesh:
        binding.attach_integrity()

    class _FakeCfg:
        num_pages = binding.engine.cfg.num_pages + 1

    class _FakeEngine:
        cfg = _FakeCfg()

    with pytest.raises(ValueError, match="page-geometry change"):
        binding.integrity.rebind(_FakeEngine())


# ---------------------------------------------------------------------------
# Snapshot plumbing: partial page reads + the ledger in the manifest
# ---------------------------------------------------------------------------


def test_checkpointer_partial_reads_and_snapshot_ledger(mesh, rmc1,
                                                        tmp_path):
    binding = bind_model(rmc1, mesh, storage="int8")
    with mesh:
        hot_pages = _promote_hot(binding, rmc1)
        binding.attach_integrity()
        binding.attach_checkpointer(Checkpointer(str(tmp_path)),
                                    save_now=True)
    ck = binding.checkpointer
    eng = binding.engine
    ps = eng.cfg.page_size

    # partial reads slice exactly out of the full leaf, through one mmap
    cold = ck.read_leaf("cold")
    np.testing.assert_array_equal(ck.read_page("cold", ps, ps),
                                  cold[ps:2 * ps])
    spans = [(0, ps), (3 * ps, 2 * ps)]
    got = ck.read_pages("cold", spans)
    np.testing.assert_array_equal(got[0], cold[:ps])
    np.testing.assert_array_equal(got[1], cold[3 * ps:5 * ps])
    with pytest.raises(KeyError):
        ck.read_page("no_such_leaf", 0, ps)

    # the manifest carries the snapshot-time ledger, one entry per page
    rec = ck.extra().get("page_checksums")
    assert rec is not None
    assert len(rec["checksums"]) == eng.cfg.num_pages

    # fetch_snapshot_page host-verifies for both tiers
    cold_pages = np.setdiff1d(np.arange(eng.cfg.num_pages), hot_pages)
    for page in (int(hot_pages[0]), int(cold_pages[0])):
        snap = fetch_snapshot_page(ck, eng.cfg, page)
        assert snap["checksum"] is not None
        assert page_checksum_host(snap["rows"], snap["scale"]) == \
            snap["checksum"]
        assert snap["checksum"] == int(binding.integrity.checksums[page])
    assert snap["tier"] == "cold"


def test_ledger_export_load_roundtrip_and_size_guard(mesh, rmc1):
    binding = bind_model(rmc1, mesh)
    with mesh:
        binding.attach_integrity()
    ledger = binding.integrity
    data = ledger.export()
    fresh = PageChecksumLedger(binding.engine)
    fresh.load(data)
    np.testing.assert_array_equal(fresh.checksums, ledger.checksums)
    with pytest.raises(ValueError, match="size mismatch"):
        fresh.load({"checksums": data["checksums"][:-1]})


# ---------------------------------------------------------------------------
# Serving-seam accounting + degradation coupling
# ---------------------------------------------------------------------------


def test_scrub_time_is_maintenance_never_latency(mesh, rmc1):
    """Scrub wall time lands in maintenance_s['scrub'] and never moves a
    latency percentile: two identical virtual-clock runs, one with the
    scrubber armed, must report bitwise-equal latency numbers."""
    binding = bind_model(rmc1, mesh)
    with mesh:
        binding.attach_integrity()
    model = FixedServiceModel(base_s=2e-3, per_row_s=0.0)
    cfg = RuntimeConfig(observe_every=0, replan_every=0)
    assert cfg.account_maintenance is False

    def run(scrubber):
        rt = ServingRuntime(
            SimulatedExecutor(model), FixedBatcher(batch=4, pooling=4),
            padder=lambda reqs, bucket: {"n": len(reqs)}, cfg=cfg,
            service_model=model, scrubber=scrubber)
        reqs = [Request(rid=i, arrival_s=1e-3 * i, deadline_s=10.0,
                        features={}, pooling=4) for i in range(32)]
        with mesh:
            return rt.run(OpenLoopSource(reqs))

    plain = run(None)
    scrub = ScrubController(binding, ScrubConfig(pages_per_cycle=4,
                                                 repair=False))
    scrubbed = run(scrub)
    assert "scrub" not in plain["maintenance_s"]
    assert scrubbed["maintenance_s"]["scrub"] > 0.0
    assert scrubbed["scrub_run"]["cycles"] == scrub.cycles > 0
    assert scrubbed["scrub"]["pages_detected"] == 0      # clean store
    for k in ("p50_ms", "p99_ms", "served", "qps", "availability"):
        assert plain[k] == scrubbed[k], k


def test_on_corruption_matches_straggler_half_weight():
    a = DegradationController()
    b = DegradationController()
    a.on_straggler(0.0)
    b.on_corruption(0.0)
    assert b.pressure == a.pressure > 0.0
    assert b.corruption_trips == 1 and a.corruption_trips == 0
    assert b.report()["corruption_trips"] == 1
