"""Deadline-aware dynamic micro-batcher over a fixed set of shape buckets.

The engine's compiled-lookup plan cache (core/pifs.py) makes lookups free
of retraces *per input signature*; serving therefore coalesces queued
requests into a small closed set of ``(batch, pooling)`` buckets and pads
every micro-batch up to its bucket, so the whole serving lifetime touches
exactly ``len(buckets)`` signatures — zero steady-state retraces across
the bucket set (warmed once at startup).

Padding is exact, not approximate:

  * pooling axis — a bag with ``L_r < bucket.pooling`` entries repeats its
    first row id with SLS weight 0, so the padded lookup is bit-identical
    to the unpadded one (weight-0 entries contribute exactly zero in both
    the jnp and Pallas datapaths) and the access profiler only ever sees
    ids the request actually touched;
  * batch axis — missing rows replicate request 0 with all-zero weights;
    their scores are discarded by the runtime.

The coalescing policy is deliberately deterministic (a pure function of
the queue view, the clock, and the service-time model) so decisions can be
replay-tested under a fixed seed:

  flush now  iff  the bucket is full, the stream has drained, or waiting
  any longer would push the head-of-line request past its flush-by time;
  otherwise sleep until the earliest of those times or the next arrival.

The flush-by time is **load-adaptive**.  The deadline bound
``head.deadline - est_service(bucket) - safety`` always applies; the
eager ``head.arrival + max_wait`` bound applies only while the arrival
rate (estimated from the arrival stamps already sitting in the queue —
no extra state) says small-batch flushing is sustainable
(``rate * est_service(smallest bucket) / smallest_batch <
early_flush_util``).  Without that guard, marginal load degenerates into
permanent minimum-size flushes: the head is always past ``max_wait`` by
the time the server frees, so the batcher never grows its buckets and
saturates at the small bucket's capacity.  With it, low load gets the
short-wait tail, and rising load smoothly shifts batches larger until
only the deadline forces a flush.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True, order=True)
class Bucket:
    """One compiled micro-batch signature: padded batch x padded pooling."""
    batch: int
    pooling: int


@dataclasses.dataclass(frozen=True)
class Flush:
    """Serve the first ``count`` queued requests, padded to ``bucket``."""
    bucket: Bucket
    count: int


@dataclasses.dataclass(frozen=True)
class Wait:
    """Idle until ``until`` (the runtime wakes earlier on a new arrival)."""
    until: float


Decision = object  # Flush | Wait | None


class ServiceModel:
    """Per-bucket service-time estimate: EMA over measured executions,
    seeded by the warmup measurement.  The estimate feeds the batcher's
    can-we-afford-to-wait computation."""

    def __init__(self, prior_s: float = 5e-3, alpha: float = 0.25):
        self.prior_s = prior_s
        self.alpha = alpha
        self._est: Dict[Bucket, float] = {}

    def estimate(self, bucket: Bucket) -> float:
        return self._est.get(bucket, self.prior_s)

    def update(self, bucket: Bucket, measured_s: float) -> None:
        old = self._est.get(bucket)
        self._est[bucket] = (measured_s if old is None
                             else old + self.alpha * (measured_s - old))


class FixedServiceModel(ServiceModel):
    """Deterministic affine service model for replay tests and simulation:
    ``base_s + per_row_s * bucket.batch`` — never updated by measurements."""

    def __init__(self, base_s: float = 2e-3, per_row_s: float = 1e-4):
        super().__init__()
        self.base_s = base_s
        self.per_row_s = per_row_s

    def estimate(self, bucket: Bucket) -> float:
        return self.base_s + self.per_row_s * bucket.batch

    def update(self, bucket: Bucket, measured_s: float) -> None:
        pass


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    batch_sizes: Tuple[int, ...] = (8, 16, 32)   # ascending, mesh-divisible
    poolings: Tuple[int, ...] = (8,)             # ascending pooling levels
    safety_ms: float = 1.0       # slack reserved before the deadline flush
    max_wait_ms: float = 25.0    # eager cap on head-of-line coalescing wait
    # eager max_wait flushing is allowed only while
    # rate * est(smallest bucket) / smallest_batch stays below this
    early_flush_util: float = 0.5

    def __post_init__(self):
        if tuple(sorted(self.batch_sizes)) != self.batch_sizes or \
                not self.batch_sizes:
            raise ValueError("batch_sizes must be non-empty ascending")
        if tuple(sorted(self.poolings)) != self.poolings or not self.poolings:
            raise ValueError("poolings must be non-empty ascending")

    def buckets(self) -> List[Bucket]:
        return [Bucket(b, l) for b in self.batch_sizes for l in self.poolings]


class DynamicBatcher:
    """Deadline-aware coalescing over the bucket set (see module docstring)."""

    def __init__(self, cfg: BatcherConfig):
        self.cfg = cfg

    def buckets(self) -> List[Bucket]:
        return self.cfg.buckets()

    def _pooling_level(self, reqs: Sequence[Request]) -> int:
        need = max(r.pooling for r in reqs)
        for l in self.cfg.poolings:
            if l >= need:
                return l
        raise ValueError(
            f"request pooling {need} exceeds largest bucket pooling "
            f"{self.cfg.poolings[-1]}")

    def _batch_size(self, n: int) -> int:
        for b in self.cfg.batch_sizes:
            if b >= n:
                return b
        return self.cfg.batch_sizes[-1]

    def decide(self, now: float, queued: Sequence[Request],
               next_arrival: Optional[float],
               service: ServiceModel) -> Decision:
        if not queued:
            return None
        b_max = self.cfg.batch_sizes[-1]
        cand = queued[:b_max]
        bucket = Bucket(self._batch_size(len(cand)),
                        self._pooling_level(cand))
        if len(cand) >= b_max:
            return Flush(bucket, b_max)
        head = cand[0]
        flush_by = (head.deadline_s - service.estimate(bucket)
                    - self.cfg.safety_ms * 1e-3)
        b0 = self.cfg.batch_sizes[0]
        window = now - head.arrival_s
        if len(cand) >= 3 and window > 0:
            rate = (len(cand) - 1) / window
            util_small = rate * service.estimate(
                Bucket(b0, bucket.pooling)) / b0
        else:
            util_small = 0.0
        if util_small < self.cfg.early_flush_util:
            flush_by = min(flush_by,
                           head.arrival_s + self.cfg.max_wait_ms * 1e-3)
        if now >= flush_by or next_arrival is None:
            return Flush(bucket, len(cand))
        return Wait(min(flush_by, next_arrival))


class FixedBatcher:
    """The old serve-loop policy as a baseline: always wait for a full
    fixed-size batch (flushing partials only once the stream has drained).
    Same padding/bucket machinery, no deadline awareness."""

    def __init__(self, batch: int, pooling: int):
        self.bucket = Bucket(batch, pooling)

    def buckets(self) -> List[Bucket]:
        return [self.bucket]

    def decide(self, now: float, queued: Sequence[Request],
               next_arrival: Optional[float],
               service: ServiceModel) -> Decision:
        if not queued:
            return None
        if len(queued) >= self.bucket.batch:
            return Flush(self.bucket, self.bucket.batch)
        if next_arrival is not None:
            return Wait(next_arrival)
        return Flush(self.bucket, len(queued))  # end-of-stream drain


# ---------------------------------------------------------------------------
# Padding: requests -> bucket-shaped device-ready batches
# ---------------------------------------------------------------------------


def pad_pooled_indices(reqs: Sequence[Request], bucket: Bucket,
                       key: str = "indices"
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Stack per-request ``(G, L_r)`` index bags into bucket-shaped
    ``indices (B, G, L)`` int32 + ``weights (B, G, L)`` float32.

    Pooling padding repeats each bag's first id at weight 0 (exact under
    SLS; keeps the access profiler unpolluted).  Batch padding replicates
    request 0 at weight 0."""
    B, L = bucket.batch, bucket.pooling
    if len(reqs) > B:
        raise ValueError(f"{len(reqs)} requests exceed bucket batch {B}")
    G = reqs[0].features[key].shape[0]
    idx = np.zeros((B, G, L), dtype=np.int32)
    w = np.zeros((B, G, L), dtype=np.float32)
    for i, r in enumerate(reqs):
        bags = np.asarray(r.features[key])
        if bags.shape[1] > L:
            raise ValueError(
                f"request pooling {bags.shape[1]} > bucket pooling {L}")
        lr = bags.shape[1]
        idx[i, :, :lr] = bags
        idx[i, :, lr:] = bags[:, :1]          # repeat first id, weight 0
        w[i, :, :lr] = 1.0
    for i in range(len(reqs), B):             # batch padding: replicate row 0
        idx[i] = idx[0]
    return idx, w


def stack_feature(reqs: Sequence[Request], bucket: Bucket, key: str,
                  dtype=None) -> np.ndarray:
    """Stack a fixed-shape per-request feature, replicating request 0 into
    padded batch rows."""
    first = np.asarray(reqs[0].features[key])
    out = np.empty((bucket.batch,) + first.shape, dtype=dtype or first.dtype)
    for i in range(bucket.batch):
        out[i] = np.asarray(reqs[i].features[key]) if i < len(reqs) else first
    return out
