"""Request abstraction, arrival processes, and the bounded admission queue.

A ``Request`` is one inference query: a dict of host-side per-example
features (model-family specific; the padder in ``repro.serving.batcher``
knows how to stack them), an arrival timestamp, and an absolute SLO
deadline.  Arrival processes model production access streams (the regimes
RecNMP / UpDLRM evaluate under): Poisson, a two-state bursty process
(Markov-modulated Poisson), and a deterministic uniform pacer.  All are
pure functions of their config — same seed, same stream.

Times are in seconds on the runtime's virtual clock (the discrete-event
loop in ``repro.serving.runtime``); service times come from real device
execution, arrivals from these generators.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Dict, List

import numpy as np

_ARRIVAL_TAG = 0x5EA1


@dataclasses.dataclass
class Request:
    """One inference query travelling through the serving runtime."""
    rid: int
    arrival_s: float
    deadline_s: float                 # absolute: arrival + SLO budget
    features: Dict[str, np.ndarray]   # per-example host arrays (unbatched)
    pooling: int = 1                  # lookups per bag (bucket dimension)
    user: int = -1                    # closed-loop: issuing virtual user
    start_s: float = math.nan         # set by the runtime at flush
    finish_s: float = math.nan        # set by the runtime at batch completion
    failed: bool = False              # retry budget exhausted / breaker open

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def queued_s(self) -> float:
        return self.start_s - self.arrival_s

    @property
    def slo_ok(self) -> bool:
        return not self.failed and self.finish_s <= self.deadline_s


@dataclasses.dataclass(frozen=True)
class ArrivalConfig:
    """Open-loop arrival process (offered load)."""
    rate_qps: float
    process: str = "poisson"     # poisson | bursty | uniform
    # bursty = MMPP-2: a base state and a burst state whose instantaneous
    # rate is burst_factor * rate_qps; burst_fraction is the fraction of
    # *time* spent bursting.  Overall mean rate stays rate_qps.
    burst_factor: float = 8.0
    burst_fraction: float = 0.1
    mean_burst_s: float = 0.25   # average burst-state dwell time
    seed: int = 0

    def __post_init__(self):
        if self.rate_qps <= 0:
            raise ValueError("rate_qps must be positive")
        if self.process == "bursty" and not (
                0 < self.burst_fraction * self.burst_factor < 1):
            raise ValueError(
                "bursty process needs burst_fraction * burst_factor in (0, 1) "
                "so the base-state rate stays positive")


def arrival_times(cfg: ArrivalConfig, n: int) -> np.ndarray:
    """Absolute arrival times (seconds, ascending, start near 0) for n
    requests.  Deterministic in (cfg.seed, cfg)."""
    rng = np.random.default_rng([cfg.seed, _ARRIVAL_TAG])
    if cfg.process == "uniform":
        return np.arange(n, dtype=np.float64) / cfg.rate_qps
    if cfg.process == "poisson":
        gaps = rng.exponential(1.0 / cfg.rate_qps, n)
        return np.cumsum(gaps)
    if cfg.process != "bursty":
        raise ValueError(f"unknown arrival process {cfg.process!r}")
    # MMPP-2: rates chosen so time-weighted mean rate == rate_qps
    f = cfg.burst_fraction
    r_burst = cfg.burst_factor * cfg.rate_qps
    r_base = cfg.rate_qps * (1.0 - f * cfg.burst_factor) / (1.0 - f)
    mean_dwell = {True: cfg.mean_burst_s,
                  False: cfg.mean_burst_s * (1.0 - f) / f}
    times = np.empty(n, dtype=np.float64)
    t = 0.0
    bursting = False
    state_end = rng.exponential(mean_dwell[bursting])
    for i in range(n):
        gap = rng.exponential(1.0 / (r_burst if bursting else r_base))
        while t + gap > state_end:
            # rate changes mid-gap: re-draw the remainder under the new
            # rate (memoryless, so this is exact for an MMPP)
            t = state_end
            bursting = not bursting
            state_end = t + rng.exponential(mean_dwell[bursting])
            gap = rng.exponential(1.0 / (r_burst if bursting else r_base))
        t += gap
        times[i] = t
    return times


class AdmissionQueue:
    """Bounded FIFO admission queue with load-shedding accounting.

    ``offer`` rejects (sheds) when full — the runtime records the drop so
    SLO math stays honest under overload instead of letting latency grow
    without bound."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError("queue capacity must be positive")
        self.capacity = capacity
        self._q: "deque[Request]" = deque()
        self.offered = 0
        self.dropped = 0
        self.peak_depth = 0

    def __len__(self) -> int:
        return len(self._q)

    def set_capacity(self, capacity: int) -> None:
        """Resize the bound (the brown-out ladder's shed rung tightens it).

        Already-admitted requests are never evicted — shrinking only
        affects future ``offer`` calls, so accounting stays monotonic."""
        if capacity <= 0:
            raise ValueError("queue capacity must be positive")
        self.capacity = capacity

    def offer(self, req: Request) -> bool:
        self.offered += 1
        if len(self._q) >= self.capacity:
            self.dropped += 1
            return False
        self._q.append(req)
        self.peak_depth = max(self.peak_depth, len(self._q))
        return True

    def view(self) -> List[Request]:
        """Current contents in arrival order (the batcher's read-only view)."""
        return list(self._q)

    def pop_n(self, n: int) -> List[Request]:
        if n > len(self._q):
            raise ValueError(f"pop_n({n}) from queue of {len(self._q)}")
        return [self._q.popleft() for _ in range(n)]
