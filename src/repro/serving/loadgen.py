"""Load generation and model-binding glue for the serving runtime.

This is the only serving module that knows about model families: it builds
the ``ServeBinding`` (engine + params + jitted serve step) for a config,
provides the request->bucket padder, fabricates warmup dummies, and turns
trace distributions (``repro.data.traces``) into per-request open-loop or
closed-loop streams with SLO deadlines attached.

Request features are host numpy, one example each:

  * DLRM:           ``dense (n_dense,)``, ``indices (T, L_r)`` (global row
                    ids, variable per-request pooling ``L_r``)
  * field recsys:   ``fields (F,)`` (+ ``dense`` when the config has it)
  * sequence recsys:``seq (S,)``, ``target ()`` (+ ``dense`` for BST)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DLRMConfig, RecConfig
from repro.core.pifs import ServeBinding
from repro.data.synth import _padded_rows, _zipf_ids
from repro.data.traces import TraceConfig, TraceGenerator
from repro.models import dlrm as dlrm_mod
from repro.models import params as prm
from repro.models import recsys as rec_mod
from repro.serving.batcher import (Bucket, pad_pooled_indices, stack_feature)
from repro.serving.request import ArrivalConfig, Request, arrival_times
from repro.serving.updates import UpdateBatch

_DENSE_TAG = 0xD0
_FIELD_TAG = 0xF1
_DELTA_TAG = 0xDE17A


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    """One offered-load experiment: how many requests, arriving how, with
    what SLO budget and (DLRM) per-request pooling mix."""
    n_requests: int
    arrival: ArrivalConfig
    slo_ms: float = 50.0
    poolings: Tuple[int, ...] = ()       # DLRM pooling choices; () = fixed
    distribution: str = "zipfian"
    drift_every: int = 256               # serve-stream hot-set churn period
    seed: int = 0
    storage: str = "fp32"                # engine cold-tier storage; DLRM
    #                                      table offsets depend on its page size
    dedup: str = "off"                   # gather-once duplicate coalescing
    #                                      (off/auto/on; bit-exact either way)
    front_end: str = "split"             # DLRM lookup->interaction pipeline:
    #                                      'fused' keeps pooled features in
    #                                      VMEM through the interaction; tp-
    #                                      sharded configs resolve 'fused_tp'
    #                                      (partial-pool -> psum -> resume)
    update_qps: float = 0.0              # streaming embedding updates: delta
    #                                      rows/second on the virtual clock
    #                                      (0 = no update stream)
    update_batch: int = 64               # rows per trainer-emitted delta batch


# ---------------------------------------------------------------------------
# Model binding
# ---------------------------------------------------------------------------


def _dlrm_steps(cfg, engine, mesh, *, mode, impl, block_l, dedup,
                front_end, degraded_variants):
    """Jitted serve-step variants for a DLRM (engine, mesh) pair — split
    out of :func:`bind_model` so an elastic re-mesh can rebuild them
    against the survivor mesh with identical knobs (``front_end`` re-
    resolves per mesh: tp>1 picks ``fused_tp``, tp=1 plain fused)."""
    step = jax.jit(dlrm_mod.make_serve_step(
        cfg, engine, mesh, mode=mode, impl=impl, block_l=block_l,
        dedup=dedup, front_end=front_end))
    steps = None
    if degraded_variants:
        def dlrm_step(**kw):
            return jax.jit(dlrm_mod.make_serve_step(
                cfg, engine, mesh, mode=mode, impl=impl,
                block_l=block_l, **kw))
        hot_only = dlrm_step(dedup="off", front_end="split",
                             tiers="hot_only")
        steps = {
            "split_fe": dlrm_step(dedup=dedup, front_end="split"),
            "no_dedup": dlrm_step(dedup="off", front_end="split"),
            "hot_only": hot_only,
            "shed": hot_only,
        }
    return step, steps


def _rec_steps(cfg, engine, offs, mesh, *, mode, impl, block_l, dedup,
               degraded_variants):
    """Rec-family analogue of :func:`_dlrm_steps` (``offs`` are page-size
    offsets — a function of storage, not of the mesh, so they carry
    verbatim across a re-mesh)."""
    step = jax.jit(rec_mod.make_serve_step(
        cfg, engine, offs, mesh, mode=mode, impl=impl, block_l=block_l,
        dedup=dedup))
    steps = None
    if degraded_variants:
        no_dedup = jax.jit(rec_mod.make_serve_step(
            cfg, engine, offs, mesh, mode=mode, impl=impl,
            block_l=block_l, dedup="off"))
        steps = {"split_fe": step, "no_dedup": no_dedup,
                 "hot_only": no_dedup, "shed": no_dedup}
    return step, steps


def bind_model(cfg, mesh, mode: str = "pifs", impl: str = "jnp",
               block_l: int = 8, hot_fraction: float = 0.05,
               seed: int = 0, storage: str = "fp32",
               dedup: str = "off", front_end: str = "split",
               degraded_variants: bool = False,
               validate_ids: bool = False,
               scrub_scores: bool = False,
               update_capacity: int = 0,
               elastic: bool = False,
               prefer_tp: int = 4) -> ServeBinding:
    """Build engine + params + jitted serve step for a DLRM or Rec config.

    ``storage`` selects the engine's cold-tier format (fp32 passthrough or
    int8 with per-page scales and fused dequant in the SLS datapath);
    ``dedup`` the gather-once duplicate-coalescing knob (off/auto/on —
    bit-exact either way; 'auto' resolves per shape bucket from the
    observe-phase histogram); ``front_end`` the DLRM lookup->interaction
    pipeline ('fused' keeps pooled features in VMEM through the dot
    interaction; tp-sharded meshes and pond mode resolve it to
    'fused_tp' — each shard partial-pools its owned rows and only the
    small (B, F, d) cold tile is psum'd between the kernel halves — still
    bit-exact vs split; Rec configs have no DLRM dot-interaction stage,
    so the knob is DLRM-only and ignored for them).  The brown-out rungs
    stay on the split path by construction: ``split_fe``/``no_dedup``
    pass ``front_end='split'`` and ``hot_only``/``shed`` force it (the
    fused path is all-tiers only).

    ``degraded_variants`` additionally builds the brown-out ladder's
    serve-step variants (``repro.serving.degradation.RUNGS``) as separate
    jitted executables sharing the engine/params/state — the degradation
    controller switches between them via ``binding.set_mode`` without
    retracing (each variant is warmed per bucket by the caller).  DLRM
    rungs: split_fe (split front end, bit-exact), no_dedup (split + dedup
    off, bit-exact), hot_only (hot-tier-only lookups, cold rows
    zero-filled — scores change), shed (same datapath as hot_only; the
    controller also tightens admission).  Rec configs have no DLRM front
    end or tiers knob, so split_fe aliases full and hot_only/shed alias
    no_dedup.  ``validate_ids``/``scrub_scores`` arm the binding's
    host-side guardrails (OOB-id raise, NaN/Inf score scrub).
    ``update_capacity`` (> 0) sets the binding's fixed streaming-update
    apply width (rows per device chunk — one plan signature, zero
    steady-state retraces; see ``repro.serving.updates``).

    ``elastic`` arms mid-serving shard-loss recovery: the binding gets a
    rebinder closure that rebuilds every serve-step variant (same knobs)
    for a re-meshed engine, so ``ServeBinding.remesh`` can survive losing
    a tp shard — ``prefer_tp`` parameterizes the survivor-mesh policy
    (``runtime/elastic.scale_plan``).
    """
    k_params, k_state = jax.random.split(jax.random.PRNGKey(seed), 2)
    if isinstance(cfg, DLRMConfig):
        engine, _ = dlrm_mod.build_engine(cfg, mesh,
                                          hot_fraction=hot_fraction,
                                          storage=storage, dedup=dedup)
        params = prm.initialize(dlrm_mod.model_specs(cfg, mesh), k_params)
        step, steps = _dlrm_steps(
            cfg, engine, mesh, mode=mode, impl=impl, block_l=block_l,
            dedup=dedup, front_end=front_end,
            degraded_variants=degraded_variants)
        idx_key = "indices"

        def rebind(new_engine, new_mesh):
            return _dlrm_steps(
                cfg, new_engine, new_mesh, mode=mode, impl=impl,
                block_l=block_l, dedup=dedup, front_end=front_end,
                degraded_variants=degraded_variants)
    elif isinstance(cfg, RecConfig):
        engine, offs = rec_mod.build_engine(cfg, mesh,
                                            hot_fraction=hot_fraction,
                                            storage=storage, dedup=dedup)
        params = prm.initialize(rec_mod.model_specs(cfg, mesh), k_params)
        step, steps = _rec_steps(
            cfg, engine, offs, mesh, mode=mode, impl=impl,
            block_l=block_l, dedup=dedup,
            degraded_variants=degraded_variants)
        idx_key = None     # field ids are table-local; profiler stays off

        def rebind(new_engine, new_mesh):
            return _rec_steps(
                cfg, new_engine, offs, new_mesh, mode=mode, impl=impl,
                block_l=block_l, dedup=dedup,
                degraded_variants=degraded_variants)
    else:
        raise TypeError(f"unsupported serving config {type(cfg)}")
    state = engine.init_state(k_state)
    binding = ServeBinding(engine, state, params, step, idx_key=idx_key,
                           steps=steps, validate_ids=validate_ids,
                           scrub_scores=scrub_scores)
    if update_capacity > 0:
        binding.update_capacity = int(update_capacity)
    if elastic:
        binding.attach_remesher(rebind, prefer_tp=prefer_tp)
    return binding


def make_padder(cfg) -> Callable[[Sequence[Request], Bucket], dict]:
    """Request-list -> bucket-shaped host batch for the config's family."""
    if isinstance(cfg, DLRMConfig):
        def pad_dlrm(reqs, bucket):
            idx, w = pad_pooled_indices(reqs, bucket)
            return {"dense": stack_feature(reqs, bucket, "dense"),
                    "indices": idx, "weights": w}
        return pad_dlrm
    it = cfg.interaction
    if it in ("self-attn-seq", "transformer-seq"):
        def pad_seq(reqs, bucket):
            out = {"seq": stack_feature(reqs, bucket, "seq"),
                   "target": stack_feature(reqs, bucket, "target")}
            if cfg.n_dense:
                out["dense"] = stack_feature(reqs, bucket, "dense")
            return out
        return pad_seq

    def pad_fields(reqs, bucket):
        out = {"fields": stack_feature(reqs, bucket, "fields")}
        if cfg.n_dense:
            out["dense"] = stack_feature(reqs, bucket, "dense")
        return out
    return pad_fields


# ---------------------------------------------------------------------------
# Request fabrication
# ---------------------------------------------------------------------------


def _dlrm_features(cfg: DLRMConfig, ids: np.ndarray, rid: int,
                   seed: int, storage: str = "fp32") -> dict:
    # global-row offsets follow the engine's page rounding, which depends
    # on the cold-tier storage format (int8 pages hold 4x the rows)
    offs = (np.arange(cfg.n_tables, dtype=np.int64)
            * _padded_rows(cfg, storage=storage))[:, None]
    rng = np.random.default_rng([seed, _DENSE_TAG, rid])
    return {"dense": rng.normal(size=(cfg.n_dense,)).astype(np.float32),
            "indices": (ids + offs).astype(np.int32)}


def _rec_features(cfg: RecConfig, rid: int, seed: int) -> dict:
    rng = np.random.default_rng([seed, _FIELD_TAG, rid])
    it = cfg.interaction
    out: dict = {}
    if it in ("self-attn-seq", "transformer-seq"):
        V = cfg.vocab_sizes[0]
        out["seq"] = _zipf_ids(rng, V, (cfg.seq_len,)).astype(np.int32)
        out["target"] = _zipf_ids(rng, V, ()).astype(np.int32)
    else:
        out["fields"] = np.stack(
            [_zipf_ids(rng, v, ()) for v in cfg.vocab_sizes]
        ).astype(np.int32)
    if cfg.n_dense:
        out["dense"] = rng.normal(size=(cfg.n_dense,)).astype(np.float32)
    return out


def request_stream(cfg, load: LoadConfig) -> List[Request]:
    """Materialise an open-loop request list (arrival times + features)."""
    times = arrival_times(load.arrival, load.n_requests)
    slo_s = load.slo_ms * 1e-3
    reqs: List[Request] = []
    if isinstance(cfg, DLRMConfig):
        gen = TraceGenerator(TraceConfig(
            n_rows=cfg.emb_num, n_tables=cfg.n_tables, pooling=cfg.pooling,
            batch=1, distribution=load.distribution, seed=load.seed))
        it = gen.serve_requests(load.n_requests,
                                poolings=load.poolings or None,
                                drift_every=load.drift_every)
        for i, ids in enumerate(it):
            reqs.append(Request(
                rid=i, arrival_s=float(times[i]),
                deadline_s=float(times[i]) + slo_s,
                features=_dlrm_features(cfg, ids, i, load.seed,
                                        storage=load.storage),
                pooling=ids.shape[1]))
    else:
        for i in range(load.n_requests):
            reqs.append(Request(
                rid=i, arrival_s=float(times[i]),
                deadline_s=float(times[i]) + slo_s,
                features=_rec_features(cfg, i, load.seed),
                pooling=1))
    return reqs


def closed_loop_factory(cfg, load: LoadConfig
                        ) -> Callable[[int, int, float], Request]:
    """Request factory for ``ClosedLoopSource`` (same feature streams as
    the open-loop generator, arrival set by the completion that frees the
    virtual user)."""
    slo_s = load.slo_ms * 1e-3
    if isinstance(cfg, DLRMConfig):
        gen = TraceGenerator(TraceConfig(
            n_rows=cfg.emb_num, n_tables=cfg.n_tables, pooling=cfg.pooling,
            batch=1, distribution=load.distribution, seed=load.seed))
        it = gen.serve_requests(None, poolings=load.poolings or None,
                                drift_every=load.drift_every)

        def make_dlrm(rid: int, user: int, arrival_s: float) -> Request:
            ids = next(it)
            return Request(rid=rid, arrival_s=arrival_s,
                           deadline_s=arrival_s + slo_s,
                           features=_dlrm_features(cfg, ids, rid, load.seed,
                                                   storage=load.storage),
                           pooling=ids.shape[1], user=user)
        return make_dlrm

    def make_rec(rid: int, user: int, arrival_s: float) -> Request:
        return Request(rid=rid, arrival_s=arrival_s,
                       deadline_s=arrival_s + slo_s,
                       features=_rec_features(cfg, rid, load.seed),
                       pooling=1, user=user)
    return make_rec


def update_stream(cfg, load: LoadConfig, scale: float = 1e-3
                  ) -> List[UpdateBatch]:
    """Materialise the trainer-side delta stream for an offered load.

    Batches of ``load.update_batch`` rows arrive at ``load.update_qps``
    delta rows/second on the same virtual clock as the request stream,
    covering the request horizon (last arrival).  Rows follow the load's
    trace distribution — an independent TraceGenerator with its own
    popularity drift, so the update stream skews hot exactly like real
    trainer output (hot rows train most) and stresses the requant-demote
    path.  Deltas are small gaussians (``scale``), keyed deterministically
    per batch.

    Only DLRM configs carry the global row-id space the engine's
    ``apply_deltas`` addresses; Rec families keep table-local ids inside
    the model, so an update stream for them is a config error."""
    if load.update_qps <= 0:
        return []
    if not isinstance(cfg, DLRMConfig):
        raise TypeError(
            "update streams address engine-global row ids; only DLRM "
            f"configs are supported (got {type(cfg).__name__})")
    times = arrival_times(load.arrival, load.n_requests)
    horizon = float(times[-1]) if len(times) else 0.0
    interval = load.update_batch / load.update_qps
    n_batches = max(1, int(horizon / interval) + 1)
    per_table = -(-load.update_batch // cfg.n_tables)
    gen = TraceGenerator(TraceConfig(
        n_rows=cfg.emb_num, n_tables=cfg.n_tables, pooling=per_table,
        batch=1, distribution=load.distribution, seed=load.seed + 1))
    offs = (np.arange(cfg.n_tables, dtype=np.int64)
            * _padded_rows(cfg, storage=load.storage))[:, None]
    out: List[UpdateBatch] = []
    for k in range(n_batches):
        ids = gen.next_batch()[0] + offs             # (T, per_table)
        rows = ids.reshape(-1)[: load.update_batch].astype(np.int64)
        rng = np.random.default_rng([load.seed, _DELTA_TAG, k])
        deltas = (rng.normal(size=(rows.size, cfg.emb_dim)) * scale
                  ).astype(np.float32)
        out.append(UpdateBatch(seq=k + 1, t_gen=(k + 1) * interval,
                               rows=rows, deltas=deltas))
    return out


def prime_dedup_auto(binding: ServeBinding, requests: Sequence[Request],
                     n: int = 64) -> int:
    """Prime the engine's access histogram for serving ``dedup='auto'``.

    The 'auto' coalescing decision is frozen per lookup plan when the plan
    is first built — for a serving runtime that is during bucket *warmup*,
    before any live traffic has populated the observe-phase histogram, so
    every bucket would freeze to the uniform-prior answer (off) and the
    knob would be inert.  This feeds the first ``n`` requests' index
    streams through the profiler (maintenance path), then drops the
    compiled plans and probe state so the caller's **re-warmup** rebuilds
    every bucket against the primed histogram; the rebuild traces land
    before the caller resets plan stats, so the zero-steady-retrace
    contract is untouched.  Returns the number of requests observed (0
    for model families whose profiler is off — nothing was dropped)."""
    if binding.idx_key is None:
        return 0
    engine = binding.engine
    dp = max(1, engine.axes.dp_size(engine.mesh))
    seen = 0
    by_pooling: dict = {}
    for r in requests[:n]:
        feats = r.features.get(binding.idx_key)
        if feats is None:
            continue
        feats = np.asarray(feats)
        # observe shards its batch over dp: tile the single request to a
        # dp-divisible batch (uniform inflation — the histogram's relative
        # skew, which is all 'auto' reads, is unchanged)
        idx = np.broadcast_to(feats[None], (dp,) + feats.shape)
        binding.observe({binding.idx_key: idx})
        by_pooling.setdefault(feats.shape[-1], []).append(feats)
        seen += 1
    if seen:
        # measured-duplicate hint: the page-granular histogram is blind to
        # row-level skew scattered across pages (hashed production ids),
        # so replay the stacked prefix through the exact gather ledger the
        # dedup datapath realizes; 'auto' resolutions built under the
        # outer serve-step trace use this as evidence alongside the
        # analytic expectation.  Prefix batches (~n requests) are larger
        # than single buckets, so the hint leans optimistic — it is a
        # decision heuristic, not the gated ledger (which stays measured
        # per batch).
        entries = uniques = 0
        for feats_list in by_pooling.values():
            d = engine.dedup_factor(binding.state, np.stack(feats_list))
            entries += d["entries"]
            uniques += d["unique_rows"]
        engine.dedup_auto_hint = entries / max(uniques, 1)
        binding.engine.reset_plan_stats(clear_plans=True)
        # the engine's lookup plans are built while *tracing* the outer
        # jitted serve step — once that step is compiled, the engine layer
        # is bypassed entirely, so its cleared registry would never
        # repopulate: drop the outer executables too (every ladder-rung
        # variant, not just the active one), forcing the re-warmup to
        # re-trace through engine.lookup against the primed histogram
        for s in {id(v): v for v in binding.steps.values()}.values():
            if hasattr(s, "clear_cache"):
                s.clear_cache()
        binding.dedup_stats.clear()
    return seen


def dummy_request_factory(cfg, storage: str = "fp32"
                          ) -> Callable[[int, int], Request]:
    """Fabricate bucket-warmup dummies (valid ids, zero-ish features)."""
    if isinstance(cfg, DLRMConfig):
        def make_dlrm(rid: int, pooling: int) -> Request:
            ids = np.zeros((cfg.n_tables, pooling), dtype=np.int64)
            return Request(rid=-1 - rid, arrival_s=0.0, deadline_s=1e9,
                           features=_dlrm_features(cfg, ids, 0, 0,
                                                   storage=storage),
                           pooling=pooling)
        return make_dlrm

    def make_rec(rid: int, pooling: int) -> Request:
        return Request(rid=-1 - rid, arrival_s=0.0, deadline_s=1e9,
                       features=_rec_features(cfg, 0, 0), pooling=1)
    return make_rec
