"""Graceful degradation for the serving runtime: retry, circuit breaker,
and the hysteresis brown-out ladder.

Under injected (or real) faults the runtime should bend, not break:

  * **Retry with backoff** — a transient executor failure
    (:class:`~repro.serving.faults.TransientServingFailure`) is retried up
    to ``RetryPolicy.max_attempts`` times; each backoff consumes *virtual*
    time, so the latency cost of retrying is visible in p99.  A request
    whose budget is exhausted is marked ``failed`` and counted exactly
    once in SLO metrics.
  * **Circuit breaker** — ``BreakerConfig.trip_after`` consecutive failed
    attempts trip the breaker open: batches fail fast (no executor call)
    until ``cooldown_s`` of virtual time passes, then a half-open probe
    batch decides between closing and re-opening.  Fail-fast keeps a
    persistent fault from head-of-line-blocking the queue behind doomed
    retries.
  * **Brown-out ladder** — a pressure EWMA (1 per failed batch, 0 per
    healthy one) steps the service down a quality ladder under sustained
    pressure and back up on recovery, with hysteresis (distinct down/up
    thresholds + a minimum dwell) so it never flaps:

        full > split_fe > no_dedup > hot_only > shed

    Rungs are applied through ``ServeBinding.set_mode`` — each rung is a
    pre-warmed jitted serve-step variant over the *same* bucket
    signatures, so stepping down (or up) never retraces.  ``split_fe``
    and ``no_dedup`` are bit-exact with ``full`` (test-pinned); ``hot_only``
    zero-fills cold-tier contributions (scores change, availability
    survives); ``shed`` additionally tightens the admission-queue bound so
    overload is rejected at the door instead of timing out inside.
  * **Poison-triggered restore** — ``poison_restore_after`` consecutive
    batches with scrubbed (non-finite) scores signal a corrupted store;
    the runtime heals it between micro-batches via ``ServeBinding.restore()``
    (checkpoint reload on the maintenance seam — no retrace, no restart).
  * **Remesh escalation** — the ladder and breaker handle *transient*
    pressure; a dead tp shard is *persistent* and per-shard.  The
    distinguisher is attribution: attempt failures carrying a ``shard``
    id (:class:`~repro.serving.faults.ShardLossFailure`) build a
    consecutive same-shard streak, while any interleaved *non*-attributed
    transient breaks the evidence chain (a genuinely flaky fabric does
    not blame one shard consistently).  ``remesh_after`` same-shard
    failures escalate past the ladder to the ``remesh`` recovery action:
    the runtime quiesces, re-meshes the engine onto the survivors
    (``ServeBinding.remesh``), re-warms, and ``note_remeshed`` resets the
    breaker/pressure/ladder — the fault is *gone*, not cooling down.

All state advances on the runtime's virtual clock, so chaos runs are
deterministic and replayable.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.serving.faults import TransientServingFailure

RUNGS = ("full", "split_fe", "no_dedup", "hot_only", "shed")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_attempts: int = 3            # total attempts (first try included)
    backoff_s: float = 0.002         # virtual seconds before attempt 2
    backoff_mult: float = 2.0        # exponential growth per further attempt

    def backoff(self, failures: int) -> float:
        """Virtual-time penalty after the ``failures``-th failed attempt."""
        return self.backoff_s * self.backoff_mult ** (failures - 1)


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    trip_after: int = 5              # consecutive failed attempts to trip
    cooldown_s: float = 0.5          # open-state dwell before half-open


@dataclasses.dataclass(frozen=True)
class LadderConfig:
    alpha: float = 0.3               # pressure EWMA weight
    step_down_at: float = 0.5        # pressure >= this -> one rung down
    step_up_at: float = 0.05         # pressure <= this -> one rung up
    min_dwell_batches: int = 8       # hysteresis: batches between moves
    shed_capacity: int = 64          # admission bound while on 'shed'
    poison_restore_after: int = 2    # consecutive poisoned batches -> restore
    # consecutive attempt failures *attributed to one shard* before the
    # controller escalates to elastic re-mesh (0 disables).  The default
    # equals RetryPolicy.max_attempts: one retry-exhausted batch whose
    # every attempt blamed the same shard is already persistent-failure
    # evidence no transient produces.
    remesh_after: int = 3


class CircuitBreaker:
    """closed -> (trip_after consecutive failures) -> open -> (cooldown on
    the virtual clock) -> half-open probe -> closed | open."""

    def __init__(self, cfg: BreakerConfig):
        self.cfg = cfg
        self.state = "closed"
        self.consecutive = 0
        self.open_until = 0.0
        self.trips = 0

    def allow(self, now: float) -> bool:
        if self.state == "open":
            if now >= self.open_until:
                self.state = "half_open"     # admit one probe batch
                return True
            return False
        return True

    def record_failure(self, now: float) -> None:
        self.consecutive += 1
        if (self.state == "half_open"
                or self.consecutive >= self.cfg.trip_after):
            self.state = "open"
            self.open_until = now + self.cfg.cooldown_s
            self.trips += 1
            self.consecutive = 0

    def record_success(self) -> None:
        self.consecutive = 0
        if self.state == "half_open":
            self.state = "closed"


class DegradationController:
    """Composes retry policy, circuit breaker, and the brown-out ladder;
    the runtime consults it around every executor call.  ``binding`` is
    optional — a controller over a :class:`SimulatedExecutor` still
    retries, trips, and walks the ladder (rungs just change no datapath).
    """

    def __init__(self, binding=None,
                 retry: Optional[RetryPolicy] = None,
                 breaker: Optional[BreakerConfig] = None,
                 ladder: Optional[LadderConfig] = None,
                 retryable: Tuple[type, ...] = (TransientServingFailure,)):
        self.binding = binding
        self.retry = retry or RetryPolicy()
        self.breaker = CircuitBreaker(breaker or BreakerConfig())
        self.ladder = ladder or LadderConfig()
        self.retryable = tuple(retryable)
        self.rung = 0
        self.pressure = 0.0
        self.transitions: List[dict] = []
        self.queue = None
        self._base_capacity: Optional[int] = None
        self._dwell = 0
        self._poison_streak = 0
        self.restores = 0
        # per-shard failure attribution (remesh escalation)
        self._shard_streak = 0
        self.suspect_shard: Optional[int] = None
        self.remeshes = 0
        self.remesh_events: List[dict] = []
        self.straggler_trips = 0
        self.corruption_trips = 0

    # --------------------------------------------------------------- wiring
    @property
    def rung_label(self) -> str:
        return RUNGS[self.rung]

    def bind_queue(self, queue) -> None:
        """Give the shed rung an admission queue to tighten."""
        self.queue = queue
        self._base_capacity = queue.capacity

    # -------------------------------------------------------------- breaker
    def allow_execute(self, now: float) -> bool:
        return self.breaker.allow(now)

    def on_attempt_failure(self, now: float, exc=None) -> None:
        self.breaker.record_failure(now)
        # per-shard attribution: failures carrying a shard id build a
        # same-shard streak; an interleaved *non*-attributed transient
        # breaks the chain (flaky fabrics don't blame one shard
        # consistently — that inconsistency IS the transient/persistent
        # distinguisher).  exc=None (legacy callers) leaves the streak
        # untouched.
        shard = getattr(exc, "shard", None)
        if shard is not None:
            if shard == self.suspect_shard:
                self._shard_streak += 1
            else:
                self.suspect_shard = shard
                self._shard_streak = 1
        elif exc is not None:
            self.suspect_shard = None
            self._shard_streak = 0

    def on_straggler(self, now: float) -> None:
        """Watchdog trip: one micro-batch served far above the service-time
        EWMA.  A half-weight pressure bump — slow-but-correct is pressure,
        not failure — so sustained straggling walks the ladder down while
        one blip decays away."""
        l = self.ladder
        self.pressure = (1 - l.alpha) * self.pressure + l.alpha * 0.5
        self.straggler_trips += 1

    def on_corruption(self, now: float) -> None:
        """Scrub detection: a page's live checksum diverged from the
        ledger (silent store corruption).  The page is being repaired on
        the maintenance seam, so like a straggler this is evidence of
        trouble, not a failed batch — the same half-weight pressure bump:
        sustained flips walk the ladder down, one cosmic ray decays
        away."""
        l = self.ladder
        self.pressure = (1 - l.alpha) * self.pressure + l.alpha * 0.5
        self.corruption_trips += 1

    # --------------------------------------------------------------- ladder
    def on_batch_done(self, now: float, ok: bool, poisoned: int = 0) -> None:
        """Feed the ladder one resolved micro-batch (success, retry-
        exhausted failure, or fail-fast) and move rungs if warranted."""
        if ok:
            self.breaker.record_success()
            self._poison_streak = self._poison_streak + 1 if poisoned else 0
            if self.rung < RUNGS.index("hot_only"):
                # a success through the cross-shard datapath exonerates the
                # suspect; hot-only/shed successes don't touch the cold
                # shards, so they are not evidence either way
                self.suspect_shard = None
                self._shard_streak = 0
        l = self.ladder
        self.pressure = ((1 - l.alpha) * self.pressure
                         + l.alpha * (0.0 if ok else 1.0))
        self._dwell += 1
        if self._dwell < l.min_dwell_batches:
            return
        if self.pressure >= l.step_down_at and self.rung < len(RUNGS) - 1:
            self._move(now, self.rung + 1, f"pressure={self.pressure:.2f}")
        elif self.pressure <= l.step_up_at and self.rung > 0:
            self._move(now, self.rung - 1, f"pressure={self.pressure:.2f}")

    def _move(self, now: float, new_rung: int, reason: str) -> None:
        frm, to = RUNGS[self.rung], RUNGS[new_rung]
        self.rung = new_rung
        self._dwell = 0
        self.transitions.append({"t": round(now, 6), "from": frm, "to": to,
                                 "reason": reason})
        if self.binding is not None:
            self.binding.set_mode(to)
        if self.queue is not None:
            self.queue.set_capacity(self.ladder.shed_capacity
                                    if to == "shed" else self._base_capacity)

    # ------------------------------------------------------------- recovery
    @property
    def wants_restore(self) -> bool:
        return (self.binding is not None
                and self.binding.checkpointer is not None
                and self._poison_streak >= self.ladder.poison_restore_after)

    def note_restored(self) -> None:
        self._poison_streak = 0
        self.restores += 1

    @property
    def wants_remesh(self) -> bool:
        """Escalate past the ladder: enough consecutive failures blamed on
        one shard, and the binding can actually re-mesh."""
        return (self.ladder.remesh_after > 0
                and self.binding is not None
                and getattr(self.binding, "can_remesh", False)
                and self._shard_streak >= self.ladder.remesh_after)

    def note_remeshed(self, now: float, event: Optional[dict] = None
                      ) -> None:
        """The dead shard left the mesh: unlike a breaker cooldown, the
        fault is *gone* — reset breaker, pressure, and ladder so serving
        resumes at full quality on the survivor mesh."""
        self.remeshes += 1
        self.remesh_events.append(
            {"t": round(now, 6), "shard": self.suspect_shard,
             **(event or {})})
        self.suspect_shard = None
        self._shard_streak = 0
        self.breaker.state = "closed"
        self.breaker.consecutive = 0
        self.pressure = 0.0
        if self.rung != 0:
            self._move(now, 0, "remesh recovery")

    # --------------------------------------------------------------- report
    def report(self) -> dict:
        return {
            "rung": self.rung_label,
            "pressure": round(self.pressure, 4),
            "transitions": list(self.transitions),
            "n_transitions": len(self.transitions),
            "breaker_state": self.breaker.state,
            "breaker_trips": self.breaker.trips,
            "restores": self.restores,
            "remeshes": self.remeshes,
            "remesh_events": list(self.remesh_events),
            "suspect_shard": self.suspect_shard,
            "straggler_trips": self.straggler_trips,
            "corruption_trips": self.corruption_trips,
        }
