"""Serving-side fault injection: deterministic chaos for the serving loop.

The paper's claim is tail latency under heavy concurrent load; a real
CXL-fabric deployment only delivers that p99 if it also survives the
faults such fabrics see — congested links (stragglers), transient device
errors, maintenance stalls, corrupted pages.  ``FaultInjectingExecutor``
wraps any executor (``BindingExecutor`` or ``SimulatedExecutor``) and
injects four fault classes, each driven by its own
:class:`repro.runtime.fault_tolerance.FailureInjector` so training and
serving share one injection vocabulary (scheduled steps + seeded-hash
chaos, reproducible across runs):

  * **straggler** — the batch's service time is multiplied by
    ``straggler_factor`` (a congested fabric link slowing one collective).
    The batch still *succeeds*; only the virtual clock suffers.
  * **transient** — ``run_batch`` raises :class:`TransientServingFailure`
    (a device error / dropped RPC).  ``transient_runs`` > 1 makes the
    failure persist across that many consecutive attempts, which is how
    tests drive a burst past the retry budget and into the circuit
    breaker.
  * **stall** — maintenance (``observe``/``replan``) takes ``stall_s``
    extra seconds (a fabric-switch firmware pause landing on the
    maintenance path).
  * **shard_loss** — a tp shard's device disappears: once fired, every
    attempt that exercises the cross-shard datapath raises
    :class:`ShardLossFailure` (carrying the dead shard id) until the
    runtime re-meshes onto the survivors and calls :meth:`on_remesh`.
    Persistent, not transient — the class the elastic recovery path
    exists for.
  * **corruption** — the *data plane* is poisoned: some ids pushed out of
    range (``corrupt_oob``; the device gather would clamp them silently —
    ``validate_ids`` exists to catch exactly this) or dense rows set to
    NaN (``corrupt_nan``; the score scrub in ``ServeBinding`` catches the
    fallout).  Corruption copies the batch first — a retry of the same
    micro-batch sees the *original* data, matching a re-read from the
    (healthy) feature store.

  * **bit_flip** — a *silent* store corruption: a seeded, deterministic
    bit flip lands in live hot/cold page content, producing finite wrong
    values (fp32 flips stay inside the mantissa, int8 flips are always
    finite).  ``validate_ids`` never sees it (the ids are fine) and the
    NaN score scrub structurally cannot (nothing is non-finite) — this
    is the fault class the per-page checksum ledger + scrub sweep
    (``repro.core.integrity`` / ``serving/scrub.py``) exists to catch.

Every ``run_batch`` *attempt* advances the fault step, so a retried batch
re-rolls the dice rather than deterministically re-failing forever.

``corrupt_store`` poisons the engine's replicated hot tier in place — NaN
rows (``mode='nan'``: the score scrub catches the fallout) or finite
mantissa flips (``mode='finite'``: only a checksum audit can see it) —
the stand-in for a corrupted memory page, healed by
``ServeBinding.restore()`` or page-granular repair.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.runtime.fault_tolerance import FailureInjector, SimulatedFailure


class TransientServingFailure(SimulatedFailure):
    """A retryable serving-path failure (transient device/RPC error)."""


class ShardLossFailure(TransientServingFailure):
    """A tp shard's device is gone: its psum contribution is dead.

    Unlike a transient, this is *persistent* — retries keep failing until
    the dead shard leaves the mesh (an elastic re-mesh onto the
    survivors).  ``shard`` identifies the lost tp index, which is what
    lets the degradation controller attribute consecutive failures to one
    shard and escalate past the brown-out ladder to the ``remesh``
    recovery action instead of uselessly cycling the breaker."""

    def __init__(self, msg: str, shard: int):
        super().__init__(msg)
        self.shard = int(shard)


# distinct per-class seed salts so one FaultConfig.seed yields independent
# (but individually reproducible) schedules per fault class
_SALTS = {"straggler": 0x57A6, "transient": 0x7EA4, "stall": 0x57A1,
          "corrupt_oob": 0x00B0, "corrupt_nan": 0x0A17,
          "shard_loss": 0x10AD, "bit_flip": 0xB17F}


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Per-class fire schedules: explicit steps and/or chaos probability.

    ``*_at`` steps index run_batch *attempts* (for straggler / transient /
    corruption) or maintenance calls (for stall), starting at 0 and
    counting warmup executions too if the wrapper is installed before
    warmup — install it after warmup (the usual pattern) to keep warmup
    deterministic and fault-free.
    """
    seed: int = 0
    straggler_prob: float = 0.0
    straggler_at: Tuple[int, ...] = ()
    straggler_factor: float = 8.0
    transient_prob: float = 0.0
    transient_at: Tuple[int, ...] = ()
    transient_runs: int = 1          # consecutive failing attempts per firing
    stall_prob: float = 0.0
    stall_at: Tuple[int, ...] = ()
    stall_s: float = 0.25
    corrupt_oob_prob: float = 0.0
    corrupt_oob_at: Tuple[int, ...] = ()
    corrupt_nan_prob: float = 0.0
    corrupt_nan_at: Tuple[int, ...] = ()
    # shard_loss: once fired, *every* subsequent attempt that exercises the
    # cross-shard datapath fails until the executor is told the shard left
    # the mesh (on_remesh) — the persistent-failure class the elastic
    # recovery path exists for.  shard_loss_shard = -1 picks the highest
    # tp index from the bound engine at fire time.
    shard_loss_prob: float = 0.0
    shard_loss_at: Tuple[int, ...] = ()
    shard_loss_shard: int = -1
    # bit_flip: silent store corruption — deterministic seeded flips of
    # live page content (finite values, invisible to the score scrub).
    # Fires against the wrapped executor's binding; each firing flips
    # bit_flip_rows rows across bit_flip_tier ('hot' / 'cold' / 'both').
    bit_flip_prob: float = 0.0
    bit_flip_at: Tuple[int, ...] = ()
    bit_flip_rows: int = 2
    bit_flip_tier: str = "both"

    def injectors(self) -> Dict[str, FailureInjector]:
        def inj(name: str, prob: float, at: Tuple[int, ...]):
            return FailureInjector(fail_at_steps=tuple(at), fail_prob=prob,
                                   seed=hash((self.seed, _SALTS[name])))
        return {
            "straggler": inj("straggler", self.straggler_prob,
                             self.straggler_at),
            "transient": inj("transient", self.transient_prob,
                             self.transient_at),
            "stall": inj("stall", self.stall_prob, self.stall_at),
            "corrupt_oob": inj("corrupt_oob", self.corrupt_oob_prob,
                               self.corrupt_oob_at),
            "corrupt_nan": inj("corrupt_nan", self.corrupt_nan_prob,
                               self.corrupt_nan_at),
            "shard_loss": inj("shard_loss", self.shard_loss_prob,
                              self.shard_loss_at),
            "bit_flip": inj("bit_flip", self.bit_flip_prob,
                            self.bit_flip_at),
        }


class FaultInjectingExecutor:
    """Wraps an executor, injecting the :class:`FaultConfig` fault classes.

    Duck-types the executor protocol (``run_batch``/``observe``/
    ``replan``) so the runtime, retry loop, and benchmarks cannot tell it
    from the real thing.  ``fired`` counts injections per class;
    ``corrupted_batches`` remembers which attempt steps carried poisoned
    data (tests assert the scrub caught exactly those).
    """

    def __init__(self, inner, cfg: FaultConfig,
                 idx_key: Optional[str] = "indices",
                 dense_key: Optional[str] = "dense",
                 oob_id: int = 2 ** 31 - 2):
        self.inner = inner
        self.cfg = cfg
        self.idx_key = idx_key
        self.dense_key = dense_key
        self.oob_id = oob_id
        self._inj = cfg.injectors()
        self._step = 0           # run_batch attempts
        self._mstep = 0          # maintenance calls (observe + replan)
        self._transient_left = 0
        self.lost_shard: Optional[int] = None   # armed by shard_loss
        self.fired: Dict[str, int] = {k: 0 for k in self._inj}
        self.corrupted_batches: list = []
        self.bit_flip_events: list = []   # [{step, pages}] per firing

    # ------------------------------------------------------------- helpers
    def _fire(self, name: str, step: int) -> bool:
        if self._inj[name].fires(step):
            self.fired[name] += 1
            return True
        return False

    def _corrupt(self, step: int, batch: dict) -> dict:
        """Return a (possibly) corrupted shallow copy; never mutate the
        caller's batch — a retry must see the original data."""
        oob = (self.idx_key and self.idx_key in batch
               and self._fire("corrupt_oob", step))
        nan = (self.dense_key and self.dense_key in batch
               and self._fire("corrupt_nan", step))
        if not (oob or nan):
            return batch
        rng = np.random.default_rng([self.cfg.seed & 0x7FFFFFFF, step])
        batch = dict(batch)
        if oob:
            idx = np.array(batch[self.idx_key], copy=True)
            flat = idx.reshape(-1)
            k = max(1, flat.size // 64)
            pos = rng.choice(flat.size, size=k, replace=False)
            flat[pos] = self.oob_id
            batch[self.idx_key] = idx
        if nan:
            dense = np.array(batch[self.dense_key], copy=True,
                             dtype=np.float32)
            rows = rng.choice(dense.shape[0],
                              size=max(1, dense.shape[0] // 8),
                              replace=False)
            dense[rows] = np.nan
            batch[self.dense_key] = dense
        self.corrupted_batches.append(step)
        return batch

    def _resolve_lost_shard(self) -> int:
        """Which tp index dies: the configured one, else the highest tp
        index on the bound engine's mesh (the canonical 'last device on
        the fabric port' victim), else 0."""
        if self.cfg.shard_loss_shard >= 0:
            return self.cfg.shard_loss_shard
        binding = getattr(self.inner, "binding", None)
        if binding is not None:
            eng = binding.engine
            return max(0, eng.axes.tp_size(eng.mesh) - 1)
        return 0

    def on_remesh(self, event=None) -> None:
        """The runtime tells us the dead shard left the mesh: the
        persistent failure clears (the survivors' collectives no longer
        wait on the lost device)."""
        self.lost_shard = None

    # ------------------------------------------------ executor protocol
    def run_batch(self, bucket, batch) -> float:
        step = self._step
        self._step += 1
        if self.lost_shard is None and self._inj["shard_loss"].fires(step):
            self.lost_shard = self._resolve_lost_shard()
        if self.lost_shard is not None:
            # persistent until on_remesh(): every attempt that crosses
            # shards dies on the dead device's collective.  The hot-only
            # and shed rungs run zero cross-shard work (replicated hot
            # tier only), so a dead cold shard is invisible to them —
            # which is exactly why the ladder alone cannot *recover*,
            # only limp.
            binding = getattr(self.inner, "binding", None)
            rung = getattr(binding, "active", None)
            if rung not in ("hot_only", "shed"):
                self.fired["shard_loss"] += 1
                raise ShardLossFailure(
                    f"injected shard loss: tp shard {self.lost_shard} "
                    f"dead at attempt {step}", shard=self.lost_shard)
        if self._transient_left > 0:
            self._transient_left -= 1
            self.fired["transient"] += 1
            raise TransientServingFailure(
                f"injected transient failure (burst) at attempt {step}")
        if self._fire("transient", step):
            self._transient_left = self.cfg.transient_runs - 1
            raise TransientServingFailure(
                f"injected transient failure at attempt {step}")
        if self._fire("bit_flip", step):
            # silent store corruption: flip live page bits *before* this
            # attempt serves — the batch succeeds with finite wrong
            # scores, which is the whole point
            binding = getattr(self.inner, "binding", None)
            if binding is not None:
                pages = flip_store_bits(
                    binding, n_rows=self.cfg.bit_flip_rows,
                    seed=hash((self.cfg.seed, _SALTS["bit_flip"], step))
                    & 0x7FFFFFFF,
                    tier=self.cfg.bit_flip_tier)
                self.bit_flip_events.append(
                    {"step": step, "pages": [int(p) for p in pages]})
        batch = self._corrupt(step, batch)
        svc = self.inner.run_batch(bucket, batch)
        if self._fire("straggler", step):
            svc *= self.cfg.straggler_factor
        return svc

    def observe(self, batch) -> float:
        dt = self.inner.observe(batch)
        step = self._mstep
        self._mstep += 1
        if self._fire("stall", step):
            dt += self.cfg.stall_s
        return dt

    def replan(self) -> float:
        dt = self.inner.replan()
        step = self._mstep
        self._mstep += 1
        if self._fire("stall", step):
            dt += self.cfg.stall_s
        return dt

    def report(self) -> Dict[str, int]:
        return dict(self.fired)


def corrupt_store(binding, frac: float = 0.25, seed: int = 0,
                  mode: str = "nan") -> int:
    """Corrupt a fraction of the binding's replicated hot tier in place
    (the stand-in for a corrupted fabric-attached memory page).  Returns
    the number of poisoned rows.

    ``mode='nan'``: rows become NaN — lookups hitting them produce
    non-finite scores, which the ``scrub_scores`` path catches (and only
    ``binding.restore()`` heals).  ``mode='finite'``: each chosen row gets
    one mantissa bit flipped — the values stay finite, the score scrub is
    structurally blind to them, and only a checksum audit
    (``repro.core.integrity``) can detect the damage.  The NaN-only
    default used to overstate what ``scrub_scores`` covers; fault drills
    that claim scrub coverage must say ``mode='nan'`` explicitly.
    """
    import dataclasses as _dc

    import jax

    hot = np.array(binding.state.hot, copy=True)
    n = max(1, int(hot.shape[0] * frac))
    rng = np.random.default_rng(seed)
    rows = rng.choice(hot.shape[0], size=n, replace=False)
    if mode == "nan":
        hot[rows] = np.nan
    elif mode == "finite":
        # flip one mantissa bit per row: the exponent is untouched, so
        # finite values stay finite (zero becomes a subnormal) — wrong
        # embeddings that serve without a single non-finite score
        cols = rng.integers(0, hot.shape[1], size=n)
        bits = hot[rows, cols].astype(np.float32).view(np.uint32)
        bits ^= (np.uint32(1) << rng.integers(0, 23, size=n,
                                              dtype=np.uint32))
        hot[rows, cols] = bits.view(np.float32)
    else:
        raise ValueError(f"unknown corrupt_store mode {mode!r} "
                         "(expected 'nan' or 'finite')")
    sh = binding.engine.state_shardings().hot
    binding.state = _dc.replace(
        binding.state, hot=jax.device_put(hot.astype(np.float32), sh))
    return n


def flip_store_bits(binding, n_rows: int = 2, seed: int = 0,
                    tier: str = "both") -> list:
    """Flip one bit in each of ``n_rows`` live store rows — deterministic,
    seeded, always *finite* (fp32 flips stay in the mantissa; int8 code
    flips are finite by construction).  Returns the sorted list of global
    page ids touched (what a scrub sweep must detect).

    ``tier`` picks victim pages: ``'hot'`` (replicated fp32 tier),
    ``'cold'`` (sharded fp32-or-int8 tier), or ``'both'``.  The flip is
    applied to the page's *native-domain* content — exactly the bytes the
    per-page checksum covers — so every flip is detectable by one audit
    of its page.
    """
    import dataclasses as _dc

    import jax

    from repro.core.paging import HOT_SHARD

    eng = binding.engine
    cfg = eng.cfg
    ps = cfg.page_size
    rng = np.random.default_rng(seed)
    p2s = np.asarray(binding.state.page_to_shard)
    p2slot = np.asarray(binding.state.page_to_slot)
    hot_pages = np.nonzero(p2s == HOT_SHARD)[0]
    cold_pages = np.nonzero(p2s != HOT_SHARD)[0]
    if tier == "hot":
        candidates = hot_pages
    elif tier == "cold":
        candidates = cold_pages
    elif tier == "both":
        candidates = np.concatenate([hot_pages, cold_pages])
    else:
        raise ValueError(f"unknown tier {tier!r} "
                         "(expected 'hot', 'cold', or 'both')")
    if candidates.size == 0:
        raise ValueError(f"no pages resident in tier {tier!r} to corrupt")

    hot = np.array(binding.state.hot, copy=True)
    cold = np.array(binding.state.cold, copy=True)
    touched = set()
    hot_dirty = cold_dirty = False
    for _ in range(int(n_rows)):
        page = int(rng.choice(candidates))
        off = int(rng.integers(0, ps))
        col = int(rng.integers(0, cfg.dim))
        touched.add(page)
        if p2s[page] == HOT_SHARD:
            r = int(p2slot[page]) * ps + off
            bits = np.float32(hot[r, col]).view(np.uint32)
            bits ^= np.uint32(1) << rng.integers(0, 23, dtype=np.uint32)
            hot[r, col] = bits.view(np.float32)
            hot_dirty = True
        else:
            r = int(p2s[page]) * cfg.rows_per_shard + int(p2slot[page]) * ps \
                + off
            if cold.dtype == np.int8:
                bits = np.int8(cold[r, col]).view(np.uint8)
                bits ^= np.uint8(1) << rng.integers(0, 8, dtype=np.uint8)
                cold[r, col] = bits.view(np.int8)
            else:
                bits = np.float32(cold[r, col]).view(np.uint32)
                bits ^= np.uint32(1) << rng.integers(0, 23, dtype=np.uint32)
                cold[r, col] = bits.view(np.float32)
            cold_dirty = True
    sh = eng.state_shardings()
    new = binding.state
    if hot_dirty:
        new = _dc.replace(new, hot=jax.device_put(hot, sh.hot))
    if cold_dirty:
        new = _dc.replace(new, cold=jax.device_put(cold, sh.cold))
    binding.state = new
    return sorted(int(p) for p in touched)
