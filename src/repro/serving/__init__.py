"""repro.serving — deadline-aware dynamic-batching serving runtime.

The online-inference layer the paper evaluates under (concurrent
production-style access streams, tail-latency SLOs) on top of the PIFS
engine's compiled-lookup plan cache:

  request.py     — Request, arrival processes, bounded admission queue
  batcher.py     — shape buckets, deadline-aware coalescing, exact padding
  metrics.py     — latency histograms, p50/p90/p99/p99.9, QPS, SLO/
                   availability accounting
  runtime.py     — the discrete-event loop + engine executor + load sources
  loadgen.py     — model bindings, padders, request streams (open/closed)
  faults.py      — deterministic fault injection around any executor
  degradation.py — retry / circuit breaker / brown-out ladder controller
  updates.py     — streaming embedding updates between micro-batches
                   (WAL-logged delta apply, staleness SLOs, requant-demote)
  scrub.py       — integrity scrubbing: per-page checksum audits on the
                   maintenance seam + page-granular snapshot/WAL repair

The engine-facing seam is ``repro.core.pifs.ServeBinding``.
"""
from repro.serving.batcher import (BatcherConfig, Bucket, DynamicBatcher,
                                   FixedBatcher, FixedServiceModel, Flush,
                                   ServiceModel, Wait, pad_pooled_indices,
                                   stack_feature)
from repro.serving.degradation import (RUNGS, BreakerConfig, CircuitBreaker,
                                       DegradationController, LadderConfig,
                                       RetryPolicy)
from repro.serving.faults import (FaultConfig, FaultInjectingExecutor,
                                  ShardLossFailure, TransientServingFailure,
                                  corrupt_store, flip_store_bits)
from repro.core.updates import UpdateConfig
from repro.serving.loadgen import (LoadConfig, bind_model,
                                   closed_loop_factory,
                                   dummy_request_factory, make_padder,
                                   prime_dedup_auto, request_stream,
                                   update_stream)
from repro.serving.metrics import LatencyHistogram, ServingMetrics
from repro.serving.request import (AdmissionQueue, ArrivalConfig, Request,
                                   arrival_times)
from repro.serving.runtime import (BindingExecutor, ClosedLoopSource,
                                   OpenLoopSource, RuntimeConfig,
                                   ServingRuntime, SimulatedExecutor)
from repro.serving.scrub import ScrubConfig, ScrubController
from repro.serving.updates import StreamingUpdater, UpdateBatch

__all__ = [
    "AdmissionQueue", "ArrivalConfig", "BatcherConfig", "BindingExecutor",
    "BreakerConfig", "Bucket", "CircuitBreaker", "ClosedLoopSource",
    "DegradationController", "DynamicBatcher", "FaultConfig",
    "FaultInjectingExecutor", "FixedBatcher", "FixedServiceModel", "Flush",
    "LadderConfig", "LatencyHistogram", "LoadConfig", "OpenLoopSource",
    "RUNGS", "Request", "RetryPolicy", "RuntimeConfig", "ScrubConfig",
    "ScrubController", "ServiceModel",
    "ServingMetrics", "ServingRuntime", "ShardLossFailure",
    "SimulatedExecutor",
    "StreamingUpdater", "TransientServingFailure", "UpdateBatch",
    "UpdateConfig", "Wait", "arrival_times", "bind_model",
    "closed_loop_factory", "corrupt_store", "dummy_request_factory",
    "flip_store_bits", "make_padder", "pad_pooled_indices",
    "prime_dedup_auto", "request_stream", "stack_feature", "update_stream",
]
