"""Serving metrics core: tail-latency histograms, queue/occupancy/QPS/SLO.

Latencies are kept both raw (exact percentiles — request counts in this
repo are 1e3-1e5, trivially held) and as a log-spaced histogram (the
export format that survives aggregation across runs/hosts; schema in
EXPERIMENTS.md §Serving).  Percentiles reported: p50 / p90 / p99 / p99.9.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serving.batcher import Bucket
from repro.serving.request import Request

PERCENTILES = (50.0, 90.0, 99.0, 99.9)


class LatencyHistogram:
    """Log-spaced latency histogram (lo_ms..hi_ms) + raw samples."""

    def __init__(self, lo_ms: float = 1e-3, hi_ms: float = 6e4,
                 n_bins: int = 128):
        self.edges_ms = np.logspace(np.log10(lo_ms), np.log10(hi_ms),
                                    n_bins + 1)
        self.counts = np.zeros(n_bins, dtype=np.int64)
        self._raw_ms: List[float] = []
        self.nonfinite = 0

    def record(self, seconds: float) -> None:
        if not np.isfinite(seconds):
            # NaN/Inf samples (a request that never started, a poisoned
            # clock) must not poison the percentiles — count, don't record
            self.nonfinite += 1
            return
        ms = seconds * 1e3
        self._raw_ms.append(ms)
        b = int(np.searchsorted(self.edges_ms, ms, side="right") - 1)
        self.counts[max(0, min(b, len(self.counts) - 1))] += 1

    def __len__(self) -> int:
        return len(self._raw_ms)

    def percentiles_ms(self) -> Dict[str, float]:
        if not self._raw_ms:
            return {f"p{str(q).rstrip('0').rstrip('.')}_ms": float("nan")
                    for q in PERCENTILES}
        raw = np.asarray(self._raw_ms)
        out = {}
        for q in PERCENTILES:
            label = f"p{str(q).rstrip('0').rstrip('.')}_ms"
            out[label] = float(np.percentile(raw, q))
        out["mean_ms"] = float(raw.mean())
        out["max_ms"] = float(raw.max())
        return out

    def export(self) -> Dict[str, list]:
        """Histogram-only export (aggregatable; no raw samples): per
        non-empty bin, its [lo, hi) edges and count — bins need not be
        contiguous, so each carries both edges."""
        nz = np.nonzero(self.counts)[0]
        return {"bin_lo_ms": [float(self.edges_ms[i]) for i in nz],
                "bin_hi_ms": [float(self.edges_ms[i + 1]) for i in nz],
                "counts": [int(self.counts[i]) for i in nz]}


@dataclasses.dataclass
class BatchRecord:
    t: float
    bucket: Bucket
    n_real: int
    service_s: float
    queue_depth: int        # depth *after* popping this batch

    @property
    def occupancy(self) -> float:
        return self.n_real / self.bucket.batch


class ServingMetrics:
    """Aggregates everything the serving runtime observes."""

    def __init__(self):
        self.latency = LatencyHistogram()
        self.queue_wait = LatencyHistogram()
        self.batches: List[BatchRecord] = []
        self.served = 0
        self.slo_violations = 0
        self.dropped = 0
        self.failed = 0            # retry budget exhausted / breaker open
        self.failed_fast = 0       # subset of failed: rejected by open breaker
        self.retries = 0           # extra run_batch attempts that succeeded
                                   # a request (set by the runtime)
        self.maintenance_s: Dict[str, float] = {}
        self.maintenance_calls: Dict[str, int] = {}
        self.first_arrival_s: Optional[float] = None
        self.last_finish_s: float = 0.0
        # streaming-update staleness samples, one per micro-batch boundary
        # (recorded by the updater *before* it drains): how far serving
        # lags the trainer's delta stream
        self.staleness_rows: List[float] = []
        self.staleness_s: List[float] = []
        # integrity-scrub counters (recorded by the ScrubController on the
        # maintenance seam): audit coverage, detections, per-page repair
        # MTTR samples
        self.scrub_cycles = 0
        self.scrub_pages_audited = 0
        self.scrub_pages_detected = 0
        self.scrub_pages_repaired = 0
        self.scrub_repair_s: List[float] = []

    # ------------------------------------------------------------ recording
    def record_request(self, req: Request) -> None:
        self.served += 1
        self.latency.record(req.latency_s)
        self.queue_wait.record(req.queued_s)
        if not req.slo_ok:
            self.slo_violations += 1
        if self.first_arrival_s is None or req.arrival_s < self.first_arrival_s:
            self.first_arrival_s = req.arrival_s
        self.last_finish_s = max(self.last_finish_s, req.finish_s)

    def record_batch(self, t: float, bucket: Bucket, n_real: int,
                     service_s: float, queue_depth: int) -> None:
        self.batches.append(BatchRecord(t, bucket, n_real, service_s,
                                        queue_depth))

    def record_drop(self, req: Request) -> None:
        self.dropped += 1

    def record_failure(self, req: Request, fast: bool = False) -> None:
        """A request whose retry budget was exhausted (or that an open
        circuit breaker failed fast).  Counted exactly once: failed
        requests never pass through ``record_request``, they contribute
        one SLO violation here, and availability/goodput treat them as
        unserved."""
        self.failed += 1
        if fast:
            self.failed_fast += 1
        self.slo_violations += 1
        if self.first_arrival_s is None or req.arrival_s < self.first_arrival_s:
            self.first_arrival_s = req.arrival_s
        if np.isfinite(req.finish_s):
            self.last_finish_s = max(self.last_finish_s, req.finish_s)

    def record_maintenance(self, kind: str, seconds: float) -> None:
        self.maintenance_s[kind] = self.maintenance_s.get(kind, 0.0) + seconds
        self.maintenance_calls[kind] = self.maintenance_calls.get(kind, 0) + 1

    def record_staleness(self, rows_behind: float, seconds_behind: float
                         ) -> None:
        """One update-lag sample: rows generated-but-unapplied at a
        micro-batch boundary, and the age of the oldest pending batch."""
        self.staleness_rows.append(float(rows_behind))
        self.staleness_s.append(float(seconds_behind))

    def record_scrub(self, pages: int) -> None:
        """One scrub cycle audited ``pages`` pages."""
        self.scrub_cycles += 1
        self.scrub_pages_audited += int(pages)

    def record_scrub_detection(self, page: int) -> None:
        """A page's live checksum diverged from the ledger (first
        detection of that page)."""
        self.scrub_pages_detected += 1

    def record_scrub_repair(self, page: int, seconds: float) -> None:
        """One page repaired; ``seconds`` is its repair MTTR (detection
        to verified write-back)."""
        self.scrub_pages_repaired += 1
        self.scrub_repair_s.append(float(seconds))

    # ------------------------------------------------------------- summary
    def summary(self) -> Dict[str, object]:
        # guard the degenerate windows the fault bench hits: an all-shed
        # regime serves nothing (no first arrival, zero duration) and a
        # fail-everything regime can finish at its only arrival instant —
        # every rate below must stay finite (0.0), never divide by zero
        makespan = self.last_finish_s - (self.first_arrival_s or 0.0)
        if not np.isfinite(makespan) or makespan <= 0.0:
            makespan = float("nan")
        completed = self.served + self.failed     # everything not shed
        good = completed - self.slo_violations    # served inside SLO
        occ = [b.occupancy for b in self.batches]
        depth = [b.queue_depth for b in self.batches]
        bucket_mix: Dict[str, int] = {}
        for b in self.batches:
            k = f"{b.bucket.batch}x{b.bucket.pooling}"
            bucket_mix[k] = bucket_mix.get(k, 0) + 1
        out: Dict[str, object] = {
            "served": self.served,
            "dropped": self.dropped,
            "failed": self.failed,
            "failed_fast": self.failed_fast,
            "retries": self.retries,
            "batches": len(self.batches),
            "qps": self.served / makespan if makespan == makespan else 0.0,
            "goodput_qps": (good / makespan if makespan == makespan else 0.0),
            "availability": (self.served / completed if completed else 1.0),
            "slo_violation_rate": (self.slo_violations / completed
                                   if completed else 0.0),
            "batch_occupancy_mean": float(np.mean(occ)) if occ else 0.0,
            "queue_depth_mean": float(np.mean(depth)) if depth else 0.0,
            "queue_depth_max": int(np.max(depth)) if depth else 0,
            "bucket_mix": bucket_mix,
            "maintenance_s": {k: round(v, 6)
                              for k, v in self.maintenance_s.items()},
            "maintenance_calls": dict(self.maintenance_calls),
        }
        out.update(self.latency.percentiles_ms())
        qw = self.queue_wait.percentiles_ms()
        out["queue_wait_p50_ms"] = qw["p50_ms"]
        out["queue_wait_p99_ms"] = qw["p99_ms"]
        # present only when an update stream ran: runs without one keep
        # the exact legacy summary shape
        if self.staleness_rows:
            rows = np.asarray(self.staleness_rows)
            secs = np.asarray(self.staleness_s)
            out["staleness"] = {
                "samples": int(rows.size),
                "rows_behind_p50": float(np.percentile(rows, 50.0)),
                "rows_behind_p99": float(np.percentile(rows, 99.0)),
                "rows_behind_max": float(rows.max()),
                "seconds_behind_p50": float(np.percentile(secs, 50.0)),
                "seconds_behind_p99": float(np.percentile(secs, 99.0)),
                "seconds_behind_max": float(secs.max()),
            }
        # present only when a scrub controller ran: runs without one keep
        # the exact legacy summary shape
        if self.scrub_cycles:
            scrub: Dict[str, object] = {
                "cycles": self.scrub_cycles,
                "pages_audited": self.scrub_pages_audited,
                "pages_detected": self.scrub_pages_detected,
                "pages_repaired": self.scrub_pages_repaired,
            }
            if self.scrub_repair_s:
                rep = np.asarray(self.scrub_repair_s)
                scrub["repair_mttr_mean_s"] = float(rep.mean())
                scrub["repair_mttr_max_s"] = float(rep.max())
            out["scrub"] = scrub
        out["latency_hist"] = self.latency.export()
        return out
