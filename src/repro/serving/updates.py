"""Streaming embedding updates under live traffic (the serving half).

The trainer side of a production recommender emits a continuous stream of
embedding-row deltas; the serving side must fold them into the live
tables without blowing the service tail.  This module drives the engine's
``apply_deltas`` path through the same maintenance seam that observe/
replan/restore already use: pending delta batches are drained *between*
micro-batches, never inside the timed service path, and the wall time is
recorded (and optionally charged to the virtual clock) exactly like every
other maintenance kind.

Three concerns ride the same cadence:

  * **Apply** — due batches (virtual ``t_gen`` <= now) are coalesced,
    write-ahead-logged, and applied in fixed-capacity chunks (zero
    steady-state retraces; see ``repro.core.updates``).
  * **Staleness accounting** — at every micro-batch boundary, *before*
    draining, the updater samples how far serving lags the update stream:
    ``rows_behind`` (rows generated-but-unapplied) and ``seconds_behind``
    (age of the oldest due batch).  p50/p99 land in the metrics summary —
    the serving-side SLO of the update subsystem.
  * **Requant-demote** — applied deltas pull hot fp32 rows off their
    carried-scale grid; on a configurable cadence the updater demotes
    drifted, traffic-cold hot pages back into the int8 cold tier (the
    planner's placement discipline, the engine's typed migrate), and
    takes WAL-truncating snapshots.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.updates import (PAD_ROW, DriftTracker, UpdateConfig,
                                demote_table)


@dataclasses.dataclass(frozen=True)
class UpdateBatch:
    """One trainer-emitted delta batch on the virtual clock."""
    seq: int
    t_gen: float            # virtual generation time (seconds)
    rows: np.ndarray        # (n,) global row ids
    deltas: np.ndarray      # (n, D) float32


class StreamingUpdater:
    """Drains an update stream through a ServeBinding between micro-batches.

    Plugs into ``ServingRuntime`` as ``runtime.updater``: the event loop
    calls :meth:`on_batch` after each micro-batch's own maintenance, and
    treats the returned wall seconds like any other maintenance cost.
    """

    def __init__(self, binding, batches: Sequence[UpdateBatch],
                 cfg: UpdateConfig = UpdateConfig(), wal=None):
        self.binding = binding
        self.cfg = cfg
        binding.update_capacity = cfg.capacity
        if wal is not None:
            binding.attach_wal(wal)
        self.pending = deque(
            sorted(batches, key=lambda b: (b.t_gen, b.seq)))
        self.generated_batches = len(self.pending)
        self.generated_rows = int(sum(len(b.rows) for b in self.pending))
        self.tracker = DriftTracker(binding.engine.cfg)
        self.applied_batches = 0
        self.applied_rows = 0
        self.demoted_pages = 0
        self.snapshots = 0
        self._mb = 0            # micro-batches seen

    # ----------------------------------------------------------- warmup
    def warmup(self) -> None:
        """Compile the apply plan before steady state (an all-pad batch —
        every scatter target is dropped, so state is untouched bit-for-bit
        while the (storage, capacity) signature traces).  Counted traces
        land before the caller resets plan stats, preserving the
        zero-steady-retrace contract once live updates flow."""
        eng = self.binding.engine
        rows = jnp.asarray(np.full(self.cfg.capacity, PAD_ROW, np.int32))
        deltas = jnp.asarray(
            np.zeros((self.cfg.capacity, eng.cfg.dim), np.float32))
        new = eng.apply_deltas(self.binding.state, rows, deltas)
        jax.block_until_ready((new.cold, new.hot))
        self.binding.state = new

    # ------------------------------------------------------- event hook
    def on_batch(self, now: float, metrics=None) -> float:
        """One maintenance turn at virtual time ``now``.

        Samples staleness (pre-drain — the lag the serving loop actually
        exposed), then applies every due batch unless this turn is
        skipped by ``apply_every``.  Returns wall seconds spent applying
        (0.0 when nothing was due)."""
        self._mb += 1
        due_rows = 0
        oldest: Optional[float] = None
        for b in self.pending:
            if b.t_gen > now:
                break
            if oldest is None:
                oldest = b.t_gen
            due_rows += len(b.rows)
        if metrics is not None:
            metrics.record_staleness(
                due_rows, (now - oldest) if oldest is not None else 0.0)
        if self.cfg.apply_every > 1 and self._mb % self.cfg.apply_every:
            return 0.0
        if due_rows == 0:
            return 0.0
        t0 = time.perf_counter()
        self._drain_due(now)
        return time.perf_counter() - t0

    def _drain_due(self, now: float) -> None:
        cfg = self.cfg
        while self.pending and self.pending[0].t_gen <= now:
            b = self.pending.popleft()
            n = self.binding.apply_deltas(b.rows, b.deltas)
            self.tracker.update(b.rows, b.deltas)
            self.applied_batches += 1
            self.applied_rows += n
            if cfg.demote_every and \
                    self.applied_batches % cfg.demote_every == 0:
                self.requant_demote()
            if cfg.snapshot_every and \
                    self.applied_batches % cfg.snapshot_every == 0:
                self.binding.snapshot()
                self.snapshots += 1

    def drain(self) -> int:
        """Apply *everything* still pending (end-of-run flush; not timed).
        Returns the number of batches applied."""
        n = len(self.pending)
        self._drain_due(float("inf"))
        return n

    # -------------------------------------------------- requant-demote
    def requant_demote(self) -> int:
        """One demote scan: pick drifted, traffic-cold hot pages (the
        tracker's drift mass vs the observe-phase access histogram) and
        migrate them into the cold tier.  For int8 storage the typed
        migrate re-quantizes with each page's carried scale; counts are
        *not* decayed (this is not a replan).  Returns pages demoted."""
        binding = self.binding
        if binding.wal is not None and binding.checkpointer is None:
            raise RuntimeError(
                "requant-demote with a WAL attached requires a "
                "checkpointer: demotions are not WAL-representable, so "
                "every demote must fence with a WAL-truncating snapshot "
                "or a later restore's replay diverges from the live run")
        eng = binding.engine
        state = binding.state
        counts = np.asarray(jax.device_get(state.counts))
        table = state.page_table
        pages = self.tracker.demote_candidates(table, counts, self.cfg)
        if pages.size == 0:
            return 0
        new_table = demote_table(eng.cfg, table, counts, pages)
        new = eng.migrate(state, new_table, count_decay=1.0)
        jax.block_until_ready((new.cold, new.hot))
        binding.state = new
        if getattr(binding, "integrity", None) is not None:
            # demoted pages change native-domain content (hot fp32 ->
            # requantized codes): refresh their checksum ledger entries
            binding.integrity.note_tier_changes(
                new, np.asarray(table.page_to_shard),
                np.asarray(new_table.page_to_shard))
        self.tracker.note_requantized(pages)
        self.demoted_pages += int(pages.size)
        # Demotions move rows between tiers and are NOT WAL-logged (the
        # WAL holds deltas only), so a post-snapshot demote would make
        # replay diverge.  Fence it: a demote forces a WAL-truncating
        # snapshot, keeping mid-serving restore bit-exact unconditionally
        # (the WAL-without-checkpointer case raised at entry above).
        if binding.checkpointer is not None:
            binding.snapshot()
            self.snapshots += 1
        return int(pages.size)

    # ----------------------------------------------------------- report
    def report(self) -> dict:
        out = {
            "generated_batches": self.generated_batches,
            "generated_rows": self.generated_rows,
            "applied_batches": self.applied_batches,
            "applied_rows": self.applied_rows,
            "pending_batches": len(self.pending),
            "demoted_pages": self.demoted_pages,
            "snapshots": self.snapshots,
            "update_seq": self.binding.update_seq,
        }
        if self.binding.wal is not None:
            out["wal_records"] = len(self.binding.wal)
        return out
