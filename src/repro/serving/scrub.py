"""Background integrity scrubbing + page-granular self-healing.

The :class:`ScrubController` rides the ServingRuntime maintenance seam
exactly like :class:`repro.serving.updates.StreamingUpdater`: after each
micro-batch's own maintenance, the event loop calls :meth:`on_batch` and
treats the returned wall seconds as maintenance time (never part of the
service EMA).  Each turn audits a rotating window of K pages against the
binding's per-page checksum ledger (``repro.core.integrity``) through one
fixed jitted reduction signature — a full sweep of the store every
``ceil(num_pages / K)`` cycles, zero steady-state retraces.

On divergence the page is *quarantined* and repaired surgically:

  1. capture the ledger's expected checksum (the pre-corruption truth —
     flips never touch the ledger, only legitimate mutations do);
  2. fetch just that page's rows from the last committed snapshot
     (``Checkpointer.read_page``: a memory-mapped slice, never the full
     store leaf) and verify them on the host against the snapshot-time
     ledger recorded in the manifest — a rotted snapshot fails loudly
     here instead of being written into the store;
  3. write the snapshot page back through the engine's single-page
     scatter (``write_page``);
  4. replay every WAL record past the snapshot's sequence point,
     *filtered to this page's rows*, through the identical coalesce/apply
     path the live stream used;
  5. re-verify: the page's device-recomputed checksum must equal the
     expected one — the repaired store is bit-identical to a
     never-corrupted engine, or the repair raises.

Repair assumes the page's tier has not flipped since the snapshot; the
binding's mutation paths enforce that by WAL-fencing every tier flip with
a fresh (WAL-truncating) snapshot when integrity is armed — see
``ServeBinding.replan`` / ``StreamingUpdater.requant_demote``.
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.integrity import fetch_snapshot_page, page_checksum_host
from repro.core.paging import HOT_SHARD
from repro.core.updates import PAD_ROW


@dataclasses.dataclass(frozen=True)
class ScrubConfig:
    """``pages_per_cycle``: the rotating audit window K (clamped to the
    store's page count); ``scrub_every``: audit every Nth maintenance
    turn; ``repair``: heal detected pages from snapshot + WAL (False =
    detect-and-quarantine only)."""
    pages_per_cycle: int = 8
    scrub_every: int = 1
    repair: bool = True


class ScrubController:
    """Audits a ServeBinding's store against its checksum ledger and
    repairs diverged pages page-granularly.  Plugs into ``ServingRuntime``
    as ``runtime.scrubber``."""

    def __init__(self, binding, cfg: ScrubConfig = ScrubConfig(),
                 controller=None):
        if getattr(binding, "integrity", None) is None:
            raise RuntimeError(
                "ScrubController needs an armed integrity ledger — call "
                "binding.attach_integrity() first")
        self.binding = binding
        self.cfg = cfg
        self.controller = controller   # DegradationController or None
        n = int(binding.engine.cfg.num_pages)
        self.window = max(1, min(int(cfg.pages_per_cycle), n))
        self.cursor = 0
        self.cycles = 0                # audit turns actually run
        self._mb = 0                   # maintenance turns seen
        self.pages_audited = 0
        self.quarantined: set = set()
        self.detected_cycle: dict = {}   # page -> cycle of first detection
        self.repairs: list = []          # [{page, mttr_s, wal_batches, cycle}]

    # ----------------------------------------------------------- warmup
    def warmup(self) -> None:
        """Compile every plan the scrub/repair path needs, outside the
        timed loop: the fixed-window checksum reduction (all-pad window —
        reads nothing), the single-page writer (page -1 — every scatter
        drops, state bit-untouched), and, when a WAL is attached, the
        fixed-capacity apply plan the replay path uses."""
        binding = self.binding
        eng = binding.engine
        state = binding.state
        binding.integrity.warmup(state)
        ps, d = eng.cfg.page_size, eng.cfg.dim
        new = eng.write_page(
            state, -1, np.zeros((ps, d), eng.cold_dtype),
            np.zeros((ps, d), np.float32), 1.0)
        jax.block_until_ready((new.cold, new.hot))
        binding.state = new
        if binding.wal is not None:
            cap = binding.update_capacity
            rows = jnp.asarray(np.full(cap, PAD_ROW, np.int32))
            deltas = jnp.asarray(np.zeros((cap, d), np.float32))
            new = eng.apply_deltas(binding.state, rows, deltas)
            jax.block_until_ready((new.cold, new.hot))
            binding.state = new

    # ------------------------------------------------------- event hook
    def on_batch(self, now: float, metrics=None) -> float:
        """One maintenance turn: audit the next window of pages, repair
        any divergence.  Returns wall seconds spent (scrub + repair)."""
        self._mb += 1
        if self.cfg.scrub_every > 1 and self._mb % self.cfg.scrub_every:
            return 0.0
        t0 = time.perf_counter()
        n = int(self.binding.engine.cfg.num_pages)
        window = (self.cursor + np.arange(self.window)) % n
        self.cursor = int((self.cursor + self.window) % n)
        self.cycles += 1
        self.pages_audited += int(window.size)
        bad = self.binding.integrity.verify(self.binding.state, window)
        if metrics is not None:
            metrics.record_scrub(int(window.size))
        for page in bad:
            self._on_detect(int(page), now, metrics)
        return time.perf_counter() - t0

    def _on_detect(self, page: int, now: float, metrics=None) -> None:
        if page not in self.detected_cycle:
            self.detected_cycle[page] = self.cycles
            if metrics is not None:
                metrics.record_scrub_detection(page)
            if self.controller is not None:
                # a silent flip is evidence of store trouble, but softer
                # than a dead shard: bump failure pressure at the same
                # half weight a straggler carries
                self.controller.on_corruption(now)
        self.quarantined.add(page)
        if not (self.cfg.repair and self.binding.checkpointer is not None):
            return
        t0 = time.perf_counter()
        replayed = self._repair(page)
        mttr = time.perf_counter() - t0
        self.quarantined.discard(page)
        self.repairs.append({"page": page, "mttr_s": mttr,
                             "wal_batches": replayed,
                             "cycle": self.cycles})
        if metrics is not None:
            metrics.record_scrub_repair(page, mttr)

    # ------------------------------------------------------------ repair
    def _repair(self, page: int) -> int:
        """Surgical single-page repair; returns WAL batches replayed.

        Raises rather than degrade: a repair that cannot prove bitwise
        equality with the never-corrupted state must not silently pass.
        """
        binding = self.binding
        eng = binding.engine
        ledger = binding.integrity
        # the expected checksum BEFORE any write-back: the replay below
        # routes through binding.apply_deltas, whose ledger hook would
        # overwrite this entry with whatever we produced
        expected = int(ledger.checksums[page])
        snap = fetch_snapshot_page(binding.checkpointer, eng.cfg, page)
        if snap["checksum"] is not None:
            got = page_checksum_host(snap["rows"], snap["scale"])
            if got != snap["checksum"]:
                raise IOError(
                    f"page {page}: snapshot itself fails its recorded "
                    f"checksum ({got:016x} != {snap['checksum']:016x}) — "
                    "the snapshot is corrupt, full restore() is the only "
                    "heal path")
        live_hot = bool(np.asarray(
            binding.state.page_to_shard)[page] == HOT_SHARD)
        snap_hot = snap["tier"] == "hot"
        if live_hot != snap_hot and eng.quantized:
            raise RuntimeError(
                f"page {page}: tier flipped since the snapshot "
                f"({snap['tier']} -> {'hot' if live_hot else 'cold'}) — "
                "quantized-domain updates do not replay across a flip. "
                "Mutation paths WAL-fence tier flips with a snapshot "
                "when integrity is armed; a missing fence is a bug.")
        ps, d = eng.cfg.page_size, eng.cfg.dim
        rows = np.asarray(snap["rows"])
        if snap_hot and not live_hot:
            # fp32 storage only (the quantized case raised above): hot
            # and cold content are the same domain, copy verbatim
            cold_rows, hot_rows = rows, np.zeros((ps, d), np.float32)
        elif live_hot and not snap_hot:
            if eng.quantized:
                raise AssertionError("unreachable: guarded above")
            cold_rows, hot_rows = np.zeros((ps, d), eng.cold_dtype), rows
        elif live_hot:
            cold_rows, hot_rows = np.zeros((ps, d), eng.cold_dtype), rows
        else:
            cold_rows, hot_rows = rows, np.zeros((ps, d), np.float32)
        new = eng.write_page(binding.state, page, cold_rows, hot_rows,
                             snap["scale"])
        jax.block_until_ready((new.cold, new.hot))
        binding.state = new
        # the write-back restored the snapshot content; re-record it so
        # the replay's apply hook starts from a consistent entry
        ledger.note_pages(binding.state, [page])
        replayed = 0
        if binding.wal is not None:
            snap_seq = int(binding.checkpointer.extra().get("update_seq", 0))
            lo, hi = page * ps, (page + 1) * ps
            for seq, wrows, wdeltas in binding.wal.replay():
                if seq <= snap_seq:
                    continue
                wrows = np.asarray(wrows)
                m = (wrows >= lo) & (wrows < hi)
                if not m.any():
                    continue
                binding.apply_deltas(wrows[m], np.asarray(wdeltas)[m],
                                     log=False)
                replayed += 1
        live = int(ledger.compute(binding.state, [page])[0])
        if live != expected:
            raise RuntimeError(
                f"page {page}: repair failed re-verification "
                f"({live:016x} != expected {expected:016x}) — repaired "
                "content is not bit-identical to the never-corrupted "
                "state")
        # pin the ledger back to the (equal) expected value explicitly
        ledger.checksums[page] = np.uint64(expected)
        return replayed

    # ----------------------------------------------------------- report
    def report(self) -> dict:
        n = int(self.binding.engine.cfg.num_pages)
        sweep_cycles = int(math.ceil(n / self.window))
        out = {
            "cycles": self.cycles,
            "pages_per_cycle": self.window,
            "pages_audited": self.pages_audited,
            "pages_detected": len(self.detected_cycle),
            "pages_repaired": len(self.repairs),
            "sweep_cycles": sweep_cycles,
            "sweeps_completed": self.cycles // sweep_cycles,
            "coverage": min(1.0, (self.cycles * self.window) / max(n, 1)),
            "quarantined": sorted(self.quarantined),
            "detections": {int(p): int(c)
                           for p, c in self.detected_cycle.items()},
            "repairs": list(self.repairs),
        }
        if self.repairs:
            mttrs = [r["mttr_s"] for r in self.repairs]
            out["repair_mttr_mean_s"] = float(np.mean(mttrs))
            out["repair_mttr_max_s"] = float(np.max(mttrs))
        return out
