"""The serving runtime: a discrete-event loop over arrivals, the bounded
admission queue, the deadline-aware batcher, and the engine executor.

Time model
----------
Arrivals live on a *virtual* clock (seconds, from the arrival process or a
closed-loop driver); service times come from wherever the executor gets
them — the real executor measures wall time of the jitted serve step on
the device, the simulated executor evaluates a deterministic service
model.  Queueing delay (the quantity that separates batching policies) is
exact virtual time either way, so offered-load sweeps and p99 comparisons
are meaningful even on CPU containers.

Maintenance folding
-------------------
``observe`` (access-histogram update) and periodic ``plan_and_migrate``
(hot-page re-planning, paper §IV-B4) run between micro-batches at a
configurable cadence.  Because engine lookups are placement-invariant and
migration is a pure gather, a production deployment overlaps them with
serving on a background stream; the event loop models that by *not*
advancing the virtual clock for maintenance (set
``account_maintenance=True`` to charge it to the serving path instead —
the pessimistic bound).  Wall time spent is always recorded in metrics.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.batcher import (Bucket, Flush, ServiceModel, Wait)
from repro.serving.metrics import ServingMetrics
from repro.serving.request import AdmissionQueue, Request


# ---------------------------------------------------------------------------
# Load sources: open-loop (pre-scheduled) and closed-loop (completion-driven)
# ---------------------------------------------------------------------------


class OpenLoopSource:
    """Offered-load stream with pre-computed arrival times."""

    def __init__(self, requests: Sequence[Request]):
        self.requests = sorted(requests, key=lambda r: (r.arrival_s, r.rid))

    def initial(self) -> List[Request]:
        return list(self.requests)

    def on_complete(self, req: Request, now: float) -> List[Request]:
        return []


class ClosedLoopSource:
    """N virtual users, each issuing its next request ``think_time_s``
    after the previous one completes (classic closed-loop load)."""

    def __init__(self, n_users: int, n_requests: int,
                 factory: Callable[[int, int, float], Request],
                 think_time_s: float = 0.0):
        self.n_users = n_users
        self.n_requests = n_requests
        self.factory = factory          # (rid, user, arrival_s) -> Request
        self.think_time_s = think_time_s
        self._next_rid = 0

    def _make(self, user: int, arrival_s: float) -> Optional[Request]:
        if self._next_rid >= self.n_requests:
            return None
        rid = self._next_rid
        self._next_rid += 1
        req = self.factory(rid, user, arrival_s)
        req.user = user
        return req

    def initial(self) -> List[Request]:
        out = []
        for u in range(self.n_users):
            r = self._make(u, 0.0)
            if r:
                out.append(r)
        return out

    def on_complete(self, req: Request, now: float) -> List[Request]:
        r = self._make(req.user, now + self.think_time_s)
        return [r] if r else []


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------


class BindingExecutor:
    """Runs micro-batches on a real engine through the ``ServeBinding`` seam
    (core/pifs.py), measuring device wall time."""

    def __init__(self, binding):
        self.binding = binding

    def run_batch(self, bucket: Bucket, batch: Dict[str, np.ndarray]) -> float:
        t0 = time.perf_counter()
        self.binding.execute(batch)
        return time.perf_counter() - t0

    def observe(self, batch: Dict[str, np.ndarray]) -> float:
        t0 = time.perf_counter()
        self.binding.observe(batch)
        return time.perf_counter() - t0

    def replan(self) -> float:
        t0 = time.perf_counter()
        self.binding.replan()
        return time.perf_counter() - t0


class SimulatedExecutor:
    """Deterministic executor for replay tests: service time comes from the
    service model, maintenance is free."""

    def __init__(self, service_model: ServiceModel):
        self.service_model = service_model

    def run_batch(self, bucket: Bucket, batch) -> float:
        return self.service_model.estimate(bucket)

    def observe(self, batch) -> float:
        return 0.0

    def replan(self) -> float:
        return 0.0


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    queue_capacity: int = 4096
    observe_every: int = 4        # micro-batches between observe() (0 = off)
    replan_every: int = 64        # micro-batches between replan()  (0 = off)
    account_maintenance: bool = False
    max_batches: int = 10_000_000  # runaway guard for ill-posed tests


class ServingRuntime:
    """Queue + batcher + executor, advanced by a discrete-event loop."""

    def __init__(self, executor, batcher,
                 padder: Callable[[Sequence[Request], Bucket], dict],
                 cfg: RuntimeConfig = RuntimeConfig(),
                 service_model: Optional[ServiceModel] = None,
                 controller=None, updater=None, watchdog=None,
                 warmup_factory=None, scrubber=None):
        self.executor = executor
        self.batcher = batcher
        self.padder = padder
        self.cfg = cfg
        self.service_model = service_model or ServiceModel()
        self.metrics = ServingMetrics()
        self.n_batches = 0
        # optional repro.serving.degradation.DegradationController: retry /
        # circuit-breaker / brown-out policy around every executor call
        self.controller = controller
        self.failed_batches = 0
        # optional repro.serving.updates.StreamingUpdater: drains the
        # trainer's delta stream between micro-batches on the maintenance
        # seam (same accounting as observe/replan)
        self.updater = updater
        # optional repro.serving.scrub.ScrubController: audits a rotating
        # window of store pages against the per-page checksum ledger on
        # the same maintenance cadence and repairs divergent pages
        # page-granularly (snapshot slice + filtered WAL replay)
        self.scrubber = scrubber
        # optional repro.runtime.fault_tolerance.StragglerWatchdog over
        # per-batch *service* times: warmup seeds its EWMA baseline, each
        # successful batch feeds it, and a trip bumps the degradation
        # controller's pressure (on_straggler) — a slow shard walks the
        # ladder down before it ever fails outright
        self.watchdog = watchdog
        # dummy-request factory for post-remesh re-warm of the rebuilt
        # serve-step variants; warmup() records the one it was given, or
        # pass one at construction when warmup happens out-of-band
        self.warmup_factory = warmup_factory
        self.remesh_record: Optional[dict] = None

    # ----------------------------------------------------------- warmup
    def warmup(self, request_factory: Callable[[int, int], Request],
               observe: bool = True) -> Dict[str, float]:
        """Trace/compile every bucket signature once before taking load.

        ``request_factory(rid, pooling)`` fabricates a dummy request.  Also
        warms the observe plan per bucket (same shape set) and the replan
        path (the migrate gather compiles on first use — pay that here,
        not mid-serving), and seeds the service model with the *second*
        measured execution (the first includes compile time)."""
        times = {}
        self.warmup_factory = request_factory
        for bucket in self.batcher.buckets():
            reqs = [request_factory(i, bucket.pooling)
                    for i in range(bucket.batch)]
            batch = self.padder(reqs, bucket)
            self.executor.run_batch(bucket, batch)          # traces/compiles
            svc = self.executor.run_batch(bucket, batch)    # steady measure
            self.service_model.update(bucket, svc)
            if self.watchdog is not None:
                # seed the EWMA baseline with healthy steady measures so
                # the first live batches aren't judged against nothing
                self.watchdog.observe(-1, svc)
            if observe and self.cfg.observe_every:
                self.executor.observe(batch)
            times[f"{bucket.batch}x{bucket.pooling}"] = svc
        if self.cfg.replan_every:
            self.executor.replan()
        return times

    # ----------------------------------------------------- fault policy
    def _attempt(self, bucket, batch, now: float):
        """One micro-batch under the controller's retry policy.

        Returns ``(service_s, backoff_delay_s)``; ``service_s`` is None
        when the retry budget is exhausted.  Backoff consumes *virtual*
        time (it lands in the requests' latency, not in the service
        model's estimate)."""
        ctrl = self.controller
        if ctrl is None:
            return self.executor.run_batch(bucket, batch), 0.0
        delay, failures = 0.0, 0
        while True:
            try:
                return self.executor.run_batch(bucket, batch), delay
            except ctrl.retryable as e:
                failures += 1
                ctrl.on_attempt_failure(now + delay, e)
                if failures >= ctrl.retry.max_attempts:
                    return None, delay
                self.metrics.retries += 1
                delay += ctrl.retry.backoff(failures)

    def _remesh_recover(self, now: float) -> float:
        """Elastic recovery on the maintenance seam: re-mesh the binding
        onto the survivor mesh, tell the fault layer the dead shard left,
        re-warm every rebuilt serve-step variant across all buckets and
        rungs (warmup traces are not steady-state — the engine-level trace
        counter resets after, while pre-remesh steady traces stay in the
        binding's carried ledger), and reset the degradation state.
        Returns the wall time spent, recorded as 'remesh' maintenance —
        recovery is maintenance-seam time, never service time."""
        ctrl = self.controller
        binding = ctrl.binding
        t0 = time.perf_counter()
        # the survivor mesh's dp axis must divide every bucket batch the
        # rebuilt step will shard — the batcher knows the granule
        granule = math.gcd(*(b.batch for b in self.batcher.buckets()))
        event = binding.remesh(lost_shard=ctrl.suspect_shard,
                               batch_granule=granule)
        if hasattr(self.executor, "on_remesh"):
            self.executor.on_remesh(event)
        if self.warmup_factory is not None:
            # re-warm through the *inner* executor: fault injection must
            # not advance its schedule (or fire) on warmup traffic
            inner = getattr(self.executor, "inner", self.executor)
            active = binding.active
            for rung in binding.modes():
                binding.set_mode(rung)
                for bucket in self.batcher.buckets():
                    reqs = [self.warmup_factory(i, bucket.pooling)
                            for i in range(bucket.batch)]
                    batch = self.padder(reqs, bucket)
                    inner.run_batch(bucket, batch)
                    if rung == active and self.cfg.observe_every:
                        inner.observe(batch)
            binding.set_mode(active)
            if self.cfg.replan_every:
                inner.replan()
            binding.engine.reset_plan_stats()
        dt = time.perf_counter() - t0
        self.metrics.record_maintenance("remesh", dt)
        ctrl.note_remeshed(now, event)
        self.remesh_record = {**event, "mttr_s": dt,
                              "at_batch": self.n_batches,
                              "t_virtual": round(now, 6)}
        return dt

    def _fail_batch(self, reqs, start: float, finish: float, source, heap,
                    seq, fast: bool) -> None:
        """Mark a whole micro-batch failed (retry-exhausted or breaker
        fail-fast): each request is counted exactly once in SLO metrics,
        and closed-loop users are released so load generation survives."""
        self.failed_batches += 1
        for r in reqs:
            r.start_s = start
            r.finish_s = finish
            r.failed = True
            self.metrics.record_failure(r, fast=fast)
        for r in reqs:
            for nr in source.on_complete(r, finish):
                heapq.heappush(heap, (nr.arrival_s, next(seq), nr))

    # -------------------------------------------------------------- run
    def run(self, source) -> Dict[str, object]:
        cfg = self.cfg
        ctrl = self.controller
        queue = AdmissionQueue(cfg.queue_capacity)
        if ctrl is not None:
            ctrl.bind_queue(queue)
        seq = itertools.count()
        heap: List = []
        for r in source.initial():
            heapq.heappush(heap, (r.arrival_s, next(seq), r))
        now = 0.0

        def admit(limit: float) -> None:
            while heap and heap[0][0] <= limit:
                _, _, r = heapq.heappop(heap)
                if not queue.offer(r):
                    self.metrics.record_drop(r)
                    # a dropped closed-loop request still releases its user
                    for nr in source.on_complete(r, r.arrival_s):
                        heapq.heappush(heap, (nr.arrival_s, next(seq), nr))

        while True:
            admit(now)
            next_arrival = heap[0][0] if heap else None
            decision = self.batcher.decide(now, queue.view(), next_arrival,
                                           self.service_model)
            if decision is None:
                if next_arrival is None:
                    break                                  # fully drained
                now = next_arrival
                continue
            if isinstance(decision, Wait):
                wake = decision.until
                if next_arrival is not None:
                    wake = min(wake, next_arrival)
                now = wake if wake > now else np.nextafter(now, np.inf)
                continue
            assert isinstance(decision, Flush)
            reqs = queue.pop_n(decision.count)
            batch = self.padder(reqs, decision.bucket)
            if ctrl is not None and not ctrl.allow_execute(now):
                # breaker open: fail fast without touching the executor —
                # the clock re-advances via the arrival stream
                self._fail_batch(reqs, now, now, source, heap, seq,
                                 fast=True)
                ctrl.on_batch_done(now, ok=False)
                continue
            svc, delay = self._attempt(decision.bucket, batch, now)
            if svc is None and ctrl is not None and ctrl.wants_remesh:
                # persistent per-shard failure: escalate past the ladder —
                # re-mesh onto the survivors, then re-attempt this same
                # micro-batch on the recovered engine (availability holds
                # because the batch is served, late, not failed)
                dt = self._remesh_recover(now + delay)
                if cfg.account_maintenance:
                    delay += dt
                svc, d2 = self._attempt(decision.bucket, batch, now + delay)
                delay += d2
            if svc is None:                      # retry budget exhausted
                finish = now + delay
                self._fail_batch(reqs, now, finish, source, heap, seq,
                                 fast=False)
                ctrl.on_batch_done(finish, ok=False)
                now = finish
                continue
            self.service_model.update(decision.bucket, svc)
            finish = now + delay + svc
            self.n_batches += 1
            if (self.watchdog is not None
                    and self.watchdog.observe(self.n_batches, svc)
                    and ctrl is not None):
                ctrl.on_straggler(now)
            if cfg.observe_every and self.n_batches % cfg.observe_every == 0:
                dt = self.executor.observe(batch)
                self.metrics.record_maintenance("observe", dt)
                if cfg.account_maintenance:
                    finish += dt
            if cfg.replan_every and self.n_batches % cfg.replan_every == 0:
                dt = self.executor.replan()
                self.metrics.record_maintenance("replan", dt)
                if cfg.account_maintenance:
                    finish += dt
            if self.updater is not None:
                # streaming embedding updates: drain due delta batches on
                # the maintenance seam; the updater samples staleness into
                # the metrics every boundary, drained or not
                dt = self.updater.on_batch(finish, self.metrics)
                if dt:
                    self.metrics.record_maintenance("updates", dt)
                    if cfg.account_maintenance:
                        finish += dt
            if self.scrubber is not None:
                # integrity scrub: audit the next page window (and repair
                # any divergence) on the maintenance seam — the wall time
                # is maintenance-accounted, never in the service EMA
                dt = self.scrubber.on_batch(finish, self.metrics)
                if dt:
                    self.metrics.record_maintenance("scrub", dt)
                    if cfg.account_maintenance:
                        finish += dt
            for r in reqs:
                r.start_s = now
                r.finish_s = finish
                self.metrics.record_request(r)
            self.metrics.record_batch(now, decision.bucket, len(reqs), svc,
                                      len(queue))
            for r in reqs:
                for nr in source.on_complete(r, finish):
                    heapq.heappush(heap, (nr.arrival_s, next(seq), nr))
            now = finish
            if ctrl is not None:
                poisoned = (ctrl.binding.last_poisoned
                            if ctrl.binding is not None else 0)
                ctrl.on_batch_done(finish, ok=True, poisoned=poisoned)
                if ctrl.wants_restore:
                    # corrupted store: heal between micro-batches on the
                    # maintenance seam (checkpoint reload, no retrace)
                    t0 = time.perf_counter()
                    ctrl.binding.restore()
                    dt = time.perf_counter() - t0
                    self.metrics.record_maintenance("restore", dt)
                    ctrl.note_restored()
                    if cfg.account_maintenance:
                        now += dt
            if self.n_batches >= cfg.max_batches:
                break

        s = self.metrics.summary()
        s["queue_offered"] = queue.offered
        s["queue_dropped"] = queue.dropped
        # summary()'s depth stats are post-pop snapshots at flush time; the
        # queue itself tracks the true admission-time peak
        s["queue_depth_max"] = queue.peak_depth
        s["failed_batches"] = self.failed_batches
        if ctrl is not None:
            s["degradation"] = ctrl.report()
        if self.watchdog is not None:
            s["watchdog"] = {"trips": len(self.watchdog.events),
                             "ewma_s": self.watchdog.ewma,
                             "events": list(self.watchdog.events)}
        if self.scrubber is not None:
            s["scrub_run"] = self.scrubber.report()
        if self.remesh_record is not None:
            s["remesh"] = dict(self.remesh_record)
        return s
