from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adagrad, adam, get_optimizer, rowwise_adagrad)
from repro.optim import compression  # noqa: F401
