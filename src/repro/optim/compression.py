"""Gradient compression for data-parallel reduction (distributed-optimization
trick; used by the explicit-psum DLRM/recsys trainer).

bf16: halves DP collective bytes.  int8: 4x, with per-tensor scale and error
feedback (residual carried to the next step) so compression error does not
accumulate [Seide et al. 2014; 1-bit SGD lineage].
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jax.Array, method: str) -> Tuple[jax.Array, Optional[jax.Array]]:
    if method == "none":
        return g, None
    if method == "bf16":
        return g.astype(jnp.bfloat16), None
    if method == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q, scale
    raise ValueError(method)


def decompress(q: jax.Array, scale: Optional[jax.Array], method: str,
               dtype=jnp.float32) -> jax.Array:
    if method == "none":
        return q
    if method == "bf16":
        return q.astype(dtype)
    if method == "int8":
        return q.astype(dtype) * scale
    raise ValueError(method)


def compressed_psum(grads, axis_names, method: str = "none", error_fb=None):
    """psum a grad pytree across `axis_names` with optional compression +
    error feedback.  Must be called inside shard_map.

    Returns (reduced_grads, new_error_fb).
    """
    if method == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axis_names), grads), error_fb

    def one(g, e):
        g32 = g.astype(jnp.float32) + (e if e is not None else 0.0)
        q, scale = compress(g32, method)
        qs = jax.lax.psum(q.astype(jnp.float32) if method == "int8" else q,
                          axis_names)
        if method == "int8":
            # scales differ per shard: reduce with max for a conservative bound
            scale = jax.lax.pmax(scale, axis_names)
            red = qs * scale
        else:
            red = qs.astype(jnp.float32)
        new_e = g32 - decompress(q, scale, method) if method == "int8" else None
        return red.astype(g.dtype), new_e

    if error_fb is None:
        error_fb = jax.tree.map(lambda _: None, grads,
                                is_leaf=lambda x: x is None)
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_fb) if error_fb is not None else [None] * len(flat_g)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = tdef.unflatten([o[0] for o in out])
    new_e = tdef.unflatten([o[1] for o in out])
    return red, new_e
