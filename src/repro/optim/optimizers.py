"""Optimizers as pure pytree transforms (no external deps).

* ``adam``  — configurable state dtype.  With bf16 moments the optimizer
  state for a 671B-param model drops from 8 TB (fp32 m+v+master) to 2.7 TB,
  which is what lets deepseek-v3 train_4k fit 16 GB/chip at 512 ways (see
  EXPERIMENTS.md §Dry-run).  States inherit the parameter sharding, so FSDP
  parameters automatically give ZeRO-sharded optimizer states.
* ``adagrad`` — DLRM-convention dense/embedding optimizer.
* ``rowwise_adagrad`` — one accumulator per embedding *row* (the FBGEMM/
  TorchRec trick): state is (rows, 1) instead of (rows, dim), an 16-128x
  state-memory saving on the PIFS tables.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


def adam(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
         eps: float = 1e-8, weight_decay: float = 0.0,
         state_dtype=jnp.float32, rowwise_keys: tuple = ()) -> Optimizer:
    def init(params):
        def mk(p):
            return {"m": jnp.zeros(p.shape, state_dtype),
                    "v": jnp.zeros(p.shape, state_dtype)}
        return {"step": jnp.zeros((), jnp.int32),
                "mv": jax.tree.map(mk, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, mv, p):
            g32 = g.astype(jnp.float32)
            m = b1 * mv["m"].astype(jnp.float32) + (1 - b1) * g32
            v = b2 * mv["v"].astype(jnp.float32) + (1 - b2) * g32 * g32
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            return new_p, {"m": m.astype(state_dtype), "v": v.astype(state_dtype)}

        flat_g, tdef = jax.tree.flatten(grads)
        flat_mv = tdef.flatten_up_to(state["mv"])
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, mv, p) for g, mv, p in zip(flat_g, flat_mv, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_mv = tdef.unflatten([o[1] for o in out])
        return new_p, {"step": step, "mv": new_mv}

    return Optimizer(init, update)


def adagrad(lr: float = 1e-2, eps: float = 1e-10) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params):
        def upd(g, acc, p):
            g32 = g.astype(jnp.float32)
            acc = acc + g32 * g32
            new_p = (p.astype(jnp.float32)
                     - lr * g32 / (jnp.sqrt(acc) + eps)).astype(p.dtype)
            return new_p, acc
        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = tdef.flatten_up_to(state)
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    return Optimizer(init, update)


def rowwise_adagrad(lr: float = 1e-2, eps: float = 1e-10,
                    min_dim_for_rowwise: int = 2) -> Optimizer:
    """Row-wise accumulators for >=2D params (embedding tables), scalar-wise
    adagrad otherwise."""
    def _rowwise(p):
        return p.ndim >= min_dim_for_rowwise

    def init(params):
        def mk(p):
            if _rowwise(p):
                return jnp.zeros(p.shape[:1] + (1,) * (p.ndim - 1), jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)
        return jax.tree.map(mk, params)

    def update(grads, state, params):
        def upd(g, acc, p):
            g32 = g.astype(jnp.float32)
            if _rowwise(p):
                acc = acc + jnp.mean(g32 * g32, axis=tuple(range(1, p.ndim)),
                                     keepdims=True)
            else:
                acc = acc + g32 * g32
            new_p = (p.astype(jnp.float32)
                     - lr * g32 / (jnp.sqrt(acc) + eps)).astype(p.dtype)
            return new_p, acc
        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = tdef.flatten_up_to(state)
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    return Optimizer(init, update)


def adafactor(lr: float = 1e-2, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, min_dim_factored: int = 128
              ) -> Optimizer:
    """Adafactor (Shazeer & Stern) without first moment: the second moment of
    a (R, C) matrix is stored as rank-1 factors (R,) x (C,) — state is
    ~(R+C)/(R*C) of the parameter size instead of 2x.  This is what lets the
    671B/340B train steps fit the fixed 256-chip mesh: params + grads +
    O(params/128) state instead of params + grads + 2x state.

    Tensors whose two trailing dims are both >= min_dim_factored factor over
    those dims; everything else keeps a full accumulator (they are small)."""
    def _factored(p) -> bool:
        return (p.ndim >= 2 and p.shape[-1] >= min_dim_factored
                and p.shape[-2] >= min_dim_factored)

    def init(params):
        def mk(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(mk, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** -decay                      # increasing decay

        def upd(g, v, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(p):
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True), eps)[..., None]
                ) * vc[..., None, :]
                u = g32 * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta * v["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(jnp.maximum(vv, eps))
                new_v = {"v": vv}
            # relative update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return new_p, new_v

        flat_g, tdef = jax.tree.flatten(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        return (tdef.unflatten([o[0] for o in out]),
                {"step": step, "v": tdef.unflatten([o[1] for o in out])})

    return Optimizer(init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"adam": adam, "adagrad": adagrad, "adafactor": adafactor,
            "rowwise_adagrad": rowwise_adagrad}[name](**kw)
