"""Fault-tolerant training runtime: checkpoint/restart loop, failure
injection, straggler watchdog.

At 1000+ nodes, MTBF is minutes-to-hours; the runtime assumes every step can
die.  Mechanisms (all exercised by tests/test_runtime.py):

  * **Restart loop** — `run_resilient` drives (restore latest -> train ->
    checkpoint every N) and survives injected exceptions by re-entering from
    the last committed checkpoint; a crash mid-save leaves a .tmp the
    checkpointer ignores.
  * **Failure injection** — `FailureInjector` raises `SimulatedFailure` at
    configured steps (deterministic) or with per-step probability (chaos
    mode) — stands in for a host dropping out of the collective.
  * **Straggler watchdog** — per-step wall-time EWMA; a step slower than
    `threshold` x EWMA is flagged.  On a real pod the remediation is
    hot-spare swap / re-mesh (runtime/elastic.py); here the watchdog records
    the event and (optionally) triggers a user callback, and its statistics
    feed the EXPERIMENTS.md fault-tolerance section.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint.checkpointer import Checkpointer


class SimulatedFailure(RuntimeError):
    """A injected node/step failure."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministic fault schedule shared by training and serving.

    Fires at the listed steps exactly once each, plus (chaos mode) with a
    per-step probability via a seeded hash — reproducible across restarts
    and across the processes of a run.  Training calls :meth:`maybe_fail`
    (raise on fire); the serving fault layer (``repro.serving.faults``)
    calls :meth:`fires` and maps the decision onto its own fault classes
    (stragglers, transient executor errors, stalls, data corruption), so
    both runtimes speak one injection vocabulary.
    """
    fail_at_steps: Tuple[int, ...] = ()
    fail_prob: float = 0.0
    seed: int = 0
    _fired: set = dataclasses.field(default_factory=set)

    @property
    def armed(self) -> bool:
        """Whether this injector can ever fire (lets wrappers skip work)."""
        return bool(self.fail_at_steps) or self.fail_prob > 0.0

    def fires(self, step: int) -> bool:
        """Decide (and record) whether the fault fires at ``step``.

        Each step fires at most once: the training restart loop re-runs the
        failed step after restore, and serving retries re-run the failed
        batch — neither should loop forever on one scheduled fault.
        """
        if step in self._fired:
            return False
        if step in self.fail_at_steps:
            self._fired.add(step)
            return True
        if self.fail_prob > 0.0:
            # deterministic hash-based chaos (reproducible across restarts)
            h = hash((self.seed, step)) % 10_000
            if h < self.fail_prob * 10_000:
                self._fired.add(step)
                return True
        return False

    def maybe_fail(self, step: int) -> None:
        if self.fires(step):
            raise SimulatedFailure(f"injected failure at step {step}")


class StragglerWatchdog:
    """EWMA step-time monitor (the paper-scale analogue watches per-host
    collective arrival times; here step wall-time is the observable)."""

    def __init__(self, alpha: float = 0.2, threshold: float = 2.5,
                 warmup: int = 3,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.events: List[Dict[str, float]] = []
        self._n = 0
        self._on = on_straggler

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = (self._n > self.warmup
                        and dt > self.threshold * self.ewma)
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "ewma": self.ewma})
            if self._on is not None:
                self._on(step, dt, self.ewma)
        else:
            # stragglers do not poison the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


@dataclasses.dataclass
class RunReport:
    steps_done: int
    restarts: int
    straggler_events: List[Dict[str, float]]
    final_metrics: Optional[Dict[str, Any]]


def run_resilient(train_step: Callable[[Any, Any], Tuple[Any, Dict]],
                  init_state: Any,
                  batches: Callable[[int], Any],
                  n_steps: int,
                  checkpointer: Checkpointer,
                  ckpt_every: int = 10,
                  injector: Optional[FailureInjector] = None,
                  watchdog: Optional[StragglerWatchdog] = None,
                  max_restarts: int = 10,
                  state_shardings: Optional[Any] = None) -> RunReport:
    """Drive training to n_steps surviving injected failures.

    train_step: (state, batch) -> (state, metrics); state is a pytree that
    the checkpointer can round-trip.  batches(step) returns the batch for a
    given global step (restart-deterministic data order).
    """
    restarts = 0
    metrics: Optional[Dict[str, Any]] = None
    while True:
        # ---- (re)enter from the last committed checkpoint ----
        start = checkpointer.latest_step()
        if start is None:
            state, step = init_state, 0
        else:
            state = checkpointer.restore(init_state, step=start,
                                         shardings=state_shardings)
            step = start
        try:
            while step < n_steps:
                if injector is not None:
                    injector.maybe_fail(step)
                t0 = time.perf_counter()
                state, metrics = train_step(state, batches(step))
                dt = time.perf_counter() - t0
                if watchdog is not None:
                    watchdog.observe(step, dt)
                step += 1
                if step % ckpt_every == 0 or step == n_steps:
                    checkpointer.save(step, state)
            checkpointer.wait()
            return RunReport(
                steps_done=step, restarts=restarts,
                straggler_events=watchdog.events if watchdog else [],
                final_metrics=metrics)
        except SimulatedFailure:
            restarts += 1
            checkpointer.wait()  # let any in-flight save commit or be ignored
            if restarts > max_restarts:
                raise
