"""Elastic scaling: re-mesh a training state onto a different device count.

Checkpoints store logical (unsharded) arrays + the model's *logical* pspecs
are functions of the mesh, so scaling down (512 -> 256 chips after a pod
loss) or up is: build the new mesh, rebuild shardings from the same spec
functions, restore.  The only constraint is divisibility (tables over tp,
batch over dp), which `validate_mesh_for` checks before committing.

The PIFS engine needs one extra step on re-mesh: the page table maps pages
to *shard ids*, so a tp-size change re-runs the planner against the new
shard count (a pure host-side re-plan + one gather migration).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.paging import PagingConfig
from repro.core.pifs import PIFSEmbeddingEngine
from repro.core.planner import PlannerConfig, plan
from repro.distributed.sharding import make_mesh


def validate_mesh_for(shape: Sequence[int], names: Sequence[str],
                      divisibility: Dict[str, int]) -> None:
    """divisibility: axis name -> value that must divide the axis size
    (e.g. {"model": n_pages, "data": global_batch})."""
    for name, size in zip(names, shape):
        need = divisibility.get(name)
        if need is not None and need % size != 0:
            raise ValueError(
                f"axis {name}={size} does not divide workload dim {need}")


def remesh_engine(old_engine: PIFSEmbeddingEngine, new_mesh: Mesh,
                  state, counts: Optional[np.ndarray] = None
                  ) -> Tuple[PIFSEmbeddingEngine, Any]:
    """Re-shard a PIFS engine state onto a new mesh (different tp size).

    Strategy: export to the dense logical table (placement-invariant), build
    a fresh engine for the new shard count, re-plan placement from the saved
    access histogram, and re-pack.  Cost: one gather each way — the same
    cache-line-granular move the migration path uses.
    """
    from repro.distributed.sharding import axes_for
    dense = old_engine.to_dense(state)
    new_axes = axes_for(new_mesh)
    new_cfg = dataclasses.replace(
        old_engine.cfg, n_shards=new_axes.tp_size(new_mesh))
    new_engine = PIFSEmbeddingEngine(new_cfg, new_mesh, axes=new_axes,
                                     planner=old_engine.planner,
                                     dtype=old_engine.dtype)
    counts = counts if counts is not None else np.asarray(
        jax.device_get(state.counts))
    # re-plan under the new shard count using the carried histogram
    from repro.core.paging import initial_page_table
    table0 = initial_page_table(new_cfg)
    new_table, _ = plan(new_cfg, table0, counts, new_engine.planner)
    new_state = new_engine.from_dense(dense, new_table)
    new_state = dataclasses.replace(
        new_state, counts=jax.numpy.asarray(counts, jax.numpy.float32))
    return new_engine, new_state


def scale_plan(n_devices: int, prefer_tp: int = 16
               ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Pick a (data, model) mesh for an arbitrary surviving device count —
    the re-mesh policy after partial failure.  Keeps tp at `prefer_tp` when
    divisible (table shards move less), else the largest power-of-two
    divisor."""
    tp = prefer_tp
    while tp > 1 and n_devices % tp:
        tp //= 2
    return (n_devices // tp, tp), ("data", "model")
