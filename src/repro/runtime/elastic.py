"""Elastic scaling: re-mesh a training state onto a different device count.

Checkpoints store logical (unsharded) arrays + the model's *logical* pspecs
are functions of the mesh, so scaling down (512 -> 256 chips after a pod
loss) or up is: build the new mesh, rebuild shardings from the same spec
functions, restore.  The only constraint is divisibility (tables over tp,
batch over dp), which `validate_mesh_for` checks before committing.

The PIFS engine needs one extra step on re-mesh: the page table maps pages
to *shard ids*, so a tp-size change re-runs the planner against the new
shard count (a pure host-side re-plan + one gather migration).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.paging import PagingConfig
from repro.core.pifs import PIFSEmbeddingEngine
from repro.core.planner import PlannerConfig, plan
from repro.distributed.sharding import make_mesh


def validate_mesh_for(shape: Sequence[int], names: Sequence[str],
                      divisibility: Dict[str, int]) -> None:
    """divisibility: axis name -> value that must divide the axis size
    (e.g. {"model": n_pages, "data": global_batch})."""
    for name, size in zip(names, shape):
        need = divisibility.get(name)
        if need is not None and need % size != 0:
            raise ValueError(
                f"axis {name}={size} does not divide workload dim {need}")


def remesh_engine(old_engine: PIFSEmbeddingEngine, new_mesh: Mesh,
                  state, counts: Optional[np.ndarray] = None
                  ) -> Tuple[PIFSEmbeddingEngine, Any]:
    """Re-shard a PIFS engine state onto a new mesh (different tp size).

    Strategy: export the state through the engine's placement-invariant
    logical view (``export_state``: cold rows as storage-native codes, hot
    rows as fp32 values, per-page scales carried verbatim), build a fresh
    engine for the new shard count, re-plan placement from the saved access
    histogram, and re-pack (``pack_state``).  Cost: one gather each way —
    the same cache-line-granular move the migration path uses.

    The quantized domain matters: page geometry (``page_size``,
    ``num_pages``, ``padded_rows``) is a function of dim / page_bytes /
    storage only — never ``n_shards`` — so an int8 cold page's codes and
    its carried scale move bit-for-bit to wherever the new plan places the
    page.  No dequantize/requantize round trip, no fresh scales: re-mesh
    composes with PR 3/7's carried-scale idempotency, and a tp 4→2→4 round
    trip is bitwise the identity on (codes, values, scales).

    Engine-level serving knobs (dedup default/threshold/staging,
    validate_ids, the measured dedup-auto hint, the host counts mirror)
    carry over so a re-meshed serving engine resolves its plans from the
    same evidence the old one did.
    """
    from repro.distributed.sharding import axes_for
    codes, values, page_scales = old_engine.export_state(state)
    jax.block_until_ready((codes, values))
    new_axes = axes_for(new_mesh)
    new_cfg = dataclasses.replace(
        old_engine.cfg, n_shards=new_axes.tp_size(new_mesh))
    new_engine = PIFSEmbeddingEngine(
        new_cfg, new_mesh, axes=new_axes,
        planner=old_engine.planner,
        dtype=old_engine.dtype,
        dedup=old_engine.default_dedup,
        dedup_auto_threshold=old_engine.dedup_auto_threshold,
        dedup_staging_bytes=old_engine.dedup_staging_bytes,
        validate_ids=old_engine.validate_ids)
    new_engine.dedup_auto_hint = old_engine.dedup_auto_hint
    new_engine._host_counts = (
        None if old_engine._host_counts is None
        else np.array(old_engine._host_counts, copy=True))
    counts = counts if counts is not None else np.asarray(
        jax.device_get(state.counts))
    # re-plan under the new shard count using the carried histogram
    from repro.core.paging import initial_page_table
    table0 = initial_page_table(new_cfg)
    new_table, _ = plan(new_cfg, table0, counts, new_engine.planner)
    new_state = new_engine.pack_state(codes, values, page_scales,
                                      table=new_table, counts=counts)
    return new_engine, new_state


def scale_plan(n_devices: int, prefer_tp: int = 16, batch_granule: int = 0
               ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Pick a (data, model) mesh for an arbitrary surviving device count —
    the re-mesh policy after partial failure.  Keeps tp at `prefer_tp` when
    divisible (table shards move less), else the largest power-of-two
    divisor.

    ``batch_granule`` > 0 adds the serving constraint: the data axis
    shards bucket-shaped micro-batches, so dp must divide the bucket
    batch granule (the gcd of the batcher's batch sizes).  When the full
    survivor count cannot satisfy it (e.g. 6 survivors -> dp=3 against
    power-of-two buckets), the plan shrinks the *used* device count until
    it can — an idle survivor beats a mesh the serve step cannot shard
    over."""
    if batch_granule:
        for n in range(n_devices, 0, -1):
            tp = prefer_tp
            while tp > 1 and n % tp:
                tp //= 2
            if batch_granule % (n // tp) == 0:
                return (n // tp, tp), ("data", "model")
    tp = prefer_tp
    while tp > 1 and n_devices % tp:
        tp //= 2
    return (n_devices // tp, tp), ("data", "model")
