"""Sharded, asynchronous, atomic checkpointing with elastic restore.

Design (what a 1000-node deployment needs, realized single-process here):

  * **Sharded save** — every array leaf is written as one .npy per leaf
    (fetched via jax.device_get; on a multi-host runtime each host would
    write only its addressable shards — the layout and manifest already
    carry the full logical shape, so the single-host writer is the
    degenerate case of the same format).
  * **Async** — `save()` snapshots the pytree (device_get) and hands the
    file I/O to a background thread; training continues immediately.  The
    snapshot is taken synchronously (consistent cut), only serialization
    overlaps compute.
  * **Atomic commit** — writes go to `step_<N>.tmp/`; a manifest with
    content checksums is written last, then the directory is renamed to
    `step_<N>/`.  A crash mid-write leaves only a .tmp that restore ignores
    (tested by the fault-tolerance suite).
  * **Elastic restore** — leaves are stored with their *logical* shapes;
    `restore(..., shardings=...)` re-places them under ANY mesh whose
    shapes divide the logical shapes, so a 512-chip checkpoint restores
    onto 256 chips (or 8 CPU devices in tests) unchanged.
  * **Retention** — `keep` most recent checkpoints are retained; commits
    prune older ones.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


_SEP = "::"  # path separator in flattened keys


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = False,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot now, write in background (or synchronously).

        ``extra`` is a small JSON-serializable dict stored in the manifest
        (e.g. the serving WAL's last-applied update sequence number, the
        cut point replay resumes from)."""
        flat = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}
        self.wait()  # one outstanding write at a time
        t = threading.Thread(target=self._write, args=(step, flat, extra),
                             daemon=True)
        t.start()
        self._pending = t
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               extra: Optional[Dict[str, Any]] = None) -> None:
        tmp = os.path.join(self.dir, f"step_{step:012d}.tmp")
        final = os.path.join(self.dir, f"step_{step:012d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}, "time": time.time(),
                    "extra": dict(extra or {})}
        for i, (key, arr) in enumerate(sorted(flat.items())):
            fname = f"leaf_{i:06d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc": _crc(arr),
            }
        # manifest written last = commit barrier
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()

    def _prune(self) -> None:
        with self._lock:
            steps = self.all_steps()
            for s in steps[: -self.keep]:
                shutil.rmtree(os.path.join(self.dir, f"step_{s:012d}"),
                              ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def extra(self, step: Optional[int] = None) -> Dict[str, Any]:
        """The ``extra`` metadata dict of a committed checkpoint (latest by
        default).  Pre-``extra`` manifests read as ``{}``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:012d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return dict(json.load(f).get("extra", {}))

    def manifest(self, step: Optional[int] = None) -> Dict[str, Any]:
        """The full manifest dict of a committed checkpoint (latest by
        default): ``{step, leaves: {key: {file, shape, dtype, crc}},
        time, extra}``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:012d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)

    def read_leaf(self, key: str, step: Optional[int] = None,
                  validate: bool = True) -> np.ndarray:
        """Load ONE leaf by manifest key (CRC-checked by default).

        The partial-read companion to :meth:`restore`: page repair loads
        the small metadata leaves (page tables, scales) whole without
        touching the multi-GB store leaves."""
        step = step if step is not None else self.latest_step()
        manifest = self.manifest(step)
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(
                f"no leaf {key!r} in checkpoint step {manifest['step']} "
                f"(has {sorted(manifest['leaves'])})")
        d = os.path.join(self.dir, f"step_{manifest['step']:012d}")
        arr = np.load(os.path.join(d, meta["file"]))
        if validate and _crc(arr) != meta["crc"]:
            raise IOError(f"checksum mismatch on {key}")
        return arr

    def read_page(self, key: str, start: int, rows: int,
                  step: Optional[int] = None) -> np.ndarray:
        """Read ``rows`` consecutive rows of a leaf starting at row
        ``start`` without materializing the full array.

        The leaf is opened as a read-only memory map and only the
        requested row slice is copied out — this is what lets page-
        granular repair pull one page out of a store-sized snapshot leaf
        for the cost of one page.  The manifest CRC covers the whole
        leaf, so a partial read cannot be CRC-verified here; repair
        verifies the slice against the snapshot-time *page* checksum
        ledger instead (``repro.core.integrity.fetch_snapshot_page``).
        """
        return self.read_pages(key, [(start, rows)], step=step)[0]

    def read_pages(self, key: str, spans, step: Optional[int] = None
                   ) -> List[np.ndarray]:
        """Batched :meth:`read_page`: ``spans`` is a list of
        ``(start_row, n_rows)`` pairs, read through one shared memory
        map of the leaf."""
        step = step if step is not None else self.latest_step()
        manifest = self.manifest(step)
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(
                f"no leaf {key!r} in checkpoint step {manifest['step']} "
                f"(has {sorted(manifest['leaves'])})")
        d = os.path.join(self.dir, f"step_{manifest['step']:012d}")
        mm = np.load(os.path.join(d, meta["file"]), mmap_mode="r")
        n = int(meta["shape"][0]) if meta["shape"] else 0
        out = []
        for start, rows in spans:
            start, rows = int(start), int(rows)
            if start < 0 or start + rows > n:
                raise IndexError(
                    f"page read [{start}, {start + rows}) outside leaf "
                    f"{key!r} with {n} rows")
            out.append(np.array(mm[start:start + rows]))
        del mm
        return out

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None, validate: bool = True) -> Any:
        """Restore into the structure of `tree_like`.  `shardings` (same
        structure) re-places leaves under the current mesh — elastic restore
        across mesh shapes."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:012d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        leaves_meta = manifest["leaves"]
        flat_struct = _flatten(tree_like)
        if set(flat_struct) != set(leaves_meta):
            missing = set(flat_struct) ^ set(leaves_meta)
            raise ValueError(f"checkpoint/tree structure mismatch: {missing}")
        # dtype/shape guard: the leaves of a tiered-precision EngineState
        # carry storage semantics (int8 codes + per-page scales) — silently
        # restoring them into a differently-built tree (e.g. an fp32-storage
        # engine) would produce garbage lookups, so fail loudly instead.
        # Shapes compare logically; sharding may differ (elastic restore).
        for key, meta in leaves_meta.items():
            want = flat_struct[key]
            if str(want.dtype) != meta["dtype"]:
                raise ValueError(
                    f"checkpoint leaf {key!r} dtype mismatch: saved "
                    f"{meta['dtype']}, restoring into {want.dtype} — was "
                    "the engine built with the same storage= mode?")
            if list(want.shape) != list(meta["shape"]):
                raise ValueError(
                    f"checkpoint leaf {key!r} shape mismatch: saved "
                    f"{meta['shape']}, restoring into {list(want.shape)}")

        flat_shard = (_flatten_nonarray(shardings, flat_struct)
                      if shardings is not None else {})

        restored: Dict[str, Any] = {}
        for key, meta in leaves_meta.items():
            arr = np.load(os.path.join(d, meta["file"]))
            if validate and _crc(arr) != meta["crc"]:
                raise IOError(f"checksum mismatch on {key}")
            if key in flat_shard and flat_shard[key] is not None:
                restored[key] = jax.device_put(arr, flat_shard[key])
            else:
                restored[key] = jax.numpy.asarray(arr)
        # rebuild in tree_like's structure
        paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(
            tree_like)
        ordered = [restored[_SEP.join(_path_str(p) for p in path)]
                   for path, _ in paths_and_leaves]
        return jax.tree_util.tree_unflatten(treedef, ordered)


def _flatten_nonarray(tree: Any, ref: Dict[str, Any]) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=lambda x: x is None or hasattr(x, "memory_kind")
    )[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _crc(arr: np.ndarray) -> str:
    return hashlib.md5(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]
