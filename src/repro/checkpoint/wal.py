"""Write-ahead log for streaming embedding-update batches.

The delta counterpart of the checkpointer: snapshots commit the full
EngineState at a sequence point; the WAL records every applied delta
batch *since* that point, so a mid-serving restore replays the suffix and
loses nothing.  Single append-only binary file:

    file   := MAGIC record*
    record := header payload
    header := little-endian struct "<qiiI":
                seq (int64), n_rows (int32), dim (int32),
                crc32(payload) (uint32)
    payload:= rows  (n_rows,)      int32  little-endian
              deltas (n_rows, dim) float32 little-endian

Durability semantics (standard WAL):

  * ``append`` writes + flushes before the caller applies the batch to
    the device — a crash after append but before apply replays a batch
    that is idempotent to re-apply on top of the *snapshot* (replay
    always starts from the snapshot's sequence point, never mid-state).
  * ``replay`` stops cleanly at a torn tail (a partial record from a
    crash mid-append is not data loss — the batch was never applied),
    but a CRC mismatch on a *complete* record is corruption and raises.
  * *opening* an existing log truncates any torn tail first, so
    post-recovery appends always start on a valid record boundary —
    without the cut they would land behind the garbage bytes and replay
    would silently stop before them.
  * ``truncate`` resets the log after a snapshot commits: every logged
    batch is inside the checkpoint, so replay must not see it again
    (the snapshot manifest's ``update_seq`` guards the race where
    truncation itself is interrupted).
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Iterator, Tuple

import numpy as np

MAGIC = b"PIFSWAL1"
_HEADER = struct.Struct("<qiiI")


class WriteAheadLog:
    """Append-only delta-batch log (see module docstring for the format).

    Opening an existing log keeps its complete records (append continues
    after them) and truncates a torn tail from a crash mid-append;
    ``records`` counts complete records currently on disk."""

    def __init__(self, path: str):
        self.path = path
        if not os.path.exists(path) or os.path.getsize(path) == 0:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            with open(path, "wb") as f:
                f.write(MAGIC)
        self.records = self._recover()

    def _recover(self) -> int:
        """Walk to the end of the last complete record (the same walk
        ``replay`` does) and cut anything after it.  ``append`` opens the
        file with mode 'ab': without this cut, a record appended after a
        crash mid-append would start inside the partial record's garbage
        bytes, and a later replay would either stop at the torn point
        (silently dropping every post-recovery record) or mis-parse and
        raise.  Returns the number of complete records kept; raises on
        bad magic or a checksum mismatch in a complete record, exactly
        like ``replay``."""
        records = 0
        with open(self.path, "r+b") as f:
            head = f.read(len(MAGIC))
            if head != MAGIC:
                raise IOError(f"{self.path}: bad WAL magic {head!r}")
            end = f.tell()
            while True:
                hdr = f.read(_HEADER.size)
                if len(hdr) < _HEADER.size:
                    break                           # torn/absent header
                seq, n, d, crc = _HEADER.unpack(hdr)
                if n < 0 or d <= 0:
                    raise IOError(f"{self.path}: corrupt WAL header "
                                  f"(n_rows={n}, dim={d})")
                payload = f.read(n * 4 + n * d * 4)
                if len(payload) < n * 4 + n * d * 4:
                    break                           # torn payload
                if zlib.crc32(payload) & 0xFFFFFFFF != crc:
                    raise IOError(f"{self.path}: WAL record seq={seq} "
                                  "checksum mismatch")
                records += 1
                end = f.tell()
            f.seek(0, os.SEEK_END)
            if f.tell() > end:
                f.truncate(end)
                f.flush()
                os.fsync(f.fileno())
        return records

    def append(self, seq: int, rows, deltas) -> None:
        """Log one coalesced delta batch (rows (U,) ids, deltas (U, D))."""
        rows = np.ascontiguousarray(np.asarray(rows, dtype="<i4").reshape(-1))
        deltas = np.ascontiguousarray(
            np.asarray(deltas, dtype="<f4").reshape(rows.size, -1))
        payload = rows.tobytes() + deltas.tobytes()
        header = _HEADER.pack(int(seq), rows.size, deltas.shape[1],
                              zlib.crc32(payload) & 0xFFFFFFFF)
        with open(self.path, "ab") as f:
            f.write(header + payload)
            f.flush()
            os.fsync(f.fileno())
        self.records += 1

    def replay(self) -> Iterator[Tuple[int, np.ndarray, np.ndarray]]:
        """Yield ``(seq, rows, deltas)`` for every complete record.

        A torn tail (partial header or payload — crash mid-append) ends
        iteration silently; a checksum mismatch on a complete record
        raises IOError."""
        with open(self.path, "rb") as f:
            head = f.read(len(MAGIC))
            if head != MAGIC:
                raise IOError(f"{self.path}: bad WAL magic {head!r}")
            while True:
                hdr = f.read(_HEADER.size)
                if len(hdr) < _HEADER.size:
                    return                              # torn/absent header
                seq, n, d, crc = _HEADER.unpack(hdr)
                if n < 0 or d <= 0:
                    raise IOError(f"{self.path}: corrupt WAL header "
                                  f"(n_rows={n}, dim={d})")
                payload = f.read(n * 4 + n * d * 4)
                if len(payload) < n * 4 + n * d * 4:
                    return                              # torn payload
                if zlib.crc32(payload) & 0xFFFFFFFF != crc:
                    raise IOError(f"{self.path}: WAL record seq={seq} "
                                  "checksum mismatch")
                rows = np.frombuffer(payload, dtype="<i4", count=n)
                deltas = np.frombuffer(payload, dtype="<f4",
                                       offset=n * 4).reshape(n, d)
                yield int(seq), rows.astype(np.int32), \
                    deltas.astype(np.float32)

    def truncate(self) -> None:
        """Reset to an empty log (call after a snapshot commits)."""
        with open(self.path, "wb") as f:
            f.write(MAGIC)
            f.flush()
            os.fsync(f.fileno())
        self.records = 0

    def __len__(self) -> int:
        return self.records
