"""TCO + power/area analysis (paper §VI-D/E, Table III, Fig. 16-18).

Two deployment shapes for a parameter-server tier of a given memory size:

  * **GPU parameter server** — host CPU + N GPUs (HBM holds the tables; the
    paper notes memory cost scales with model size), NIC + network switch.
  * **PIFS-Rec** — host CPU + fabric switch with PUs (Tofino-class price) +
    DDR4-as-CXL memory for the tables + a DDR5 local tier.

CAPEX from Table III, OPEX = 3 years of power at $0.05/kWh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.simlab.devices import CostParams, SiliconParams


@dataclasses.dataclass
class TCOReport:
    capex: float
    opex: float

    @property
    def total(self) -> float:
        return self.capex + self.opex


def model_memory_gb(cfg) -> float:
    """Embedding-table footprint of a DLRM config (fp32)."""
    return cfg.emb_num * cfg.emb_dim * 4 * cfg.n_tables / 2 ** 30


def pifs_tco(mem_gb: float, cost: CostParams = CostParams(),
             local_gb: float = 128.0) -> TCOReport:
    """CPU + switch-with-PUs + DDR4 CXL pool (+ DDR5 local tier)."""
    capex = (cost.cpu_price + cost.switch_pu_price
             + mem_gb * cost.ddr4_per_gb + local_gb * cost.ddr5_per_gb)
    watts = (cost.cpu_tdp_w + cost.switch_pu_w
             # CXL memory at 90% of local DRAM power (paper's estimate)
             + (mem_gb / 64.0) * cost.dimm_w_per_64gb_ddr4 * 0.9
             + (local_gb / 64.0) * cost.dimm_w_per_64gb_ddr5)
    return TCOReport(capex=capex, opex=cost.opex(watts))


def gpu_tco(mem_gb: float, n_gpus: int, cost: CostParams = CostParams(),
            local_gb: float = 128.0) -> TCOReport:
    """CPU + N GPUs + NIC + network switch; host DRAM sized to the model
    (the parameter server stages tables in host memory)."""
    capex = (cost.cpu_price + n_gpus * cost.gpu_price + cost.nic_price
             + cost.switch_price
             + max(mem_gb, local_gb) * cost.ddr5_per_gb)
    watts = (cost.cpu_tdp_w + n_gpus * cost.gpu_w + cost.nic_w
             + cost.switch_w
             + (max(mem_gb, local_gb) / 64.0) * cost.dimm_w_per_64gb_ddr5)
    return TCOReport(capex=capex, opex=cost.opex(watts))


def tco_comparison(cfg, n_gpus_list=(1, 2, 4), scale_to_gb: float = 2048.0
                   ) -> Dict[str, float]:
    """Fig. 16: TCO ratio GPU/PIFS per GPU count.  `scale_to_gb` stands in
    for the production-scale deployment the paper prices (2 TB system for
    RMC4); smaller models scale proportionally to their footprint."""
    raw = model_memory_gb(cfg)
    # paper prices deployment-scale systems: tables replicated/sharded to
    # serve production QPS; footprint scales with the model class
    mem = max(raw, scale_to_gb * raw / max(model_memory_gb(_RMC4REF), 1e-9)) \
        if raw > 0 else scale_to_gb
    mem = min(mem, scale_to_gb)
    p = pifs_tco(mem)
    out = {"pifs_capex": p.capex, "pifs_opex": p.opex, "pifs_total": p.total,
           "mem_gb": mem}
    for n in n_gpus_list:
        g = gpu_tco(mem, n)
        out[f"gpu_x{n}_total"] = g.total
        out[f"ratio_x{n}"] = g.total / p.total
    return out


class _RMC4REF:
    emb_num, emb_dim, n_tables = 1048576, 128, 8


def power_area_table(sil: SiliconParams = SiliconParams()) -> Dict[str, float]:
    """Fig. 18: PIFS-Rec silicon vs RecNMP x8."""
    return {
        "pifs_mw": sil.pifs_total_mw,
        "pifs_um2": sil.pifs_total_um2,
        "recnmp_x8_mw": sil.recnmp_x8_mw,
        "recnmp_x8_um2": sil.recnmp_x8_um2,
        "power_ratio": sil.recnmp_x8_mw / sil.pifs_total_mw,
        # paper compares logic area "with the same cache buffer" on both
        # sides, i.e. buffer excluded from the ratio
        "area_ratio": sil.recnmp_x8_um2 / (sil.pc_um2 + sil.ctrl_um2),
    }


def performance_per_watt(model_scale: float,
                         cost: CostParams = CostParams()) -> float:
    """PPW of PIFS vs a 4-GPU parameter server (paper: 1.22x -> 1.61x as the
    model grows).  model_scale in [0, 1]: footprint relative to RMC4.

    PPW = (T_pifs / T_gpu) x (W_gpu / W_pifs).  GPU throughput degrades as
    tables spill out of HBM (Fig. 17: GPUs win on small models, lose at
    scale); the relative-throughput curve is calibrated to the paper's
    reported PPW endpoints."""
    mem_gb = 2048.0 * max(model_scale, 0.05)
    pifs_w = (cost.cpu_tdp_w + cost.switch_pu_w
              + (mem_gb / 64.0) * cost.dimm_w_per_64gb_ddr4 * 0.9
              + 2 * cost.dimm_w_per_64gb_ddr5)
    gpu_w = (cost.cpu_tdp_w + 4 * cost.gpu_w + cost.nic_w + cost.switch_w
             + (mem_gb / 64.0) * cost.dimm_w_per_64gb_ddr5)
    rel_throughput = 0.49 + 0.36 * model_scale   # PIFS/GPU, Fig. 17 shape
    return rel_throughput * gpu_w / pifs_w
