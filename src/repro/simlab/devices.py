"""Hardware parameters for the latency simulator (paper Table II + III).

The paper evaluates with Ramulator 2.0 wrapped in a 1 ns/clk top module; we
reproduce the same *resource model* analytically: every component is a
(bandwidth, latency) pair and the simulator composes them per system.  Values
below are Table II where given, public datasheet figures otherwise.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareParams:
    # ---- local DRAM (DDR5-4800, 12 channels populated on the socket) ----
    bw_local_GBs: float = 307.0          # 12ch x 4800MT/s x 8B x ~0.67 eff
    lat_local_ns: float = 90.0

    # ---- CXL memory devices (DDR4 behind the switch, Table II) ----
    n_devices: int = 4
    bw_device_GBs: float = 64.0          # downstream port: 64 GB/s x16
    bw_media_GBs: float = 35.0           # DDR4 media behind the port (4ch eff)
    lat_cxl_extra_ns: float = 100.0      # CXL access penalty over DRAM [28]
    lat_switch_ns: float = 25.0          # switch traversal (port+retimer leg)
    lat_proto_ns: float = 135.0          # CXL.mem protocol + retimer legs
    switch_congestion: float = 1.25      # per-extra-port round-trip inflation

    # ---- host link (flex bus upstream, PCIe5 x16) ----
    bw_upstream_GBs: float = 64.0
    outstanding: int = 136               # host line-fetch MSHR/LFB depth
    lat_queue_ns: float = 400.0          # hot-port queueing per unit imbalance

    # ---- host LLC (dual Genoa: large L3 absorbs hot rows for host-centric
    # systems — this is why Pond+PM barely beats Pond in the paper) ----
    host_cache_mb: int = 256

    # ---- on-switch SRAM buffer (Table II: 0.91-4.19 ns per line R/W) ----
    bw_sram_GBs: float = 128.0
    lat_sram_ns: float = 2.5
    buffer_kb_default: int = 512         # paper's sweet spot

    # ---- process core (1 GHz synthesis clock, §VI-D) ----
    pc_GBs: float = 168.0                # accumulate datapath width x 1 GHz
    ooo_stall_free_frac: float = 0.068   # stalls removed by OoO (<=7.3%, Fig12e)

    # ---- host-side reduce (Pond-style communicate-then-reduce) ----
    host_reduce_ns_per_row: float = 1.0

    # ---- BEACON extra memory-translation logic in the switch (§II-B2):
    # translation serializes ahead of the device issue path ----
    beacon_translate_factor: float = 1.05

    # ---- RecNMP: DIMM-side PNM with rank/bank-level parallelism ----
    bw_recnmp_GBs: float = 105.0         # x8 ranks, intra-DIMM effective
    recnmp_cache_kb: int = 512           # RecNMP explored DIMM caching

    # ---- memory capacity model ----
    local_capacity_frac: float = 0.06    # 128 GB local vs multi-TB tables
    page_bytes: int = 4096
    replan_every_batches: int = 32       # planner cadence (amortizes moves)


# --------------------------- Table III (TCO) -------------------------------


@dataclasses.dataclass(frozen=True)
class CostParams:
    cpu_price: float = 4695.0            # AMD EPYC 9654
    cpu_tdp_w: float = 360.0
    ddr4_per_gb: float = 4.90            # CXL mem (re-purposed DDR4)
    ddr5_per_gb: float = 11.25
    dimm_w_per_64gb_ddr4: float = 21.6
    dimm_w_per_64gb_ddr5: float = 24.0
    nic_price: float = 1900.0            # ConnectX-6 200Gbps
    nic_w: float = 23.6
    switch_price: float = 11899.0        # Juniper QFX10002-36Q
    switch_w: float = 360.0
    switch_pu_price: float = 13039.0     # Tofino-class switch + PUs
    switch_pu_w: float = 400.0
    gpu_price: float = 18900.0           # A100 80GB PCIe
    gpu_w: float = 300.0
    kwh_price: float = 0.05
    years: float = 3.0

    def opex(self, watts: float) -> float:
        hours = self.years * 365 * 24
        return watts / 1000.0 * hours * self.kwh_price


# ------------------- PIFS-Rec silicon overheads (Fig. 18) ------------------


@dataclasses.dataclass(frozen=True)
class SiliconParams:
    pc_mw: float = 9.3
    pc_um2: float = 33709.0
    ctrl_mw: float = 3.2
    ctrl_um2: float = 73114.0
    buffer_mw: float = 15.2
    buffer_um2: float = 2.38e6
    recnmp_x8_mw: float = 75.4
    recnmp_x8_um2: float = 215984.0

    @property
    def pifs_total_mw(self) -> float:
        return self.pc_mw + self.ctrl_mw + self.buffer_mw

    @property
    def pifs_total_um2(self) -> float:
        return self.pc_um2 + self.ctrl_um2 + self.buffer_um2
