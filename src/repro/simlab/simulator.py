"""Trace-driven latency model for the five systems the paper compares.

Methodology (paper §VI-A, adapted from Ramulator cycle simulation to an
analytic resource model): a trace of SLS row accesses is pushed through a
system description; every shared resource accumulates bytes; the batch
latency is the *binding* resource's service time plus serial terms.

The physics the model encodes (each is a paper observation):

  * **Host-centric CXL reads are latency-limited** (Key Takeaway 1; "fetching
    a single address from memory pools can take up to 270 ns").  A host
    keeps only `outstanding` line fetches in flight, so its effective CXL
    bandwidth is  outstanding x row_bytes / round_trip  — well below the
    link rate.  Round-trip grows with switch fan-out and device imbalance
    ("flex bus congestion under heavy memory traffic").  This is what makes
    Pond slow and what near-data processing removes.
  * **In-switch compute is bandwidth-limited** — the switch is the requester
    (short loop, many outstanding DMAs), so PIFS/BEACON stream at DDR4 media
    bandwidth per device; the PC accumulate datapath has a fixed width
    (`pc_GBs`), and without OoO it stalls on interleaved bags (Fig. 12e).
  * **Only pooled results cross the upstream link** for switch-compute
    systems; host-centric systems ship every row.
  * **Placement**: hot-aware promotion + spreading (PM) vs address-
    interleaved capacity (Pond) vs all-CXL (BEACON) vs all-local-DIMM
    (RecNMP).  Placement is decided on the *first half* of the trace and
    evaluated on the second (production traces drift; a stationary
    evaluation would overstate PM).
  * **On-switch buffer**: row-granular cache simulation (HTR/LRU/FIFO from
    core/hot_cache.py) over the CXL-row stream.

Systems:
  pond / pond_pm / beacon / recnmp / pifs (+ ablation flags, Fig. 12e).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.hot_cache import make_policy
from repro.simlab.devices import HardwareParams


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    name: str
    in_switch_compute: bool = False      # PC in the fabric switch
    page_mgmt: bool = False              # hot-tier promotion + spreading
    buffer_kb: int = 0                   # on-switch SRAM buffer size
    buffer_policy: str = "htr"
    ooo: bool = False                    # out-of-order accumulation
    all_cxl: bool = False                # BEACON: no local-DRAM interleave
    translate_factor: float = 1.0        # BEACON memory-translation slowdown
    pnm: bool = False                    # RecNMP: DIMM-side processing
    migration_granularity: str = "line"  # "line" | "page" (Fig. 13a/d)


def pond(pm: bool = False) -> SystemConfig:
    return SystemConfig(name="pond_pm" if pm else "pond", page_mgmt=pm)


def beacon(hw: HardwareParams) -> SystemConfig:
    return SystemConfig(name="beacon", in_switch_compute=True, all_cxl=True,
                        translate_factor=hw.beacon_translate_factor)


def recnmp(hw: HardwareParams) -> SystemConfig:
    return SystemConfig(name="recnmp", pnm=True,
                        buffer_kb=hw.recnmp_cache_kb, buffer_policy="htr")


def pifs(hw: HardwareParams, *, pc: bool = True, pm: bool = True,
         buffer_kb: Optional[int] = None, ooo: bool = True,
         buffer_policy: str = "htr",
         migration_granularity: str = "line") -> SystemConfig:
    return SystemConfig(
        name="pifs", in_switch_compute=pc, page_mgmt=pm,
        buffer_kb=hw.buffer_kb_default if buffer_kb is None else buffer_kb,
        ooo=ooo, buffer_policy=buffer_policy,
        migration_granularity=migration_granularity)


ALL_SYSTEMS = ("pond", "pond_pm", "beacon", "recnmp", "pifs")


def make_system(name: str, hw: HardwareParams) -> SystemConfig:
    return {
        "pond": lambda: pond(False),
        "pond_pm": lambda: pond(True),
        "beacon": lambda: beacon(hw),
        "recnmp": lambda: recnmp(hw),
        "pifs": lambda: pifs(hw),
    }[name]()


@dataclasses.dataclass
class SimResult:
    system: str
    total_us: float
    components_us: Dict[str, float]
    binding: str
    frac_local_access: float
    buffer_hit_rate: float
    device_imbalance: float
    migration_cost_us: float
    device_loads: np.ndarray

    def speedup_over(self, other: "SimResult") -> float:
        return other.total_us / self.total_us


# ---------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------


def _place_pages(page_counts: np.ndarray, n_pages_local: int, n_devices: int,
                 hot_aware: bool, spread: bool, all_cxl: bool,
                 balance_counts: Optional[np.ndarray] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (is_local (P,) bool, device (P,) int; device=-1 for local).

    `balance_counts`: the counts the *spreading* step balances against.  Hot
    promotion uses the (stale) profiling counts — page-temperature ranking
    lags; spreading reacts to node-level warmness online (the paper's
    migrate_threshold fires during the run), so it sees fresher counts.
    """
    P = page_counts.shape[0]
    is_local = np.zeros(P, dtype=bool)
    if not all_cxl and n_pages_local > 0:
        if hot_aware:
            hot = np.argsort(-page_counts, kind="stable")[:n_pages_local]
        else:
            # address-interleaved capacity: an even stride over the address
            # space — uncorrelated with hotness (Pond's default mapping)
            stride = max(1, P // max(n_pages_local, 1))
            hot = np.arange(0, P, stride)[:n_pages_local]
        is_local[hot] = True
    cold = np.nonzero(~is_local)[0]
    device = np.full(P, -1, dtype=np.int64)
    if spread:
        # weighted LPT over access counts (the embedding-spreading planner);
        # round-robin in descending-count order is the vectorized equivalent
        bc = balance_counts if balance_counts is not None else page_counts
        order = cold[np.argsort(-bc[cold], kind="stable")]
        device[order] = np.arange(order.size) % n_devices
    else:
        device[cold] = cold % n_devices
    return is_local, device


# ---------------------------------------------------------------------------
# Simulation
# ---------------------------------------------------------------------------


def simulate(row_ids: np.ndarray, row_bytes: int, pooling: int,
             sys: SystemConfig, hw: Optional[HardwareParams] = None,
             n_rows_total: Optional[int] = None,
             n_devices: Optional[int] = None,
             local_capacity_frac: Optional[float] = None,
             seed: int = 0) -> SimResult:
    """row_ids: flat (N,) global row access stream (bag-major: consecutive
    groups of `pooling` ids form one bag).

    The first half of the stream is the profiling epoch (placement input);
    metrics are measured on the second half (drift-honest evaluation).
    """
    hw = hw or HardwareParams()
    D = n_devices if n_devices is not None else hw.n_devices
    cap_frac = (local_capacity_frac if local_capacity_frac is not None
                else hw.local_capacity_frac)
    N_all = row_ids.shape[0]
    half = (N_all // 2 // pooling) * pooling
    profile_ids, eval_ids = row_ids[:half], row_ids[half:]
    N = eval_ids.shape[0]
    n_rows_total = n_rows_total or int(row_ids.max()) + 1
    rows_per_page = max(1, hw.page_bytes // row_bytes)
    n_pages = -(-n_rows_total // rows_per_page)

    prof_counts = np.bincount(profile_ids // rows_per_page,
                              minlength=n_pages).astype(np.float64)
    pages = eval_ids // rows_per_page
    eval_counts = np.bincount(pages, minlength=n_pages).astype(np.float64)

    # ---- placement (hot tier from the profiling epoch; spreading balances
    # against a profile/eval blend — it re-fires online) --------------------
    if sys.pnm:
        is_local = np.ones(n_pages, dtype=bool)
        device = np.full(n_pages, -1, dtype=np.int64)
    else:
        n_local = 0 if sys.all_cxl else int(n_pages * cap_frac)
        is_local, device = _place_pages(
            prof_counts, n_local, D,
            hot_aware=sys.page_mgmt, spread=sys.page_mgmt,
            all_cxl=sys.all_cxl,
            balance_counts=0.5 * prof_counts + 0.5 * eval_counts)

    acc_local = is_local[pages]
    frac_local = float(acc_local.mean())

    # ---- on-switch buffer over the CXL-row stream -------------------------
    hit = np.zeros(N, dtype=bool)
    hit_rate = 0.0
    if sys.buffer_kb > 0:
        capacity_rows = max(1, sys.buffer_kb * 1024 // row_bytes)
        policy = make_policy(sys.buffer_policy, capacity_rows)
        stream_idx = np.arange(N) if sys.pnm else np.nonzero(~acc_local)[0]
        if stream_idx.size:
            # warm the policy on the profiling epoch's miss stream
            warm = profile_ids if sys.pnm else \
                profile_ids[~is_local[profile_ids // rows_per_page]]
            for r in warm[-4 * capacity_rows:]:
                policy.access(int(r))
            hits = np.fromiter((policy.access(int(eval_ids[i]))
                                for i in stream_idx), dtype=bool,
                               count=stream_idx.size)
            hit[stream_idx] = hits
            hit_rate = float(hits.mean())

    # ---- byte accounting ---------------------------------------------------
    raw_bytes = float(N * row_bytes)
    n_bags = N // max(pooling, 1)
    pooled_bytes = float(n_bags * row_bytes)

    local_bytes = float(acc_local.sum() * row_bytes)
    sram_bytes = float(hit.sum() * row_bytes)
    cxl_mask = ~acc_local & ~hit
    cxl_rows = int(cxl_mask.sum())
    cxl_bytes = float(cxl_rows * row_bytes)

    dev_loads = np.zeros(D)
    if cxl_rows and not sys.pnm:
        acc_dev = device[pages[cxl_mask]]
        dev_loads = np.bincount(acc_dev, minlength=D
                                ).astype(np.float64) * row_bytes
    imbalance = float(dev_loads.max() / max(dev_loads.mean(), 1e-9)) \
        if dev_loads.sum() else 1.0

    G = 1e9
    comp: Dict[str, float] = {}

    # round-trip a host-issued CXL line fetch sees: DRAM + CXL penalty +
    # switch traversal, inflated by fan-out congestion and hot-port queueing
    congest = 1.0 + hw.switch_congestion * max(0, D - 4) * imbalance ** 2
    rt_ns = (hw.lat_local_ns + hw.lat_cxl_extra_ns + hw.lat_proto_ns
             + hw.lat_switch_ns * congest
             + hw.lat_queue_ns * max(0.0, imbalance - 1.0))

    if sys.pnm:
        miss_bytes = raw_bytes - sram_bytes
        comp["dimm"] = miss_bytes / (hw.bw_recnmp_GBs * G)
        # per-DIMM caches are rank-parallel; hits are effectively free at
        # rank aggregate SRAM bandwidth
        comp["sram"] = sram_bytes / (hw.bw_sram_GBs * 8 * G)
        comp["upstream"] = pooled_bytes / (hw.bw_upstream_GBs * G)
    else:
        comp["local"] = local_bytes / (hw.bw_local_GBs * G)
        # CXL devices stream at DDR4 media bandwidth behind the port
        comp["device_max"] = float(dev_loads.max()) / (hw.bw_media_GBs * G)
        comp["sram"] = sram_bytes / (hw.bw_sram_GBs * G)
        if sys.in_switch_compute:
            comp["upstream"] = pooled_bytes / (hw.bw_upstream_GBs * G)
            pc_time = (cxl_bytes + sram_bytes) / (hw.pc_GBs * G)
            if not sys.ooo:
                pc_time /= (1.0 - hw.ooo_stall_free_frac)
            comp["pc"] = pc_time
            # translation logic serializes ahead of the device issue path
            comp["device_max"] *= sys.translate_factor
        else:
            # host-centric: every CXL row is a host-issued line fetch.  The
            # host LLC (dual Genoa ~768 MB L3; modeled at host_cache_mb)
            # absorbs re-referenced rows regardless of page placement — the
            # reason PM helps Pond only marginally in the paper.
            cache_rows = max(1, hw.host_cache_mb * 2 ** 20 // row_bytes)
            llc = make_policy("lru", cache_rows)
            cxl_idx = np.nonzero(~acc_local)[0]
            warm_rows = profile_ids[~is_local[profile_ids // rows_per_page]]
            for r in warm_rows[-2 * cache_rows:]:
                llc.access(int(r))
            llc_hits = np.fromiter(
                (llc.access(int(eval_ids[i])) for i in cxl_idx),
                dtype=bool, count=cxl_idx.size)
            llc_hit_bytes = float(llc_hits.sum() * row_bytes)
            hit_rate = float(llc_hits.mean()) if cxl_idx.size else 0.0
            miss_bytes = cxl_bytes + sram_bytes - llc_hit_bytes
            # latency-limited effective bandwidth, capped by the link.
            # Fetches are cache-line (64 B) granular: a 128 B row is two
            # pipelined line fills, so effective bytes/s is row-size
            # independent
            eff_bw = min(hw.bw_upstream_GBs * G,
                         hw.outstanding * 64.0 / (rt_ns / 1e9))
            comp["upstream"] = miss_bytes / eff_bw
            comp["llc"] = llc_hit_bytes / (hw.bw_local_GBs * G)
            comp["host_reduce"] = (N * hw.host_reduce_ns_per_row) / 1e9

    # ---- serial terms ------------------------------------------------------
    lat_ns = hw.lat_local_ns * frac_local + rt_ns * (1.0 - frac_local)
    fill = lat_ns * 1e-9  # one pipeline fill per batch

    mig = 0.0
    if sys.page_mgmt and not sys.pnm:
        # one re-plan moves ~10% of the hot set; it is amortized over the
        # batches between re-plans (planner default cadence)
        n_local_pages = int(is_local.sum())
        moved_pages = max(1, int(0.1 * n_local_pages))
        page_move = moved_pages * hw.page_bytes / (hw.bw_media_GBs * G)
        mig = page_move / (5.1 if sys.migration_granularity == "line"
                           else 1.0)
        mig /= hw.replan_every_batches
    comp["migration"] = mig

    total = max(comp.values()) + fill + mig
    binding = max(comp, key=comp.get)
    return SimResult(
        system=sys.name,
        total_us=total * 1e6,
        components_us={k: v * 1e6 for k, v in comp.items()},
        binding=binding,
        frac_local_access=frac_local,
        buffer_hit_rate=hit_rate,
        device_imbalance=imbalance,
        migration_cost_us=mig * 1e6,
        device_loads=dev_loads,
    )


# ---------------------------------------------------------------------------
# End-to-end model-level weighting (Fig. 14)
# ---------------------------------------------------------------------------


def e2e_speedup(sls_speedup: float, sls_fraction: float) -> float:
    """Amdahl weighting of SLS vs non-SLS operators (§VI-C4)."""
    return 1.0 / ((1.0 - sls_fraction) + sls_fraction / sls_speedup)


def sls_fraction_for(model_cfg, batch: int, hw: Optional[HardwareParams] = None
                     ) -> float:
    """SLS share of end-to-end time for a DLRM config: MLP FLOPs at host
    throughput vs SLS bytes at the host's effective CXL bandwidth."""
    hw = hw or HardwareParams()
    dims_b = (model_cfg.n_dense,) + model_cfg.bottom_mlp
    dims_t = model_cfg.top_mlp
    F = model_cfg.n_tables + 1
    inter_in = F * (F - 1) // 2 + model_cfg.emb_dim
    mlp_flops = 0
    for a, b in zip(dims_b[:-1], dims_b[1:]):
        mlp_flops += 2 * a * b
    mlp_flops += 2 * inter_in * dims_t[0]
    for a, b in zip(dims_t[:-1], dims_t[1:]):
        mlp_flops += 2 * a * b
    mlp_flops *= batch
    host_flops = 2.0e12                    # dual-socket Genoa, ~2 TFLOP/s eff
    t_mlp = mlp_flops / host_flops
    sls_bytes = (batch * model_cfg.n_tables * model_cfg.pooling
                 * model_cfg.emb_dim * 4)
    t_sls = sls_bytes / (hw.bw_upstream_GBs * 1e9)
    return t_sls / (t_sls + t_mlp)
