"""Serving driver: batched recsys inference with the PIFS engine.

``python -m repro.launch.serve --arch dcn-v2 --requests 2000 --batch 64``

Simulates an online-serving loop: requests arrive, are micro-batched, scored
with the jit'd serve step, and the engine's access profiler + planner run in
the background (periodic re-plan = the paper's page management during a
live-on inference system, §IV-B4 — migration here is a pure gather, so no
"page block" ever stalls a query).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgs
from repro.configs import get_config, reduced
from repro.data import synth
from repro.launch.mesh import make_test_mesh
from repro.models import dlrm as dlrm_mod
from repro.models import params as prm
from repro.models import recsys as rec_mod


def serve_loop(cfg, mesh, n_requests: int, batch: int, mode: str = "pifs",
               replan_every: int = 8) -> Dict[str, float]:
    if isinstance(cfg, cfgs.DLRMConfig):
        engine, offs = dlrm_mod.build_engine(cfg, mesh)
        params = prm.initialize(dlrm_mod.model_specs(cfg, mesh),
                                jax.random.PRNGKey(0))
        step = jax.jit(dlrm_mod.make_serve_step(cfg, engine, mesh, mode=mode))
        gen = synth.dlrm_batches(cfg, batch, -(-n_requests // batch))
        idx_key = "indices"
    else:
        engine, offs = rec_mod.build_engine(cfg, mesh)
        params = prm.initialize(rec_mod.model_specs(cfg, mesh),
                                jax.random.PRNGKey(0))
        step = jax.jit(rec_mod.make_serve_step(cfg, engine, offs, mesh,
                                               mode=mode))
        gen = synth.rec_batches(cfg, batch, -(-n_requests // batch),
                                kind="serve")
        idx_key = None

    state = engine.init_state(jax.random.PRNGKey(1))
    lat_ms = []
    served = 0
    with mesh:
        for i, b in enumerate(gen):
            jb = {k: jnp.asarray(v) for k, v in b.items()
                  if k != "labels"}
            t0 = time.perf_counter()
            scores = step(params, state, jb)
            scores.block_until_ready()
            lat_ms.append((time.perf_counter() - t0) * 1e3)
            served += batch
            if idx_key and idx_key in jb:
                state = engine.observe(state, jb[idx_key])
                if (i + 1) % replan_every == 0:
                    state, _ = engine.plan_and_migrate(state)
    lat = np.asarray(lat_ms[1:])  # drop compile
    return {"served": served,
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99)),
            "mean_ms": float(lat.mean())}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dcn-v2")
    ap.add_argument("--requests", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--mode", default="pifs",
                    choices=["pifs", "pond", "beacon"])
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    n_dev = len(jax.devices())
    mesh = make_test_mesh(n_dev, min(4, n_dev))
    out = serve_loop(cfg, mesh, args.requests, args.batch, mode=args.mode)
    print(out)


if __name__ == "__main__":
    main()
