"""Serving driver: online recsys inference through ``repro.serving``.

``python -m repro.launch.serve --arch rmc1 --qps 200 --requests 2000
--slo-ms 50 --impl pallas --block-l 8 --batcher dynamic``

Thin composition over the serving subsystem: binds the model to a
``ServeBinding`` (core/pifs.py), generates an open- or closed-loop request
stream from the trace distributions, warms every shape bucket (one
compile per bucket — afterwards the whole run does zero retraces), and
drives the deadline-aware dynamic micro-batcher.  The engine's access
profiler and periodic re-planning (paper §IV-B4) fold into the serving
cadence between micro-batches; migration is a pure gather with
placement-invariant lookups, so no query ever blocks on page management.
"""
from __future__ import annotations

import argparse
from typing import Dict, Optional, Tuple

import jax

from repro.configs import get_config, reduced
from repro.runtime.fault_tolerance import StragglerWatchdog
from repro.serving import (BatcherConfig, BindingExecutor, BreakerConfig,
                           ClosedLoopSource, DegradationController,
                           DynamicBatcher, FaultConfig,
                           FaultInjectingExecutor, FixedBatcher,
                           LadderConfig, LoadConfig,
                           OpenLoopSource, RetryPolicy, RuntimeConfig,
                           ScrubConfig, ScrubController, ServingRuntime,
                           StreamingUpdater, UpdateConfig, bind_model,
                           closed_loop_factory, dummy_request_factory,
                           make_padder, prime_dedup_auto, request_stream,
                           update_stream)
from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.wal import WriteAheadLog
from repro.launch.mesh import make_test_mesh
from repro.serving.request import ArrivalConfig


def build_serving(cfg, mesh, *, mode: str = "pifs", impl: str = "jnp",
                  block_l: int = 8, batcher: str = "dynamic",
                  batch_sizes: Tuple[int, ...] = (8, 16, 32),
                  poolings: Tuple[int, ...] = (),
                  slo_ms: float = 50.0, hot_fraction: float = 0.05,
                  storage: str = "fp32", dedup: str = "off",
                  front_end: str = "split",
                  runtime_cfg: RuntimeConfig = RuntimeConfig(),
                  validate_ids: bool = False,
                  elastic: bool = False, prefer_tp: int = 2,
                  ) -> Tuple[ServingRuntime, "object"]:
    """Compose (runtime, binding) for a config; buckets warmed by the
    caller via ``runtime.warmup``.  ``validate_ids`` arms the binding's
    host-side strict OOB-id check (raise loudly instead of letting the
    device gather clamp bad ids silently).  ``elastic`` additionally
    binds degraded serve-step variants and attaches the re-mesh rebinder
    so a persistent shard loss can recover mid-serving onto the
    survivors (tp preference ``prefer_tp``)."""
    binding = bind_model(cfg, mesh, mode=mode, impl=impl, block_l=block_l,
                         hot_fraction=hot_fraction, storage=storage,
                         dedup=dedup, front_end=front_end,
                         validate_ids=validate_ids,
                         degraded_variants=elastic, scrub_scores=elastic,
                         elastic=elastic, prefer_tp=prefer_tp)
    levels = tuple(sorted(set(poolings))) or (
        (cfg.pooling,) if hasattr(cfg, "pooling") else (1,))
    if batcher == "dynamic":
        b = DynamicBatcher(BatcherConfig(
            batch_sizes=tuple(sorted(batch_sizes)), poolings=levels,
            max_wait_ms=slo_ms / 2))
    elif batcher == "fixed":
        b = FixedBatcher(batch=max(batch_sizes), pooling=levels[-1])
    else:
        raise ValueError(f"unknown batcher {batcher!r}")
    runtime = ServingRuntime(BindingExecutor(binding), b, make_padder(cfg),
                             runtime_cfg)
    return runtime, binding


def serve_offered_load(cfg, mesh, load: LoadConfig, *, mode: str = "pifs",
                       impl: str = "jnp", block_l: int = 8,
                       batcher: str = "dynamic",
                       batch_sizes: Tuple[int, ...] = (8, 16, 32),
                       hot_fraction: float = 0.05,
                       runtime_cfg: RuntimeConfig = RuntimeConfig(),
                       closed_loop_users: int = 0,
                       validate_ids: bool = False,
                       update_cfg: Optional[UpdateConfig] = None,
                       wal_path: Optional[str] = None,
                       mesh_faults: bool = False, prefer_tp: int = 2,
                       fault_seed: int = 13,
                       scrub: bool = False, scrub_pages_per_cycle: int = 8,
                       ) -> Dict[str, object]:
    """End-to-end: bind, warm every bucket, serve the stream, and report
    metrics + the steady-state retrace count (must be 0).  The engine's
    cold-tier storage format rides in ``load.storage`` (the DLRM request
    streams need it for table-offset page rounding), the duplicate-
    coalescing knob in ``load.dedup``; the summary carries the measured
    per-bucket dedup factor so serving-side bytes wins are attributable.

    ``load.update_qps > 0`` arms the streaming-update subsystem: a
    trainer-side delta stream on the same virtual clock, drained between
    micro-batches by a ``StreamingUpdater`` (warmed *before* plan stats
    reset, so steady state stays retrace-free), with staleness p50/p99 in
    the summary and, when ``wal_path`` is given, every applied batch
    write-ahead-logged for mid-serving replay.

    ``mesh_faults`` arms the degraded-mesh regime: a ``shard_loss`` fault
    kills the highest tp shard at live attempt 2, the degradation
    controller attributes the same-shard streak and escalates past the
    brown-out ladder to an elastic re-mesh (quiesce, export, re-plan on
    the survivor mesh, re-pack, rebuild + re-warm the serve steps), and
    the run finishes on the survivors.  The summary carries the remesh
    record (MTTR = maintenance-seam wall time), watchdog trips, and the
    degradation report.

    ``scrub`` arms the integrity subsystem: a per-page checksum ledger
    over the live store, a snapshot (into a temp dir) whose manifest
    records the snapshot-time ledger, and a ``ScrubController`` on the
    runtime's maintenance seam auditing ``scrub_pages_per_cycle`` pages
    per micro-batch and repairing any diverged page surgically (snapshot
    page slice + filtered WAL replay).  The summary carries the scrub
    report (``scrub_run``: coverage, detections, per-page repair MTTR)."""
    runtime, binding = build_serving(
        cfg, mesh, mode=mode, impl=impl, block_l=block_l, batcher=batcher,
        batch_sizes=batch_sizes, poolings=load.poolings, slo_ms=load.slo_ms,
        hot_fraction=hot_fraction, storage=load.storage, dedup=load.dedup,
        front_end=load.front_end, runtime_cfg=runtime_cfg,
        validate_ids=validate_ids, elastic=mesh_faults, prefer_tp=prefer_tp)
    if mesh_faults:
        if dict(mesh.shape).get("model", 1) < 2:
            raise ValueError(
                "--mesh-faults needs a tp-sharded mesh (model >= 2): "
                "losing the only model shard is total loss, not a "
                f"degraded mesh (got {dict(mesh.shape)})")
        runtime.controller = DegradationController(
            binding=binding,
            retry=RetryPolicy(max_attempts=3),
            breaker=BreakerConfig(trip_after=6, cooldown_s=0.02),
            ladder=LadderConfig(min_dwell_batches=4, remesh_after=3))
        runtime.watchdog = StragglerWatchdog(threshold=4.0, warmup=4)
    with mesh:
        if mesh_faults:
            # warm every ladder rung over every bucket through the clean
            # executor (fault schedules index live attempts only); the
            # fault wrapper is armed after all warmup, right before run
            factory = dummy_request_factory(cfg, storage=load.storage)
            for rung in binding.modes():
                binding.set_mode(rung)
                runtime.warmup(factory)
            binding.set_mode("full")
        else:
            runtime.warmup(dummy_request_factory(cfg, storage=load.storage))
        # the open-loop stream is only materialized when something uses it
        # (the serving source, or the 'auto' priming prefix) — closed-loop
        # runs draw from their own factory
        reqs = (request_stream(cfg, load)
                if load.dedup == "auto" or closed_loop_users <= 0 else None)
        if load.dedup == "auto" and prime_dedup_auto(binding, reqs):
            # per-bucket 'auto' decisions freeze at plan build: prime the
            # profiler with a prefix of the live stream, then rebuild the
            # buckets against the primed histogram (still pre-steady-state)
            runtime.warmup(dummy_request_factory(cfg, storage=load.storage))
        updater = None
        if load.update_qps > 0:
            ucfg = update_cfg or UpdateConfig()
            wal = WriteAheadLog(wal_path) if wal_path else None
            updater = StreamingUpdater(binding, update_stream(cfg, load),
                                       ucfg, wal=wal)
            updater.warmup()              # compile the apply plan now
            runtime.updater = updater
        if scrub:
            # arm the ledger over the live store, snapshot it (manifest
            # records the snapshot-time checksums the repair path
            # verifies against), and ride the maintenance seam
            import tempfile
            binding.attach_integrity()
            if binding.checkpointer is None:
                binding.attach_checkpointer(
                    Checkpointer(tempfile.mkdtemp(prefix="serve_scrub_")),
                    save_now=True)
            scrubber = ScrubController(
                binding,
                ScrubConfig(pages_per_cycle=scrub_pages_per_cycle),
                controller=runtime.controller)
            scrubber.warmup()             # compile audit/repair plans now
            runtime.scrubber = scrubber
        if mesh_faults:
            runtime.executor = FaultInjectingExecutor(
                runtime.executor,
                FaultConfig(seed=fault_seed, shard_loss_at=(2,)),
                idx_key=binding.idx_key)
        binding.reset_plan_stats()        # steady state begins here
        binding.dedup_stats.clear()       # drop warmup-dummy observations
        warm_replans = binding.replans
        if closed_loop_users > 0:
            source = ClosedLoopSource(
                closed_loop_users, load.n_requests,
                closed_loop_factory(cfg, load),
                think_time_s=closed_loop_users / load.arrival.rate_qps)
        else:
            source = OpenLoopSource(reqs)
        summary = runtime.run(source)
    stats = binding.plan_stats()
    summary["steady_traces"] = stats["traces"]
    if mesh_faults:
        summary["remeshes"] = binding.remeshes
        summary["faults_fired"] = runtime.executor.report()
    summary["plans"] = stats["plans"]
    summary["front_end"] = stats.get("front_end", {})
    summary["replans"] = binding.replans - warm_replans
    summary["dedup_factors"] = binding.dedup_report()
    if updater is not None:
        summary["updates"] = updater.report()
    return summary


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="rmc1")
    ap.add_argument("--requests", type=int, default=1024)
    ap.add_argument("--qps", type=float, default=200.0,
                    help="offered load (virtual-clock requests/second)")
    ap.add_argument("--slo-ms", type=float, default=50.0)
    ap.add_argument("--mode", default="pifs",
                    choices=["pifs", "pond", "beacon"])
    ap.add_argument("--impl", default="jnp", choices=["jnp", "pallas"],
                    help="engine SLS datapath (pallas = bag-tiled kernel)")
    ap.add_argument("--block-l", type=int, default=8,
                    help="pallas kernel pooling-tile size")
    ap.add_argument("--storage", default="fp32", choices=["fp32", "int8"],
                    help="cold-tier storage: fp32 passthrough or int8 with "
                         "per-page scales (dequant fused into the SLS "
                         "accumulate)")
    ap.add_argument("--dedup", default="off", choices=["off", "auto", "on"],
                    help="gather-once duplicate coalescing in the SLS "
                         "datapath (bit-exact; 'auto' decides per shape "
                         "bucket from the access histogram)")
    ap.add_argument("--front-end", default="split",
                    choices=["split", "fused"],
                    help="DLRM lookup->interaction pipeline: 'fused' keeps "
                         "pooled features in VMEM from the SLS accumulate "
                         "through the dot-interaction matmul (bit-exact; "
                         "tp-sharded meshes and pond mode resolve it to "
                         "'fused_tp' — partial-pool, psum the (B, F, d) "
                         "cold tile, resume)")
    ap.add_argument("--batcher", default="dynamic",
                    choices=["dynamic", "fixed"])
    ap.add_argument("--batch-sizes", type=int, nargs="+",
                    default=[8, 16, 32])
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty", "uniform"])
    ap.add_argument("--closed-loop-users", type=int, default=0,
                    help="> 0 switches to a closed-loop load of N users")
    ap.add_argument("--validate-ids", action="store_true",
                    help="strict mode: raise host-side on out-of-range "
                         "embedding ids instead of letting the device "
                         "gather clamp them silently")
    ap.add_argument("--update-qps", type=float, default=0.0,
                    help="> 0 arms the streaming embedding-update stream "
                         "(delta rows/second on the virtual clock), drained "
                         "between micro-batches")
    ap.add_argument("--update-batch", type=int, default=64,
                    help="rows per trainer-emitted delta batch")
    ap.add_argument("--wal", default=None, metavar="PATH",
                    help="write-ahead-log applied update batches to PATH "
                         "(mid-serving restore replays it)")
    ap.add_argument("--mesh-faults", action="store_true",
                    help="degraded-mesh regime: inject a persistent "
                         "shard_loss fault (highest tp shard, live attempt "
                         "2) and require a mid-serving elastic re-mesh "
                         "onto the survivors — prints the remesh record "
                         "(MTTR, from/to mesh) and the degradation report")
    ap.add_argument("--prefer-tp", type=int, default=2,
                    help="tp preference handed to scale_plan when the "
                         "elastic re-mesh lays out the survivor mesh")
    ap.add_argument("--scrub", action="store_true",
                    help="arm the integrity scrubber: per-page checksum "
                         "ledger + snapshot, then audit a rotating page "
                         "window between micro-batches and repair any "
                         "diverged page from the snapshot + WAL tail "
                         "(prints the scrub report)")
    ap.add_argument("--scrub-pages-per-cycle", type=int, default=8,
                    help="pages audited per maintenance turn (--scrub); "
                         "a full store sweep every ceil(num_pages / K) "
                         "micro-batches")
    ap.add_argument("--observe-every", type=int, default=4)
    ap.add_argument("--replan-every", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    n_dev = len(jax.devices())
    mesh = make_test_mesh(n_dev, min(4, n_dev))
    load = LoadConfig(
        n_requests=args.requests,
        arrival=ArrivalConfig(rate_qps=args.qps, process=args.arrival,
                              seed=args.seed),
        slo_ms=args.slo_ms, seed=args.seed, storage=args.storage,
        dedup=args.dedup, front_end=args.front_end,
        update_qps=args.update_qps, update_batch=args.update_batch)
    out = serve_offered_load(
        cfg, mesh, load, mode=args.mode, impl=args.impl,
        block_l=args.block_l, batcher=args.batcher,
        batch_sizes=tuple(args.batch_sizes),
        runtime_cfg=RuntimeConfig(observe_every=args.observe_every,
                                  replan_every=args.replan_every),
        closed_loop_users=args.closed_loop_users,
        validate_ids=args.validate_ids, wal_path=args.wal,
        mesh_faults=args.mesh_faults, prefer_tp=args.prefer_tp,
        scrub=args.scrub,
        scrub_pages_per_cycle=args.scrub_pages_per_cycle)
    out.pop("latency_hist", None)
    fe_plans = out.pop("front_end", {})
    dedup_factors = out.pop("dedup_factors", {})
    staleness = out.pop("staleness", None)
    updates = out.pop("updates", None)
    scrub_run = out.pop("scrub_run", None)
    remesh = out.pop("remesh", None)
    watchdog = out.pop("watchdog", None)
    degradation = out.pop("degradation", None)
    for k, v in out.items():
        print(f"  {k:24s} {v}")
    if remesh is not None:
        print("  -- elastic re-mesh --")
        for k, v in remesh.items():
            print(f"  {k:24s} {v}")
    if watchdog is not None:
        print(f"  watchdog_trips           {watchdog['trips']} "
              f"(ewma={watchdog['ewma_s']:.4f}s)")
    if degradation is not None:
        print(f"  degradation              rung={degradation['rung']} "
              f"remeshes={degradation['remeshes']} "
              f"suspect_shard={degradation['suspect_shard']} "
              f"straggler_trips={degradation['straggler_trips']}")
    if updates is not None:
        print("  -- streaming updates --")
        for k, v in updates.items():
            print(f"  {k:24s} {v}")
    if scrub_run is not None:
        print("  -- scrub --")
        print(f"  audited                  "
              f"{scrub_run['pages_audited']} pages over "
              f"{scrub_run['cycles']} cycles "
              f"(window={scrub_run['pages_per_cycle']}, full sweep every "
              f"{scrub_run['sweep_cycles']} cycles, "
              f"{scrub_run['sweeps_completed']} sweeps, "
              f"coverage={scrub_run['coverage']:.2f})")
        print(f"  detected/repaired        "
              f"{scrub_run['pages_detected']}/"
              f"{scrub_run['pages_repaired']} "
              f"(quarantined={scrub_run['quarantined']})")
        if "repair_mttr_mean_s" in scrub_run:
            print(f"  repair_mttr              "
                  f"mean={scrub_run['repair_mttr_mean_s']:.4f}s "
                  f"max={scrub_run['repair_mttr_max_s']:.4f}s")
    if staleness is not None:
        print("  -- staleness (rows / seconds behind) --")
        print(f"  rows_behind   p50={staleness['rows_behind_p50']:.1f} "
              f"p99={staleness['rows_behind_p99']:.1f} "
              f"max={staleness['rows_behind_max']:.1f}")
        print(f"  seconds_behind p50={staleness['seconds_behind_p50']:.4f} "
              f"p99={staleness['seconds_behind_p99']:.4f} "
              f"max={staleness['seconds_behind_max']:.4f}")
    for label, rec in fe_plans.items():
        print(f"  front_end[{label}]  requested={rec['requested']} "
              f"resolved={rec['resolved']} (tp={rec['tp']})")
    for bucket, rec in dedup_factors.items():
        print(f"  dedup[{bucket}]  factor={rec['factor']:.2f} "
              f"({rec['entries']} entries -> {rec['unique_rows']} unique "
              f"rows over {rec['batches']} observed batches)")


if __name__ == "__main__":
    main()
