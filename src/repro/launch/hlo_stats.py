"""Analytic HLO statistics: dot FLOPs + collective wire bytes with
while-loop trip-count multipliers.

XLA's ``compiled.cost_analysis()`` counts a rolled ``while`` body ONCE — a
61-layer scanned transformer reports ~1/61 of its real FLOPs.  This parser
walks the optimized HLO text, attributes dots/collectives to their enclosing
computation, resolves the call graph (fusion/call/while/conditional), and
multiplies while bodies by their trip count (the loop-bound constant found in
the condition computation).  Operand shapes are resolved through a
per-computation symbol table (the scheduled HLO text names operands without
inline shapes).

Used by the dry-run for the §Roofline compute and collective terms; `bytes`
here is dot-operand traffic — a structural proxy for HBM traffic that tracks
the true value for matmul-dominated models.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{$")
_INSTR = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"\b(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|c64|s64|u64|s32|"
                    r"u32|s16|u16|s8|u8|pred)\[([\d,]*)\]")
_OP = re.compile(r"^\(?[\w\[\],{}\s/*=]*?\)?\s*([a-z][a-z0-9\-]*)\(")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_TRIP = re.compile(r"known_trip_count[\"':{\s]+n[\"':\s]+(\d+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    return [(dt, [int(d) for d in dims.split(",") if d])
            for dt, dims in _SHAPE.findall(text)]


def _numel(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _bytes_of(shapes: List[Tuple[str, List[int]]]) -> int:
    return sum(_numel(d) * _DTYPE_BYTES[dt] for dt, d in shapes)


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll_wire: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, int] = dataclasses.field(default_factory=dict)
    callees: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    max_const: int = 0


class _Parser:
    def __init__(self) -> None:
        self.comps: Dict[str, CompStats] = {}
        self.entry: Optional[str] = None
        self._cur: Optional[str] = None
        self._symbols: Dict[str, List[Tuple[str, List[int]]]] = {}

    def feed(self, line: str) -> None:
        s = line.strip()
        if self._cur is None:
            m = _COMP_HDR.match(s)
            if m:
                self._cur = m.group(2)
                self.comps[self._cur] = CompStats()
                self._symbols = {}
                if m.group(1):
                    self.entry = self._cur
            return
        if s == "}":
            self._cur = None
            return
        self._instr(s)

    def _instr(self, s: str) -> None:
        st = self.comps[self._cur]
        m = _INSTR.match(s)
        if not m:
            return
        name, rhs = m.group(1), m.group(2)
        # result shapes: everything before the op token
        opm = _OP.match(rhs)
        op = opm.group(1) if opm else ""
        head = rhs[: opm.start(1)] if opm else rhs
        out_shapes = _shapes_in(head)
        self._symbols[name] = out_shapes

        for c in _CONST_INT.finditer(rhs):
            st.max_const = max(st.max_const, int(c.group(1)))

        base_op = op[:-6] if op.endswith("-start") else op
        if base_op == "dot":
            args = rhs[opm.end(1):]
            paren = args[1: args.find(")")]
            names = _OPERANDS.findall(paren)
            if len(names) >= 2 and out_shapes:
                lhs = self._symbols.get(names[0], [])
                rhsh = self._symbols.get(names[1], [])
                cd = _LHS_CDIMS.search(rhs)
                cdims = ([int(x) for x in cd.group(1).split(",") if x]
                         if cd else [])
                if lhs:
                    _, lhs_dims = lhs[0]
                    k = 1
                    for c in cdims:
                        if c < len(lhs_dims):
                            k *= lhs_dims[c]
                    st.dot_flops += 2.0 * _numel(out_shapes[0][1]) * k
                    st.dot_bytes += (_bytes_of(out_shapes) + _bytes_of(lhs)
                                     + _bytes_of(rhsh))
        elif base_op in _COLL_KINDS:
            out_bytes = _bytes_of(out_shapes)
            g = 1
            gm = _GROUPS.search(rhs)
            if gm:
                first = gm.group(1).split("}")[0]
                g = max(1, first.count(",") + 1)
            else:
                g2 = _GROUPS_V2.search(rhs)
                if g2:
                    g = max(1, int(g2.group(2)))
            if base_op == "all-gather":
                wire = out_bytes * (g - 1) / max(g, 1)
            elif base_op == "reduce-scatter":
                wire = out_bytes * (g - 1)
            elif base_op == "all-reduce":
                wire = 2.0 * out_bytes * (g - 1) / max(g, 1)
            elif base_op == "all-to-all":
                wire = out_bytes * (g - 1) / max(g, 1)
            else:
                wire = float(out_bytes)
            st.coll_wire[base_op] = st.coll_wire.get(base_op, 0.0) + wire
            st.coll_count[base_op] = st.coll_count.get(base_op, 0) + 1

        if base_op == "while":
            b = _CALLS.search(rhs)
            c = _COND.search(rhs)
            tm = _TRIP.search(rhs)
            trip = int(tm.group(1)) if tm else 0
            if b:
                st.callees.append((b.group(1), f"while_body:{trip}"))
            if c:
                st.callees.append((c.group(1), "while_cond"))
        elif base_op in ("fusion", "call", "map", "reduce", "reduce-window",
                         "sort", "scatter", "select-and-scatter",
                         "all-reduce", "reduce-scatter", "custom-call"):
            for cm in _CALLS.finditer(rhs):
                st.callees.append((cm.group(1), "call"))
        elif base_op == "conditional":
            bm = _BRANCHES.search(rhs)
            if bm:
                for n in bm.group(1).split(","):
                    st.callees.append((n.strip().lstrip("%"), "branch"))


@dataclasses.dataclass
class HloSummary:
    flops: float
    dot_bytes: float
    collectives: Dict[str, Dict[str, float]]

    @property
    def wire_bytes(self) -> float:
        return sum(v["wire_bytes"] for v in self.collectives.values())


def parse_hlo(text: str) -> Tuple[Dict[str, CompStats], Optional[str]]:
    p = _Parser()
    for line in text.splitlines():
        p.feed(line)
    return p.comps, p.entry


def summarize(text: str) -> HloSummary:
    comps, entry = parse_hlo(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    def deep_max_const(name: str, seen=None) -> int:
        seen = seen or set()
        if name in seen or name not in comps:
            return 0
        seen.add(name)
        st = comps[name]
        best = st.max_const
        for callee, kind in st.callees:
            if kind == "call":
                best = max(best, deep_max_const(callee, seen))
        return best

    totals = {"flops": 0.0, "bytes": 0.0}
    coll: Dict[str, Dict[str, float]] = {}

    def visit(name: str, mult: float, stack: frozenset) -> None:
        st = comps.get(name)
        if st is None or name in stack:
            return
        stack = stack | {name}
        totals["flops"] += st.dot_flops * mult
        totals["bytes"] += st.dot_bytes * mult
        for kind, wire in st.coll_wire.items():
            c = coll.setdefault(kind, {"count": 0.0, "wire_bytes": 0.0})
            c["count"] += st.coll_count[kind] * mult
            c["wire_bytes"] += wire * mult
        cond = next((c for c, k in st.callees if k == "while_cond"), None)
        for callee, kind in st.callees:
            if kind.startswith("while_body"):
                trip = int(kind.split(":")[1])
                if trip <= 0:  # no backend annotation: condition constant
                    trip = max(deep_max_const(cond), 1) if cond else 1
                visit(callee, mult * trip, stack)
            elif kind == "while_cond":
                continue
            else:
                visit(callee, mult, stack)

    visit(entry, 1.0, frozenset())
    return HloSummary(flops=totals["flops"], dot_bytes=totals["bytes"],
                      collectives=coll)


def top_collectives(text: str, k: int = 12) -> List[Tuple[float, str]]:
    """The k largest collectives by loop-adjusted wire bytes, with their
    shapes and op metadata — the §Perf 'where is the collective term' lens."""
    comps, entry = parse_hlo(text)
    mults: Dict[str, float] = {}

    def walk(name: str, mult: float, stack: frozenset) -> None:
        st = comps.get(name)
        if st is None or name in stack:
            return
        mults[name] = mults.get(name, 0.0) + mult
        stack = stack | {name}
        cond = next((c for c, kk in st.callees if kk == "while_cond"), None)
        for callee, kind in st.callees:
            if kind.startswith("while_body"):
                trip = int(kind.split(":")[1]) or 1
                walk(callee, mult * trip, stack)
            elif kind != "while_cond":
                walk(callee, mult, stack)

    if entry:
        walk(entry, 1.0, frozenset())

    out: List[Tuple[float, str]] = []
    cur = None
    for line in text.splitlines():
        s = line.strip()
        if cur is None:
            m = _COMP_HDR.match(s)
            if m:
                cur = m.group(2)
            continue
        if s == "}":
            cur = None
            continue
        eq = s.find("=")
        if eq < 0:
            continue
        rhs = s[eq + 1:]
        cm = re.search(r"\b(all-reduce|all-gather|reduce-scatter|"
                       r"all-to-all|collective-permute)(?:-start)?\(", rhs)
        if cm:
            shapes = _shapes_in(rhs[: cm.start(1)])
            b = _bytes_of(shapes) * mults.get(cur, 1.0)
            meta = re.search(r'op_name="([^"]+)"', s)
            out.append((b, f"{cm.group(1)} {shapes[:2]} "
                        f"x{mults.get(cur, 1.0):.0f} "
                        f"{meta.group(1)[:110] if meta else ''}"))
    out.sort(key=lambda t: -t[0])
    return out[:k]


def top_dots(text: str, k: int = 12) -> List[Tuple[float, str]]:
    """The k largest dots by loop-adjusted FLOPs."""
    comps, entry = parse_hlo(text)
    mults: Dict[str, float] = {}

    def walk(name: str, mult: float, stack: frozenset) -> None:
        st = comps.get(name)
        if st is None or name in stack:
            return
        mults[name] = mults.get(name, 0.0) + mult
        stack = stack | {name}
        for callee, kind in st.callees:
            if kind.startswith("while_body"):
                walk(callee, mult * (int(kind.split(":")[1]) or 1), stack)
            elif kind != "while_cond":
                walk(callee, mult, stack)

    if entry:
        walk(entry, 1.0, frozenset())

    out: List[Tuple[float, str]] = []
    p = _Parser()
    cur = None
    symbols: Dict[str, List] = {}
    for line in text.splitlines():
        s = line.strip()
        if cur is None:
            m = _COMP_HDR.match(s)
            if m:
                cur = m.group(2)
                symbols = {}
            continue
        if s == "}":
            cur = None
            continue
        im = _INSTR.match(s)
        if not im:
            continue
        name, rhs = im.group(1), im.group(2)
        opm = _OP.match(rhs)
        if not opm:
            continue
        head = rhs[: opm.start(1)]
        symbols[name] = _shapes_in(head)
        if opm.group(1) != "dot":
            continue
        args = rhs[opm.end(1):]
        names = _OPERANDS.findall(args[1: args.find(")")])
        outs = symbols[name]
        if len(names) < 2 or not outs:
            continue
        lhs = symbols.get(names[0], [])
        cd = _LHS_CDIMS.search(rhs)
        cdims = [int(x) for x in cd.group(1).split(",") if x] if cd else []
        kk = 1
        if lhs:
            for c in cdims:
                if c < len(lhs[0][1]):
                    kk *= lhs[0][1][c]
        fl = 2.0 * _numel(outs[0][1]) * kk * mults.get(cur, 1.0)
        meta = re.search(r'op_name="([^"]+)"', s)
        out.append((fl, f"dot out={outs[:1]} lhs={lhs[:1]} "
                    f"x{mults.get(cur, 1.0):.0f} "
                    f"{meta.group(1)[:100] if meta else ''}"))
    out.sort(key=lambda t: -t[0])
    return out[:k]
