"""End-to-end training driver: ``python -m repro.launch.train --arch <id>``.

Wires the full stack: config -> model -> data pipeline -> optimizer ->
fault-tolerant runtime (checkpoint/restart, straggler watchdog) -> metrics.
On this CPU container it runs the *reduced* config by default (the full
configs are exercised by the dry-run); pass --full on real hardware.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import base as cfgs
from repro.configs import get_config, reduced
from repro.data import synth
from repro.launch.mesh import make_test_mesh
from repro.models import dlrm as dlrm_mod
from repro.models import gnn as gnn_mod
from repro.models import params as prm
from repro.models import recsys as rec_mod
from repro.models import transformer as tfm
from repro.optim.optimizers import adafactor, adam, rowwise_adagrad
from repro.runtime.fault_tolerance import (FailureInjector, StragglerWatchdog,
                                           run_resilient)


def train_lm(cfg, mesh, steps: int, batch: int, seq: int, ckpt_dir=None,
             log_every: int = 10) -> Dict[str, Any]:
    params = prm.initialize(tfm.model_specs(cfg, mesh), jax.random.PRNGKey(0))
    opt = adafactor(lr=3e-3)
    opt_state = opt.init(params)
    step_fn = jax.jit(tfm.make_train_step(cfg, mesh, opt))
    batches = list(synth.lm_batches(cfg, batch, seq, steps))
    losses = []
    state = {"params": params, "opt": opt_state}

    def one(state, b):
        p, o, m = step_fn(state["params"], state["opt"],
                          {k: jnp.asarray(v) for k, v in b.items()})
        return {"params": p, "opt": o}, m

    with mesh:
        if ckpt_dir:
            ck = Checkpointer(ckpt_dir)
            rep = run_resilient(one, state, lambda i: batches[i], steps, ck,
                                ckpt_every=max(steps // 4, 1),
                                watchdog=StragglerWatchdog())
            return {"steps": rep.steps_done,
                    "final_loss": float(rep.final_metrics["loss"])}
        for i, b in enumerate(batches):
            state, m = one(state, b)
            losses.append(float(m["loss"]))
            if i % log_every == 0:
                print(f"step {i:4d} loss {losses[-1]:.4f}")
    return {"first_loss": losses[0], "final_loss": losses[-1],
            "losses": losses}


def train_dlrm(cfg, mesh, steps: int, batch: int, mode: str = "pifs",
               replan_every: int = 0, log_every: int = 10) -> Dict[str, Any]:
    engine, offs = dlrm_mod.build_engine(cfg, mesh)
    params = prm.initialize(dlrm_mod.model_specs(cfg, mesh),
                            jax.random.PRNGKey(0))
    state = engine.init_state(jax.random.PRNGKey(1))
    opt, eopt = adam(1e-3), rowwise_adagrad(5e-2)
    ostate = opt.init(params)
    eostate = eopt.init({"cold": state.cold, "hot": state.hot})
    step_fn = jax.jit(dlrm_mod.make_train_step(cfg, engine, mesh, opt, eopt,
                                               mode=mode))
    losses = []
    with mesh:
        for i, b in enumerate(synth.dlrm_batches(cfg, batch, steps)):
            jb = {k: jnp.asarray(v) for k, v in b.items()}
            params, state, ostate, eostate, m = step_fn(
                params, state, ostate, eostate, jb)
            losses.append(float(m["loss"]))
            state = engine.observe(state, jb["indices"])
            if replan_every and (i + 1) % replan_every == 0:
                state, stats = engine.plan_and_migrate(state)
            if i % log_every == 0:
                print(f"step {i:4d} loss {losses[-1]:.4f}")
    return {"first_loss": losses[0], "final_loss": losses[-1],
            "losses": losses}


def train_rec(cfg, mesh, steps: int, batch: int, mode: str = "pifs",
              log_every: int = 10) -> Dict[str, Any]:
    engine, offs = rec_mod.build_engine(cfg, mesh)
    params = prm.initialize(rec_mod.model_specs(cfg, mesh),
                            jax.random.PRNGKey(0))
    state = engine.init_state(jax.random.PRNGKey(1))
    opt, eopt = adam(1e-3), rowwise_adagrad(5e-2)
    ostate = opt.init(params)
    eostate = eopt.init({"cold": state.cold, "hot": state.hot})
    step_fn = jax.jit(rec_mod.make_train_step(cfg, engine, offs, mesh, opt,
                                              eopt, mode=mode))
    losses = []
    with mesh:
        for i, b in enumerate(synth.rec_batches(cfg, batch, steps)):
            jb = {k: jnp.asarray(v) for k, v in b.items()}
            params, state, ostate, eostate, m = step_fn(
                params, state, ostate, eostate, jb)
            losses.append(float(m["loss"]))
            if i % log_every == 0:
                print(f"step {i:4d} loss {losses[-1]:.4f}")
    return {"first_loss": losses[0], "final_loss": losses[-1],
            "losses": losses}


def train_gnn(cfg, mesh, steps: int, log_every: int = 10) -> Dict[str, Any]:
    g = synth.make_graph(256, 2048, d_feat=32, n_classes=cfg.n_classes)
    params = prm.initialize(gnn_mod.model_specs(cfg, 32),
                            jax.random.PRNGKey(0))
    opt = adam(1e-2)
    ostate = opt.init(params)
    step_fn = jax.jit(gnn_mod.make_train_step(cfg, mesh, opt, "full"))
    batch = {k: jnp.asarray(v) for k, v in g.items()}
    losses = []
    with mesh:
        for i in range(steps):
            params, ostate, m = step_fn(params, ostate, batch)
            losses.append(float(m["loss"]))
            if i % log_every == 0:
                print(f"step {i:4d} loss {losses[-1]:.4f}")
    return {"first_loss": losses[0], "final_loss": losses[-1],
            "losses": losses}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", default="pifs",
                    choices=["pifs", "pond", "beacon"])
    ap.add_argument("--full", action="store_true",
                    help="full config (real hardware)")
    ap.add_argument("--ckpt-dir")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    n_dev = len(jax.devices())
    tp = min(4, n_dev)
    mesh = make_test_mesh(n_dev, tp)
    t0 = time.time()
    if isinstance(cfg, cfgs.LMConfig):
        out = train_lm(cfg, mesh, args.steps, args.batch, args.seq,
                       ckpt_dir=args.ckpt_dir)
    elif isinstance(cfg, cfgs.DLRMConfig):
        out = train_dlrm(cfg, mesh, args.steps, args.batch, mode=args.mode,
                         replan_every=max(args.steps // 4, 1))
    elif isinstance(cfg, cfgs.RecConfig):
        out = train_rec(cfg, mesh, args.steps, args.batch, mode=args.mode)
    else:
        out = train_gnn(cfg, mesh, args.steps)
    out.pop("losses", None)
    print(f"done in {time.time() - t0:.1f}s: {out}")


if __name__ == "__main__":
    main()
