"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import,
and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.  Multi-pod adds a leading
    pure-DP "pod" axis: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int = 8, tp: int = 4):
    """Small mesh for CPU smoke tests (same logical axes)."""
    return jax.make_mesh((n_devices // tp, tp), ("data", "model"))
