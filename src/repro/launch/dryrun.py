import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import (jax locks the
# device count on first init), which is why the docstring sits below them.

_DOC = """Multi-pod dry-run: lower + compile every (architecture x
input-shape) cell on the production meshes and extract the roofline terms.

  single-pod  : (data=16, model=16)        = 256 chips
  multi-pod   : (pod=2, data=16, model=16) = 512 chips

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k --mesh pod
      one cell, prints + writes JSON under results/dryrun/
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
      orchestrates every cell in a fresh subprocess each (compile isolation),
      skipping cells whose JSON already exists (cache).

This module is the ONLY place that forces 512 host devices — smoke tests and
benchmarks see the real device count.
"""

import argparse
import json
import re
import subprocess
import sys
import time
from typing import Dict, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# TPU v5e hardware constants for the roofline terms
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per-device effective)


_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\(?([a-z0-9\[\],{}\s/]*?)\)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.MULTILINE)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind wire bytes (per participating device, ring model).

    all-gather      : out x (G-1)/G      (each device receives the rest)
    reduce-scatter  : out x (G-1)        (ring: sends (G-1) output-sized chunks)
    all-reduce      : 2 x out x (G-1)/G  (reduce-scatter + all-gather)
    all-to-all      : out x (G-1)/G
    collective-permute : out
    """
    stats: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = re.search(
            r"=\s*(?:\([^)]*\)\s*)?([a-z0-9\[\],{}\s]*?)"
            r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        kind = m.group(2)
        out_bytes = _shape_bytes(line.split("=")[0] + m.group(1))
        if out_bytes == 0:
            out_bytes = _shape_bytes(line)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            first = gm.group(1).split("}")[0]
            g = max(1, first.count(",") + 1)
        else:
            gm2 = _GROUPS_V2_RE.search(line)
            if gm2:
                g = max(1, int(gm2.group(2)))
        if kind == "all-gather":
            wire = out_bytes * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            wire = out_bytes * (g - 1)
        elif kind == "all-reduce":
            wire = 2.0 * out_bytes * (g - 1) / max(g, 1)
        elif kind == "all-to-all":
            wire = out_bytes * (g - 1) / max(g, 1)
        else:
            wire = float(out_bytes)
        s = stats.setdefault(kind, {"count": 0, "wire_bytes": 0.0,
                                    "payload_bytes": 0.0})
        s["count"] += 1
        s["wire_bytes"] += wire
        s["payload_bytes"] += out_bytes
    return stats


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: Optional[str] = None) -> Dict:
    import jax
    from repro.configs import get_config, iter_cells
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = mesh.devices.size
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh)
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # analytic pass with while-loop trip-count multipliers — XLA's
    # cost_analysis counts rolled scan bodies once (see hlo_stats.py)
    from repro.launch.hlo_stats import summarize
    summary = summarize(hlo)
    colls = summary.collectives
    del hlo

    flops_raw = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))
    flops_total = summary.flops               # per device, loop-corrected
    # HBM traffic proxy: dot operand/output bytes (loop-corrected) — the
    # matmul share of traffic; elementwise fusions add a small constant
    # factor on top (documented in EXPERIMENTS.md §Roofline)
    bytes_total = summary.dot_bytes
    wire = summary.wire_bytes

    # XLA CPU upcasts bf16 tensors to f32 ("excess precision"), doubling the
    # byte counts of activations/grads that are bf16 on real TPU; halve the
    # byte-denominated terms for bf16-dtype models (flag recorded)
    model_dtype = str(getattr(get_config(arch), "dtype", "float32"))
    bf16_corr = 0.5 if model_dtype == "bfloat16" else 1.0

    compute_s = flops_total / PEAK_FLOPS
    memory_s = bytes_total * bf16_corr / HBM_BW
    collective_s = wire * bf16_corr / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "n_chips": int(n_chips),
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "cost": {"flops": flops_total, "dot_bytes": bytes_total,
                 "xla_flops_raw": flops_raw,
                 "xla_bytes_raw": bytes_raw},
        "collectives": colls,
        "roofline": {
            **terms,
            "bf16_cpu_upcast_correction": bf16_corr,
            "dominant": dominant,
            "model_flops": cell.model_flops,
            "model_flops_per_chip": cell.model_flops / n_chips,
            "useful_flops_ratio": (cell.model_flops / n_chips / flops_total
                                   if flops_total else 0.0),
        },
    }
    # peak per-device bytes: arguments + temps must fit 16 GB
    rec["memory"]["total_bytes"] = (rec["memory"]["argument_bytes"]
                                    + rec["memory"]["temp_bytes"]
                                    + rec["memory"]["output_bytes"])
    rec["memory"]["fits_16gb"] = rec["memory"]["argument_bytes"] \
        + rec["memory"]["temp_bytes"] < 16e9

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def _cell_path(out_dir: str, arch: str, shape: str, mesh: str) -> str:
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")


def orchestrate(mesh_kinds, out_dir: str, arch_filter=None,
                timeout_s: int = 3600) -> int:
    from repro.configs import iter_cells
    os.makedirs(out_dir, exist_ok=True)
    failures = 0
    for arch, shape, skip in iter_cells():
        if arch_filter and arch != arch_filter:
            continue
        for mk in mesh_kinds:
            path = _cell_path(out_dir, arch, shape, mk)
            if skip:
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "mesh": mk,
                               "ok": True, "skipped": skip}, f, indent=1)
                print(f"[skip] {arch}:{shape}:{mk} — {skip}")
                continue
            if os.path.exists(path):
                with open(path) as f:
                    prev = json.load(f)
                if prev.get("ok"):
                    print(f"[cache] {arch}:{shape}:{mk}")
                    continue
            print(f"[run ] {arch}:{shape}:{mk} ...", flush=True)
            t0 = time.time()
            proc = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", arch, "--shape", shape, "--mesh", mk,
                 "--out", out_dir],
                capture_output=True, text=True, timeout=timeout_s,
                env={**os.environ, "PYTHONPATH": os.environ.get(
                    "PYTHONPATH", "src")})
            dt = time.time() - t0
            if proc.returncode != 0:
                failures += 1
                tail = proc.stderr.strip().splitlines()[-12:]
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "mesh": mk,
                               "ok": False, "error": "\n".join(tail)},
                              f, indent=1)
                print(f"[FAIL] {arch}:{shape}:{mk} ({dt:.0f}s)\n  "
                      + "\n  ".join(tail))
            else:
                print(f"[ ok ] {arch}:{shape}:{mk} ({dt:.0f}s)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    mesh_kinds = (["pod", "multipod"] if args.mesh == "both"
                  else [args.mesh])
    if args.all:
        failures = orchestrate(mesh_kinds, args.out, arch_filter=args.arch)
        sys.exit(1 if failures else 0)

    rec = run_cell(args.arch, args.shape, mesh_kinds[0], out_dir=args.out)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("collectives",)}, indent=1))
    print("collectives:", json.dumps(rec["collectives"], indent=1))


if __name__ == "__main__":
    main()
