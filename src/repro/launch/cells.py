"""Dry-run cell builders: (arch x input-shape x mesh) -> a jit-able step with
abstract inputs and shardings.

Every builder returns a CellSpec: lower it with
``jax.jit(fn, in_shardings=...).lower(*abstract)`` — no real allocation ever
happens (ShapeDtypeStruct stand-ins).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import base as cfgs
from repro.configs import get_config
from repro.models import dlrm as dlrm_mod
from repro.models import gnn as gnn_mod
from repro.models import params as prm
from repro.models import recsys as rec_mod
from repro.models import transformer as tfm
from repro.optim.optimizers import adafactor, adam, rowwise_adagrad


@dataclasses.dataclass
class CellSpec:
    name: str
    fn: Callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...] = ()
    # model FLOPs per step for §Roofline's MODEL_FLOPS/HLO_FLOPs ratio
    model_flops: float = 0.0


def _dp_axes(mesh: Mesh):
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data")
    if "data" in names:
        return ("data",)
    return ()


def _ns(mesh: Mesh, tree_pspec):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_pspec,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Optimizer-state pspec derivation
# ---------------------------------------------------------------------------


def _adafactor_pspecs(params_ps, params_abs):
    def one(ps, ab):
        if (ab.ndim >= 2 and ab.shape[-1] >= 128 and ab.shape[-2] >= 128):
            t = tuple(ps)
            t = t + (None,) * (ab.ndim - len(t))
            return {"vr": P(*t[:-1]), "vc": P(*(t[:-2] + t[-1:]))}
        return {"v": ps}
    return {"step": P(),
            "v": jax.tree.map(one, params_ps, params_abs,
                              is_leaf=lambda x: isinstance(x, P))}


def _adam_pspecs(params_ps):
    return {"step": P(),
            "mv": jax.tree.map(lambda ps: {"m": ps, "v": ps}, params_ps,
                               is_leaf=lambda x: isinstance(x, P))}


def _rowwise_pspecs(params_ps):
    # accumulator is (rows, 1) for >=2D params: keep the row axis's sharding
    return jax.tree.map(lambda ps: ps, params_ps,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Model-FLOPs estimates (6·N·D dense / 6·N_active·D MoE; serving: 2·N·D)
# ---------------------------------------------------------------------------


def lm_param_counts(cfg: cfgs.LMConfig) -> Tuple[float, float]:
    """(total_params, active_params) excluding embeddings (6ND convention)."""
    d = cfg.d_model
    if cfg.attn_type == "mla":
        m = cfg.mla
        qd = m.qk_nope_head_dim + m.qk_rope_head_dim
        attn = (d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qd
                + d * m.kv_lora_rank
                + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + d * m.qk_rope_head_dim + cfg.n_heads * m.v_head_dim * d)
    else:
        h = cfg.head_dim
        attn = d * cfg.n_heads * h * 2 + d * cfg.n_kv_heads * h * 2
    glu = cfg.activation != "relu2"
    ffn_dense = d * cfg.d_ff * (3 if glu else 2)
    n_dense = cfg.n_layers if cfg.moe is None else cfg.moe.first_dense_layers
    n_moe = 0 if cfg.moe is None else cfg.n_layers - n_dense
    total = active = cfg.n_layers * attn + n_dense * ffn_dense
    if cfg.moe is not None:
        e = cfg.moe
        expert = d * e.d_ff_expert * 3
        total += n_moe * (e.n_experts + e.n_shared_experts) * expert
        active += n_moe * (e.top_k + e.n_shared_experts) * expert
    return float(total), float(active)


def lm_model_flops(cfg: cfgs.LMConfig, tokens: int, kind: str,
                   seq: int = 0) -> float:
    """6ND (train) / 2ND (serve) plus the attention score/value flops
    (2 x 2 x s_kv_avg x H x h per token per layer, causal halves prefill)."""
    total, active = lm_param_counts(cfg)
    per_tok = 6.0 * active if kind == "train" else 2.0 * active
    if seq:
        if cfg.attn_type == "mla":
            d_attn = cfg.n_heads * (cfg.mla.qk_nope_head_dim
                                    + cfg.mla.qk_rope_head_dim)
        else:
            d_attn = cfg.n_heads * cfg.head_dim
        kv_avg = seq / 2.0 if kind in ("train", "prefill") else seq
        attn = 4.0 * kv_avg * d_attn * cfg.n_layers
        per_tok += attn * (3.0 if kind == "train" else 1.0)
    return per_tok * tokens


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def build_lm_cell(cfg: cfgs.LMConfig, shape: cfgs.LMShape, mesh: Mesh
                  ) -> CellSpec:
    dp = _dp_axes(mesh)
    dpp = dp if dp else None
    # decode uses weight-stationary width sharding (see layer_specs)
    specs = tfm.model_specs(cfg, mesh, serving=(shape.kind == "decode"))
    p_abs = prm.abstract(specs)
    p_ps = prm.pspecs(specs)
    p_sh = _ns(mesh, p_ps)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        opt = adafactor(lr=1e-2)
        o_abs = jax.eval_shape(opt.init, p_abs)
        o_ps = _adafactor_pspecs(p_ps, p_abs)
        o_sh = _ns(mesh, o_ps)
        batch_abs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        b_sh = _ns(mesh, {"tokens": P(dpp, None), "labels": P(dpp, None)})
        # train_accum is the single-pod setting; the multi-pod mesh has 2x
        # the memory, so it needs half the accumulation (and pays half the
        # repeated weight-gather traffic)
        accum = cfg.train_accum
        if "pod" in mesh.axis_names and accum > 1:
            accum = max(1, accum // 2)
        step = tfm.make_train_step(cfg, mesh, opt, remat=cfg.remat, sp=True,
                                   accum=accum)
        return CellSpec(
            name=f"{cfg.name}:{shape.name}", fn=step,
            abstract_args=(p_abs, o_abs, batch_abs),
            in_shardings=(p_sh, o_sh, b_sh),
            donate_argnums=(0, 1),
            model_flops=lm_model_flops(cfg, B * S, "train", seq=S))

    if shape.kind == "prefill":
        def step(params, tokens):
            return tfm.prefill_step(params, tokens, cfg, mesh)
        t_abs = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return CellSpec(
            name=f"{cfg.name}:{shape.name}", fn=step,
            abstract_args=(p_abs, t_abs),
            in_shardings=(p_sh, NamedSharding(mesh, P(dpp, None))),
            model_flops=lm_model_flops(cfg, B * S, "prefill", seq=S))

    # decode: one new token against a seq-sharded KV cache of length S
    cache_abs = tfm.cache_specs(cfg, mesh, batch=B, seq=S)
    cache_sh = _ns(mesh, tfm.cache_pspecs(cfg, mesh))

    def step(params, cache, tokens, pos):
        return tfm.decode_step(params, cache, tokens, pos, cfg, mesh)

    return CellSpec(
        name=f"{cfg.name}:{shape.name}", fn=step,
        abstract_args=(p_abs, cache_abs,
                       jax.ShapeDtypeStruct((B, 1), jnp.int32),
                       jax.ShapeDtypeStruct((), jnp.int32)),
        in_shardings=(p_sh, cache_sh,
                      NamedSharding(mesh, P(dpp, None)),
                      NamedSharding(mesh, P())),
        donate_argnums=(1,),
        model_flops=lm_model_flops(cfg, B, "serve", seq=S))


# ---------------------------------------------------------------------------
# Recsys cells
# ---------------------------------------------------------------------------


def build_rec_cell(cfg: cfgs.RecConfig, shape: cfgs.RecShape, mesh: Mesh
                   ) -> CellSpec:
    dp = _dp_axes(mesh)
    engine, offsets = rec_mod.build_engine(cfg, mesh)
    specs = rec_mod.model_specs(cfg, mesh)
    p_abs = prm.abstract(specs)
    p_sh = _ns(mesh, prm.pspecs(specs))
    e_abs = engine.state_shapes()
    e_sh = engine.state_shardings()

    kind = shape.kind
    batch_abs = rec_mod.input_specs(
        cfg, kind, shape.batch, n_candidates=shape.n_candidates,
        with_labels=True)
    b_sh = _ns(mesh, rec_mod.input_pspecs(cfg, kind, mesh, with_labels=True))
    # align key sets (input_pspecs mirrors input_specs keys)
    b_sh = {k: b_sh[k] for k in batch_abs}

    # rough model flops: embedding bytes ~ lookups; interaction+MLP dominate
    flops = _rec_model_flops(cfg, shape)

    if kind == "train":
        opt = adam(1e-3)
        eopt = rowwise_adagrad(1e-2)
        o_abs = jax.eval_shape(opt.init, p_abs)
        o_ps = _adam_pspecs(prm.pspecs(specs))
        o_sh = _ns(mesh, o_ps)
        emb_params_abs = {"cold": e_abs.cold, "hot": e_abs.hot}
        eo_abs = jax.eval_shape(eopt.init, emb_params_abs)
        eo_sh = _ns(mesh, {"cold": engine.state_pspecs().cold,
                           "hot": engine.state_pspecs().hot})
        step = rec_mod.make_train_step(cfg, engine, offsets, mesh, opt, eopt)
        return CellSpec(
            name=f"{cfg.name}:{shape.name}", fn=step,
            abstract_args=(p_abs, e_abs, o_abs, eo_abs, batch_abs),
            in_shardings=(p_sh, e_sh, o_sh, eo_sh, b_sh),
            donate_argnums=(1, 2, 3), model_flops=flops)

    if kind == "retrieval":
        step = rec_mod.make_retrieval_step(cfg, engine, offsets, mesh)
    else:
        step = rec_mod.make_serve_step(cfg, engine, offsets, mesh)
    return CellSpec(
        name=f"{cfg.name}:{shape.name}", fn=step,
        abstract_args=(p_abs, e_abs, batch_abs),
        in_shardings=(p_sh, e_sh, b_sh), model_flops=flops)


def _rec_model_flops(cfg: cfgs.RecConfig, shape: cfgs.RecShape) -> float:
    d = cfg.embed_dim
    it = cfg.interaction
    if it == "self-attn-seq":
        S = cfg.seq_len
        per = cfg.n_blocks * (4 * S * d * d * 2 + 2 * S * S * d * 2)
    elif it == "transformer-seq":
        S = cfg.seq_len + 1
        per = cfg.n_blocks * (4 * S * d * d * 2 + 2 * S * S * d * 2
                              + 8 * S * d * d * 2)
        dims = (S * d + cfg.n_dense,) + cfg.mlp_dims + (1,)
        per += sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    elif it == "self-attn":
        F = cfg.n_sparse
        da = cfg.d_attn * cfg.n_heads
        per = cfg.n_attn_layers * (3 * F * d * da * 2 + 2 * F * F * da * 2
                                   + F * d * d * 2)
    else:
        x0 = cfg.n_dense + cfg.n_sparse * d
        per = cfg.n_cross_layers * 2 * x0 * x0
        dims = (x0,) + cfg.mlp_dims
        per += sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    if shape.kind == "retrieval" and it == "self-attn-seq":
        # two-tower retrieval: one query encode + a dot per candidate
        return float(per) + 2.0 * d * max(shape.n_candidates, 1)
    n = shape.n_candidates if shape.kind == "retrieval" else shape.batch
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd+bwd
    return float(per) * max(n, 1) * mult


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def build_gnn_cell(cfg: cfgs.GNNConfig, shape: cfgs.GNNShape, mesh: Mesh
                   ) -> CellSpec:
    dp = _dp_axes(mesh)
    tp_size = mesh.shape["model"]
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    # pad node/edge counts to mesh divisibility (pad edges are inert:
    # src=-1 fails the ownership test, dst=0 accumulates zero)
    pad_nodes = -(-shape.n_nodes // tp_size) * tp_size
    d_feat = shape.d_feat or 16
    specs = gnn_mod.model_specs(cfg, d_feat)
    p_abs = prm.abstract(specs)
    p_sh = _ns(mesh, prm.pspecs(specs))

    if shape.kind == "full":
        pad_edges = -(-shape.n_edges // dp_size) * dp_size
        shape = dataclasses.replace(shape, n_edges=pad_edges)
    batch_abs = gnn_mod.input_specs(cfg, shape, pad_nodes=pad_nodes)
    b_sh = _ns(mesh, gnn_mod.input_pspecs(cfg, shape, mesh))

    regime = {"full": "full", "minibatch": "minibatch",
              "batched_small": "molecule"}[shape.kind]
    opt = adam(1e-2)
    o_abs = jax.eval_shape(opt.init, p_abs)
    o_sh = _ns(mesh, _adam_pspecs(prm.pspecs(specs)))
    step = gnn_mod.make_train_step(cfg, mesh, opt, regime)

    # flops: 2 (gather+matmul) x edges x d x d' per layer + node transforms
    dims = gnn_mod.layer_dims(cfg, d_feat)
    if shape.kind == "full":
        f = sum(2 * shape.n_edges * dims[i] +
                2 * shape.n_nodes * dims[i] * dims[i + 1] * 2
                for i in range(cfg.n_layers))
    elif shape.kind == "minibatch":
        B = shape.batch_nodes
        f1, f2 = shape.fanout
        n_agg = B * (1 + f1 + f1 * f2)
        f = 2 * n_agg * dims[0] * dims[1] * 2 + 2 * B * dims[1] * dims[2] * 2
    else:
        f = shape.graph_batch * sum(
            2 * shape.n_edges * dims[i]
            + 2 * shape.n_nodes * dims[i] * dims[i + 1] * 2
            for i in range(cfg.n_layers))
    return CellSpec(
        name=f"{cfg.name}:{shape.name}", fn=step,
        abstract_args=(p_abs, o_abs, batch_abs),
        in_shardings=(p_sh, o_sh, b_sh),
        donate_argnums=(0, 1), model_flops=float(f) * 3.0)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def build_cell(arch: str, shape_name: str, mesh: Mesh) -> CellSpec:
    cfg = get_config(arch)
    shape = cfg.shapes()[shape_name]
    if isinstance(cfg, cfgs.LMConfig):
        return build_lm_cell(cfg, shape, mesh)
    if isinstance(cfg, cfgs.RecConfig):
        return build_rec_cell(cfg, shape, mesh)
    if isinstance(cfg, cfgs.GNNConfig):
        return build_gnn_cell(cfg, shape, mesh)
    if isinstance(cfg, cfgs.DLRMConfig):
        return build_dlrm_cell(cfg, shape, mesh)
    raise TypeError(type(cfg))


def build_dlrm_cell(cfg: cfgs.DLRMConfig, shape: cfgs.RecShape, mesh: Mesh
                    ) -> CellSpec:
    """Paper's own RMC models (used by benchmarks, not the assigned pool)."""
    dp = _dp_axes(mesh)
    dpp = dp if dp else None
    engine, offsets = dlrm_mod.build_engine(cfg, mesh)
    specs = dlrm_mod.model_specs(cfg, mesh)
    p_abs = prm.abstract(specs)
    p_sh = _ns(mesh, prm.pspecs(specs))
    e_abs = engine.state_shapes()
    e_sh = engine.state_shardings()
    with_labels = shape.kind == "train"
    batch_abs = dlrm_mod.input_specs(cfg, shape.batch, mesh, with_labels)
    b_sh = _ns(mesh, dlrm_mod.input_pspecs(cfg, mesh, with_labels))

    if shape.kind == "train":
        opt, eopt = adam(1e-3), rowwise_adagrad(1e-2)
        o_abs = jax.eval_shape(opt.init, p_abs)
        o_sh = _ns(mesh, _adam_pspecs(prm.pspecs(specs)))
        emb_params_abs = {"cold": e_abs.cold, "hot": e_abs.hot}
        eo_abs = jax.eval_shape(eopt.init, emb_params_abs)
        eo_sh = _ns(mesh, {"cold": engine.state_pspecs().cold,
                           "hot": engine.state_pspecs().hot})
        step = dlrm_mod.make_train_step(cfg, engine, mesh, opt, eopt)
        return CellSpec(
            name=f"{cfg.name}:{shape.name}", fn=step,
            abstract_args=(p_abs, e_abs, o_abs, eo_abs, batch_abs),
            in_shardings=(p_sh, e_sh, o_sh, eo_sh, b_sh),
            donate_argnums=(1, 2, 3))
    step = dlrm_mod.make_serve_step(cfg, engine, mesh)
    return CellSpec(
        name=f"{cfg.name}:{shape.name}", fn=step,
        abstract_args=(p_abs, e_abs, batch_abs),
        in_shardings=(p_sh, e_sh, b_sh))
