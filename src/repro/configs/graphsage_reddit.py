"""graphsage-reddit [arXiv:1706.02216]: 2 layers, mean agg, fanout 25-10."""
from repro.configs.base import GNNConfig, register

CONFIG = register(GNNConfig(
    name="graphsage-reddit",
    n_layers=2,
    d_hidden=128,
    aggregator="mean",
    sample_sizes=(25, 10),
    n_classes=41,             # Reddit community labels
    source="arXiv:1706.02216",
))
