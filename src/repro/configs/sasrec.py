"""sasrec [arXiv:1808.09781]: self-attentive sequential recommendation.

Paper dims: embed 50, 2 blocks, 1 head, seq 50.  Item vocabulary is dataset
dependent; we use a production-scale 1M-item catalogue so the PIFS embedding
engine and the retrieval_cand shape (1M candidates) are exercised at scale.
"""
from repro.configs.base import RecConfig, register

CONFIG = register(RecConfig(
    name="sasrec",
    interaction="self-attn-seq",
    embed_dim=50,
    vocab_sizes=(1_000_000,),  # item catalogue
    seq_len=50,
    n_blocks=2,
    n_heads=1,
    mlp_dims=(),
    source="arXiv:1808.09781",
))
