"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import LMConfig, MoEConfig, register

CONFIG = register(LMConfig(
    name="granite-moe-1b-a400m",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,                # dense ffn width == expert width for this model
    vocab=49155,
    d_head=64,
    attn_type="gqa",
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
    activation="silu_glu",
    rope_theta=10000.0,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
