"""deepseek-67b [arXiv:2401.02954]: dense llama-arch, GQA kv=8."""
from repro.configs.base import LMConfig, register

CONFIG = register(LMConfig(
    name="deepseek-67b",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    d_head=128,
    attn_type="gqa",
    activation="silu_glu",
    rope_theta=10000.0,
    remat="full",
    train_accum=4,
    source="arXiv:2401.02954",
))
