"""autoint [arXiv:1810.11921]: self-attention feature interaction over Criteo.

39 sparse fields = 13 discretized numerical + 26 categorical (Criteo convention
in the AutoInt paper).  Categorical cardinalities follow the public Criteo
Kaggle field statistics; numerical fields are bucketized to 64 bins.
"""
from repro.configs.base import RecConfig, register

CRITEO_CAT_VOCABS = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572,
)

CONFIG = register(RecConfig(
    name="autoint",
    interaction="self-attn",
    embed_dim=16,
    vocab_sizes=tuple([64] * 13) + CRITEO_CAT_VOCABS,
    n_attn_layers=3,
    n_heads=2,
    d_attn=32,
    mlp_dims=(),
    source="arXiv:1810.11921",
))
