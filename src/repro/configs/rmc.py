"""The paper's own models (PIFS-Rec Table I): RMC1-4.

Emb.Num is rows *per table*; the paper runs up to 192 tables in the
characterization and 8 lookups per bag in the evaluation (section VI-C).
We default to 8 tables / pooling 8 to match the evaluation setup, with the
characterization-scale table count available via dataclasses.replace.
"""
from repro.configs.base import DLRMConfig, register

RMC1 = register(DLRMConfig(
    name="rmc1", emb_num=16384, emb_dim=64,
    bottom_mlp=(256, 128, 128), top_mlp=(128, 64, 1)))

RMC2 = register(DLRMConfig(
    name="rmc2", emb_num=131072, emb_dim=64,
    bottom_mlp=(1024, 512, 128), top_mlp=(384, 192, 1)))

RMC3 = register(DLRMConfig(
    name="rmc3", emb_num=1048576, emb_dim=64,
    bottom_mlp=(2048, 1024, 256), top_mlp=(512, 256, 1)))

RMC4 = register(DLRMConfig(
    name="rmc4", emb_num=1048576, emb_dim=128,
    bottom_mlp=(2048, 2048, 256), top_mlp=(768, 384, 1)))
