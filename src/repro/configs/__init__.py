from repro.configs.base import (  # noqa: F401
    Config, DLRMConfig, GNNConfig, LMConfig, MLAConfig, MoEConfig, RecConfig,
    GNN_SHAPES, LM_SHAPES, REC_SHAPES,
    get_config, iter_cells, list_archs, reduced, reduced_shape, register,
)
