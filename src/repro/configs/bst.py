"""bst [arXiv:1905.06874]: Behavior Sequence Transformer (Alibaba).

Item catalogue at Taobao scale (1M hashed ids) + item-category side feature;
sequence of 20 recent behaviours + target item -> transformer block -> MLP.
"""
from repro.configs.base import RecConfig, register

CONFIG = register(RecConfig(
    name="bst",
    interaction="transformer-seq",
    embed_dim=32,
    vocab_sizes=(1_000_000, 10_000),   # (item id, category id)
    seq_len=20,
    n_blocks=1,
    n_heads=8,
    n_dense=8,                          # user/context profile features
    mlp_dims=(1024, 512, 256),
    source="arXiv:1905.06874",
))
