"""deepseek-v3-671b [arXiv:2412.19437]: MLA, 1 shared + 256 routed top-8, MTP."""
from repro.configs.base import LMConfig, MLAConfig, MoEConfig, register

CONFIG = register(LMConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,          # MLA: kv latent shared; head count for q/k after up-proj
    d_ff=18432,              # dense FFN width (first_dense_layers)
    vocab=129280,
    attn_type="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, first_dense_layers=3),
    activation="silu_glu",
    rope_theta=10000.0,
    mtp_depth=1,
    remat="full",
    train_accum=8,
    source="arXiv:2412.19437",
))
