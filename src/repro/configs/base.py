"""Config system: dataclasses, shape sets, and the architecture registry.

Every assigned architecture is a frozen dataclass instance living in its own
module under ``repro.configs``.  ``get_config(name)`` resolves by registry id
(the ``--arch <id>`` string).  ``reduced(cfg)`` returns a CPU-smoke-testable
shrink of the same family.  ``iter_cells()`` enumerates the full
(architecture x input-shape) dry-run matrix, with skip reasons where the pool
spec mandates a skip (long_500k on pure full-attention archs).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Shape descriptors
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LMShape:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    subquadratic_only: bool = False


@dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str            # "full" | "minibatch" | "batched_small"
    n_nodes: int
    n_edges: int
    d_feat: int = 0
    batch_nodes: int = 0          # sampled-training root batch
    fanout: Tuple[int, ...] = ()  # neighbor-sampling fanout per hop
    graph_batch: int = 0          # batched-small-graphs batch size


@dataclass(frozen=True)
class RecShape:
    name: str
    kind: str            # "train" | "serve" | "retrieval"
    batch: int
    n_candidates: int = 0


LM_SHAPES: Dict[str, LMShape] = {
    "train_4k": LMShape("train_4k", "train", 4096, 256),
    "prefill_32k": LMShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": LMShape("decode_32k", "decode", 32768, 128),
    "long_500k": LMShape("long_500k", "decode", 524288, 1, subquadratic_only=True),
}

GNN_SHAPES: Dict[str, GNNShape] = {
    # Cora full-batch
    "full_graph_sm": GNNShape("full_graph_sm", "full", 2708, 10556, d_feat=1433),
    # Reddit sampled-training
    "minibatch_lg": GNNShape("minibatch_lg", "minibatch", 232965, 114615892,
                             d_feat=602, batch_nodes=1024, fanout=(15, 10)),
    # ogbn-products full-batch
    "ogb_products": GNNShape("ogb_products", "full", 2449029, 61859140, d_feat=100),
    # batched small molecule graphs
    "molecule": GNNShape("molecule", "batched_small", 30, 64, d_feat=32, graph_batch=128),
}

REC_SHAPES: Dict[str, RecShape] = {
    "train_batch": RecShape("train_batch", "train", 65536),
    "serve_p99": RecShape("serve_p99", "serve", 512),
    "serve_bulk": RecShape("serve_bulk", "serve", 262144),
    "retrieval_cand": RecShape("retrieval_cand", "retrieval", 1, n_candidates=1_000_000),
}


# ---------------------------------------------------------------------------
# Architecture configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    first_dense_layers: int = 0      # deepseek-v3: first 3 layers are dense
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    family: str = "lm"
    d_head: int = 0                  # 0 -> d_model // n_heads
    attn_type: str = "gqa"           # "gqa" | "mla"
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    activation: str = "silu_glu"     # "silu_glu" | "relu2"
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # multi-token prediction heads (deepseek-v3 MTP); 0 disables
    mtp_depth: int = 0
    # activation-checkpoint policy for the layer scan: "dots" saves matmul
    # outputs (fast backward but saves O(s^2) attention scores); "full"
    # saves only carries — the default: at seq 4096 every assigned arch
    # overflows 16 GB/chip under "dots" (measured in the dry-run)
    remat: str = "full"
    # gradient-accumulation microbatches for train_4k (shrinks the remat
    # carry stack by the same factor; the giants need it at 16 GB/chip)
    train_accum: int = 1
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def shapes(self) -> Dict[str, LMShape]:
        return LM_SHAPES


@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    aggregator: str = "mean"
    sample_sizes: Tuple[int, ...] = (25, 10)
    n_classes: int = 41
    family: str = "gnn"
    dtype: str = "float32"
    source: str = ""

    def shapes(self) -> Dict[str, GNNShape]:
        return GNN_SHAPES


@dataclass(frozen=True)
class RecConfig:
    name: str
    interaction: str                  # "self-attn-seq" | "self-attn" | "cross" | "transformer-seq"
    embed_dim: int
    vocab_sizes: Tuple[int, ...]      # per sparse field (or (n_items,) for seq models)
    n_dense: int = 0
    seq_len: int = 0                  # behaviour-sequence length (sasrec/bst)
    n_blocks: int = 0
    n_heads: int = 0
    d_attn: int = 0
    n_attn_layers: int = 0
    n_cross_layers: int = 0
    mlp_dims: Tuple[int, ...] = ()
    multi_hot: int = 1                # lookups per field per sample (SLS pooling factor)
    family: str = "recsys"
    dtype: str = "float32"
    source: str = ""

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    def shapes(self) -> Dict[str, RecShape]:
        return REC_SHAPES


@dataclass(frozen=True)
class DLRMConfig:
    """Paper Table I models (RMC1-4)."""
    name: str
    emb_num: int
    emb_dim: int
    bottom_mlp: Tuple[int, ...]
    top_mlp: Tuple[int, ...]
    n_tables: int = 8
    pooling: int = 8                  # paper default: 8 lookups per bag
    n_dense: int = 13
    family: str = "dlrm"
    dtype: str = "float32"
    source: str = "PIFS-Rec Table I"

    def shapes(self) -> Dict[str, RecShape]:
        return REC_SHAPES


Config = Any  # union of the above


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Config] = {}


def register(cfg: Config) -> Config:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch id {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def _ensure_loaded() -> None:
    # import side-effect registration
    from repro.configs import (  # noqa: F401
        granite_moe_1b_a400m, deepseek_v3_671b, deepseek_67b, llama3_2_3b,
        nemotron_4_340b, graphsage_reddit, sasrec, autoint, dcn_v2, bst, rmc,
    )


def get_config(name: str) -> Config:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs(assigned_only: bool = True) -> List[str]:
    _ensure_loaded()
    names = sorted(_REGISTRY)
    if assigned_only:
        names = [n for n in names if not n.startswith("rmc")]
    return names


def iter_cells() -> List[Tuple[str, str, Optional[str]]]:
    """All 40 (arch, shape) dry-run cells with skip reasons where mandated."""
    _ensure_loaded()
    cells: List[Tuple[str, str, Optional[str]]] = []
    for name in list_archs():
        cfg = _REGISTRY[name]
        for sname, shape in cfg.shapes().items():
            skip = None
            if getattr(shape, "subquadratic_only", False) and cfg.family == "lm":
                skip = ("full-attention arch: long_500k requires sub-quadratic "
                        "attention (see DESIGN.md section 5)")
            cells.append((name, sname, skip))
    return cells


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------


def reduced(cfg: Config) -> Config:
    """Shrink a config to something a CPU smoke test can run in seconds."""
    if isinstance(cfg, LMConfig):
        kw: Dict[str, Any] = dict(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab=512, d_head=16, rope_theta=10000.0,
            mtp_depth=min(cfg.mtp_depth, 1), train_accum=1)
        if cfg.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16)
            kw["d_head"] = 0
        if cfg.moe is not None:
            kw["moe"] = replace(cfg.moe, n_experts=4, top_k=2, d_ff_expert=32,
                                n_shared_experts=min(cfg.moe.n_shared_experts, 1),
                                first_dense_layers=min(cfg.moe.first_dense_layers, 1))
        return replace(cfg, **kw)
    if isinstance(cfg, GNNConfig):
        return replace(cfg, d_hidden=16, sample_sizes=(4, 3), n_classes=5)
    if isinstance(cfg, RecConfig):
        vocabs = tuple(min(v, 100) for v in cfg.vocab_sizes)
        kw = dict(vocab_sizes=vocabs, embed_dim=8)
        if cfg.mlp_dims:
            kw["mlp_dims"] = tuple(min(d, 32) for d in cfg.mlp_dims)
        if cfg.seq_len:
            kw["seq_len"] = min(cfg.seq_len, 12)
        if cfg.d_attn:
            kw["d_attn"] = 8
        return replace(cfg, **kw)
    if isinstance(cfg, DLRMConfig):
        return replace(cfg, emb_num=256, emb_dim=16, n_tables=4, pooling=4,
                       bottom_mlp=(32, 16, 16), top_mlp=(16, 8, 1))
    raise TypeError(f"unknown config type {type(cfg)}")


def reduced_shape(shape: Any) -> Any:
    """Shrink a shape descriptor for smoke tests."""
    if isinstance(shape, LMShape):
        return replace(shape, seq_len=min(shape.seq_len, 64),
                       global_batch=min(shape.global_batch, 4))
    if isinstance(shape, GNNShape):
        return replace(
            shape,
            n_nodes=min(shape.n_nodes, 200),
            n_edges=min(shape.n_edges, 800),
            d_feat=min(shape.d_feat, 16) if shape.d_feat else 0,
            batch_nodes=min(shape.batch_nodes, 8) if shape.batch_nodes else 0,
            fanout=tuple(min(f, 3) for f in shape.fanout),
            graph_batch=min(shape.graph_batch, 4) if shape.graph_batch else 0)
    if isinstance(shape, RecShape):
        return replace(shape, batch=min(shape.batch, 16),
                       n_candidates=min(shape.n_candidates, 64) if shape.n_candidates else 0)
    raise TypeError(f"unknown shape type {type(shape)}")
