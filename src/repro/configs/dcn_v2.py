"""dcn-v2 [arXiv:2008.13535]: cross network v2 over Criteo (13 dense, 26 sparse)."""
from repro.configs.base import RecConfig, register
from repro.configs.autoint import CRITEO_CAT_VOCABS

CONFIG = register(RecConfig(
    name="dcn-v2",
    interaction="cross",
    embed_dim=16,
    vocab_sizes=CRITEO_CAT_VOCABS,
    n_dense=13,
    n_cross_layers=3,
    mlp_dims=(1024, 1024, 512),
    source="arXiv:2008.13535",
))
