"""nemotron-4-340b [arXiv:2402.16819]: dense, GQA kv=8, squared-ReLU FFN."""
from repro.configs.base import LMConfig, register

CONFIG = register(LMConfig(
    name="nemotron-4-340b",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    d_head=192,
    attn_type="gqa",
    activation="relu2",       # squared-ReLU, no GLU gate
    rope_theta=10000.0,
    remat="full",
    train_accum=16,
    source="arXiv:2402.16819",
))
