"""llama3.2-3b [hf:meta-llama/Llama-3.2-*; assigned dims]."""
from repro.configs.base import LMConfig, register

CONFIG = register(LMConfig(
    name="llama3.2-3b",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    d_head=128,
    attn_type="gqa",
    activation="silu_glu",
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-3.2-3B",
))
