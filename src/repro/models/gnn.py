"""GraphSAGE (mean aggregator) in three execution regimes.

JAX has no CSR/CSC sparse — message passing is built from first principles on
edge lists: gather by src -> ``jax.ops.segment_sum`` by dst -> degree
normalize.  That segment-reduce IS the system (kernel_taxonomy §GNN).

Distribution follows the PIFS pattern:
  * node features row-sharded over `model` (tp) — the "memory pool";
  * edges sharded over `data` (dp) — each dp shard owns E/dp edges;
  * each (dp, tp) device aggregates messages only for edges whose *source
    rows it owns* (reduce near the data), then partial aggregates are
    psum'd over dp and psum_scatter'd over tp back into the node layout —
    pooled (N, d) partials cross the ICI, never raw gathered edge features.

Regimes:
  * full      — full-graph layers (Cora / ogbn-products shapes);
  * minibatch — fanout-sampled blocks (Reddit shape): a host-side neighbor
    sampler (numpy, CSR) emits fixed-shape (B, f1), (B, f1, f2) id tensors;
    features for sampled ids are fetched from the tp-sharded store with the
    same masked-partial-gather the PIFS engine uses;
  * batched_small — (G, n, d) molecule batches, graph-parallel over dp.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import GNNConfig
from repro.distributed.sharding import shard_map
from repro.models.params import Spec


def _axes(mesh: Mesh):
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    if "data" in names:
        return ("data",), "model"
    return (), names[-1]


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def layer_dims(cfg: GNNConfig, d_feat: int) -> list:
    dims = [d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    return dims


def model_specs(cfg: GNNConfig, d_feat: int, dtype=jnp.float32) -> dict:
    dims = layer_dims(cfg, d_feat)
    layers = []
    for i in range(cfg.n_layers):
        a, b = dims[i], dims[i + 1]
        layers.append({
            "w_self": Spec((a, b), dtype),
            "w_neigh": Spec((a, b), dtype),
            "bias": Spec((b,), dtype, init="zeros"),
        })
    return {"layers": layers}


def _sage_combine(lp: dict, h_self: jax.Array, h_neigh: jax.Array,
                  last: bool) -> jax.Array:
    out = h_self @ lp["w_self"] + h_neigh @ lp["w_neigh"] + lp["bias"]
    if not last:
        out = jax.nn.relu(out)
        # GraphSAGE l2-normalizes hidden layers
        out = out / jnp.maximum(
            jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-6)
    return out


# ---------------------------------------------------------------------------
# Full-graph regime (edge-parallel x node-sharded)
# ---------------------------------------------------------------------------


def full_forward(params: dict, feats: jax.Array, edges: jax.Array,
                 cfg: GNNConfig, mesh: Mesh) -> jax.Array:
    """feats: (N, F) P(tp, None); edges: (E, 2) [src, dst] P(dp, None).
    Returns logits (N_loc..) sharded P(tp, None)."""
    dp, tp = _axes(mesh)
    N = feats.shape[0]
    tp_size = mesh.shape[tp]
    assert N % tp_size == 0, "pad node count to tp multiple"

    def agg_block(h, e):
        """One aggregation: per-device partial mean-message accumulation."""
        n_loc = h.shape[0]
        my = jax.lax.axis_index(tp)
        src, dst = e[:, 0], e[:, 1]
        local = src - my * n_loc
        owned = (local >= 0) & (local < n_loc)
        rows = jnp.take(h, jnp.clip(local, 0, n_loc - 1), axis=0)
        rows = rows * owned.astype(rows.dtype)[:, None]
        part = jax.ops.segment_sum(rows, dst, num_segments=N)     # (N, d)
        deg = jax.ops.segment_sum(owned.astype(h.dtype), dst, num_segments=N)
        # combine partials: sum over edge shards (dp) ...
        if dp:
            part = jax.lax.psum(part, dp)
            deg = jax.lax.psum(deg, dp)
        # ... and scatter-reduce over tp back into the node layout
        part = jax.lax.psum_scatter(part, tp, scatter_dimension=0, tiled=True)
        deg = jax.lax.psum_scatter(deg, tp, scatter_dimension=0, tiled=True)
        return part / jnp.maximum(deg, 1.0)[:, None]

    espec = P(dp, None) if dp else P(None, None)
    h = feats
    for i, lp in enumerate(params["layers"]):
        neigh = shard_map(
            agg_block, mesh=mesh, in_specs=(P(tp, None), espec),
            out_specs=P(tp, None), check_vma=False)(h, edges)
        h = _sage_combine(lp, h, neigh, last=i == cfg.n_layers - 1)
    return h


# ---------------------------------------------------------------------------
# Minibatch regime (fanout-sampled blocks)
# ---------------------------------------------------------------------------


def sharded_feature_gather(feats: jax.Array, ids: jax.Array, mesh: Mesh
                           ) -> jax.Array:
    """Gather rows of a tp-sharded (N, F) store for dp-sharded flat ids —
    the PIFS masked partial gather: each tp shard contributes owned rows,
    pooled (n_ids, F) partials psum over tp."""
    dp, tp = _axes(mesh)
    idspec = P(dp) if dp else P(None)

    def block(f, i):
        n_loc = f.shape[0]
        my = jax.lax.axis_index(tp)
        local = i - my * n_loc
        owned = (local >= 0) & (local < n_loc)
        rows = jnp.take(f, jnp.clip(local, 0, n_loc - 1), axis=0)
        rows = rows * owned.astype(rows.dtype)[..., None]
        return jax.lax.psum(rows, tp)

    return shard_map(block, mesh=mesh, in_specs=(P(tp, None), idspec),
                         out_specs=(P(dp, None) if dp else P(None, None)),
                         check_vma=False)(feats, ids.reshape(-1))


def minibatch_forward(params: dict, feats: jax.Array, batch: Dict[str, Any],
                      cfg: GNNConfig, mesh: Mesh) -> jax.Array:
    """2-hop fanout-sampled forward (fanout f1-f2).

    batch: roots (B,), hop1 (B, f1), hop2 (B, f1, f2) — node ids, sampled
    with replacement by the host sampler (ids dp-sharded over B).
    """
    B = batch["roots"].shape[0]
    f1 = batch["hop1"].shape[1]
    f2 = batch["hop2"].shape[2]
    d = feats.shape[1]

    x_root = sharded_feature_gather(feats, batch["roots"], mesh)       # (B,d)
    x_h1 = sharded_feature_gather(feats, batch["hop1"], mesh
                                  ).reshape(B, f1, d)
    x_h2 = sharded_feature_gather(feats, batch["hop2"], mesh
                                  ).reshape(B, f1, f2, d)

    # layer 1: hop1 nodes aggregate their hop2 neighbours
    lp = params["layers"][0]
    h1 = _sage_combine(lp, x_h1, x_h2.mean(axis=2), last=False)  # (B, f1, d')
    r1 = _sage_combine(lp, x_root, x_h1.mean(axis=1), last=False)  # (B, d')
    # layer 2: roots aggregate their (now-updated) hop1 neighbours
    lp2 = params["layers"][1]
    out = _sage_combine(lp2, r1, h1.mean(axis=1), last=True)
    return out


def make_sampler(indptr: np.ndarray, indices: np.ndarray,
                 fanout: Tuple[int, int], seed: int = 0):
    """Host-side uniform neighbor sampler over CSR (with replacement;
    isolated nodes sample themselves — self-loop fallback)."""
    rng = np.random.default_rng(seed)

    def sample_one_hop(ids: np.ndarray, k: int) -> np.ndarray:
        flat = ids.reshape(-1)
        deg = indptr[flat + 1] - indptr[flat]
        pick = rng.integers(0, np.maximum(deg, 1)[:, None],
                            size=(flat.size, k))
        starts = indptr[flat]
        # clip for deg-0 nodes (value replaced by the self-loop below)
        pos = np.minimum(starts[:, None] + pick, len(indices) - 1)
        nbr = indices[pos]
        nbr = np.where(deg[:, None] > 0, nbr, flat[:, None])   # self-loop
        return nbr.reshape(ids.shape + (k,))

    def sample(roots: np.ndarray):
        hop1 = sample_one_hop(roots, fanout[0])                # (B, f1)
        hop2 = sample_one_hop(hop1, fanout[1])                 # (B, f1, f2)
        return {"roots": roots.astype(np.int32),
                "hop1": hop1.astype(np.int32),
                "hop2": hop2.astype(np.int32)}

    return sample


# ---------------------------------------------------------------------------
# Batched-small-graphs regime (molecules)
# ---------------------------------------------------------------------------


def molecule_forward(params: dict, feats: jax.Array, edges: jax.Array,
                     cfg: GNNConfig, mesh: Mesh) -> jax.Array:
    """feats: (G, n, F); edges: (G, E, 2) — graph-parallel over dp.
    Returns per-graph logits (G, n_classes) via mean readout."""
    G, n, F = feats.shape

    def one_graph(h, e):
        src, dst = e[:, 0], e[:, 1]
        for i, lp in enumerate(params["layers"]):
            msg = jnp.take(h, src, axis=0)
            agg = jax.ops.segment_sum(msg, dst, num_segments=n)
            deg = jax.ops.segment_sum(jnp.ones_like(dst, h.dtype), dst,
                                      num_segments=n)
            neigh = agg / jnp.maximum(deg, 1.0)[:, None]
            h = _sage_combine(lp, h, neigh, last=i == cfg.n_layers - 1)
        return h.mean(axis=0)

    return jax.vmap(one_graph)(feats, edges)


# ---------------------------------------------------------------------------
# Losses / steps
# ---------------------------------------------------------------------------


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    lg = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return -gold.mean()


def make_train_step(cfg: GNNConfig, mesh: Mesh, optimizer, regime: str,
                    n_nodes: int = 0):
    dp, tp = _axes(mesh)

    def loss(params, batch):
        if regime == "full":
            logits = full_forward(params, batch["feats"], batch["edges"],
                                  cfg, mesh)
            lab = batch["labels"]
            return _xent(logits, lab)
        if regime == "minibatch":
            logits = minibatch_forward(params, batch["feats"], batch, cfg, mesh)
            return _xent(logits, batch["labels"])
        logits = molecule_forward(params, batch["feats"], batch["edges"],
                                  cfg, mesh)
        return _xent(logits, batch["labels"])

    def step(params, opt_state, batch):
        l, grads = jax.value_and_grad(loss)(params, batch)
        new_p, new_o = optimizer.update(grads, opt_state, params)
        return new_p, new_o, {"loss": l}

    return step


def input_specs(cfg: GNNConfig, shape, pad_nodes: Optional[int] = None
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Dry-run stand-ins per GNN shape descriptor."""
    i32, f32 = jnp.int32, jnp.float32
    if shape.kind == "full":
        N = pad_nodes or shape.n_nodes
        return {
            "feats": jax.ShapeDtypeStruct((N, shape.d_feat), f32),
            "edges": jax.ShapeDtypeStruct((shape.n_edges, 2), i32),
            "labels": jax.ShapeDtypeStruct((N,), i32),
        }
    if shape.kind == "minibatch":
        N = pad_nodes or shape.n_nodes
        B = shape.batch_nodes
        f1, f2 = shape.fanout
        return {
            "feats": jax.ShapeDtypeStruct((N, shape.d_feat), f32),
            "roots": jax.ShapeDtypeStruct((B,), i32),
            "hop1": jax.ShapeDtypeStruct((B, f1), i32),
            "hop2": jax.ShapeDtypeStruct((B, f1, f2), i32),
            "labels": jax.ShapeDtypeStruct((B,), i32),
        }
    G = shape.graph_batch
    return {
        "feats": jax.ShapeDtypeStruct((G, shape.n_nodes, shape.d_feat), f32),
        "edges": jax.ShapeDtypeStruct((G, shape.n_edges, 2), i32),
        "labels": jax.ShapeDtypeStruct((G,), i32),
    }


def input_pspecs(cfg: GNNConfig, shape, mesh: Mesh) -> Dict[str, P]:
    dp, tp = _axes(mesh)
    dpp = dp if dp else None
    if shape.kind == "full":
        return {"feats": P(tp, None), "edges": P(dpp, None),
                "labels": P(tp)}
    if shape.kind == "minibatch":
        return {"feats": P(tp, None), "roots": P(dpp),
                "hop1": P(dpp, None), "hop2": P(dpp, None, None),
                "labels": P(dpp)}
    return {"feats": P(dpp, None, None), "edges": P(dpp, None, None),
            "labels": P(dpp)}
