"""LM transformer (dense + MoE, GQA + MLA) with train / prefill / decode steps.

Distribution (single-pod mesh ("data", "model"); multi-pod adds a leading
"pod" axis that behaves as extra DP):

  * batch over dp, FSDP parameter sharding over dp (ZeRO-3 style: params are
    stored sharded over dp and all-gathered by XLA at use — `fsdp` below),
  * attention heads / FFN width over tp ("model"),
  * the token embedding + logits are **vocab-parallel** — the PIFS pattern:
    each tp shard owns a vocab slice, embeds/scores only tokens it owns, and
    only pooled (b, s, d) activations / (b, s, V/tp) logit shards cross the
    interconnect, never the (V, d) table,
  * MoE experts over (data, model) or (model,) — see models/moe.py,
  * decode KV caches sequence-sharded over tp — see models/attention.py.

Layers are stacked with `jax.lax.scan` over a params pytree whose leaves have
a leading (n_layers,) axis: one compiled layer body regardless of depth
(compile time and HLO size stay O(1) in depth; XLA overlaps the next layer's
weight all-gather with current compute).  Activation checkpointing:
`jax.checkpoint` on the scanned body with a dots-saveable policy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import LMConfig
from repro.distributed.sharding import shard_map
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import (ffn_apply, ffn_apply_sharded, ffn_specs,
                                 rms_norm)
from repro.models.params import Spec


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def _axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    if "data" in names:
        return ("data",), "model"
    return (), names[-1]


def _is_moe_layer(cfg: LMConfig, li: int) -> bool:
    return cfg.moe is not None and li >= cfg.moe.first_dense_layers


def layer_specs(cfg: LMConfig, mesh: Mesh, kind: str, dtype,
                serving: bool = False) -> dict:
    """Specs for one layer family (dense-FFN layers vs MoE layers).

    Training: attention weights are tp-sharded only when the head layout
    divides tp (see _constrain_heads); under sequence-parallel attention
    they are fsdp-sharded only, so the q/k/v/o projections are fully local
    on the seq-sharded residual stream.

    Serving (decode): weight-stationary width sharding over the FULL mesh —
    every big matrix splits its width dim over (dp + tp); only tiny (b, 1, *)
    activations are gathered/reduced.  The alternative (train-style FSDP)
    makes XLA hoist per-layer weight gathers out of the decode loop and
    materialize whole gathered stacks (34 GB/device on nemotron-340b,
    measured — the PIFS lesson again: move the small thing).
    """
    dp, tp = _axes(mesh)
    fsdp = dp or None
    d = cfg.d_model
    tp_size = mesh.shape[tp]
    n_total = int(np.prod([mesh.shape[a] for a in dp + (tp,)])) if dp \
        else tp_size

    if serving:
        W = (dp + (tp,)) if dp else tp

        def wspec(shape, width_axis):
            # width-shard when divisible, else replicate (tiny tensors)
            if shape[width_axis] % n_total == 0:
                return P(*[W if i == width_axis else None
                           for i in range(len(shape))])
            return P()

        if cfg.attn_type == "mla":
            m = cfg.mla
            qd = m.qk_nope_head_dim + m.qk_rope_head_dim
            H = cfg.n_heads
            a = {
                "wdq": Spec((d, m.q_lora_rank), dtype,
                            wspec((d, m.q_lora_rank), 1)),
                "q_norm": Spec((m.q_lora_rank,), dtype, P(), init="ones"),
                "wuq": Spec((m.q_lora_rank, H * qd), dtype,
                            wspec((m.q_lora_rank, H * qd), 1)),
                "wdkv": Spec((d, m.kv_lora_rank), dtype,
                             wspec((d, m.kv_lora_rank), 1)),
                "kv_norm": Spec((m.kv_lora_rank,), dtype, P(), init="ones"),
                "wukv": Spec((m.kv_lora_rank,
                              H * (m.qk_nope_head_dim + m.v_head_dim)),
                             dtype,
                             wspec((m.kv_lora_rank,
                                    H * (m.qk_nope_head_dim + m.v_head_dim)),
                                   1)),
                "wkr": Spec((d, m.qk_rope_head_dim), dtype, P()),
                "wo": Spec((H * m.v_head_dim, d), dtype,
                           wspec((H * m.v_head_dim, d), 0)),
            }
        else:
            H, K, h = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            a = {
                "wq": Spec((d, H * h), dtype, wspec((d, H * h), 1)),
                "wk": Spec((d, K * h), dtype, wspec((d, K * h), 1)),
                "wv": Spec((d, K * h), dtype, wspec((d, K * h), 1)),
                "wo": Spec((H * h, d), dtype, wspec((H * h, d), 0)),
            }
        specs = {
            "attn": a,
            "attn_norm": Spec((d,), dtype, P(), init="ones"),
            "ffn_norm": Spec((d,), dtype, P(), init="ones"),
        }
        if kind == "moe":
            specs["moe"] = moe_mod.moe_specs(cfg, mesh, dp, tp, dtype)
        else:
            f = cfg.d_ff
            if cfg.activation == "relu2":
                specs["ffn"] = {
                    "in": Spec((d, f), dtype, wspec((d, f), 1)),
                    "out": Spec((f, d), dtype, wspec((f, d), 0)),
                }
            else:
                specs["ffn"] = {
                    "gate": Spec((d, f), dtype, wspec((d, f), 1)),
                    "up": Spec((d, f), dtype, wspec((d, f), 1)),
                    "down": Spec((f, d), dtype, wspec((f, d), 0)),
                }
        return specs

    if cfg.attn_type == "mla":
        head_ok = cfg.n_heads % tp_size == 0
    else:
        head_ok = (cfg.n_heads % tp_size == 0
                   and cfg.n_kv_heads % tp_size == 0)
    if head_ok:
        attn_fsdp, attn_tp = fsdp, tp
    else:
        # sequence-parallel attention: weights are not head-sharded; FSDP
        # them over (dp + tp) so the gradient reduction is a reduce-scatter
        # over the full mesh instead of an all-reduce over tp
        attn_fsdp, attn_tp = (tuple(dp) + (tp,)) or None, None
    if cfg.attn_type == "mla":
        a = attn.mla_specs(cfg, attn_fsdp, attn_tp, dtype)
    else:
        a = attn.gqa_specs(cfg, attn_fsdp, attn_tp, dtype)
    specs = {
        "attn": a,
        "attn_norm": Spec((d,), dtype, P(), init="ones"),
        "ffn_norm": Spec((d,), dtype, P(), init="ones"),
    }
    if kind == "moe":
        specs["moe"] = moe_mod.moe_specs(cfg, mesh, dp, tp, dtype)
    else:
        specs["ffn"] = ffn_specs(d, cfg.d_ff, _ffn_act(cfg), dtype, fsdp, tp)
    return specs


def _ffn_act(cfg: LMConfig) -> str:
    return "relu2" if cfg.activation == "relu2" else "silu_glu"


def _stack_specs(specs: dict, n: int) -> dict:
    """Add a leading (n,) layer axis to every Spec leaf (for lax.scan)."""
    def stack(s: Spec) -> Spec:
        return Spec((n,) + s.shape, s.dtype, P(*((None,) + tuple(s.pspec))),
                    init=s.init, scale=s.scale)
    return jax.tree.map(stack, specs, is_leaf=lambda x: isinstance(x, Spec))


def model_specs(cfg: LMConfig, mesh: Mesh, dtype=None,
                serving: bool = False) -> dict:
    """Full parameter tree: embed + scanned layer stacks + final norm + head.

    Embedding is vocab-sharded over tp (the PIFS placement: the table is the
    "memory pool" spread over the model axis).  The LM head reuses a separate
    vocab-sharded matrix (untied, matching the assigned archs).
    """
    dp, tp = _axes(mesh)
    fsdp = dp or None
    dtype = dtype or jnp.dtype(cfg.dtype)
    d = cfg.d_model
    # vocab padded to a tp multiple (granite: 49155 -> 49168); padded logit
    # columns are masked to -inf in lm_logits, so they are grad- and
    # sample-inert
    V = padded_vocab(cfg, mesh)

    n_dense, n_moe = _layer_split(cfg)
    specs: Dict[str, Any] = {
        "embed": Spec((V, d), dtype, P(tp, None), init="embed", scale=0.02),
        "head": Spec((d, V), dtype, P(None, tp)),
        "final_norm": Spec((d,), dtype, P(), init="ones"),
    }
    if n_dense:
        specs["dense_layers"] = _stack_specs(
            layer_specs(cfg, mesh, "dense", dtype, serving=serving), n_dense)
    if n_moe:
        specs["moe_layers"] = _stack_specs(
            layer_specs(cfg, mesh, "moe", dtype, serving=serving), n_moe)
    if cfg.mtp_depth:
        # DeepSeek-V3 MTP: one extra transformer block + projection per depth
        mtp = {
            "proj": Spec((2 * d, d), dtype, P(fsdp, None)),
            "norm_prev": Spec((d,), dtype, P(), init="ones"),
            "norm_emb": Spec((d,), dtype, P(), init="ones"),
            "block": layer_specs(cfg, mesh, "moe" if cfg.moe else "dense",
                                 dtype),
        }
        specs["mtp"] = _stack_specs(mtp, cfg.mtp_depth)
    return specs


def padded_vocab(cfg: LMConfig, mesh: Mesh) -> int:
    tp_size = mesh.shape[_axes(mesh)[1]]
    return -(-cfg.vocab // tp_size) * tp_size


def _layer_split(cfg: LMConfig) -> Tuple[int, int]:
    if cfg.moe is None:
        return cfg.n_layers, 0
    nd = cfg.moe.first_dense_layers
    return nd, cfg.n_layers - nd


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _constrain_heads(mesh: Mesh, cfg: Optional[LMConfig] = None):
    """Attention activation layout.

    * head-sharded over tp when BOTH n_heads and n_kv_heads divide tp (MLA:
      the latent kv is shared, only n_heads matters);
    * otherwise sequence-parallel attention: q/out shard the seq axis over
      tp, kv replicates along seq (each shard scores its q rows against the
      full kv).  Every assigned GQA arch has kv_heads=8 < tp=16 — naive
      head sharding there makes XLA emit replicate-then-reshard collectives
      (measured 493 GB/device/step on llama3.2-3b train_4k; see
      EXPERIMENTS.md §Perf iteration 1).
    """
    dp, tp = _axes(mesh)
    tp_size = mesh.shape[tp]
    if cfg is None:
        head_ok = False
    elif cfg.attn_type == "mla":
        head_ok = cfg.n_heads % tp_size == 0
    else:
        head_ok = (cfg.n_heads % tp_size == 0
                   and cfg.n_kv_heads % tp_size == 0)

    def c(a, kind):
        b = dp if dp else None
        if head_ok:
            spec = P(b, None, tp, None)
        elif kind == "kv":
            spec = P(b, None, None, None)
        else:  # q / attention output: seq-sharded
            spec = P(b, tp, None, None)
        return jax.lax.with_sharding_constraint(
            a, jax.sharding.NamedSharding(mesh, spec))
    return c


def _constrain_seq(x: jax.Array, mesh: Mesh) -> jax.Array:
    """Sequence-parallel residual stream (Megatron SP): between blocks the
    (b, s, d) activations live sharded over tp along the sequence axis; XLA
    inserts the all-gather before attention/FFN and the reduce-scatter after.
    This divides the remat-saved layer carries by tp — the difference between
    the 671B/340B trains fitting 16 GB/chip or not."""
    dp, tp = _axes(mesh)
    spec = P(dp if dp else None, tp, None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


_REMAT_POLICIES = {
    # save matmul outputs (fast backward, large residency) — small archs
    "dots": lambda: jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    # save nothing but the scan carry (full recompute) — the giants
    "full": lambda: jax.checkpoint_policies.nothing_saveable,
}


def _layer_fwd(p: dict, x: jax.Array, cfg: LMConfig, mesh: Mesh, kind: str
               ) -> Tuple[jax.Array, jax.Array]:
    """One transformer block (prefill/train form). Returns (x, aux_loss)."""
    dp, tp = _axes(mesh)
    tp_size = mesh.shape[tp]
    if cfg.attn_type == "mla":
        head_ok = cfg.n_heads % tp_size == 0
    else:
        head_ok = (cfg.n_heads % tp_size == 0
                   and cfg.n_kv_heads % tp_size == 0)
    seq_ctx = None if head_ok else (mesh, dp, tp)
    c = _constrain_heads(mesh, cfg)
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, _ = attn.mla_prefill(p["attn"], h, cfg, constrain=c,
                                seq_ctx=seq_ctx)
    else:
        a, _ = attn.gqa_prefill(p["attn"], h, cfg, constrain=c,
                                seq_ctx=seq_ctx)
    x = x + a
    h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    if kind == "moe":
        f, aux = moe_mod.moe_apply(p["moe"], h, cfg, mesh, dp, tp)
    else:
        # explicit Megatron-SP FFN: per-layer weight gathers stay inside the
        # scan body (auto-SPMD hoisted the gathered stack out of the loop)
        f = ffn_apply_sharded(p["ffn"], h, _ffn_act(cfg), mesh, dp, tp)
        aux = jnp.zeros((), jnp.float32)
    return x + f, aux


def _scan_stack(stack_params: dict, x: jax.Array, cfg: LMConfig, mesh: Mesh,
                kind: str, remat: str, sp: bool,
                layer_pspecs: Optional[dict] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """lax.scan over the layer axis; body optionally rematerialized.

    remat: "none" | "dots" | "full" (see _REMAT_POLICIES); sp: sequence-
    parallel residual constraint at block boundaries.

    layer_pspecs: per-layer (unstacked) PartitionSpecs.  When given, the
    scan-sliced layer params are re-constrained to their sharded layout
    INSIDE the body: without this, XLA commutes gather(slice(i, stack)) into
    slice(i, gather(stack)) and materializes the all-gathered weight stack
    for the whole loop — 6+ GB/device for the 67B/340B archs (measured;
    EXPERIMENTS.md §Perf).
    """
    def body(carry, lp):
        if layer_pspecs is not None:
            lp = jax.tree.map(
                lambda a, s: jax.lax.with_sharding_constraint(
                    a, jax.sharding.NamedSharding(mesh, s)),
                lp, layer_pspecs, is_leaf=lambda z: isinstance(z, P))
        if sp:
            carry = _constrain_seq(carry, mesh)
        y, aux = _layer_fwd(lp, carry, cfg, mesh, kind)
        if sp:
            y = _constrain_seq(y, mesh)
        return y, aux

    if remat != "none":
        body = jax.checkpoint(body, policy=_REMAT_POLICIES[remat]())
    x, auxs = jax.lax.scan(body, x, stack_params)
    return x, auxs.sum()


def embed_tokens(p: dict, tokens: jax.Array, cfg: LMConfig, mesh: Mesh
                 ) -> jax.Array:
    """Vocab-parallel embedding — the PIFS lookup pattern on the LM table.

    Each tp shard holds V/tp rows; it embeds only the tokens whose ids fall in
    its slice (others contribute zeros) and the (b, s, d) partials are psum'd:
    reduce-near-data, pooled activations cross the ICI, never table rows.
    """
    dp, tp = _axes(mesh)
    tspec = P(dp if dp else None, None)

    def block(emb, tok):
        V_loc = emb.shape[0]
        my = jax.lax.axis_index(tp)
        lo = my * V_loc
        local = tok - lo
        owned = (local >= 0) & (local < V_loc)
        rows = jnp.take(emb, jnp.clip(local, 0, V_loc - 1), axis=0)
        rows = jnp.where(owned[..., None], rows, 0)
        return jax.lax.psum(rows, tp)

    return shard_map(
        block, mesh=mesh, in_specs=(P(tp, None), tspec),
        out_specs=P(dp if dp else None, None, None), check_vma=False,
    )(p["embed"], tokens)


def lm_logits(p: dict, x: jax.Array, cfg: LMConfig, mesh: Mesh) -> jax.Array:
    """Head matmul with tp-sharded output logits (never replicated (b,s,V)).
    Padded vocab columns are masked to -inf (grad- and sample-inert)."""
    dp, tp = _axes(mesh)
    out = x @ p["head"]
    Vp = out.shape[-1]
    if Vp != cfg.vocab:
        pad_mask = jnp.arange(Vp) >= cfg.vocab
        out = jnp.where(pad_mask, jnp.asarray(-1e30, out.dtype), out)
    return jax.lax.with_sharding_constraint(
        out, jax.sharding.NamedSharding(
            mesh, P(dp if dp else None, None, tp)))


def forward(params: dict, tokens: jax.Array, cfg: LMConfig, mesh: Mesh,
            remat: str = "dots", sp: bool = True
            ) -> Tuple[jax.Array, jax.Array]:
    """tokens (b, s) -> hidden (b, s, d); also returns summed MoE aux loss."""
    x = embed_tokens(params, tokens, cfg, mesh).astype(jnp.dtype(cfg.dtype))
    n_dense, n_moe = _layer_split(cfg)
    aux = jnp.zeros((), jnp.float32)
    from repro.models.params import pspecs as _pspecs
    if n_dense:
        lps = _pspecs(layer_specs(cfg, mesh, "dense", jnp.dtype(cfg.dtype)))
        x, a = _scan_stack(params["dense_layers"], x, cfg, mesh, "dense",
                           remat, sp, layer_pspecs=lps)
        aux = aux + a
    if n_moe:
        lps = _pspecs(layer_specs(cfg, mesh, "moe", jnp.dtype(cfg.dtype)))
        x, a = _scan_stack(params["moe_layers"], x, cfg, mesh, "moe",
                           remat, sp, layer_pspecs=lps)
        aux = aux + a
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


# ---------------------------------------------------------------------------
# Losses / steps
# ---------------------------------------------------------------------------


def _xent_vocab_parallel(logits: jax.Array, labels: jax.Array, mesh: Mesh
                         ) -> jax.Array:
    """Cross-entropy over tp-sharded logits without materializing the full
    softmax: per-shard max/sumexp + psum (reduce-near-data again)."""
    dp, tp = _axes(mesh)
    lspec = P(dp if dp else None, None, tp)
    yspec = P(dp if dp else None, None)

    def block(lg, y):
        V_loc = lg.shape[-1]
        my = jax.lax.axis_index(tp)
        lo = my * V_loc
        lg = lg.astype(jnp.float32)
        # stability shift: mathematically cancels in logsumexp-gold, so no
        # gradient flows through it.  pmax has no AD rule, so gather the
        # per-shard maxes (a (tp, b, s) tensor — tiny) and reduce locally.
        m = jax.lax.stop_gradient(
            jax.lax.all_gather(lg.max(axis=-1), tp).max(axis=0))
        se = jax.lax.psum(jnp.exp(lg - m[..., None]).sum(axis=-1), tp)
        local = y - lo
        owned = (local >= 0) & (local < V_loc)
        picked = jnp.take_along_axis(
            lg, jnp.clip(local, 0, V_loc - 1)[..., None], axis=-1)[..., 0]
        gold = jax.lax.psum(jnp.where(owned, picked, 0.0), tp)
        return jnp.log(se) + m - gold

    nll = shard_map(block, mesh=mesh, in_specs=(lspec, yspec),
                        out_specs=yspec, check_vma=False)(logits, labels)
    return nll.mean()


def loss_fn(params: dict, tokens: jax.Array, labels: jax.Array,
            cfg: LMConfig, mesh: Mesh, remat: str = "dots",
            sp: bool = True) -> jax.Array:
    x, aux = forward(params, tokens, cfg, mesh, remat=remat, sp=sp)
    logits = lm_logits(params, x, cfg, mesh)
    loss = _xent_vocab_parallel(logits, labels, mesh)
    if cfg.mtp_depth:
        loss = loss + _mtp_loss(params, x, tokens, labels, cfg, mesh)
    return loss + aux


def _mtp_loss(params: dict, h: jax.Array, tokens: jax.Array,
              labels: jax.Array, cfg: LMConfig, mesh: Mesh,
              weight: float = 0.3) -> jax.Array:
    """DeepSeek-V3 multi-token prediction: each depth-k module combines the
    previous hidden state with the embedding of the (k+1)-shifted token and
    predicts one extra step ahead."""
    kind = "moe" if cfg.moe is not None else "dense"

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, mp):
        hprev, shift = carry
        # shift tokens/labels left by one position per depth
        tok_k = jnp.roll(tokens, -1, axis=1)
        emb = embed_tokens(params, tok_k, cfg, mesh).astype(hprev.dtype)
        comb = jnp.concatenate(
            [rms_norm(hprev, mp["norm_prev"], cfg.norm_eps),
             rms_norm(emb, mp["norm_emb"], cfg.norm_eps)], axis=-1)
        hk = comb @ mp["proj"]
        hk, _ = _layer_fwd(mp["block"], hk, cfg, mesh, kind)
        return (hk, shift + 1), hk

    (_, _), hs = jax.lax.scan(body, (h, jnp.zeros((), jnp.int32)),
                              params["mtp"])
    # one prediction head pass per depth (share the main head)
    lab_k = jnp.roll(labels, -cfg.mtp_depth, axis=1)
    logits = lm_logits(params, hs[-1], cfg, mesh)
    return weight * _xent_vocab_parallel(logits, lab_k, mesh)


def make_train_step(cfg: LMConfig, mesh: Mesh, optimizer, remat: str = "dots",
                    sp: bool = True, accum: Optional[int] = None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    accum > 1 runs gradient accumulation over `accum` microbatches (scan):
    the remat carry stack shrinks by the same factor — how the 340B/671B
    trains fit 16 GB/chip on the fixed 256-chip mesh.  Gradients accumulate
    in f32.
    """
    accum = accum if accum is not None else cfg.train_accum

    def grad_of(params, tokens, labels):
        return jax.value_and_grad(
            lambda p: loss_fn(p, tokens, labels, cfg, mesh,
                              remat=remat, sp=sp))(params)

    def step(params, opt_state, batch):
        if accum <= 1:
            loss, grads = grad_of(params, batch["tokens"], batch["labels"])
        else:
            B = batch["tokens"].shape[0]
            mb = jax.tree.map(
                lambda x: x.reshape(accum, B // accum, *x.shape[1:]), batch)

            def micro(carry, m):
                l, g = grad_of(params, m["tokens"], m["labels"])
                acc_l, acc_g = carry
                acc_g = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     acc_g, g)
                return (acc_l + l, acc_g), None

            # accumulate in the parameter dtype: for the 671B arch the f32
            # accumulator alone is 10 GB/device (production answer at this
            # scale: bf16 accumulation; adafactor's update clipping absorbs
            # the rounding noise over <=8 microsteps)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, p.dtype), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zeros), mb)
            loss = loss / accum
            grads = jax.tree.map(lambda g, p: (g / accum).astype(p.dtype),
                                 grads, params)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}
    return step


# ---------------------------------------------------------------------------
# Serving: prefill + decode with seq-sharded KV cache
# ---------------------------------------------------------------------------


def cache_specs(cfg: LMConfig, mesh: Mesh, batch: int, seq: int, dtype=None
                ) -> Any:
    """Abstract KV-cache pytree for `seq` positions (seq-sharded over tp)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    n = cfg.n_layers
    if cfg.attn_type == "mla":
        m = cfg.mla
        return {
            "ckv": jax.ShapeDtypeStruct((n, batch, seq, m.kv_lora_rank), dtype),
            "kr": jax.ShapeDtypeStruct((n, batch, seq, m.qk_rope_head_dim), dtype),
        }
    K, h = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jax.ShapeDtypeStruct((n, batch, seq, K, h), dtype),
        "v": jax.ShapeDtypeStruct((n, batch, seq, K, h), dtype),
    }


def cache_pspecs(cfg: LMConfig, mesh: Mesh) -> Any:
    dp, tp = _axes(mesh)
    b = dp if dp else None
    if cfg.attn_type == "mla":
        return {"ckv": P(None, b, tp, None), "kr": P(None, b, tp, None)}
    return {"k": P(None, b, tp, None, None), "v": P(None, b, tp, None, None)}


def _decode_layer(lp: dict, x: jax.Array, layer_cache: Tuple,
                  pos: jax.Array, cfg: LMConfig, mesh: Mesh, kind: str
                  ) -> Tuple[jax.Array, Tuple]:
    dp, tp = _axes(mesh)
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, new_cache = attn.mla_decode(lp["attn"], h, layer_cache, pos,
                                       cfg, mesh, dp, tp)
    else:
        a, new_cache = attn.gqa_decode(lp["attn"], h, layer_cache, pos,
                                       cfg, mesh, dp, tp)
    x = x + a.astype(x.dtype)
    h = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
    if kind == "moe":
        f, _ = moe_mod.moe_apply(lp["moe"], h, cfg, mesh, dp, tp)
    else:
        f = ffn_apply(lp["ffn"], h, _ffn_act(cfg))
    return x + f.astype(x.dtype), new_cache


def decode_step(params: dict, cache: dict, tokens: jax.Array, pos: jax.Array,
                cfg: LMConfig, mesh: Mesh) -> Tuple[jax.Array, dict]:
    """One decode step: tokens (b, 1) + seq-sharded cache -> (logits, cache).

    Layers run under `lax.scan` (one compiled body per stack kind); the cache
    arrays carry a leading (n_layers,) axis that the scan maps over, so the
    HLO stays O(1) in depth even for the 96-layer archs.
    """
    x = embed_tokens(params, tokens, cfg, mesh).astype(jnp.dtype(cfg.dtype))
    n_dense, n_moe = _layer_split(cfg)
    keys = list(cache.keys())

    def split(lo, hi):
        return tuple(cache[k][lo:hi] for k in keys)

    def scan_stack(stack_params, x, cache_slice, kind):
        def body(carry, inp):
            lp = inp[0]
            lcache = inp[1:]
            y, new_c = _decode_layer(lp, carry, lcache, pos, cfg, mesh, kind)
            return y, new_c
        x, new_cache = jax.lax.scan(body, x, (stack_params,) + cache_slice)
        return x, new_cache

    new_parts = []
    if n_dense:
        x, nc = scan_stack(params["dense_layers"], x, split(0, n_dense),
                           "dense")
        new_parts.append(nc)
    if n_moe:
        x, nc = scan_stack(params["moe_layers"], x,
                           split(n_dense, cfg.n_layers), "moe")
        new_parts.append(nc)
    if len(new_parts) == 2:
        merged = tuple(jnp.concatenate([a, b], axis=0)
                       for a, b in zip(*new_parts))
    else:
        merged = new_parts[0]
    out_cache = dict(zip(keys, merged))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params, x, cfg, mesh)
    return logits, out_cache


def make_decode_step(cfg: LMConfig, mesh: Mesh):
    def step(params, cache, batch):
        return decode_step(params, cache, batch["tokens"], batch["pos"],
                           cfg, mesh)
    return step


def prefill_step(params: dict, tokens: jax.Array, cfg: LMConfig, mesh: Mesh
                 ) -> jax.Array:
    """Prefill forward (no cache retention here — dry-run measures the
    compute/collective profile; serving keeps caches via attention modules)."""
    x, _ = forward(params, tokens, cfg, mesh, remat="none", sp=True)
    return lm_logits(params, x[:, -1:], cfg, mesh)
