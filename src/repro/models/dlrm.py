"""DLRM (paper Fig. 1 / Table I): bottom MLP -> PIFS embedding lookup ->
pairwise-dot feature interaction -> top MLP -> CTR logit.

The embedding stage is the PIFSEmbeddingEngine: tables row-sharded over the
`model` axis (the "CXL memory pool"), partial SLS near the data, hot tier
replicated.  The interaction stage uses the Pallas kernel on TPU and its jnp
oracle on CPU.

Everything is a pure function over (params, engine_state, batch); batch =
{"dense": (B, n_dense) float, "indices": (B, T, L) int32} with T tables and
L = pooling lookups per bag.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import DLRMConfig
from repro.core.pifs import PIFSEmbeddingEngine, engine_for_tables
from repro.kernels import ops as kernel_ops
from repro.models.layers import mlp_apply, mlp_specs
from repro.models.params import Spec


def build_engine(cfg: DLRMConfig, mesh: Mesh, hot_fraction: float = 0.05,
                 dtype=jnp.float32, storage: str = "fp32",
                 dedup: str = "off",
                 ) -> Tuple[PIFSEmbeddingEngine, np.ndarray]:
    """``storage='int8'`` selects the quantized cold tier (serving-only:
    the int8 store is not differentiable — train with fp32).  ``dedup``
    sets the engine default for gather-once duplicate coalescing."""
    vocabs = [cfg.emb_num] * cfg.n_tables
    return engine_for_tables(vocabs, cfg.emb_dim, mesh,
                             hot_fraction=hot_fraction, dtype=dtype,
                             storage=storage, dedup=dedup)


def model_specs(cfg: DLRMConfig, mesh: Mesh, dtype=jnp.float32) -> dict:
    d = cfg.emb_dim
    F = cfg.n_tables + 1                       # pooled tables + bottom-MLP out
    n_inter = F * (F - 1) // 2
    bot = (cfg.n_dense,) + cfg.bottom_mlp
    top_in = n_inter + d
    top = (top_in,) + cfg.top_mlp
    specs = {
        "bottom": mlp_specs(bot, dtype=dtype),
        "top": mlp_specs(top, dtype=dtype),
    }
    if cfg.bottom_mlp[-1] != d:
        # Table I widths don't always end at emb_dim (RMC1: 128 vs 64);
        # a linear projection aligns the dense feature with the embeddings
        specs["bot_proj"] = Spec((cfg.bottom_mlp[-1], d), dtype, P())
    return specs


def forward(params: dict, engine: PIFSEmbeddingEngine, state,
            batch: Dict[str, jax.Array], cfg: DLRMConfig,
            mode: str = "pifs", interaction_impl: str = "jnp",
            impl: str = "jnp", block_l: int = 8,
            dedup: Optional[str] = None,
            front_end: str = "split",
            tiers: str = "all") -> jax.Array:
    """Returns CTR logits (B,).

    ``impl``/``block_l`` select the engine's SLS datapath (jnp vs the
    bag-tiled Pallas kernel); ``dedup`` the gather-once duplicate
    coalescing knob (off/auto/on, None = engine default) — bit-exact
    either way.  An optional ``batch["weights"]`` (B, T, L)
    carries per-lookup SLS weights — the serving batcher uses weight-0
    entries to pad variable-pooling bags to a shape bucket exactly.

    ``front_end='fused'`` routes lookup + feature stacking + dot
    interaction through the engine's fused front end
    (``engine.lookup_interact``): the pooled (B, F, d) features stay in
    VMEM from the SLS accumulate through the interaction matmul.  On a
    tp-sharded mesh (and in pond mode) the engine resolves ``fused_tp``
    — each shard partial-pools its owned rows and only the small (B, F,
    d) cold tile is psum'd between the kernel halves (bit-identical
    logits vs split for pifs/beacon; the resolution is recorded in
    ``engine.plan_stats()['front_end']``).

    ``tiers='hot_only'`` is the brown-out rung: embedding lookups read the
    replicated hot tier only (cold contributions zero-filled, zero
    collectives) — NOT bit-exact; only the split path supports it, so it
    forces ``front_end='split'``.
    """
    if front_end not in PIFSEmbeddingEngine.FRONT_END_MODES:
        raise ValueError(f"unknown front_end {front_end!r}")
    if tiers != "all":
        front_end = "split"                    # fused path is all-tiers only
    dense, idx = batch["dense"], batch["indices"]
    B = dense.shape[0]
    x_bot = mlp_apply(params["bottom"], dense, len(cfg.bottom_mlp),
                      final_act=True)
    if "bot_proj" in params:
        x_bot = x_bot @ params["bot_proj"]                  # (B, d)
    # dense towers use the full (dp x tp) mesh, not just dp (see
    # recsys._constrain_full_batch)
    from repro.models.recsys import _constrain_full_batch
    if front_end == "fused":
        inter = engine.lookup_interact(
            state, idx, x_bot, weights=batch.get("weights"), mode=mode,
            impl=impl, block_l=block_l, dedup=dedup, front_end="fused")
        inter = _constrain_full_batch(inter, engine)        # (B, P)
    else:
        pooled = engine.lookup(state, idx, weights=batch.get("weights"),
                               mode=mode, impl=impl, block_l=block_l,
                               dedup=dedup, tiers=tiers)    # (B, T, d)
        pooled = _constrain_full_batch(pooled, engine)
        feats = jnp.concatenate([x_bot[:, None, :], pooled],
                                axis=1)                     # (B, F, d)
        inter = kernel_ops.dot_interaction(feats, impl=interaction_impl)
    z = jnp.concatenate([x_bot, inter], axis=-1)
    logit = mlp_apply(params["top"], z, len(cfg.top_mlp))
    return logit[:, 0]


def loss_fn(params, engine, state, batch, cfg, mode="pifs",
            interaction_impl: str = "jnp") -> jax.Array:
    logits = forward(params, engine, state, batch, cfg, mode=mode,
                     interaction_impl=interaction_impl)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def make_train_step(cfg: DLRMConfig, engine: PIFSEmbeddingEngine, mesh: Mesh,
                    optimizer, emb_optimizer, mode: str = "pifs",
                    interaction_impl: str = "jnp"):
    """Joint step: dense params via `optimizer`, embedding storage via
    `emb_optimizer` (row-wise adagrad by convention).  The embedding gradient
    flows through the engine lookup (gather -> scatter-add under AD) and
    arrives sharded exactly like the storage — no gradient communication for
    the cold shards beyond what the lookup itself psums."""
    def step(params, emb_state, opt_state, emb_opt_state, batch):
        def full_loss(p, cold, hot):
            st = dataclasses.replace(emb_state, cold=cold, hot=hot)
            return loss_fn(p, engine, st, batch, cfg, mode=mode,
                           interaction_impl=interaction_impl)

        loss, grads = jax.value_and_grad(full_loss, argnums=(0, 1, 2))(
            params, emb_state.cold, emb_state.hot)
        gp, gcold, ghot = grads
        new_params, new_opt = optimizer.update(gp, opt_state, params)
        emb_params = {"cold": emb_state.cold, "hot": emb_state.hot}
        emb_grads = {"cold": gcold, "hot": ghot}
        new_emb, new_emb_opt = emb_optimizer.update(
            emb_grads, emb_opt_state, emb_params)
        new_state = dataclasses.replace(
            emb_state, cold=new_emb["cold"], hot=new_emb["hot"])
        return new_params, new_state, new_opt, new_emb_opt, {"loss": loss}
    return step


def make_serve_step(cfg: DLRMConfig, engine: PIFSEmbeddingEngine, mesh: Mesh,
                    mode: str = "pifs", interaction_impl: str = "jnp",
                    impl: str = "jnp", block_l: int = 8,
                    dedup: Optional[str] = None,
                    front_end: str = "split",
                    tiers: str = "all"):
    def step(params, emb_state, batch):
        logits = forward(params, engine, emb_state, batch, cfg, mode=mode,
                         interaction_impl=interaction_impl, impl=impl,
                         block_l=block_l, dedup=dedup, front_end=front_end,
                         tiers=tiers)
        return jax.nn.sigmoid(logits)
    return step


def input_specs(cfg: DLRMConfig, batch: int, mesh: Mesh, with_labels: bool
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    T, L = cfg.n_tables, cfg.pooling
    out = {
        "dense": jax.ShapeDtypeStruct((batch, cfg.n_dense), jnp.float32),
        "indices": jax.ShapeDtypeStruct((batch, T, L), jnp.int32),
    }
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return out


def input_pspecs(cfg: DLRMConfig, mesh: Mesh, with_labels: bool) -> Dict[str, P]:
    dp = ("pod", "data") if "pod" in mesh.axis_names else (
        ("data",) if "data" in mesh.axis_names else None)
    out = {"dense": P(dp, None), "indices": P(dp, None, None)}
    if with_labels:
        out["labels"] = P(dp)
    return out
