"""Recsys architectures: SASRec, AutoInt, DCN-v2, BST.

All sparse-feature lookups go through the PIFSEmbeddingEngine (tables
row-sharded over `model`, hot tier replicated, partial SLS near the data).
Per-field / per-position embeddings are L=1 bags: indices (B, G, 1).

Model heads are small and replicated; the batch shards over dp.  The four
models share one train/serve/retrieval step factory; `forward` dispatches on
cfg.interaction:

  * "self-attn-seq"   (SASRec): causal self-attn over the item history;
                      next-item prediction with sampled softmax (pos/neg).
  * "self-attn"       (AutoInt): multi-head attention over field embeddings,
                      residual via W_res, relu; stacked; logit from flatten.
  * "cross"           (DCN-v2): x_{l+1} = x0 * (W x_l + b) + x_l cross tower
                      in parallel with a deep MLP tower; stacked combine.
  * "transformer-seq" (BST): [history || target] through a transformer block,
                      concat with profile features, MLP tower -> CTR.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import RecConfig
from repro.core.pifs import PIFSEmbeddingEngine, engine_for_tables
from repro.models.layers import mlp_apply, mlp_specs
from repro.models.params import Spec


# ---------------------------------------------------------------------------
# Engine construction
# ---------------------------------------------------------------------------


def build_engine(cfg: RecConfig, mesh: Mesh, hot_fraction: float = 0.05,
                 dtype=jnp.float32, storage: str = "fp32",
                 dedup: str = "off",
                 ) -> Tuple[PIFSEmbeddingEngine, np.ndarray]:
    """``storage='int8'`` selects the quantized cold tier (serving-only);
    ``dedup`` sets the engine default for gather-once duplicate coalescing.

    The returned offsets are int64; lookups add them and downcast to int32
    on device, which is safe because engine_for_tables validates the whole
    padded address space fits int32 at construction (a silent-truncation
    regression is pinned in tests/test_pifs_engine.py).
    """
    return engine_for_tables(list(cfg.vocab_sizes), cfg.embed_dim, mesh,
                             hot_fraction=hot_fraction, dtype=dtype,
                             storage=storage, dedup=dedup)


def _constrain_full_batch(x: jax.Array, engine) -> jax.Array:
    """Re-shard a batch-leading tensor over (dp + tp) for the dense towers.

    The engine's lookup shards the batch over dp only (the tp axis holds the
    table shards); leaving the dense interaction/MLP compute in that layout
    makes every tp replica redundantly compute the same batch slice — a
    16x waste measured on dcn-v2 train_batch (EXPERIMENTS.md §Perf).  One
    cheap resharding here lets the dense towers use the full mesh.
    """
    axes, mesh = engine.axes, engine.mesh
    full = tuple(axes.dp) + (axes.tp,)
    spec = P(full, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def _seq_lookup(engine, state, ids: jax.Array, offset: int, mode: str,
                dp_shard: bool = True, impl: str = "jnp",
                block_l: int = 8, dedup: Optional[str] = None) -> jax.Array:
    """(B, S) ids in table `offset` -> (B, S, D) per-position embeddings."""
    idx = (ids + offset)[..., None]          # (B, S, 1): one bag per position
    return engine.lookup(state, idx.astype(jnp.int32), mode=mode,
                         dp_shard=dp_shard, impl=impl, block_l=block_l,
                         dedup=dedup)


def _field_lookup(engine, state, ids: jax.Array, offsets: np.ndarray,
                  mode: str, dp_shard: bool = True, impl: str = "jnp",
                  block_l: int = 8, dedup: Optional[str] = None) -> jax.Array:
    """(B, F) per-field ids -> (B, F, D)."""
    idx = (ids + jnp.asarray(offsets, jnp.int32)[None, :])[..., None]
    return engine.lookup(state, idx.astype(jnp.int32), mode=mode,
                         dp_shard=dp_shard, impl=impl, block_l=block_l,
                         dedup=dedup)


# ---------------------------------------------------------------------------
# Tiny dense attention (seqs are 20-50 tokens; scores fit easily)
# ---------------------------------------------------------------------------


def _mha(p: dict, x: jax.Array, n_heads: int, causal: bool,
         kv: Optional[jax.Array] = None) -> jax.Array:
    b, s, d = x.shape
    kv = x if kv is None else kv
    sk = kv.shape[1]
    dh = p["wq"].shape[1] // n_heads
    q = (x @ p["wq"]).reshape(b, s, n_heads, dh)
    k = (kv @ p["wk"]).reshape(b, sk, n_heads, dh)
    v = (kv @ p["wv"]).reshape(b, sk, n_heads, dh)
    sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(dh).astype(x.dtype)
    if causal:
        mask = jnp.tril(jnp.ones((s, sk), bool))
        sc = jnp.where(mask, sc, -1e30)
    a = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, s, n_heads * dh)
    return o @ p["wo"]


def _mha_specs(d_in: int, d_attn: int, d_out: int, dtype) -> dict:
    return {
        "wq": Spec((d_in, d_attn), dtype),
        "wk": Spec((d_in, d_attn), dtype),
        "wv": Spec((d_in, d_attn), dtype),
        "wo": Spec((d_attn, d_out), dtype),
    }


def _ln(x: jax.Array, g: jax.Array, b: jax.Array, eps: float = 1e-6):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


# ---------------------------------------------------------------------------
# Param specs per model
# ---------------------------------------------------------------------------


def model_specs(cfg: RecConfig, mesh: Mesh, dtype=jnp.float32) -> dict:
    d = cfg.embed_dim
    it = cfg.interaction
    if it == "self-attn-seq":        # SASRec
        blocks = []
        for _ in range(cfg.n_blocks):
            blocks.append({
                "attn": _mha_specs(d, d, d, dtype),
                "ln1_g": Spec((d,), dtype, init="ones"),
                "ln1_b": Spec((d,), dtype, init="zeros"),
                "ln2_g": Spec((d,), dtype, init="ones"),
                "ln2_b": Spec((d,), dtype, init="zeros"),
                "ffn_w1": Spec((d, d), dtype),
                "ffn_b1": Spec((d,), dtype, init="zeros"),
                "ffn_w2": Spec((d, d), dtype),
                "ffn_b2": Spec((d,), dtype, init="zeros"),
            })
        return {
            "pos_emb": Spec((cfg.seq_len, d), dtype, scale=0.02),
            "blocks": blocks,
            "ln_f_g": Spec((d,), dtype, init="ones"),
            "ln_f_b": Spec((d,), dtype, init="zeros"),
        }
    if it == "self-attn":            # AutoInt
        layers = []
        for _ in range(cfg.n_attn_layers):
            layers.append({
                "attn": _mha_specs(d, cfg.d_attn * cfg.n_heads, d, dtype),
                "w_res": Spec((d, d), dtype),
            })
        F = cfg.n_sparse
        return {"layers": layers,
                "head_w": Spec((F * d, 1), dtype),
                "head_b": Spec((1,), dtype, init="zeros")}
    if it == "cross":                # DCN-v2
        x0_dim = cfg.n_dense + cfg.n_sparse * d
        cross = []
        for _ in range(cfg.n_cross_layers):
            cross.append({"w": Spec((x0_dim, x0_dim), dtype,
                                    scale=1.0 / np.sqrt(x0_dim)),
                          "b": Spec((x0_dim,), dtype, init="zeros")})
        deep = mlp_specs((x0_dim,) + cfg.mlp_dims, dtype=dtype)
        head_in = x0_dim + cfg.mlp_dims[-1]
        return {"cross": cross, "deep": deep,
                "head_w": Spec((head_in, 1), dtype),
                "head_b": Spec((1,), dtype, init="zeros")}
    if it == "transformer-seq":      # BST
        S = cfg.seq_len + 1          # history + target
        block = {
            "attn": _mha_specs(d, d, d, dtype),
            "ln1_g": Spec((d,), dtype, init="ones"),
            "ln1_b": Spec((d,), dtype, init="zeros"),
            "ln2_g": Spec((d,), dtype, init="ones"),
            "ln2_b": Spec((d,), dtype, init="zeros"),
            "ffn_w1": Spec((d, 4 * d), dtype),
            "ffn_b1": Spec((4 * d,), dtype, init="zeros"),
            "ffn_w2": Spec((4 * d, d), dtype),
            "ffn_b2": Spec((d,), dtype, init="zeros"),
        }
        mlp_in = S * d + cfg.n_dense
        return {
            "pos_emb": Spec((S, d), dtype, scale=0.02),
            "blocks": [block] * cfg.n_blocks if cfg.n_blocks > 1 else [block],
            "mlp": mlp_specs((mlp_in,) + cfg.mlp_dims + (1,), dtype=dtype),
        }
    raise ValueError(f"unknown interaction {it!r}")


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _sasrec_block(bp: dict, x: jax.Array) -> jax.Array:
    h = _ln(x, bp["ln1_g"], bp["ln1_b"])
    x = x + _mha(bp["attn"], h, n_heads=1, causal=True)
    h = _ln(x, bp["ln2_g"], bp["ln2_b"])
    f = jax.nn.relu(h @ bp["ffn_w1"] + bp["ffn_b1"]) @ bp["ffn_w2"] + bp["ffn_b2"]
    return x + f


def sasrec_encode(params, engine, state, seq_ids: jax.Array, cfg: RecConfig,
                  mode: str = "pifs", dp_shard: bool = True,
                  impl: str = "jnp", block_l: int = 8,
                  dedup: Optional[str] = None) -> jax.Array:
    """(B, S) history -> (B, S, D) causal representations."""
    x = _seq_lookup(engine, state, seq_ids, 0, mode, dp_shard,
                    impl=impl, block_l=block_l, dedup=dedup)  # (B, S, D)
    if dp_shard:
        x = _constrain_full_batch(x, engine)
    x = x * jnp.sqrt(cfg.embed_dim).astype(x.dtype) + params["pos_emb"]
    for bp in params["blocks"]:
        x = _sasrec_block(bp, x)
    return _ln(x, params["ln_f_g"], params["ln_f_b"])


def bst_forward(params, engine, state, batch, cfg: RecConfig,
                mode: str = "pifs", impl: str = "jnp",
                block_l: int = 8, dedup: Optional[str] = None) -> jax.Array:
    """batch: seq (B, S), target (B,), dense (B, n_dense) -> CTR logit (B,)."""
    seq, target = batch["seq"], batch["target"]
    B, S = seq.shape
    tokens = jnp.concatenate([seq, target[:, None]], axis=1)  # (B, S+1)
    x = _seq_lookup(engine, state, tokens, 0, mode, impl=impl,
                    block_l=block_l, dedup=dedup)
    x = _constrain_full_batch(x, engine)
    x = x + params["pos_emb"]
    for bp in params["blocks"]:
        h = _ln(x, bp["ln1_g"], bp["ln1_b"])
        x = x + _mha(bp["attn"], h, n_heads=cfg.n_heads, causal=False)
        h = _ln(x, bp["ln2_g"], bp["ln2_b"])
        f = (jax.nn.leaky_relu(h @ bp["ffn_w1"] + bp["ffn_b1"])
             @ bp["ffn_w2"] + bp["ffn_b2"])
        x = x + f
    flat = x.reshape(B, -1)
    z = jnp.concatenate([flat, batch["dense"]], axis=-1)
    n_mlp = len(cfg.mlp_dims) + 1
    return mlp_apply(params["mlp"], z, n_mlp, act="relu")[:, 0]


def autoint_forward(params, engine, state, batch, cfg: RecConfig,
                    offsets: np.ndarray, mode: str = "pifs",
                    impl: str = "jnp", block_l: int = 8,
                    dedup: Optional[str] = None) -> jax.Array:
    x = _field_lookup(engine, state, batch["fields"], offsets, mode,
                      impl=impl, block_l=block_l, dedup=dedup)  # (B,F,D)
    x = _constrain_full_batch(x, engine)
    for lp in params["layers"]:
        x = jax.nn.relu(_mha(lp["attn"], x, cfg.n_heads, causal=False)
                        + x @ lp["w_res"])
    B = x.shape[0]
    return (x.reshape(B, -1) @ params["head_w"] + params["head_b"])[:, 0]


def dcnv2_forward(params, engine, state, batch, cfg: RecConfig,
                  offsets: np.ndarray, mode: str = "pifs",
                  impl: str = "jnp", block_l: int = 8,
                  dedup: Optional[str] = None) -> jax.Array:
    emb = _field_lookup(engine, state, batch["fields"], offsets, mode,
                        impl=impl, block_l=block_l, dedup=dedup)
    emb = _constrain_full_batch(emb, engine)
    B = emb.shape[0]
    x0 = jnp.concatenate([batch["dense"], emb.reshape(B, -1)], axis=-1)
    x = x0
    for cp in params["cross"]:
        x = x0 * (x @ cp["w"] + cp["b"]) + x
    deep = mlp_apply(params["deep"], x0, len(cfg.mlp_dims), final_act=True)
    z = jnp.concatenate([x, deep], axis=-1)
    return (z @ params["head_w"] + params["head_b"])[:, 0]


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def _bce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    y = labels.astype(jnp.float32)
    lg = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(lg, 0) - lg * y + jnp.log1p(jnp.exp(-jnp.abs(lg))))


def sasrec_loss(params, engine, state, batch, cfg, mode="pifs") -> jax.Array:
    """Sampled next-item BCE (paper's objective): positive = actual next item,
    negative = uniform sample, scored by dot with the item embedding."""
    h = sasrec_encode(params, engine, state, batch["seq"], cfg, mode)  # (B,S,D)
    pos_e = _seq_lookup(engine, state, batch["pos"], 0, mode)
    neg_e = _seq_lookup(engine, state, batch["neg"], 0, mode)
    pos_s = jnp.sum(h * pos_e, axis=-1)
    neg_s = jnp.sum(h * neg_e, axis=-1)
    valid = (batch["seq"] > 0).astype(jnp.float32)
    ls = (jax.nn.softplus(-pos_s) + jax.nn.softplus(neg_s)) * valid
    return ls.sum() / jnp.maximum(valid.sum(), 1.0)


def forward(params, engine, state, batch, cfg: RecConfig,
            offsets: np.ndarray, mode: str = "pifs", impl: str = "jnp",
            block_l: int = 8, dedup: Optional[str] = None) -> jax.Array:
    it = cfg.interaction
    if it == "self-attn":
        return autoint_forward(params, engine, state, batch, cfg, offsets,
                               mode, impl=impl, block_l=block_l, dedup=dedup)
    if it == "cross":
        return dcnv2_forward(params, engine, state, batch, cfg, offsets,
                             mode, impl=impl, block_l=block_l, dedup=dedup)
    if it == "transformer-seq":
        return bst_forward(params, engine, state, batch, cfg, mode,
                           impl=impl, block_l=block_l, dedup=dedup)
    if it == "self-attn-seq":
        # CTR-style scoring of a target against the sequence representation
        h = sasrec_encode(params, engine, state, batch["seq"], cfg, mode,
                          impl=impl, block_l=block_l, dedup=dedup)
        t = _seq_lookup(engine, state, batch["target"][:, None], 0, mode,
                        impl=impl, block_l=block_l, dedup=dedup)[:, 0]
        return jnp.sum(h[:, -1] * t, axis=-1)
    raise ValueError(it)


def loss_fn(params, engine, state, batch, cfg, offsets, mode="pifs"):
    if cfg.interaction == "self-attn-seq":
        return sasrec_loss(params, engine, state, batch, cfg, mode)
    logits = forward(params, engine, state, batch, cfg, offsets, mode)
    return _bce(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Retrieval: score a query against n_candidates explicit item ids
# ---------------------------------------------------------------------------


def retrieval_scores(params, engine, state, batch, cfg: RecConfig,
                     offsets: np.ndarray, mode: str = "pifs") -> jax.Array:
    """batch: model query inputs (B=1 semantics) + cand_ids (n_cand,) sharded
    over dp.  Sequential models score <user_repr, cand_emb>; CTR models tile
    the query and run a full forward per candidate."""
    cand = batch["cand_ids"]                      # (n_cand,)
    n_cand = cand.shape[0]
    it = cfg.interaction
    if it in ("self-attn-seq",):
        h = sasrec_encode(params, engine, state, batch["seq"], cfg, mode,
                          dp_shard=False)
        u = h[:, -1]                              # (1, D)
        # candidates shard over dp: (dp, n_cand/dp, 1) bags
        ce = _seq_lookup(engine, state, cand[:, None], 0, mode)[:, 0]
        return ce @ u[0]
    # CTR models: tile query features across candidates
    if it == "transformer-seq":
        tiled = {
            "seq": jnp.broadcast_to(batch["seq"], (n_cand,) + batch["seq"].shape[1:]),
            "target": cand,
            "dense": jnp.broadcast_to(batch["dense"],
                                      (n_cand,) + batch["dense"].shape[1:]),
        }
        return bst_forward(params, engine, state, tiled, cfg, mode)
    fields = jnp.broadcast_to(batch["fields"],
                              (n_cand,) + batch["fields"].shape[1:])
    # candidate id replaces field 0 (the item/ad field)
    fields = fields.at[:, 0].set(cand % cfg.vocab_sizes[0])
    tiled = {"fields": fields}
    if "dense" in batch:
        tiled["dense"] = jnp.broadcast_to(
            batch["dense"], (n_cand,) + batch["dense"].shape[1:])
    return forward(params, engine, state, tiled, cfg, offsets, mode)


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------


def make_train_step(cfg: RecConfig, engine: PIFSEmbeddingEngine,
                    offsets: np.ndarray, mesh: Mesh, optimizer, emb_optimizer,
                    mode: str = "pifs"):
    def step(params, emb_state, opt_state, emb_opt_state, batch):
        def full_loss(p, cold, hot):
            st = dataclasses.replace(emb_state, cold=cold, hot=hot)
            return loss_fn(p, engine, st, batch, cfg, offsets, mode=mode)

        loss, grads = jax.value_and_grad(full_loss, argnums=(0, 1, 2))(
            params, emb_state.cold, emb_state.hot)
        gp, gcold, ghot = grads
        new_params, new_opt = optimizer.update(gp, opt_state, params)
        emb_params = {"cold": emb_state.cold, "hot": emb_state.hot}
        emb_grads = {"cold": gcold, "hot": ghot}
        new_emb, new_emb_opt = emb_optimizer.update(
            emb_grads, emb_opt_state, emb_params)
        new_state = dataclasses.replace(
            emb_state, cold=new_emb["cold"], hot=new_emb["hot"])
        return new_params, new_state, new_opt, new_emb_opt, {"loss": loss}
    return step


def make_serve_step(cfg: RecConfig, engine: PIFSEmbeddingEngine,
                    offsets: np.ndarray, mesh: Mesh, mode: str = "pifs",
                    impl: str = "jnp", block_l: int = 8,
                    dedup: Optional[str] = None):
    def step(params, emb_state, batch):
        return jax.nn.sigmoid(
            forward(params, engine, emb_state, batch, cfg, offsets,
                    mode=mode, impl=impl, block_l=block_l, dedup=dedup))
    return step


def make_retrieval_step(cfg: RecConfig, engine: PIFSEmbeddingEngine,
                        offsets: np.ndarray, mesh: Mesh, mode: str = "pifs"):
    def step(params, emb_state, batch):
        return retrieval_scores(params, engine, emb_state, batch, cfg,
                                offsets, mode=mode)
    return step


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins)
# ---------------------------------------------------------------------------


def input_specs(cfg: RecConfig, shape_kind: str, batch: int,
                n_candidates: int = 0, with_labels: bool = False
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    i32, f32 = jnp.int32, jnp.float32
    it = cfg.interaction
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if it == "self-attn-seq":
        out["seq"] = jax.ShapeDtypeStruct((batch, cfg.seq_len), i32)
        if shape_kind == "train":
            out["pos"] = jax.ShapeDtypeStruct((batch, cfg.seq_len), i32)
            out["neg"] = jax.ShapeDtypeStruct((batch, cfg.seq_len), i32)
        elif shape_kind == "retrieval":
            out["cand_ids"] = jax.ShapeDtypeStruct((n_candidates,), i32)
        else:
            out["target"] = jax.ShapeDtypeStruct((batch,), i32)
    elif it == "transformer-seq":
        out["seq"] = jax.ShapeDtypeStruct((batch, cfg.seq_len), i32)
        out["dense"] = jax.ShapeDtypeStruct((batch, cfg.n_dense), f32)
        if shape_kind == "retrieval":
            out["cand_ids"] = jax.ShapeDtypeStruct((n_candidates,), i32)
        else:
            out["target"] = jax.ShapeDtypeStruct((batch,), i32)
    else:
        out["fields"] = jax.ShapeDtypeStruct((batch, cfg.n_sparse), i32)
        if cfg.n_dense:
            out["dense"] = jax.ShapeDtypeStruct((batch, cfg.n_dense), f32)
        if shape_kind == "retrieval":
            out["cand_ids"] = jax.ShapeDtypeStruct((n_candidates,), i32)
    if with_labels and shape_kind == "train" and it != "self-attn-seq":
        out["labels"] = jax.ShapeDtypeStruct((batch,), i32)
    return out


def input_pspecs(cfg: RecConfig, shape_kind: str, mesh: Mesh,
                 with_labels: bool = False) -> Dict[str, P]:
    dp = ("pod", "data") if "pod" in mesh.axis_names else (
        ("data",) if "data" in mesh.axis_names else None)
    specs = input_specs(cfg, shape_kind, batch=2, n_candidates=2,
                        with_labels=with_labels)
    out: Dict[str, P] = {}
    for k, s in specs.items():
        if shape_kind == "retrieval":
            # the query replicates; the candidate list shards over dp
            out[k] = P(dp) if k == "cand_ids" else P(*((None,) * len(s.shape)))
        else:
            out[k] = P(*((dp,) + (None,) * (len(s.shape) - 1)))
    return out
