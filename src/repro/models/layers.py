"""Common layers (pure functions over param dicts)."""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map
from repro.models.params import Spec


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * w.astype(jnp.float32)).astype(dt)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "relu":
        return jax.nn.relu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(name)


def ffn_specs(d: int, f: int, act: str, dtype, fsdp, tp) -> dict:
    """Gated (silu_glu) or plain (relu2) FFN param specs."""
    if act == "silu_glu":
        return {
            "gate": Spec((d, f), dtype, P(fsdp, tp)),
            "up": Spec((d, f), dtype, P(fsdp, tp)),
            "down": Spec((f, d), dtype, P(tp, fsdp)),
        }
    return {
        "in": Spec((d, f), dtype, P(fsdp, tp)),
        "out": Spec((f, d), dtype, P(tp, fsdp)),
    }


def ffn_apply(p: dict, x: jax.Array, act: str) -> jax.Array:
    if act == "silu_glu":
        h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
        return h @ p["down"]
    h = activation(act)(x @ p["in"])
    return h @ p["out"]


def ffn_apply_sharded(p: dict, x: jax.Array, act: str, mesh, dp, tp
                      ) -> jax.Array:
    """Megatron-SP FFN with explicit collectives (shard_map).

    x enters sequence-sharded P(dp, tp, None); weights enter in their FSDP x
    TP layout and are all-gathered over the fsdp axis INSIDE the block.
    Explicit per-call gathers are loop-variant when the caller scans over
    stacked layers, so XLA cannot hoist the gathered weight stack out of the
    loop (auto-SPMD did exactly that: 47 GB/device on nemotron-340b train).
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P
    glu = act == "silu_glu"
    names = ("gate", "up", "down") if glu else ("in", "out")
    fsdp = tuple(dp) if dp else ()
    xspec = P(dp if dp else None, tp, None)
    wspec_up = P(fsdp if fsdp else None, tp)     # (d, f) matrices
    wspec_dn = P(tp, fsdp if fsdp else None)     # (f, d) matrix

    def block(x_loc, *ws):
        # gather weights over fsdp (per-layer, inside the scan body)
        ws = [jax.lax.all_gather(w, fsdp, axis=(0 if i < len(ws) - 1 else 1),
                                 tiled=True) if fsdp else w
              for i, w in enumerate(ws)]
        # gather the seq-sharded activations over tp
        x_full = jax.lax.all_gather(x_loc, tp, axis=1, tiled=True)
        if glu:
            g, u, dwn = ws
            h = jax.nn.silu(x_full @ g) * (x_full @ u)
        else:
            win, dwn = ws
            h = activation(act)(x_full @ win)
        out = h @ dwn                                # partial over tp
        return jax.lax.psum_scatter(out, tp, scatter_dimension=1, tiled=True)

    in_specs = (xspec,) + tuple(
        wspec_dn if n in ("down", "out") else wspec_up for n in names)
    return shard_map(block, mesh=mesh, in_specs=in_specs,
                         out_specs=xspec, check_vma=False)(
        x, *[p[n] for n in names])


def mlp_specs(dims: Sequence[int], dtype=jnp.float32, pspec_w=P(),
              prefix: str = "layer") -> dict:
    """Plain MLP tower (recsys / DLRM): dims = (in, h1, ..., out)."""
    p = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        p[f"{prefix}{i}_w"] = Spec((a, b), dtype, pspec_w)
        p[f"{prefix}{i}_b"] = Spec((b,), dtype, P(), init="zeros")
    return p


def mlp_apply(p: dict, x: jax.Array, n_layers: int, act: str = "relu",
              final_act: bool = False, prefix: str = "layer") -> jax.Array:
    f = activation(act)
    for i in range(n_layers):
        x = x @ p[f"{prefix}{i}_w"] + p[f"{prefix}{i}_b"]
        if i < n_layers - 1 or final_act:
            x = f(x)
    return x
