"""Attention: GQA and MLA (DeepSeek-V3), with

  * chunked online-softmax ("flash") attention in pure JAX for train/prefill —
    peak memory is O(q_chunk * kv_chunk) scores instead of O(s^2);
  * decode over a sequence-sharded KV cache: every `model`-axis shard scores
    the query against its local KV slice and only the (num, denom, max)
    softmax partials are combined — the PIFS reduce-near-data pattern applied
    to attention (the KV cache is the "memory pool", the softmax combine is
    the pooled result crossing the fabric).

All assigned archs have kv_heads (8) < tp (16) or a shared MLA latent, so
head-sharding the cache is impossible and sequence sharding is the natural
layout.  MLA decode uses the absorbed-matmul form (score and reduce directly
in the 512-dim latent space; W_uk / W_uv are folded into the query / output
projections), so the cache stays (kv_lora + rope) per token.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import LMConfig
from repro.distributed.sharding import shard_map
from repro.models.params import Spec


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., s, heads?, dim) with pos (..., s) broadcastable int32."""
    dim = x.shape[-1]
    freqs = rope_freqs(dim, theta)                       # (dim/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs   # (..., s, dim/2)
    # broadcast over a possible heads axis between s and dim
    while angles.ndim < x.ndim:
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (train / prefill)
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_chunk: int = 512,
                    kv_chunk: int = 1024, scale: Optional[float] = None,
                    q_offset=0) -> jax.Array:
    """Online-softmax attention without materializing (s, s) scores.

    Flat-head layout so the head axis shards cleanly over `model`:
    q: (b, sq, H, h); k: (b, skv, H, h); v: (b, skv, H, dv) — GQA callers
    repeat kv to H heads first (zero-FLOP gather; keeps every einsum
    head-sharded instead of replicating attention over tp).
    Returns (b, sq, H, dv).
    """
    b, sq, H, h = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]
    scale = scale if scale is not None else h ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq, nk = sq // q_chunk, skv // kv_chunk
    assert sq % q_chunk == 0 and skv % kv_chunk == 0

    qr = (q.reshape(b, nq, q_chunk, H, h)
          .transpose(1, 0, 3, 2, 4))                    # (nq, b, H, qc, h)
    kr = k.reshape(b, nk, kv_chunk, H, h).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(b, nk, kv_chunk, H, dv).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_q):
        qi, qc = qi_q                                   # qc: (b, H, qc, h)
        m0 = jnp.full((b, H, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, H, q_chunk, dv), jnp.float32)

        # rematerialized: backward recomputes the (qc, kc) score tile instead
        # of saving it — without this, AD through the chunk scan stacks
        # O(nq*nk) fp32 score tiles (measured 25+ GB/device at seq 4096)
        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kc, vc = ki_kv                          # (b, H, kc, h/dv)
            s = jnp.einsum("bhqe,bhce->bhqc", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
            corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqc,bhcv->bhqv", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)                # (b, H, qc, dv)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    # (nq, b, H, qc, dv) -> (b, sq, H, dv)
    return outs.transpose(1, 0, 3, 2, 4).reshape(b, sq, H, dv)


# ---------------------------------------------------------------------------
# Sequence-parallel attention (explicit shard_map)
# ---------------------------------------------------------------------------


def seq_parallel_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           mesh: Mesh, dp, tp: str, *,
                           scale: Optional[float] = None,
                           causal: bool = True) -> jax.Array:
    """Attention with q/k/v sequence-sharded over tp; kv all-gathered in
    bf16 once per layer inside shard_map.

    Every assigned GQA arch has kv_heads < tp, so head sharding is
    impossible; leaving the layout to XLA-auto instead produced an
    all-reduce of per-chunk dk/dv partials on every flash chunk iteration
    (360 GB/device/step measured on llama train_4k — EXPERIMENTS.md §Perf).
    Under shard_map the backward of the tiled all_gather is a single
    psum_scatter per layer.

    q: (b, s, H, h); k/v: (b, s, K, h) — all P(dp, tp, None, None).
    """
    b_spec = P(dp if dp else None, tp, None, None)
    H = q.shape[2]
    K = k.shape[2]
    G = H // K
    scale = scale if scale is not None else q.shape[-1] ** -0.5

    def block(q_loc, k_loc, v_loc):
        s_loc = q_loc.shape[1]
        my = jax.lax.axis_index(tp)
        k_full = jax.lax.all_gather(k_loc, tp, axis=1, tiled=True)
        v_full = jax.lax.all_gather(v_loc, tp, axis=1, tiled=True)
        if G > 1:
            k_full = jnp.repeat(k_full, G, axis=2)
            v_full = jnp.repeat(v_full, G, axis=2)
        return flash_attention(
            q_loc, k_full, v_full, causal=causal, scale=scale,
            q_chunk=min(512, s_loc), q_offset=my * s_loc)

    return shard_map(block, mesh=mesh,
                         in_specs=(b_spec, b_spec, b_spec),
                         out_specs=b_spec, check_vma=False)(q, k, v)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------

def gqa_specs(cfg: LMConfig, fsdp, tp, dtype) -> dict:
    d, H, K, h = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": Spec((d, H * h), dtype, P(fsdp, tp)),
        "wk": Spec((d, K * h), dtype, P(fsdp, None)),
        "wv": Spec((d, K * h), dtype, P(fsdp, None)),
        "wo": Spec((H * h, d), dtype, P(tp, fsdp)),
    }


def gqa_prefill(p: dict, x: jax.Array, cfg: LMConfig, constrain=None,
                seq_ctx=None
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """x: (b, s, d) -> (out, (k_cache, v_cache)).

    constrain: optional fn(arr, kind) applying sharding constraints ("q" =
    query/attn-output layout, "kv" = key/value layout).
    seq_ctx: optional (mesh, dp, tp) — when given, attention runs
    sequence-parallel via an explicit shard_map (the layout every assigned
    GQA arch needs, since kv_heads < tp).
    """
    b, s, d = x.shape
    H, K, h = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // K
    c = constrain or (lambda a, kind: a)
    pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    q = (x @ p["wq"]).reshape(b, s, H, h)
    k = (x @ p["wk"]).reshape(b, s, K, h)
    v = (x @ p["wv"]).reshape(b, s, K, h)
    q = c(apply_rope(q, pos, cfg.rope_theta), "q")
    k = apply_rope(k, pos, cfg.rope_theta)
    if seq_ctx is not None:
        mesh, dp, tp = seq_ctx
        k = c(k, "q")
        v = c(v, "q")
        out = seq_parallel_attention(q, k, v, mesh, dp, tp, scale=h ** -0.5)
    else:
        # repeat kv to H heads (zero-FLOP broadcast-gather), head-sharded
        k_r = c(jnp.repeat(k, G, axis=2), "kv")
        v_r = c(jnp.repeat(v, G, axis=2), "kv")
        out = c(flash_attention(q, k_r, v_r), "q")
    out = out.reshape(b, s, H * h) @ p["wo"]
    return out, (k, v)


def gqa_decode_core(q: jax.Array, k_loc: jax.Array, v_loc: jax.Array,
                    pos: jax.Array, tp: str, scale: float) -> jax.Array:
    """Per-shard decode attention over the local KV slice (inside shard_map).

    q: (b, K, G, h) full heads; k_loc/v_loc: (b, s_loc, K, h); pos: () global
    position of the new token.  Returns (b, K, G, dv) combined across tp.
    """
    s_loc = k_loc.shape[1]
    my = jax.lax.axis_index(tp)
    kpos = my * s_loc + jnp.arange(s_loc)
    s = jnp.einsum("bkgh,bckh->bkgc", q.astype(jnp.float32),
                   k_loc.astype(jnp.float32)) * scale
    valid = (kpos <= pos)[None, None, None, :]
    s = jnp.where(valid, s, -jnp.inf)
    m_loc = s.max(axis=-1)
    m = jax.lax.pmax(m_loc, tp)
    m_safe = jnp.where(jnp.isinf(m), 0.0, m)
    pexp = jnp.exp(s - m_safe[..., None])
    pexp = jnp.where(valid, pexp, 0.0)
    l = jax.lax.psum(pexp.sum(axis=-1), tp)
    num = jax.lax.psum(
        jnp.einsum("bkgc,bckv->bkgv", pexp, v_loc.astype(jnp.float32)), tp)
    return (num / jnp.maximum(l[..., None], 1e-30))


def gqa_decode(p: dict, x: jax.Array, cache: Tuple[jax.Array, jax.Array],
               pos: jax.Array, cfg: LMConfig, mesh: Mesh, dp, tp
               ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """x: (b, 1, d); cache k/v: (b, S, K, h) sharded P(dp, tp, None, None)."""
    b = x.shape[0]
    H, K, h = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // K
    q = (x @ p["wq"]).reshape(b, K, G, h)
    q = apply_rope(q.reshape(b, 1, K * G, h), pos[None, None],
                   cfg.rope_theta).reshape(b, K, G, h)
    k_new = (x @ p["wk"]).reshape(b, K, h)
    k_new = apply_rope(k_new[:, None], pos[None, None], cfg.rope_theta)[:, 0]
    v_new = (x @ p["wv"]).reshape(b, K, h)
    scale = h ** -0.5

    bspec = P(dp, None, None) if dp else P(None, None, None)
    cspec = P(dp, tp, None, None) if dp else P(None, tp, None, None)

    def block(q, k_new, v_new, k_c, v_c, pos):
        s_loc = k_c.shape[1]
        my = jax.lax.axis_index(tp)
        # write the new token into whichever shard owns position `pos`
        local_pos = pos - my * s_loc
        owner = (local_pos >= 0) & (local_pos < s_loc)
        lp = jnp.clip(local_pos, 0, s_loc - 1)
        k_upd = jax.lax.dynamic_update_slice(
            k_c, k_new[:, None].astype(k_c.dtype), (0, lp, 0, 0))
        v_upd = jax.lax.dynamic_update_slice(
            v_c, v_new[:, None].astype(v_c.dtype), (0, lp, 0, 0))
        k_c = jnp.where(owner, k_upd, k_c)
        v_c = jnp.where(owner, v_upd, v_c)
        out = gqa_decode_core(q, k_c, v_c, pos, tp, scale)
        return out, k_c, v_c

    qspec = P(dp, None, None, None) if dp else P(None, None, None, None)
    out, k_c, v_c = shard_map(
        block, mesh=mesh,
        in_specs=(qspec, bspec, bspec, cspec, cspec, P()),
        out_specs=(qspec, cspec, cspec), check_vma=False,
    )(q, k_new, v_new, cache[0], cache[1], pos)
    out = out.reshape(b, 1, H * h) @ p["wo"]
    return out, (k_c, v_c)


# ---------------------------------------------------------------------------
# MLA module (DeepSeek-V3)
# ---------------------------------------------------------------------------

def mla_specs(cfg: LMConfig, fsdp, tp, dtype) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": Spec((d, m.q_lora_rank), dtype, P(fsdp, None)),
        "q_norm": Spec((m.q_lora_rank,), dtype, P(), init="ones"),
        "wuq": Spec((m.q_lora_rank, H * qd), dtype, P(None, tp)),
        "wdkv": Spec((d, m.kv_lora_rank), dtype, P(fsdp, None)),
        "kv_norm": Spec((m.kv_lora_rank,), dtype, P(), init="ones"),
        "wukv": Spec((m.kv_lora_rank,
                      H * (m.qk_nope_head_dim + m.v_head_dim)), dtype,
                     P(None, tp)),
        "wkr": Spec((d, m.qk_rope_head_dim), dtype, P(fsdp, None)),
        "wo": Spec((H * m.v_head_dim, d), dtype, P(tp, fsdp)),
    }


def _mla_qkv(p: dict, x: jax.Array, cfg: LMConfig, pos: jax.Array):
    from repro.models.layers import rms_norm
    m = cfg.mla
    b, s, _ = x.shape
    H = cfg.n_heads
    cq = rms_norm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(b, s, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    ckv = rms_norm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)  # (b, s, r)
    k_rope = apply_rope((x @ p["wkr"])[:, :, None, :], pos,
                        cfg.rope_theta)[:, :, 0]               # (b, s, dr)
    return q_nope, q_rope, ckv, k_rope


def mla_prefill(p: dict, x: jax.Array, cfg: LMConfig, constrain=None,
                seq_ctx=None
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Returns (out, (ckv_cache, k_rope_cache)) — latent cache only."""
    m = cfg.mla
    b, s, _ = x.shape
    H = cfg.n_heads
    c = constrain or (lambda a, kind: a)
    pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, cfg, pos)
    kv = (ckv @ p["wukv"]).reshape(b, s, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    # fold the shared rope key into every head (flat-head layout)
    q = c(jnp.concatenate([q_nope, q_rope], axis=-1), "q")
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, H, m.qk_rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    if seq_ctx is not None:
        mesh, dp, tp = seq_ctx
        k = c(k, "q")
        v = c(v, "q")
        out = seq_parallel_attention(q, k, v, mesh, dp, tp, scale=scale)
    else:
        k = c(k, "kv")
        v = c(v, "kv")
        out = c(flash_attention(q, k, v, scale=scale), "q")  # (b, s, H, dv)
    out = out.reshape(b, s, H * m.v_head_dim) @ p["wo"]
    return out, (ckv, k_rope)


def mla_decode(p: dict, x: jax.Array, cache: Tuple[jax.Array, jax.Array],
               pos: jax.Array, cfg: LMConfig, mesh: Mesh, dp, tp
               ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Absorbed-matmul MLA decode over the seq-sharded latent cache.

    cache: ckv (b, S, r) and k_rope (b, S, dr), both P(dp, tp, None).
    Scores/reduction happen directly in the latent space: W_uk folds into the
    query, W_uv folds into the output — per-token work is O(H*(nope*r)) once,
    then O(S*(r+dr)) per shard, matching DeepSeek's serving kernel.
    """
    m = cfg.mla
    b = x.shape[0]
    H = cfg.n_heads
    q_nope, q_rope, ckv_new, kr_new = _mla_qkv(p, x, cfg, pos[None, None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]          # (b, H, *)
    ckv_new, kr_new = ckv_new[:, 0], kr_new[:, 0]        # (b, r), (b, dr)

    wukv = p["wukv"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    wuk = wukv[:, :, : m.qk_nope_head_dim]               # (r, H, nope)
    wuv = wukv[:, :, m.qk_nope_head_dim:]                # (r, H, dv)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))          # (b, H, r)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    bspec2 = P(dp, None) if dp else P(None, None)
    bspec3 = P(dp, None, None) if dp else P(None, None, None)
    cspec = P(dp, tp, None) if dp else P(None, tp, None)

    def block(q_abs, q_rope, ckv_new, kr_new, ckv, krope, pos):
        s_loc = ckv.shape[1]
        my = jax.lax.axis_index(tp)
        local_pos = pos - my * s_loc
        owner = (local_pos >= 0) & (local_pos < s_loc)
        lp = jnp.clip(local_pos, 0, s_loc - 1)
        ckv = jnp.where(owner, jax.lax.dynamic_update_slice(
            ckv, ckv_new[:, None].astype(ckv.dtype), (0, lp, 0)), ckv)
        krope = jnp.where(owner, jax.lax.dynamic_update_slice(
            krope, kr_new[:, None].astype(krope.dtype), (0, lp, 0)), krope)
        kpos = my * s_loc + jnp.arange(s_loc)
        s = (jnp.einsum("bhr,bcr->bhc", q_abs, ckv.astype(jnp.float32))
             + jnp.einsum("bhd,bcd->bhc", q_rope.astype(jnp.float32),
                          krope.astype(jnp.float32))) * scale
        valid = (kpos <= pos)[None, None, :]
        s = jnp.where(valid, s, -jnp.inf)
        m_loc = s.max(axis=-1)
        mx = jax.lax.pmax(m_loc, tp)
        m_safe = jnp.where(jnp.isinf(mx), 0.0, mx)
        pexp = jnp.where(valid, jnp.exp(s - m_safe[..., None]), 0.0)
        l = jax.lax.psum(pexp.sum(axis=-1), tp)
        num = jax.lax.psum(jnp.einsum("bhc,bcr->bhr", pexp,
                                      ckv.astype(jnp.float32)), tp)
        out_lat = num / jnp.maximum(l[..., None], 1e-30)  # (b, H, r)
        return out_lat, ckv, krope

    out_lat, ckv_c, kr_c = shard_map(
        block, mesh=mesh,
        in_specs=(bspec3, bspec3, bspec2, bspec2, cspec, cspec, P()),
        out_specs=(bspec3, cspec, cspec), check_vma=False,
    )(q_abs, q_rope, ckv_new, kr_new, cache[0], cache[1], pos)

    out = jnp.einsum("bhr,rhv->bhv", out_lat,
                     wuv.astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(b, 1, H * m.v_head_dim) @ p["wo"]
    return out, (ckv_c, kr_c)
