"""Expert-parallel MoE: sort-based dispatch + ragged_dot grouped GEMM.

The PIFS principle applied to experts: tokens travel to the shard that owns
their expert (all_to_all of pooled activations), compute happens near the
weights, and only combined results return — never the expert weights
themselves (the communicate-then-reduce alternative would all-gather
E x d x f expert matrices).

Layout:
  * Experts are sharded over ``ep_axes`` — ("model",) when E < dp*tp (granite:
    32 experts over 16 model shards), else ("data","model") (deepseek-v3: 256
    experts over 256 devices, one expert per device; replicated over "pod").
  * Tokens are batch-sharded over dp and replicated over tp; each tp shard
    dispatches a distinct 1/tp slice, so every device injects distinct tokens.
  * Dispatch: flat (token, expert) copies are sorted by destination device and
    packed into fixed-capacity per-destination buffers (capacity_factor bounds
    them; overflow drops, GShard-style, reported as a metric).  One
    all_to_all moves rows; a second returns results; gate weighting and the
    src-token scatter-add happen at home.
  * Grouped GEMM: received rows are sorted by local expert id and pushed
    through jax.lax.ragged_dot over the (E_loc, d, f) weight stack.  Empty
    slots carry zero rows through expert 0 — bias-free experts map zeros to
    zeros, so padding is numerically inert.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import LMConfig, MoEConfig
from repro.distributed.sharding import shard_map
from repro.models.params import Spec


def ep_axes_for(moe: MoEConfig, mesh: Mesh, dp, tp) -> Tuple[str, ...]:
    """Largest usable expert-parallel axis set: ("data","model") when the
    expert count divides it, else ("model",).  'pod' is excluded — experts
    are replicated across pods (pure DP there)."""
    nonpod_dp = tuple(a for a in dp if a != "pod")
    full = nonpod_dp + (tp,)
    size_full = int(np.prod([mesh.shape[a] for a in full]))
    if moe.n_experts % size_full == 0:
        return full
    size_tp = mesh.shape[tp]
    if moe.n_experts % size_tp == 0:
        return (tp,)
    raise ValueError(
        f"experts ({moe.n_experts}) not divisible by tp ({size_tp}) "
        f"or dp*tp ({size_full})")


def moe_specs(cfg: LMConfig, mesh: Mesh, dp, tp, dtype) -> dict:
    moe = cfg.moe
    d, f, E = cfg.d_model, moe.d_ff_expert, moe.n_experts
    ep = ep_axes_for(moe, mesh, dp, tp)
    # experts are replicated across pods (pure DP there); ZeRO-3 their
    # storage over "pod" — gathered per layer inside the moe block, so the
    # 671B expert stack halves per device on the multi-pod mesh
    pod = "pod" if "pod" in mesh.axis_names else None
    especs = {
        "router": Spec((d, E), jnp.float32, P(), scale=0.02),
        "w_gate": Spec((E, d, f), dtype, P(ep, pod, None)),
        "w_up": Spec((E, d, f), dtype, P(ep, pod, None)),
        "w_down": Spec((E, f, d), dtype, P(ep, pod, None)),
    }
    if moe.n_shared_experts:
        fs = f * moe.n_shared_experts
        fsdp = tuple(a for a in dp) or None
        especs.update({
            "sh_gate": Spec((d, fs), dtype, P(fsdp, tp)),
            "sh_up": Spec((d, fs), dtype, P(fsdp, tp)),
            "sh_down": Spec((fs, d), dtype, P(tp, fsdp)),
        })
    return especs


def moe_apply(p: dict, x: jax.Array, cfg: LMConfig, mesh: Mesh, dp, tp
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) sharded P(dp, None, None). Returns (out, aux_loss)."""
    moe = cfg.moe
    ep = ep_axes_for(moe, mesh, dp, tp)
    ep_size = int(np.prod([mesh.shape[a] for a in ep]))
    tp_size = mesh.shape[tp]
    E, k = moe.n_experts, moe.top_k
    E_loc = E // ep_size
    b, s, d = x.shape

    xspec = P(dp, None, None) if dp else P(None, None, None)
    pod = "pod" if "pod" in mesh.axis_names else None
    ep_wspec = P(ep, pod, None)

    block = functools.partial(_moe_block, cfg=cfg, ep=ep, tp=tp,
                              ep_size=ep_size, tp_size=tp_size, E_loc=E_loc,
                              pod=pod)
    out, aux = shard_map(
        block, mesh=mesh,
        in_specs=(xspec, P(), ep_wspec, ep_wspec, ep_wspec),
        out_specs=(xspec, P()), check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if moe.n_shared_experts:
        sh = jax.nn.silu(x @ p["sh_gate"]) * (x @ p["sh_up"])
        out = out + sh @ p["sh_down"]
    return out, aux


def _moe_block(x, wr, w_gate, w_up, w_down, *, cfg, ep, tp, ep_size, tp_size,
               E_loc, pod=None):
    moe = cfg.moe
    if pod is not None:
        # ZeRO-3 gather of the pod-sharded expert storage (per layer, inside
        # the scan body — loop-variant, so never hoisted)
        w_gate = jax.lax.all_gather(w_gate, pod, axis=1, tiled=True)
        w_up = jax.lax.all_gather(w_up, pod, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, pod, axis=1, tiled=True)
    E, k = moe.n_experts, moe.top_k
    b, s, d = x.shape
    n_loc = b * s
    tokens = x.reshape(n_loc, d)
    # decode-shape batches can be smaller than tp: pad the token list so every
    # tp shard still dispatches a distinct (possibly zero-padded) slice
    n_pad = (-n_loc) % tp_size
    if n_pad:
        tokens = jnp.concatenate(
            [tokens, jnp.zeros((n_pad, d), tokens.dtype)], axis=0)
    n_tok = n_loc + n_pad
    tp_rank = jax.lax.axis_index(tp)

    # ---- routing (on my distinct 1/tp slice of this dp shard's tokens) ----
    n_disp = n_tok // tp_size
    my = jax.lax.dynamic_slice_in_dim(tokens, tp_rank * n_disp, n_disp, 0)
    logits = (my.astype(jnp.float32) @ wr)                    # (n_disp, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, k)                 # (n_disp, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (GShard): E * sum_e f_e * p_e
    pe = probs.mean(axis=0)
    fe = jnp.zeros((E,), jnp.float32).at[eids.reshape(-1)].add(1.0) / (n_disp * k)
    axes_all = tuple(dict.fromkeys(ep + (tp,)))
    pe = jax.lax.pmean(pe, axes_all)
    fe = jax.lax.pmean(fe, axes_all)
    aux = E * jnp.sum(pe * fe) * moe.router_aux_weight

    # ---- pack per-destination buffers ----
    cap = int(np.ceil(n_disp * k / ep_size * moe.capacity_factor))
    cap = max(cap, 1)
    flat_eid = eids.reshape(-1)                               # (n_disp*k,)
    dest = flat_eid // E_loc
    order = jnp.argsort(dest)
    dest_s = dest[order]
    eid_s = flat_eid[order]
    src_tok_s = order // k
    gate_s = gate_vals.reshape(-1)[order]
    seg_start = jnp.searchsorted(dest_s, dest_s, side="left")
    pos = jnp.arange(dest_s.shape[0]) - seg_start
    keep = pos < cap
    slot = jnp.where(keep, dest_s * cap + pos, ep_size * cap)  # OOB drops

    send = jnp.zeros((ep_size * cap, d), x.dtype)
    send = send.at[slot].set(jnp.take(my, src_tok_s, axis=0).astype(x.dtype),
                             mode="drop")
    send_eid = jnp.zeros((ep_size * cap,), jnp.int32)
    send_eid = send_eid.at[slot].set((eid_s % E_loc).astype(jnp.int32),
                                     mode="drop")

    # ---- dispatch a2a, grouped GEMM near the experts, return a2a ----
    recv = jax.lax.all_to_all(send.reshape(ep_size, cap, d), ep, 0, 0,
                              tiled=False).reshape(ep_size * cap, d)
    recv_eid = jax.lax.all_to_all(send_eid.reshape(ep_size, cap), ep, 0, 0,
                                  tiled=False).reshape(ep_size * cap)

    order2 = jnp.argsort(recv_eid)
    xs = jnp.take(recv, order2, axis=0)
    group_sizes = jnp.bincount(recv_eid, length=E_loc).astype(jnp.int32)
    h = (jax.nn.silu(jax.lax.ragged_dot(xs, w_gate, group_sizes))
         * jax.lax.ragged_dot(xs, w_up, group_sizes))
    ys = jax.lax.ragged_dot(h.astype(x.dtype), w_down, group_sizes)
    y = jnp.zeros_like(ys).at[order2].set(ys)

    back = jax.lax.all_to_all(y.reshape(ep_size, cap, d), ep, 0, 0,
                              tiled=False).reshape(ep_size * cap, d)

    # ---- combine at home: gate-weight + scatter-add by source token ----
    slot_safe = jnp.where(keep, slot, 0)
    res = jnp.take(back, slot_safe, axis=0)
    res = res * (gate_s * keep).astype(res.dtype)[:, None]
    out_disp = jax.ops.segment_sum(res, src_tok_s, num_segments=n_disp)

    out = jax.lax.all_gather(out_disp, tp, axis=0, tiled=True)  # (n_tok, d)
    out = out[:n_loc]
    dropped = jax.lax.pmean(1.0 - keep.mean(), axes_all)
    del dropped  # exposed via aux metrics in a later revision
    return out.reshape(b, s, d).astype(x.dtype), aux
