"""Declarative parameter trees: one source of truth for shapes, dtypes,
shardings and initialization.

A model module builds a pytree of ``Spec`` leaves; from it we derive
  * abstract ShapeDtypeStructs (dry-run lowering — no allocation),
  * NamedShardings (in_shardings for jit),
  * real initialized params (smoke tests / real training).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.bfloat16
    pspec: P = P()
    init: str = "normal"        # "normal" | "zeros" | "ones" | "embed"
    scale: Optional[float] = None  # None => 1/sqrt(fan_in)


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def abstract(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree, is_leaf=_is_spec)


def shardings(tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s.pspec), tree, is_leaf=_is_spec)


def pspecs(tree):
    return jax.tree.map(lambda s: s.pspec, tree, is_leaf=_is_spec)


def initialize(tree, key: jax.Array):
    """Materialize real parameters (small/reduced configs only)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for s, k in zip(leaves, keys):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, s.dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, s.dtype))
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else max(s.shape[-1], 1)
            scale = s.scale if s.scale is not None else 1.0 / np.sqrt(fan_in)
            out.append((jax.random.normal(k, s.shape, jnp.float32) * scale
                        ).astype(s.dtype))
    return jax.tree.unflatten(treedef, out)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=_is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves if isinstance(s, Spec))
