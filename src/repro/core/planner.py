"""Placement planner: global hotness detection + embedding spreading
(paper sections IV-B2, IV-B3).

Host-side control-plane logic (numpy), mirroring the paper's host daemon:
  1. *Global hotness detection*: rank pages by (decayed) access frequency;
     promote the top `hot_pages` into the replicated hot tier, but only evict
     a resident hot page when a challenger exceeds it by more than
     `cold_age_threshold` (hysteresis, paper default 20%, best 16%).
  2. *Embedding spreading*: distribute cold pages across shards so per-shard
     access load is balanced.  A shard whose load exceeds the mean by
     `1 - migrate_threshold` (default 35%) triggers redistribution; we realize
     the paper's iterative pairwise rebalance with a weighted LPT bin-pack of
     the pages that need (re)placement, which converges to the same balanced
     fixed point without the O(rounds) loop.

The planner only produces a new PageTable; executing the move is
`repro.core.pifs.PIFSEmbeddingEngine.migrate` (a pure gather — the cache-line
granular migration of section IV-B4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.paging import HOT_SHARD, PageTable, PagingConfig


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    migrate_threshold: float = 0.35   # paper section IV-B3 (best value, Fig 13a)
    cold_age_threshold: float = 0.16  # paper section VI-C6 (best value, Fig 13d)
    sticky: bool = True               # keep resident placements when possible


def shard_loads(cfg: PagingConfig, table: PageTable, counts: np.ndarray
                ) -> np.ndarray:
    """Access load per cold shard."""
    shard = np.asarray(table.page_to_shard)
    loads = np.zeros(cfg.n_shards)
    cold = shard != HOT_SHARD
    np.add.at(loads, shard[cold], counts[cold])
    return loads


def needs_migration(cfg: PagingConfig, table: PageTable, counts: np.ndarray,
                    pcfg: PlannerConfig) -> bool:
    """Paper trigger: a node is 'warm' when its access count exceeds the mean
    of the others by more than (1 - migrate_threshold)."""
    loads = shard_loads(cfg, table, counts)
    mean = loads.mean()
    if mean <= 0:
        return False
    return bool(loads.max() > mean * (2.0 - pcfg.migrate_threshold))


def plan(cfg: PagingConfig, table: PageTable, counts: np.ndarray,
         pcfg: Optional[PlannerConfig] = None) -> Tuple[PageTable, dict]:
    """Compute a new placement from page access counts.

    Returns (new_table, stats) where stats records what the paper reports:
    moved-page count, load std-dev before/after (Fig. 13b), hot promotions.
    """
    pcfg = pcfg or PlannerConfig()
    counts = np.asarray(counts, dtype=np.float64)
    old_shard = np.asarray(table.page_to_shard)
    old_slot = np.asarray(table.page_to_slot)
    P = cfg.num_pages

    # ---- 1. hot set selection with hysteresis --------------------------------
    order = np.argsort(-counts, kind="stable")
    want_hot = set(order[: cfg.hot_pages].tolist())
    resident_hot = set(np.nonzero(old_shard == HOT_SHARD)[0].tolist())
    if pcfg.sticky and resident_hot:
        # evict a resident page only if some challenger beats it by margin
        floor = min(counts[p] for p in resident_hot)
        new_hot = set(resident_hot)
        challengers = [p for p in order[: 4 * cfg.hot_pages]
                       if p not in resident_hot]
        for c in challengers:
            if len(new_hot) < cfg.hot_pages:
                new_hot.add(int(c))
                continue
            victim = min((p for p in new_hot), key=lambda p: counts[p])
            if counts[c] > counts[victim] * (1.0 + pcfg.cold_age_threshold):
                new_hot.discard(victim)
                new_hot.add(int(c))
        hot_set = new_hot
    else:
        hot_set = want_hot
    hot_list = sorted(hot_set, key=lambda p: -counts[p])[: cfg.hot_pages]
    hot_mask = np.zeros(P, dtype=bool)
    hot_mask[hot_list] = True

    # ---- 2. embedding spreading over cold shards -----------------------------
    new_shard = np.full(P, HOT_SHARD, dtype=np.int32)
    new_slot = np.zeros(P, dtype=np.int32)
    new_slot[hot_list] = np.arange(len(hot_list), dtype=np.int32)

    cold_pages = np.nonzero(~hot_mask)[0]
    loads = np.zeros(cfg.n_shards)
    fill = np.zeros(cfg.n_shards, dtype=np.int64)

    sticky_kept = 0
    if pcfg.sticky and not needs_migration(cfg, table, counts, pcfg):
        # no node is warm: keep every already-cold page in place
        for p in cold_pages:
            s = old_shard[p]
            if s != HOT_SHARD:
                new_shard[p] = s
                # keep slot if unique; slots stay unique because assignment
                # within a shard is unchanged
                new_slot[p] = old_slot[p]
                loads[s] += counts[p]
                fill[s] = max(fill[s], old_slot[p] + 1)
                sticky_kept += 1
        unplaced = cold_pages[new_shard[cold_pages] == HOT_SHARD]
    else:
        unplaced = cold_pages

    # weighted LPT: heaviest page -> least-loaded shard with capacity
    order_c = unplaced[np.argsort(-counts[unplaced], kind="stable")]
    cap = cfg.pages_per_shard
    for p in order_c:
        cands = np.nonzero(fill < cap)[0]
        s = cands[np.argmin(loads[cands])]
        new_shard[p] = s
        new_slot[p] = fill[s]
        fill[s] += 1
        loads[s] += counts[p]

    moved = int(np.sum((new_shard != old_shard) | (new_slot != old_slot)))
    stats = {
        "moved_pages": moved,
        "moved_fraction": moved / max(1, P),
        "sticky_kept": sticky_kept,
        "hot_pages": len(hot_list),
        "load_std_before": float(shard_loads(cfg, table, counts).std()),
        "load_std_after": float(loads.std()),
        "load_max_over_mean": float(loads.max() / max(loads.mean(), 1e-9)),
    }
    return PageTable(
        page_to_shard=np.asarray(new_shard),
        page_to_slot=np.asarray(new_slot),
    ), stats
