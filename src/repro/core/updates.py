"""Host-side control plane for streaming embedding updates.

Production recommenders never stop training: embedding rows drift while
the same tables serve inference (UpDLRM treats update bandwidth as a
first-class cost; the Intel CPU-cluster DLRM work shows the sparse-update
path dominating when it is not batched).  This module is the *host* half
of the repo's serving-concurrent update subsystem:

  * :func:`coalesce_deltas` — deterministic duplicate-row summing, so the
    device scatter sees unique rows (scatter-add order would otherwise be
    unspecified) and WAL replay is bit-identical to the live application.
  * :func:`chunk_delta_batch` — fixed-``capacity`` padding/chunking, so
    the engine's ``apply_deltas`` plan has exactly one input signature
    and steady-state updates cause zero retraces.
  * :class:`DriftTracker` — per-page accumulated |delta| mass.  Applied
    deltas pull hot fp32 rows off the quantized grid their carried scale
    defines; the tracker tells the requant-demote scheduler which hot
    pages have drifted enough to be worth re-quantizing, and the
    observe-phase access histogram tells it which of those are
    traffic-cold enough to demote without hurting the hot tier.
  * :func:`demote_table` — a new PageTable with the chosen pages moved
    into the least-loaded cold shards' free slots (the planner's LPT slot
    discipline), executed by the engine's ordinary typed ``migrate``.

The device half (the ``apply_deltas`` / ``requant_hot_pages`` plans)
lives in ``repro.core.pifs`` with the other shard_map plan builders.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

from repro.core.paging import HOT_SHARD, PageTable, PagingConfig
from repro.core.planner import shard_loads

PAD_ROW = -1   # pad sentinel in a fixed-capacity delta batch's row ids


@dataclasses.dataclass(frozen=True)
class UpdateConfig:
    """Knobs for the streaming-update subsystem.

    capacity        — rows per device apply (fixed shape: one plan
                      signature, zero steady-state retraces; larger
                      batches are chunked, smaller ones padded).
    apply_every     — micro-batches between drains of the pending update
                      queue (1 = drain at every batch boundary).
    demote_every    — applied batches between requant-demote scans
                      (0 = never demote).
    drift_threshold — accumulated |delta| mass at which a hot page
                      becomes a demotion candidate.
    max_demotions   — cap on pages demoted per scan (bounds the migrate
                      gather's maintenance cost per cycle).
    hotness_guard   — fraction of hot-resident pages (by access count)
                      that are never demoted no matter their drift: the
                      top of the hot tier is what the tier is *for*.
    snapshot_every  — applied batches between checkpoint snapshots
                      (each snapshot truncates the WAL; 0 = only the
                      snapshots the caller takes explicitly).
    """
    capacity: int = 256
    apply_every: int = 1
    demote_every: int = 0
    drift_threshold: float = 1.0
    max_demotions: int = 8
    hotness_guard: float = 0.5
    snapshot_every: int = 0


def coalesce_deltas(rows, deltas) -> Tuple[np.ndarray, np.ndarray]:
    """Sum duplicate-row deltas into one delta per unique row.

    Returns ``(rows (U,) int32 sorted unique, deltas (U, D) float32)``.
    Negative row ids (pads) are dropped.  Deterministic: ``np.unique`` is
    stable and ``np.add.at`` accumulates sequentially, so replaying the
    same input (e.g. from the WAL) reproduces the output bit-for-bit —
    and re-coalescing an already-coalesced batch is the identity, which
    is what makes WAL replay through the same code path exact.
    """
    rows = np.asarray(rows).reshape(-1).astype(np.int64)
    deltas = np.asarray(deltas, dtype=np.float32)
    deltas = deltas.reshape(rows.size, -1)
    keep = rows >= 0
    rows, deltas = rows[keep], deltas[keep]
    uniq, inv = np.unique(rows, return_inverse=True)
    out = np.zeros((uniq.size, deltas.shape[1]), dtype=np.float32)
    np.add.at(out, inv, deltas)
    return uniq.astype(np.int32), out


def chunk_delta_batch(rows: np.ndarray, deltas: np.ndarray, capacity: int,
                      ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Split a coalesced delta batch into fixed-``capacity`` device chunks.

    Every yielded chunk is exactly ``(capacity,)`` int32 rows (``PAD_ROW``
    padded) + ``(capacity, D)`` float32 deltas, so the engine's apply plan
    sees a single input signature regardless of live batch sizes.  An
    empty batch yields nothing — a caller that wants an all-pad batch
    (the warmup path) builds its own rather than paying a pointless
    device apply here."""
    if capacity <= 0:
        raise ValueError(f"capacity must be positive; got {capacity}")
    rows = np.asarray(rows, dtype=np.int32).reshape(-1)
    deltas = np.asarray(deltas, dtype=np.float32)
    d = deltas.shape[-1]
    for lo in range(0, rows.size, capacity):
        sl_rows = rows[lo:lo + capacity]
        sl_d = deltas[lo:lo + capacity]
        pad = capacity - sl_rows.size
        out_rows = np.concatenate(
            [sl_rows, np.full(pad, PAD_ROW, dtype=np.int32)])
        out_d = np.concatenate(
            [sl_d, np.zeros((pad, d), dtype=np.float32)], axis=0)
        yield out_rows, out_d


class DriftTracker:
    """Per-page accumulated update mass, feeding requant-demote scans.

    ``drift[p]`` is the summed |delta| applied to page ``p`` since it was
    last re-quantized (demoted or snapped onto its carried-scale grid).
    Pure host bookkeeping — the device state never sees it."""

    def __init__(self, cfg: PagingConfig):
        self.cfg = cfg
        self.drift = np.zeros(cfg.num_pages, dtype=np.float64)
        self.rows_touched = np.zeros(cfg.num_pages, dtype=np.int64)

    def update(self, rows, deltas) -> None:
        rows = np.asarray(rows).reshape(-1)
        deltas = np.asarray(deltas, dtype=np.float64)
        deltas = deltas.reshape(rows.size, -1)
        keep = rows >= 0
        rows, deltas = rows[keep], deltas[keep]
        page = rows // self.cfg.page_size
        np.add.at(self.drift, page, np.abs(deltas).sum(axis=1))
        np.add.at(self.rows_touched, page, 1)

    def note_requantized(self, pages) -> None:
        """Pages whose values were put back on the quantized grid (demoted
        or snapped) carry no drift against their scale any more."""
        pages = np.asarray(pages).reshape(-1)
        pages = pages[pages >= 0]
        self.drift[pages] = 0.0

    def demote_candidates(self, table: PageTable, counts: np.ndarray,
                          ucfg: UpdateConfig) -> np.ndarray:
        """Hot-resident pages drifted past the threshold, excluding the
        hottest ``hotness_guard`` fraction of the hot tier by access
        count.  Returns up to ``max_demotions`` page ids, most-drifted
        first (deterministic tie-break by page id)."""
        shard = np.asarray(table.page_to_shard)
        counts = np.asarray(counts, dtype=np.float64)
        hot = np.nonzero(shard == HOT_SHARD)[0]
        if hot.size == 0 or ucfg.max_demotions <= 0:
            return np.empty(0, dtype=np.int64)
        n_guard = int(np.ceil(hot.size * ucfg.hotness_guard))
        if n_guard > 0:
            # the guard protects by *traffic* rank among hot residents
            guard_order = hot[np.argsort(-counts[hot], kind="stable")]
            guarded = set(guard_order[:n_guard].tolist())
        else:
            guarded = set()
        cand = [p for p in hot.tolist()
                if p not in guarded
                and self.drift[p] >= ucfg.drift_threshold]
        cand.sort(key=lambda p: (-self.drift[p], p))
        return np.asarray(cand[: ucfg.max_demotions], dtype=np.int64)


def demote_table(cfg: PagingConfig, table: PageTable, counts: np.ndarray,
                 pages) -> PageTable:
    """New PageTable with ``pages`` (hot-resident) demoted to cold shards.

    Every other page keeps its placement, so the migration this table
    drives moves exactly the demoted pages.  Destination shards follow
    the planner's discipline — least loaded first, bounded by each
    shard's slot capacity — and each demoted page takes the smallest free
    slot on its shard (deterministic, hole-filling).  Raises if the cold
    tier has no free slot anywhere (headroom exhausted)."""
    pages = np.asarray(pages).reshape(-1).astype(np.int64)
    shard = np.asarray(table.page_to_shard).copy()
    slot = np.asarray(table.page_to_slot).copy()
    counts = np.asarray(counts, dtype=np.float64)
    loads = shard_loads(cfg, table, counts)
    cap = cfg.pages_per_shard
    used = [set(slot[shard == s].tolist()) for s in range(cfg.n_shards)]
    for p in pages:
        if shard[p] != HOT_SHARD:
            raise ValueError(f"page {int(p)} is not hot-resident "
                             f"(shard {int(shard[p])})")
        cands = [s for s in range(cfg.n_shards) if len(used[s]) < cap]
        if not cands:
            raise RuntimeError("cold tier has no free slot for demotion "
                               "(headroom exhausted)")
        s = min(cands, key=lambda s: (loads[s], s))
        free = min(set(range(cap)) - used[s])
        shard[p] = s
        slot[p] = free
        used[s].add(free)
        loads[s] += counts[p]
    return PageTable(page_to_shard=shard.astype(np.int32),
                     page_to_slot=slot.astype(np.int32))
