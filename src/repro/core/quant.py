"""Per-page symmetric int8 quantization for the cold embedding tier.

The cold tier is — by the planner's construction — the cold, accuracy-
insensitive majority of rows, so it can afford 8-bit storage; what it
cannot afford is extra bytes on the memory interface (the paper's whole
thesis is that DLRM inference is bandwidth-bound).  Rows are quantized
symmetrically per *page* (the placement/migration unit), so the scale
metadata moves with the page and dequantization needs exactly one fp32
scalar per page:

    scale[p] = max |x| over page p / 127        (1.0 for all-zero pages)
    q        = clip(round(x / scale[p]), -127, 127)   int8
    x_hat    = float32(q) * scale[p]

Properties the engine's placement invariance leans on (property-tested in
``tests/test_property.py``):

  * **Error bound** — ``|x - x_hat| <= scale[p] / 2`` elementwise (up to
    fp rounding of the divide; all-zero pages round-trip exactly).
  * **Idempotency** — re-quantizing dequantized values with the *same*
    scale recovers the codes bit-for-bit: ``quantize(x_hat, s) == q``.
    This is what makes hot->cold demotion of a previously promoted page
    lossless: the page's scale is carried in ``EngineState.page_scales``
    (global, per-page) and never recomputed on migration.
"""
from __future__ import annotations

import jax.numpy as jnp

QMAX = 127  # symmetric int8 range [-127, 127]; -128 unused


def page_scales(pages: jnp.ndarray) -> jnp.ndarray:
    """Per-page dequant scales.  pages: (..., page_size, D) -> (...,) f32.

    All-zero pages get scale 1.0 so both quantize and dequantize are
    well-defined (and exact) for them.
    """
    amax = jnp.max(jnp.abs(pages.astype(jnp.float32)), axis=(-2, -1))
    return jnp.where(amax > 0, amax / QMAX, 1.0).astype(jnp.float32)


def quantize_rows(rows: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """rows (..., D) float, scales broadcastable against rows -> int8."""
    q = jnp.round(rows.astype(jnp.float32) / scales)
    return jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)


def dequantize_rows(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """int8 codes (..., D), scales broadcastable -> float32 values."""
    return q.astype(jnp.float32) * scales


def quantize_pages(pages: jnp.ndarray):
    """(P, page_size, D) float -> ((P, page_size, D) int8, (P,) f32)."""
    scales = page_scales(pages)
    return quantize_rows(pages, scales[:, None, None]), scales


def dequantize_pages(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize_pages` (up to the half-scale error)."""
    return dequantize_rows(q, scales[:, None, None])
