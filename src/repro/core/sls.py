"""SparseLengthSum (SLS) primitives — the paper's hot operator.

Pure-jnp building blocks used (a) standalone as single-device references and
(b) inside the sharded PIFS engine's `shard_map` blocks.  All functions are
static-shape and differentiable (gather -> scatter-add under AD).

Layout convention: a *bag* is one (sample, table) pooling group.  Flattened
form: ``indices (N,)`` global row ids, ``segment_ids (N,)`` in [0, num_bags),
optional ``weights (N,)``.  Dense form: ``indices (B, L)`` with implicit
segment structure and a validity mask (padding entries carry weight 0).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

# Non-owned pooling entries are remapped to this sentinel before the
# sort-based unique, so they (a) sort past every real row id and collapse
# into at most one padded staging slot, and (b) never pollute the dequant
# scale of a *real* unique row (remapping them to row 0 would).  Gathers
# clamp the sentinel into range; its contribution is zeroed by the mask.
DEDUP_SENTINEL = jnp.iinfo(jnp.int32).max


def sls_ref(table: jax.Array, indices: jax.Array, segment_ids: jax.Array,
            num_bags: int, weights: Optional[jax.Array] = None) -> jax.Array:
    """Reference SLS: out[b] = sum_{i: seg[i]==b} w[i] * table[idx[i]]."""
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)


def sls_dense_ref(table: jax.Array, indices: jax.Array,
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """Dense-form SLS: indices (B, L) -> (B, D)."""
    rows = jnp.take(table, indices, axis=0)           # (B, L, D)
    if weights is not None:
        rows = rows * weights[..., None].astype(rows.dtype)
    return rows.sum(axis=1)


def masked_partial_sls(local_storage: jax.Array, local_rows: jax.Array,
                       owned: jax.Array, segment_ids: jax.Array, num_bags: int,
                       weights: Optional[jax.Array] = None) -> jax.Array:
    """Per-shard partial SLS: accumulate only rows this shard owns.

    This is the fabric-switch Process Core: the reduction happens where the
    rows live; only the pooled (num_bags, D) partial leaves the shard.
    Accumulation order is irrelevant (commutative adds) — the paper's
    out-of-order accumulation engine is free here by construction.
    """
    safe_rows = jnp.where(owned, local_rows, 0)
    rows = jnp.take(local_storage, safe_rows, axis=0)
    w = owned.astype(rows.dtype)
    if weights is not None:
        w = w * weights.astype(rows.dtype)
    rows = rows * w[:, None]
    return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)


class DedupPlan(NamedTuple):
    """Static-shape batch-level duplicate-coalescing plan (gather-once).

    Capacity is always ``N = B*L`` (the worst case: every entry unique), so
    shapes never depend on the data — no retraces.  ``n_slots`` / ``n_unique``
    are *traced scalars*: the kernel bounds its DMA loop with ``n_slots`` so
    the bytes actually moved scale with the realized unique count, while the
    padded tail of ``unique_rows`` is never fetched.
    """
    unique_rows: jax.Array    # (N,) int32 row id per staging slot (padded
    #                           slots and the non-owned run hold the sentinel)
    slots: jax.Array          # (B, L) int32 staging slot per pooling entry
    n_slots: jax.Array        # () int32 live staging slots (incl. the one
    #                           sentinel run, when any entry is non-owned)
    n_unique: jax.Array       # () int32 unique *owned* rows (the dedup stat)
    unique_scales: Optional[jax.Array]  # (N,) f32 per-slot dequant scales


def dedup_plan(local_rows: jax.Array, owned: jax.Array,
               scales: Optional[jax.Array] = None) -> DedupPlan:
    """Sort-based unique over the owned entries of dense (B, L) bags.

    All outputs are static-shape (capacity ``B*L``); every random-access
    structure the dedup'd accumulate needs is built here with one argsort:
    duplicate entries of a row share a staging slot, so the row is gathered
    (and dequantized) exactly once, and the accumulate reads through the
    ``slots`` indirection in the original fixed l-order — the gather
    changes, the accumulation order never does (bit-exactness).
    """
    B, L = local_rows.shape
    N = B * L
    r = jnp.where(owned, local_rows, DEDUP_SENTINEL).reshape(N)
    r = r.astype(jnp.int32)
    order = jnp.argsort(r)
    sr = r[order]                                            # ascending rows
    is_new = jnp.concatenate(
        [jnp.ones((1,), bool), sr[1:] != sr[:-1]])
    uid = (jnp.cumsum(is_new) - 1).astype(jnp.int32)         # slot per entry
    slots = jnp.zeros((N,), jnp.int32).at[order].set(uid).reshape(B, L)
    unique_rows = jnp.full((N,), DEDUP_SENTINEL, jnp.int32).at[uid].set(sr)
    n_slots = uid[-1] + 1
    n_unique = n_slots - (sr[-1] == DEDUP_SENTINEL).astype(jnp.int32)
    unique_scales = None
    if scales is not None:
        # duplicates of a row share its page, hence its scale, so the
        # conflicting-writes order is immaterial for owned slots; the
        # sentinel slot's scale is arbitrary-but-finite (masked to zero)
        ss = scales.reshape(N)[order].astype(jnp.float32)
        unique_scales = jnp.ones((N,), jnp.float32).at[uid].set(ss)
    return DedupPlan(unique_rows, slots, n_slots, n_unique, unique_scales)


def _fixed_order_accumulate(rows: jax.Array, f: jax.Array, out_dtype
                            ) -> jax.Array:
    """Sequential accumulate in the kernel's fixed l=0..L-1 order with the
    same ``add(mul(f, row))`` structure — the shared tail of every jnp SLS
    path, and the reason they all agree with the Pallas kernels bit-for-bit
    in fp32."""
    B, L, D = rows.shape

    def step(carry, xs):
        rows_l, f_l = xs
        return carry + f_l[:, None] * rows_l, None

    out, _ = jax.lax.scan(step, jnp.zeros((B, D), out_dtype),
                          (rows.transpose(1, 0, 2), f.T))
    return out


def masked_partial_sls_dense(local_storage: jax.Array, local_rows: jax.Array,
                             owned: jax.Array,
                             weights: Optional[jax.Array] = None,
                             impl: str = "jnp", block_l: int = 8,
                             interpret: Optional[bool] = None,
                             scales: Optional[jax.Array] = None,
                             out_dtype=None, dedup: bool = False,
                             dedup_capacity: Optional[int] = None
                             ) -> jax.Array:
    """Dense-bag form of :func:`masked_partial_sls`.

    local_rows/owned (B, L), optional weights (B, L) -> (B, D):
    ``out[b] = sum_l owned[b,l] * w[b,l] * local_storage[local_rows[b,l]]``.

    impl='jnp' is the differentiable gather+sum reference; impl='pallas'
    dispatches to the bag-tiled masked-partial SLS kernel (serving fast path —
    the engine's `shard_map` blocks run this near the data).

    ``scales`` (B, L): per-entry dequant scales for a quantized (int8)
    ``local_storage`` — each gathered row is dequantized
    (``float(row) * scale``) before the ``f * row`` accumulate, in both
    impls with the identical op order, so the two stay bit-for-bit equal in
    fp32.  ``out_dtype`` defaults to the storage dtype (pass float32 for a
    quantized store).

    ``dedup=True`` turns on gather-once duplicate coalescing (RecNMP /
    BEACON-style): a static-shape sort-unique (:func:`dedup_plan`) compacts
    the bags' owned rows, each unique row is gathered (and dequantized)
    exactly once into a ``(B*L, D)`` staging buffer, and the accumulate
    reads through the slot indirection in the *same* fixed l-order — so the
    result is bit-for-bit equal to ``dedup=False`` for both impls (the
    dequant multiply has identical operands whether applied per entry or
    per unique row).  ``dedup_capacity`` bounds the staging rows (e.g. a
    VMEM budget); when ``B*L`` exceeds it the call falls back to the
    non-dedup path — exact by construction, just without the bytes win.
    """
    if out_dtype is None:
        out_dtype = local_storage.dtype
    B, L = local_rows.shape
    D = local_storage.shape[-1]
    if dedup and dedup_capacity is not None and B * L > dedup_capacity:
        dedup = False                      # capacity overflow: exact fallback
    if B == 0 or L == 0:
        return jnp.zeros((B, D), out_dtype)
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops
        if dedup:
            plan = dedup_plan(local_rows, owned, scales)
            return kernel_ops.masked_sls_dedup(
                local_storage, plan, owned, weights,
                out_dtype=out_dtype, block_l=block_l, interpret=interpret)
        return kernel_ops.masked_sls(
            local_storage, local_rows, owned, weights,
            out_dtype=out_dtype, block_l=block_l,
            interpret=interpret, scales=scales)
    if impl != "jnp":
        raise ValueError(f"unknown impl {impl!r}")
    f = owned.astype(out_dtype)
    if weights is not None:
        f = f * weights.astype(out_dtype)
    # One fused gather, then the fixed-l-order accumulate with the same
    # add(mul(f, mul(scale, row))) structure as the kernels — lookup
    # numerics are *impl-invariant* (the pallas path matches this
    # bit-for-bit in fp32), at the cost of ordered adds instead of one fused
    # reduce.  Differentiable (gather + scan -> scatter-add under AD), so
    # training uses this path too (fp32 storage; int8 stores are serving-only).
    if dedup:
        plan = dedup_plan(local_rows, owned, scales)
        V = local_storage.shape[0]
        staging = jnp.take(local_storage,
                           jnp.minimum(plan.unique_rows, V - 1),
                           axis=0).astype(out_dtype)           # (B*L, D)
        if plan.unique_scales is not None:
            staging = staging * plan.unique_scales[:, None].astype(out_dtype)
        rows = jnp.take(staging, plan.slots, axis=0)           # (B, L, D)
    else:
        safe_rows = jnp.where(owned, local_rows, 0)
        rows = jnp.take(local_storage, safe_rows, axis=0).astype(out_dtype)
        if scales is not None:
            rows = rows * scales[..., None].astype(out_dtype)  # (B, L, D)
    return _fixed_order_accumulate(rows, f, out_dtype)


def fused_front_end_dense(cold_storage: jax.Array, hot_storage: jax.Array,
                          x: jax.Array, local_rows: jax.Array,
                          owned: jax.Array, is_hot: jax.Array,
                          weights: Optional[jax.Array] = None,
                          scales: Optional[jax.Array] = None,
                          impl: str = "jnp", block_l: int = 8,
                          block_b: int = 32,
                          interpret: Optional[bool] = None,
                          dedup: bool = False,
                          out_dtype=jnp.float32) -> jax.Array:
    """Fused DLRM front end: two-tier masked SLS -> dot-interaction.

    local_rows/owned/is_hot (B, G, L): per-entry local row + tier masks
    (cold vs replicated hot; entries in neither tier contribute zero);
    x (B, D): the bottom-MLP output, stacked as feature row 0.  Returns
    the (B, P) packed lower triangle of the (B, F, D) = (B, G+1, D)
    features' pairwise dots.

    impl='jnp' composes the split pipeline from this module's pieces
    (per-tier :func:`masked_partial_sls_dense` -> add -> concat ->
    interaction oracle) — it IS the split computation, so the knob is a
    pure kernel-level optimization.  impl='pallas' runs the single fused
    kernel whose phase-2 accumulates write pooled rows into persistent
    VMEM ``(BB, F, D)`` batch-tiles and whose phase 3 is the interaction
    matmul + triangle pack — the pooled features never round-trip HBM.
    Both impls (and ``dedup`` on/off, which only changes the gather) are
    bit-for-bit equal in fp32.
    """
    B, G, L = local_rows.shape
    D = cold_storage.shape[-1]
    F = G + 1
    P = F * (F - 1) // 2
    if B == 0 or L == 0 or G == 0:
        return jnp.zeros((B, P), out_dtype)
    if hot_storage.shape[0] == 0:
        # tiering disabled (hot_fraction=0, the BEACON placement): keep one
        # always-resident line so masked-out hot DMAs stay in range
        hot_storage = jnp.zeros((1, D), hot_storage.dtype)
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops
        plans = None
        if dedup:
            nb = B * G
            cp = dedup_plan(local_rows.reshape(nb, L),
                            owned.reshape(nb, L),
                            None if scales is None
                            else scales.reshape(nb, L))
            hp = dedup_plan(local_rows.reshape(nb, L),
                            is_hot.reshape(nb, L))
            plans = (cp._replace(slots=cp.slots.reshape(B, G, L)),
                     hp._replace(slots=hp.slots.reshape(B, G, L)))
        return kernel_ops.fused_front_end(
            cold_storage, hot_storage, x, local_rows, owned, is_hot,
            weights=weights, scales=scales, dedup_plans=plans,
            out_dtype=out_dtype, interpret=interpret, block_l=block_l,
            block_b=block_b)
    if impl != "jnp":
        raise ValueError(f"unknown impl {impl!r}")
    nb = B * G
    flat = local_rows.reshape(nb, L)
    w = None if weights is None else weights.reshape(nb, L)
    cold_p = masked_partial_sls_dense(
        cold_storage, flat, owned.reshape(nb, L), w, impl="jnp",
        scales=None if scales is None else scales.reshape(nb, L),
        out_dtype=out_dtype, dedup=dedup)
    hot_p = masked_partial_sls_dense(
        hot_storage, flat, is_hot.reshape(nb, L), w, impl="jnp",
        out_dtype=out_dtype, dedup=dedup)
    pooled = (cold_p + hot_p).reshape(B, G, D)
    feats = jnp.concatenate([x[:, None, :].astype(out_dtype), pooled],
                            axis=1)
    from repro.kernels import ref as kernel_ref
    return kernel_ref.dot_interaction_ref(feats)


def fused_partial_pool_dense(cold_storage: jax.Array, hot_storage: jax.Array,
                             x: jax.Array, local_rows: jax.Array,
                             owned: jax.Array, is_hot: jax.Array,
                             weights: Optional[jax.Array] = None,
                             scales: Optional[jax.Array] = None,
                             impl: str = "jnp", block_l: int = 8,
                             block_b: int = 32,
                             interpret: Optional[bool] = None,
                             dedup: bool = False,
                             out_dtype=jnp.float32):
    """Phases 1-2 of :func:`fused_front_end_dense`, stopped at the phase-2/3
    seam: returns the per-tier partial feature tiles ``(B, F, D)``.

    ``part_c`` holds this shard's cold-tier partial pools with feature row 0
    all-zero — the tile a tp dispatch ``psum``s across shards (row 0 must
    not pick up ``x`` tp times).  ``part_h`` holds the hot-tier pools with
    ``x`` in row 0 (hot is replicated, never reduced).  The jnp impl IS the
    split composition's per-tier pieces (same
    :func:`masked_partial_sls_dense` calls, same fixed l-order), so
    ``fused_resume_dense(psum(part_c), part_h)`` reproduces
    ``psum(cold_part) + hot_out`` bit-for-bit in fp32.  Dedup staging stays
    per-shard: the plans are built on this shard's ownership and only the
    pooled tile crosses the fabric.
    """
    B, G, L = local_rows.shape
    D = cold_storage.shape[-1]
    F = G + 1
    if B == 0 or L == 0 or G == 0:
        zc = jnp.zeros((B, F, D), out_dtype)
        return zc, zc.at[:, 0, :].set(x.astype(out_dtype))
    if hot_storage.shape[0] == 0:
        hot_storage = jnp.zeros((1, D), hot_storage.dtype)
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops
        plans = None
        if dedup:
            nb = B * G
            cp = dedup_plan(local_rows.reshape(nb, L),
                            owned.reshape(nb, L),
                            None if scales is None
                            else scales.reshape(nb, L))
            hp = dedup_plan(local_rows.reshape(nb, L),
                            is_hot.reshape(nb, L))
            plans = (cp._replace(slots=cp.slots.reshape(B, G, L)),
                     hp._replace(slots=hp.slots.reshape(B, G, L)))
        return kernel_ops.fused_partial_pool(
            cold_storage, hot_storage, x, local_rows, owned, is_hot,
            weights=weights, scales=scales, dedup_plans=plans,
            out_dtype=out_dtype, interpret=interpret, block_l=block_l,
            block_b=block_b)
    if impl != "jnp":
        raise ValueError(f"unknown impl {impl!r}")
    nb = B * G
    flat = local_rows.reshape(nb, L)
    w = None if weights is None else weights.reshape(nb, L)
    cold_p = masked_partial_sls_dense(
        cold_storage, flat, owned.reshape(nb, L), w, impl="jnp",
        scales=None if scales is None else scales.reshape(nb, L),
        out_dtype=out_dtype, dedup=dedup)
    hot_p = masked_partial_sls_dense(
        hot_storage, flat, is_hot.reshape(nb, L), w, impl="jnp",
        out_dtype=out_dtype, dedup=dedup)
    zero = jnp.zeros((B, 1, D), out_dtype)
    part_c = jnp.concatenate([zero, cold_p.reshape(B, G, D)], axis=1)
    part_h = jnp.concatenate([x[:, None, :].astype(out_dtype),
                              hot_p.reshape(B, G, D)], axis=1)
    return part_c, part_h


def fused_resume_dense(part_c: jax.Array, part_h: jax.Array,
                       impl: str = "jnp", block_b: int = 32,
                       interpret: Optional[bool] = None,
                       out_dtype=jnp.float32) -> jax.Array:
    """Phase 3 of the fused front end on the psum-reduced tiles: cold/hot
    add (the split path's ``psum(cold_part) + hot_out`` operand order),
    dot-interaction, packed lower triangle ``(B, P)``."""
    B, F, _ = part_c.shape
    P = F * (F - 1) // 2
    if B == 0 or F == 1:
        return jnp.zeros((B, P), out_dtype)
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops
        return kernel_ops.fused_resume(part_c, part_h, out_dtype=out_dtype,
                                       interpret=interpret, block_b=block_b)
    if impl != "jnp":
        raise ValueError(f"unknown impl {impl!r}")
    from repro.kernels import ref as kernel_ref
    return kernel_ref.fused_resume_ref(part_c, part_h)


def masked_gather_rows(local_storage: jax.Array, local_rows: jax.Array,
                       owned: jax.Array) -> jax.Array:
    """Pond-mode per-shard step: ship the *raw rows* (zeros where not owned).

    The caller psums the (N, D) result across shards — this is the
    communicate-then-reduce baseline: N*D bytes cross the interconnect
    instead of num_bags*D.
    """
    safe_rows = jnp.where(owned, local_rows, 0)
    rows = jnp.take(local_storage, safe_rows, axis=0)
    return rows * owned.astype(rows.dtype)[:, None]


def bags_to_flat(indices: jax.Array, weights: Optional[jax.Array] = None):
    """(B, L) dense bags -> flat (N,), segment_ids (N,), weights (N,)."""
    B, L = indices.shape
    flat = indices.reshape(-1)
    seg = jnp.repeat(jnp.arange(B, dtype=jnp.int32), L)
    w = None if weights is None else weights.reshape(-1)
    return flat, seg, B, w
