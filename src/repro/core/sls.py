"""SparseLengthSum (SLS) primitives — the paper's hot operator.

Pure-jnp building blocks used (a) standalone as single-device references and
(b) inside the sharded PIFS engine's `shard_map` blocks.  All functions are
static-shape and differentiable (gather -> scatter-add under AD).

Layout convention: a *bag* is one (sample, table) pooling group.  Flattened
form: ``indices (N,)`` global row ids, ``segment_ids (N,)`` in [0, num_bags),
optional ``weights (N,)``.  Dense form: ``indices (B, L)`` with implicit
segment structure and a validity mask (padding entries carry weight 0).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sls_ref(table: jax.Array, indices: jax.Array, segment_ids: jax.Array,
            num_bags: int, weights: Optional[jax.Array] = None) -> jax.Array:
    """Reference SLS: out[b] = sum_{i: seg[i]==b} w[i] * table[idx[i]]."""
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)


def sls_dense_ref(table: jax.Array, indices: jax.Array,
                  weights: Optional[jax.Array] = None) -> jax.Array:
    """Dense-form SLS: indices (B, L) -> (B, D)."""
    rows = jnp.take(table, indices, axis=0)           # (B, L, D)
    if weights is not None:
        rows = rows * weights[..., None].astype(rows.dtype)
    return rows.sum(axis=1)


def masked_partial_sls(local_storage: jax.Array, local_rows: jax.Array,
                       owned: jax.Array, segment_ids: jax.Array, num_bags: int,
                       weights: Optional[jax.Array] = None) -> jax.Array:
    """Per-shard partial SLS: accumulate only rows this shard owns.

    This is the fabric-switch Process Core: the reduction happens where the
    rows live; only the pooled (num_bags, D) partial leaves the shard.
    Accumulation order is irrelevant (commutative adds) — the paper's
    out-of-order accumulation engine is free here by construction.
    """
    safe_rows = jnp.where(owned, local_rows, 0)
    rows = jnp.take(local_storage, safe_rows, axis=0)
    w = owned.astype(rows.dtype)
    if weights is not None:
        w = w * weights.astype(rows.dtype)
    rows = rows * w[:, None]
    return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)


def masked_partial_sls_dense(local_storage: jax.Array, local_rows: jax.Array,
                             owned: jax.Array,
                             weights: Optional[jax.Array] = None,
                             impl: str = "jnp", block_l: int = 8,
                             interpret: Optional[bool] = None,
                             scales: Optional[jax.Array] = None,
                             out_dtype=None) -> jax.Array:
    """Dense-bag form of :func:`masked_partial_sls`.

    local_rows/owned (B, L), optional weights (B, L) -> (B, D):
    ``out[b] = sum_l owned[b,l] * w[b,l] * local_storage[local_rows[b,l]]``.

    impl='jnp' is the differentiable gather+sum reference; impl='pallas'
    dispatches to the bag-tiled masked-partial SLS kernel (serving fast path —
    the engine's `shard_map` blocks run this near the data).

    ``scales`` (B, L): per-entry dequant scales for a quantized (int8)
    ``local_storage`` — each gathered row is dequantized
    (``float(row) * scale``) before the ``f * row`` accumulate, in both
    impls with the identical op order, so the two stay bit-for-bit equal in
    fp32.  ``out_dtype`` defaults to the storage dtype (pass float32 for a
    quantized store).
    """
    if out_dtype is None:
        out_dtype = local_storage.dtype
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops
        return kernel_ops.masked_sls(
            local_storage, local_rows, owned, weights,
            out_dtype=out_dtype, block_l=block_l,
            interpret=interpret, scales=scales)
    if impl != "jnp":
        raise ValueError(f"unknown impl {impl!r}")
    B, L = local_rows.shape
    D = local_storage.shape[-1]
    if L == 0:
        return jnp.zeros((B, D), out_dtype)
    # One fused gather, then a sequential accumulate in the kernel's fixed
    # l=0..L-1 order with the same add(mul(f, mul(scale, row))) structure —
    # lookup numerics are *impl-invariant* (the pallas path matches this
    # bit-for-bit in fp32), at the cost of ordered adds instead of one fused
    # reduce.  Differentiable (gather + scan -> scatter-add under AD), so
    # training uses this path too (fp32 storage; int8 stores are serving-only).
    safe_rows = jnp.where(owned, local_rows, 0)
    rows = jnp.take(local_storage, safe_rows, axis=0).astype(out_dtype)
    if scales is not None:
        rows = rows * scales[..., None].astype(out_dtype)      # (B, L, D)
    f = owned.astype(out_dtype)
    if weights is not None:
        f = f * weights.astype(out_dtype)

    def step(carry, xs):
        rows_l, f_l = xs
        return carry + f_l[:, None] * rows_l, None

    out, _ = jax.lax.scan(step, jnp.zeros((B, D), out_dtype),
                          (rows.transpose(1, 0, 2), f.T))
    return out


def masked_gather_rows(local_storage: jax.Array, local_rows: jax.Array,
                       owned: jax.Array) -> jax.Array:
    """Pond-mode per-shard step: ship the *raw rows* (zeros where not owned).

    The caller psums the (N, D) result across shards — this is the
    communicate-then-reduce baseline: N*D bytes cross the interconnect
    instead of num_bags*D.
    """
    safe_rows = jnp.where(owned, local_rows, 0)
    rows = jnp.take(local_storage, safe_rows, axis=0)
    return rows * owned.astype(rows.dtype)[:, None]


def bags_to_flat(indices: jax.Array, weights: Optional[jax.Array] = None):
    """(B, L) dense bags -> flat (N,), segment_ids (N,), weights (N,)."""
    B, L = indices.shape
    flat = indices.reshape(-1)
    seg = jnp.repeat(jnp.arange(B, dtype=jnp.int32), L)
    w = None if weights is None else weights.reshape(-1)
    return flat, seg, B, w
