"""Page-granular placement for sharded embedding tables (paper section IV-B1).

The logical embedding address space (all tables stacked) is divided into
fixed-size pages (default 4 KB worth of rows, like the OS pages the paper
manages).  Every page lives in exactly one location:

  * HOT tier  — replicated on every device ("Private Hot Region" / local DRAM
                in the paper; local-HBM replica in the TPU mapping), or
  * COLD tier — one shard of the row-sharded cold storage ("Public Cold
                Region" spread over CXL memory devices; `model`-axis shards
                in the TPU mapping).

The indirection (`page_to_shard`, `page_to_slot`) is the FM-endpoint memory
indexing unit of the paper: lookups go through it, so the planner can migrate
pages without callers noticing (lookup results are placement-invariant — this
is tested as a property).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

HOT_SHARD = -1  # sentinel in page_to_shard

STORAGE_FORMATS = ("fp32", "int8")  # cold-tier storage format knob


@dataclasses.dataclass(frozen=True)
class PagingConfig:
    total_rows: int            # stacked rows across all tables
    dim: int
    n_shards: int              # size of the `model` axis
    page_bytes: int = 4096
    itemsize: int = 4          # logical (hot-tier / fp32) bytes per element
    hot_fraction: float = 0.05  # fraction of pages the hot tier can hold
    headroom: float = 1.3      # cold-shard slot over-provisioning for imbalance
    storage: str = "fp32"      # cold-tier storage: fp32 passthrough or int8

    def __post_init__(self):
        if self.storage not in STORAGE_FORMATS:
            raise ValueError(f"unknown storage {self.storage!r}; "
                             f"expected one of {STORAGE_FORMATS}")

    @property
    def cold_itemsize(self) -> int:
        """*Stored* bytes per element in the cold tier — the bytes that
        actually cross the memory interface (the paper's CXL traffic)."""
        return 1 if self.storage == "int8" else self.itemsize

    @property
    def page_size(self) -> int:
        """Rows per page (>=1).  ``page_bytes`` means *stored* bytes, so an
        int8 cold tier packs ``itemsize/cold_itemsize``x the rows per page;
        hot pages hold the same rows at fp32 width (they are larger in
        DRAM — the hot tier is small by construction)."""
        return max(1, self.page_bytes // (self.dim * self.cold_itemsize))

    @property
    def num_pages(self) -> int:
        return -(-self.total_rows // self.page_size)

    @property
    def hot_pages(self) -> int:
        return max(1, int(self.num_pages * self.hot_fraction))

    @property
    def pages_per_shard(self) -> int:
        base = -(-self.num_pages // self.n_shards)
        return max(1, int(np.ceil(base * self.headroom)))

    @property
    def rows_per_shard(self) -> int:
        return self.pages_per_shard * self.page_size

    @property
    def padded_rows(self) -> int:
        return self.num_pages * self.page_size

    @property
    def cold_rows_total(self) -> int:
        return self.n_shards * self.rows_per_shard

    @property
    def hot_rows(self) -> int:
        return self.hot_pages * self.page_size


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PageTable:
    """Placement state: for each page, its tier/shard and slot."""
    page_to_shard: jax.Array   # (num_pages,) int32; HOT_SHARD => hot tier
    page_to_slot: jax.Array    # (num_pages,) int32; slot within shard or hot tier

    def tree_flatten(self):
        return (self.page_to_shard, self.page_to_slot), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def initial_page_table(cfg: PagingConfig) -> PageTable:
    """Paper's initial policy: interleave cold pages round-robin across shards
    (section IV-B3 "initially spread them ... through the interleave policy").
    Hot tier starts empty; the planner promotes pages after observing traffic.
    """
    pages = np.arange(cfg.num_pages)
    shard = (pages % cfg.n_shards).astype(np.int32)
    slot = (pages // cfg.n_shards).astype(np.int32)
    assert slot.max(initial=0) < cfg.pages_per_shard, "headroom too small"
    return PageTable(jnp.asarray(shard), jnp.asarray(slot))


def locate(cfg: PagingConfig, table: PageTable, row_idx: jax.Array
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """row id -> (shard, local_row, is_hot). Pure, vectorized, static-shape."""
    ps = cfg.page_size
    page = row_idx // ps
    offset = row_idx % ps
    shard = table.page_to_shard[page]
    local_row = table.page_to_slot[page] * ps + offset
    is_hot = shard == HOT_SHARD
    return shard, local_row, is_hot


def placement_gather_indices(cfg: PagingConfig, old: PageTable, new: PageTable
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """Row-level gather maps realizing a migration (host-side, numpy).

    Returns (cold_src, hot_src): for each destination row in the new cold
    storage (resp. new hot tier), the source position in the *concatenated*
    old storage [cold_flat | hot_flat].  Unmapped destination rows point at
    source 0 (their content is unused — no page maps to them).

    This is the cache-line-granular migration of section IV-B4: the copy is a
    pure gather, no page is ever "blocked"; in the latency simulator the
    page-block vs line-granular costs are modeled explicitly.
    """
    ps = cfg.page_size
    o_shard = np.asarray(old.page_to_shard)
    o_slot = np.asarray(old.page_to_slot)
    n_shard = np.asarray(new.page_to_shard)
    n_slot = np.asarray(new.page_to_slot)

    def src_base(shard, slot):
        # position of a page's first row in [cold_flat | hot_flat]
        cold = shard * cfg.rows_per_shard + slot * ps
        hot = cfg.cold_rows_total + slot * ps
        return np.where(shard == HOT_SHARD, hot, cold)

    src = src_base(o_shard, o_slot)                      # (P,)
    cold_src = np.zeros(cfg.cold_rows_total, dtype=np.int64)
    hot_src = np.zeros(cfg.hot_rows, dtype=np.int64)

    row_offsets = np.arange(ps)
    cold_mask = n_shard != HOT_SHARD
    cold_pages = np.nonzero(cold_mask)[0]
    dst = (n_shard[cold_pages] * cfg.rows_per_shard + n_slot[cold_pages] * ps)
    cold_src[(dst[:, None] + row_offsets).ravel()] = (
        src[cold_pages][:, None] + row_offsets).ravel()

    hot_pages = np.nonzero(~cold_mask)[0]
    dsth = n_slot[hot_pages] * ps
    hot_src[(dsth[:, None] + row_offsets).ravel()] = (
        src[hot_pages][:, None] + row_offsets).ravel()
    return cold_src, hot_src
