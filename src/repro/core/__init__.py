from repro.core.paging import (  # noqa: F401
    HOT_SHARD, PageTable, PagingConfig, initial_page_table, locate)
from repro.core.pifs import EngineState, PIFSEmbeddingEngine, engine_for_tables  # noqa: F401
from repro.core.planner import PlannerConfig, needs_migration, plan, shard_loads  # noqa: F401
from repro.core import hot_cache, sls  # noqa: F401
