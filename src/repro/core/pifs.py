"""PIFSEmbeddingEngine: the paper's contribution as a composable JAX module.

Distributed embedding lookup with three execution modes (paper baselines):

  * ``pifs``   — reduce-then-communicate: each `model`-axis shard runs a
                 partial SLS over the rows it owns (the fabric-switch Process
                 Core), and only pooled ``(bags, D)`` partials cross the ICI
                 (psum / psum_scatter).  Hot-tier hits are served from a
                 replicated local copy with zero communication.
  * ``pond``   — communicate-then-reduce: shards ship the *raw rows*
                 (``bags*L*D`` bytes) and the bag owner reduces — the
                 host-centric CXL baseline (Pond).  With a planner-populated
                 hot tier this is the paper's "Pond + PM".
  * ``beacon`` — reduce-then-communicate but with tiering disabled
                 (all-"CXL" placement): construct the engine with
                 ``hot_fraction=0`` and never run the planner.  Mode string
                 maps to the pifs code path; the placement is what differs.

State is a pure pytree; every method is functional.  Lookup results are
placement-invariant (property-tested): the planner may migrate pages at any
time without perturbing numerics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import quant
from repro.core import sls as sls_ops
from repro.core.paging import (HOT_SHARD, PageTable, PagingConfig,
                               initial_page_table, locate,
                               placement_gather_indices)
from repro.core.planner import PlannerConfig, plan
from repro.distributed.sharding import MeshAxes, axes_for, shard_map


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class EngineState:
    cold: jax.Array           # (n_shards * rows_per_shard, D) sharded over tp;
    #                           fp32, or int8 codes for storage='int8'
    hot: jax.Array            # (hot_rows, D) fp32 replicated (never quantized)
    page_scales: jax.Array    # (num_pages,) float32 replicated per-page dequant
    #                           scales (all-ones for fp32 storage).  Indexed by
    #                           *global* page id, so a scale travels with its
    #                           page across any migration untouched — that is
    #                           what makes cold->hot->cold round trips exact
    #                           (demotion re-quantizes with the carried scale
    #                           and recovers the codes bit-for-bit).
    page_to_shard: jax.Array  # (num_pages,) int32 replicated
    page_to_slot: jax.Array   # (num_pages,) int32 replicated
    counts: jax.Array         # (num_pages,) float32 replicated access histogram

    _FIELDS = ("cold", "hot", "page_scales", "page_to_shard", "page_to_slot",
               "counts")

    def tree_flatten_with_keys(self):
        # named keys (not positional indices) so checkpoint manifests keep
        # stable leaf names across state-layout changes
        return (tuple((jax.tree_util.GetAttrKey(f), getattr(self, f))
                      for f in self._FIELDS), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def page_table(self) -> PageTable:
        return PageTable(self.page_to_shard, self.page_to_slot)


class PIFSEmbeddingEngine:
    """Sharded multi-table embedding with paged placement + hot tier."""

    DEDUP_MODES = ("off", "auto", "on")
    FRONT_END_MODES = ("split", "fused")
    TIER_MODES = ("all", "hot_only")

    def __init__(self, paging: PagingConfig, mesh: Mesh,
                 axes: Optional[MeshAxes] = None,
                 planner: Optional[PlannerConfig] = None,
                 dtype=jnp.float32, dedup: str = "off",
                 dedup_auto_threshold: float = 1.5,
                 dedup_staging_bytes: int = 4 << 20,
                 validate_ids: bool = False):
        """``dedup`` is the engine-wide default for :meth:`lookup`'s
        gather-once duplicate-coalescing knob (off / auto / on);
        ``dedup_auto_threshold`` is the expected batch-level duplicate
        factor above which ``auto`` turns coalescing on for a plan, and
        ``dedup_staging_bytes`` bounds the per-device staging buffer — a
        signature whose worst-case staging exceeds it falls back to the
        non-dedup datapath (exact, just without the bytes win).
        ``validate_ids`` is the strict-mode debug knob: lookups check their
        (concrete, host-visible) indices against the padded address space
        and raise on out-of-range ids instead of letting the device gather
        clamp them silently — OOB traffic otherwise serves row 0 /
        last-row embeddings with no error at all."""
        self.cfg = paging
        self.mesh = mesh
        self.axes = axes or axes_for(mesh)
        self.planner = planner or PlannerConfig()
        self.dtype = dtype
        if dedup not in self.DEDUP_MODES:
            raise ValueError(f"unknown dedup {dedup!r}; "
                             f"expected one of {self.DEDUP_MODES}")
        self.default_dedup = dedup
        self.validate_ids = validate_ids
        self.dedup_auto_threshold = dedup_auto_threshold
        self.dedup_staging_bytes = dedup_staging_bytes
        # optional measured-duplicate-factor hint for 'auto' resolutions
        # that happen under an outer trace (serving warmup): the page
        # histogram cannot see row-level skew when hot rows are scattered
        # across pages (production id hashing does exactly that), so
        # serving primes this from a measured replay of the live stream's
        # prefix (repro.serving.prime_dedup_auto)
        self.dedup_auto_hint: Optional[float] = None
        # compiled-lookup plan registry: signature -> shard_map+jit closure,
        # built once per (mode, combine, dp_shard, impl, dedup, shapes) and
        # reused so steady-state serving never retraces (lru_cache-style, but
        # explicit so plan_stats() can report hits/traces).
        self._plans: dict = {}
        self._dedup_plans: dict = {}   # key -> resolution record (plan_stats)
        self._fe_plans: dict = {}      # key -> front-end resolution record
        self._migrate_plan = None
        self._trace_count = 0
        self._plan_calls = 0
        # host-side copy of the page-access histogram, refreshed by
        # observe()/plan_and_migrate(): dedup='auto' resolution may run
        # under an outer jit trace where state.counts is a tracer
        self._host_counts: Optional[np.ndarray] = None
        if self.axes.tp_size(mesh) != paging.n_shards:
            raise ValueError(
                f"paging.n_shards={paging.n_shards} != tp axis size "
                f"{self.axes.tp_size(mesh)}")

    @property
    def quantized(self) -> bool:
        return self.cfg.storage == "int8"

    @property
    def cold_dtype(self):
        """Cold-tier storage dtype (int8 codes for storage='int8')."""
        return jnp.int8 if self.quantized else self.dtype

    # ------------------------------------------------------------------ specs
    def state_pspecs(self) -> EngineState:
        tp = self.axes.tp
        return EngineState(
            cold=P(tp), hot=P(), page_scales=P(), page_to_shard=P(),
            page_to_slot=P(), counts=P())

    def state_shapes(self) -> EngineState:
        c = self.cfg
        return EngineState(
            cold=jax.ShapeDtypeStruct((c.cold_rows_total, c.dim),
                                      self.cold_dtype),
            hot=jax.ShapeDtypeStruct((c.hot_rows, c.dim), self.dtype),
            page_scales=jax.ShapeDtypeStruct((c.num_pages,), jnp.float32),
            page_to_shard=jax.ShapeDtypeStruct((c.num_pages,), jnp.int32),
            page_to_slot=jax.ShapeDtypeStruct((c.num_pages,), jnp.int32),
            counts=jax.ShapeDtypeStruct((c.num_pages,), jnp.float32),
        )

    def state_shardings(self) -> EngineState:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.state_pspecs(),
                            is_leaf=lambda x: isinstance(x, P))

    # ------------------------------------------------------------------- init
    def init_state(self, key: jax.Array, scale: float = 0.01) -> EngineState:
        """Random-init tables, initial round-robin interleave placement."""
        c = self.cfg
        table = initial_page_table(c)
        dense = jax.random.normal(key, (c.padded_rows, c.dim), self.dtype) * scale
        return self.from_dense(dense, table)

    def from_dense(self, dense: jax.Array, table: Optional[PageTable] = None
                   ) -> EngineState:
        """Pack a dense (rows, D) table into paged/sharded storage.

        With ``storage='int8'`` every page gets a symmetric per-page scale
        and cold pages are stored as int8 codes; hot pages keep their raw
        fp32 values (hot-hit numerics are untouched), but still carry a
        scale so a later demotion quantizes deterministically.  Note the
        default placement starts with an *empty* hot tier, so in the
        canonical lifecycle every hot page was once cold — its values sit
        on the quantized grid and all later migrations are bit-exact.
        """
        c = self.cfg
        if table is None:
            table = initial_page_table(c)
        if dense.shape[0] < c.padded_rows:
            pad = c.padded_rows - dense.shape[0]
            dense = jnp.concatenate(
                [dense, jnp.zeros((pad, c.dim), dense.dtype)], axis=0)
        ps = c.page_size
        shard = np.asarray(table.page_to_shard)
        slot = np.asarray(table.page_to_slot)
        # destination row for each source page
        cold_dst = shard.astype(np.int64) * c.rows_per_shard + slot.astype(np.int64) * ps
        hot_dst = slot.astype(np.int64) * ps
        row_off = np.arange(ps)
        cold_pages = np.nonzero(shard != HOT_SHARD)[0]
        hot_pages = np.nonzero(shard == HOT_SHARD)[0]

        if self.quantized:
            q_pages, scales = quant.quantize_pages(
                dense.reshape(c.num_pages, ps, c.dim))
            cold_vals = q_pages.reshape(c.num_pages * ps, c.dim)
        else:
            scales = jnp.ones((c.num_pages,), jnp.float32)
            cold_vals = dense
        cold = jnp.zeros((c.cold_rows_total, c.dim), self.cold_dtype)
        hot = jnp.zeros((c.hot_rows, c.dim), dense.dtype)
        if cold_pages.size:
            dst = (cold_dst[cold_pages, None] + row_off).ravel()
            src = (cold_pages[:, None] * ps + row_off).ravel()
            cold = cold.at[dst].set(cold_vals[src])
        if hot_pages.size:
            dst = (hot_dst[hot_pages, None] + row_off).ravel()
            src = (hot_pages[:, None] * ps + row_off).ravel()
            hot = hot.at[dst].set(dense[src])
        return EngineState(
            cold=cold, hot=hot, page_scales=scales,
            page_to_shard=jnp.asarray(shard, jnp.int32),
            page_to_slot=jnp.asarray(slot, jnp.int32),
            counts=jnp.zeros((c.num_pages,), jnp.float32))

    def to_dense(self, state: EngineState) -> jax.Array:
        """Inverse of from_dense (tests / checkpoints / planner-free export).

        For ``storage='int8'`` the cold tier is dequantized, so the result
        is the *effective* table every lookup path computes against.
        """
        c = self.cfg
        ps = c.page_size
        row = jnp.arange(c.padded_rows)
        shard, local_row, is_hot = locate(c, state.page_table, row)
        cold_pos = shard * c.rows_per_shard + local_row
        cold_rows = jnp.take(state.cold, jnp.where(is_hot, 0, cold_pos), axis=0)
        if self.quantized:
            cold_rows = quant.dequantize_rows(
                cold_rows, state.page_scales[row // ps][:, None])
        hot_rows = jnp.take(state.hot, jnp.where(is_hot, local_row, 0), axis=0)
        return jnp.where(is_hot[:, None], hot_rows, cold_rows)

    def export_state(self, state: EngineState
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Placement-invariant logical export in each tier's *native* domain.

        Returns ``(codes, values, scales)``: ``codes`` is (padded_rows, D)
        in the cold-tier storage dtype — cold-resident rows are their stored
        representation verbatim (int8 codes for ``storage='int8'``), hot-
        resident rows are their demoted form (re-quantized on the page's
        carried scale, exactly what :meth:`migrate` would write on a
        demotion); ``values`` is (padded_rows, D) fp32 — hot rows verbatim,
        cold rows dequantized with the carried scale (exactly what a
        promotion would write); ``scales`` is ``state.page_scales``
        untouched.  For fp32 storage ``codes`` and ``values`` are the same
        dense table.

        Together with :meth:`pack_state` this is the cross-engine analog of
        the typed migration gather: page geometry (``page_size`` /
        ``num_pages``) depends only on dim/page_bytes/storage — never on
        ``n_shards`` — so the triple round-trips bit-exactly through any
        placement on any tp size (the elastic re-mesh path,
        ``repro.runtime.elastic.remesh_engine``, is built on it)."""
        c = self.cfg
        ps = c.page_size
        row = jnp.arange(c.padded_rows)
        shard, local_row, is_hot = locate(c, state.page_table, row)
        cold_pos = shard * c.rows_per_shard + local_row
        cold_rows = jnp.take(state.cold, jnp.where(is_hot, 0, cold_pos),
                             axis=0)
        hot_rows = jnp.take(state.hot, jnp.where(is_hot, local_row, 0),
                            axis=0)
        if self.quantized:
            s = state.page_scales[row // ps][:, None]
            codes = jnp.where(is_hot[:, None],
                              quant.quantize_rows(hot_rows, s), cold_rows)
            values = jnp.where(is_hot[:, None], hot_rows,
                               quant.dequantize_rows(cold_rows, s))
        else:
            codes = values = jnp.where(is_hot[:, None], hot_rows, cold_rows)
        return codes, values, state.page_scales

    def pack_state(self, codes: jax.Array, values: jax.Array,
                   page_scales: jax.Array, table: Optional[PageTable] = None,
                   counts=None) -> EngineState:
        """Inverse of :meth:`export_state` under any placement on *this*
        engine's mesh: cold slots take their rows from ``codes`` (storage-
        native, moved verbatim — never re-quantized), hot slots from
        ``values`` (fp32, moved verbatim), and ``page_scales`` is carried
        untouched.  Packing therefore preserves the quantized domain
        exactly: a page that was cold there and lands cold here keeps its
        codes bit-for-bit, a hot->cold transition is the standard carried-
        scale demotion, and cold->hot the standard dequantize promotion —
        the same tier-boundary semantics as :meth:`migrate`."""
        c = self.cfg
        if table is None:
            table = initial_page_table(c)
        ps = c.page_size
        shard = np.asarray(table.page_to_shard)
        slot = np.asarray(table.page_to_slot)
        cold_dst = (shard.astype(np.int64) * c.rows_per_shard
                    + slot.astype(np.int64) * ps)
        hot_dst = slot.astype(np.int64) * ps
        row_off = np.arange(ps)
        cold_pages = np.nonzero(shard != HOT_SHARD)[0]
        hot_pages = np.nonzero(shard == HOT_SHARD)[0]
        cold = jnp.zeros((c.cold_rows_total, c.dim), self.cold_dtype)
        hot = jnp.zeros((c.hot_rows, c.dim), self.dtype)
        codes = jnp.asarray(codes)
        values = jnp.asarray(values)
        if cold_pages.size:
            dst = (cold_dst[cold_pages, None] + row_off).ravel()
            src = (cold_pages[:, None] * ps + row_off).ravel()
            cold = cold.at[dst].set(codes[src].astype(self.cold_dtype))
        if hot_pages.size:
            dst = (hot_dst[hot_pages, None] + row_off).ravel()
            src = (hot_pages[:, None] * ps + row_off).ravel()
            hot = hot.at[dst].set(values[src].astype(self.dtype))
        if counts is None:
            counts = jnp.zeros((c.num_pages,), jnp.float32)
        state = EngineState(
            cold=cold, hot=hot,
            page_scales=jnp.asarray(page_scales, jnp.float32),
            page_to_shard=jnp.asarray(shard, jnp.int32),
            page_to_slot=jnp.asarray(slot, jnp.int32),
            counts=jnp.asarray(counts, jnp.float32))
        # commit to this engine's placement: the inputs may live on a
        # different (larger/smaller) mesh — the elastic re-mesh path hands
        # us arrays computed under the pre-loss mesh's sharding
        return jax.device_put(state, self.state_shardings())

    # ----------------------------------------------------------------- lookup
    def _check_ids(self, indices) -> None:
        """Strict-mode OOB guard (``validate_ids=True``): raise host-side on
        ids outside the padded address space instead of letting the device
        gather clamp them to valid rows silently.  Only concrete arrays can
        be checked — under an outer jit trace the caller (e.g.
        ``ServeBinding.execute``) must validate the host batch *before*
        entering the trace, which is where serving wires this in."""
        if isinstance(indices, jax.core.Tracer):
            return
        idx = np.asarray(indices)
        bad = (idx < 0) | (idx >= self.cfg.padded_rows)
        if bad.any():
            n = int(bad.sum())
            example = int(idx[np.unravel_index(np.argmax(bad), idx.shape)])
            raise ValueError(
                f"validate_ids: {n} out-of-range id(s) in lookup batch "
                f"(e.g. {example}; valid range is [0, "
                f"{self.cfg.padded_rows})) — the device gather would have "
                "clamped these to real rows and served wrong embeddings "
                "silently")

    def lookup(self, state: EngineState, indices: jax.Array,
               weights: Optional[jax.Array] = None, mode: str = "pifs",
               combine: str = "psum", dp_shard: bool = True,
               impl: str = "jnp", block_l: int = 8,
               dedup: Optional[str] = None,
               tiers: str = "all") -> jax.Array:
        """Pooled lookup.

        indices: (B, G, L) int32 — B batch (sharded over dp), G bags per
        example (e.g. tables), L lookups per bag.  Returns (B, G, D) for
        combine='psum', or (B, G, D) sharded additionally over tp on the batch
        dim for combine='psum_scatter' (caller's consumer must accept that
        layout; it halves collective bytes).
        weights: optional (B, G, L).
        impl: 'jnp' (gather + segment-sum; differentiable) or 'pallas'
        (the bag-tiled masked-partial SLS kernel; serving fast path).
        dedup: 'off' | 'auto' | 'on' (None = the engine default) —
        gather-once duplicate coalescing: each shard sort-uniques its owned
        (nbags*L) rows and gathers/dequantizes every unique row exactly
        once; the accumulate order is unchanged, so results are bit-for-bit
        equal to 'off'.  'auto' decides per plan from the observe-phase
        access histogram (expected duplicate factor >= the engine
        threshold); 'on' still falls back for signatures whose staging
        exceeds the VMEM budget.  The decision is frozen into the cached
        plan (the key carries the *requested* knob), so 'auto' never
        retraces across observe/replan cycles.
        tiers: 'all' (normal) or 'hot_only' — the serving brown-out rung:
        only the replicated hot tier is read, cold rows contribute exact
        zeros, and **no cross-shard collective runs at all** (the degraded
        mode for a congested/faulted fabric link).  Scores change (cold
        contributions are zero-filled), so this is never resolved
        implicitly — callers opt in per plan.

        The shard_map+jit closure for each distinct
        (mode, combine, dp_shard, impl, dedup, tiers, idx/weights
        shape+dtype) signature is built once and cached — steady-state
        serving does zero retraces (see ``plan_stats``).
        """
        if mode not in ("pifs", "pond", "beacon"):
            raise ValueError(f"unknown mode {mode!r}")
        if combine not in ("psum", "psum_scatter"):
            raise ValueError(f"unknown combine {combine!r}")
        if impl not in ("jnp", "pallas"):
            raise ValueError(f"unknown impl {impl!r}")
        if dedup is None:
            dedup = self.default_dedup
        if dedup not in self.DEDUP_MODES:
            raise ValueError(f"unknown dedup {dedup!r}; "
                             f"expected one of {self.DEDUP_MODES}")
        if tiers not in self.TIER_MODES:
            raise ValueError(f"unknown tiers {tiers!r}; "
                             f"expected one of {self.TIER_MODES}")
        if self.validate_ids:
            self._check_ids(indices)
        key = ("lookup", mode, combine, dp_shard, impl,
               int(block_l) if impl == "pallas" else None,  # jnp ignores it
               self.cfg.storage, dedup, tiers,
               tuple(indices.shape), jnp.dtype(indices.dtype).name,
               None if weights is None
               else (tuple(weights.shape), jnp.dtype(weights.dtype).name))
        plan = self._plans.get(key)
        if plan is None:
            dedup_on = self._resolve_dedup(key, dedup, state, indices,
                                           dp_shard=dp_shard)
            plan = self._build_lookup_plan(
                mode=mode, combine=combine, dp_shard=dp_shard, impl=impl,
                block_l=block_l, has_weights=weights is not None,
                dedup=dedup_on, tiers=tiers)
            self._plans[key] = plan
        self._plan_calls += 1
        args = (state.cold, state.hot, state.page_scales,
                state.page_to_shard, state.page_to_slot, indices)
        if weights is not None:
            args = args + (weights,)
        return plan(*args)

    # --------------------------------------------------- fused front end
    def lookup_interact(self, state: EngineState, indices: jax.Array,
                        dense_feature: jax.Array,
                        weights: Optional[jax.Array] = None,
                        mode: str = "pifs", combine: str = "psum",
                        dp_shard: bool = True, impl: str = "jnp",
                        block_l: int = 8, block_b: int = 32,
                        dedup: Optional[str] = None,
                        front_end: str = "split") -> jax.Array:
        """Pooled lookup fused with the DLRM dot-interaction.

        indices: (B, G, L) as in :meth:`lookup`; dense_feature: (B, D) the
        bottom-MLP output, stacked as feature row 0.  Returns the (B, P)
        packed lower triangle of the (B, F, D) = (B, G+1, D) features'
        pairwise dots — the input of the DLRM top MLP (after concatenating
        the dense feature back on).

        front_end: 'split' materializes the pooled features and runs the
        interaction as a separate op (the seed pipeline); 'fused' keeps
        them in VMEM from the SLS accumulate through the interaction
        matmul (impl='pallas'; see ``kernels/sls.py``).  On the
        replicated/dp-sharded config (tp == 1, pifs/beacon) the knob
        resolves ``'fused'`` — the single three-phase kernel.  With
        tp-sharded cold partials (tp > 1), or in ``mode='pond'``, it
        resolves ``'fused_tp'``: each shard runs phases 1-2 on its owned
        rows (dedup staging stays per-shard), the small partial-pooled
        (B, F, D) cold tile is psum'd across shards instead of raw rows,
        and phase 3 resumes on the reduced tile — features stay
        VMEM-resident on both sides of the collective.  For pond this
        means the cold partials are pooled *before* the hot/cold add (the
        reduce-near-data datapath), so pond-fused matches the fixed
        l-order split composition bitwise, not pond-split's segment-sum
        order.  The resolution is recorded in
        ``plan_stats()['front_end']`` (the dedup resolution pattern).
        Bit-for-bit equal across {front_end, impl, storage, dedup} in
        fp32 for pifs/beacon on any mesh.

        ``combine`` only names the pooled-lookup collective for plan-cache
        symmetry with :meth:`lookup`: the interaction consumes every bag of
        a sample, so the split path always materializes the full psum
        (psum_scatter's bag-sharded layout cannot feed the interaction).
        """
        if mode not in ("pifs", "pond", "beacon"):
            raise ValueError(f"unknown mode {mode!r}")
        if combine not in ("psum", "psum_scatter"):
            raise ValueError(f"unknown combine {combine!r}")
        if impl not in ("jnp", "pallas"):
            raise ValueError(f"unknown impl {impl!r}")
        if front_end not in self.FRONT_END_MODES:
            raise ValueError(f"unknown front_end {front_end!r}; "
                             f"expected one of {self.FRONT_END_MODES}")
        if dedup is None:
            dedup = self.default_dedup
        if dedup not in self.DEDUP_MODES:
            raise ValueError(f"unknown dedup {dedup!r}; "
                             f"expected one of {self.DEDUP_MODES}")
        if self.validate_ids:
            self._check_ids(indices)
        if dense_feature.ndim != 2 or dense_feature.shape[-1] != self.cfg.dim:
            raise ValueError(
                f"dense_feature must be (B, {self.cfg.dim}); got "
                f"{dense_feature.shape}")
        key = ("interact", mode, combine, dp_shard, impl,
               (int(block_l), int(block_b)) if impl == "pallas" else None,
               self.cfg.storage, dedup, front_end,
               tuple(indices.shape), jnp.dtype(indices.dtype).name,
               None if weights is None
               else (tuple(weights.shape), jnp.dtype(weights.dtype).name))
        plan = self._plans.get(key)
        if plan is None:
            fe = self._resolve_front_end(key, front_end, mode)
            dedup_on = self._resolve_dedup(
                key, dedup, state, indices, dp_shard=dp_shard,
                fused_blocks=int(block_b) if fe != "split" else None)
            plan = self._build_interact_plan(
                mode=mode, dp_shard=dp_shard, impl=impl, block_l=block_l,
                block_b=block_b, has_weights=weights is not None,
                dedup=dedup_on, front_end_resolved=fe)
            self._plans[key] = plan
        self._plan_calls += 1
        args = (state.cold, state.hot, state.page_scales,
                state.page_to_shard, state.page_to_slot, indices,
                dense_feature)
        if weights is not None:
            args = args + (weights,)
        return plan(*args)

    def _resolve_front_end(self, key, front_end: str, mode: str) -> str:
        """Freeze the front-end fusion decision for one interact plan.

        Host-side, once per signature at plan build (the dedup pattern).
        Returns the resolved datapath, one of

          * ``'split'`` — requested split: pooled features materialize and
            the interaction runs as a separate op,
          * ``'fused'`` — the replicated/dp-sharded config (tp == 1,
            pifs/beacon): the single three-phase kernel,
          * ``'fused_tp'`` — tp-sharded cold partials (tp > 1) or pond:
            the partial-pool kernel emits per-tier (B, F, D) feature
            tiles, the cold tile is psum'd across tp shards (the pooled
            tile crosses the fabric, never raw rows), and the resume
            kernel runs phase 3 on the reduced tile.  Pond requesting
            fusion opts into pooling its cold partials before the
            hot/cold add — the reduce-near-data datapath.

        The resolution (requested/resolved/reason/tp) is recorded for
        ``plan_stats()['front_end']`` so benches can assert the datapath
        they are timing."""
        tp = self.axes.tp_size(self.mesh)
        if front_end == "split":
            resolved, reason = "split", "requested"
        elif tp > 1:
            resolved, reason = "fused_tp", (
                f"tp-sharded masked partials (tp={tp}): each shard pools "
                "its partial (B, F, D) cold tile; the cross-shard psum "
                "lands between the partial-pool and resume kernels")
        elif mode == "pond":
            resolved, reason = "fused_tp", (
                "pond requesting fusion pools cold partials before the "
                "hot/cold add (partial-pool -> psum -> resume) instead of "
                "shipping raw rows")
        else:
            resolved, reason = "fused", "replicated/dp-sharded config"
        self._fe_plans[key] = {
            "requested": front_end,
            "resolved": resolved,
            "reason": reason,
            "tp": tp,
        }
        return resolved

    def _build_interact_plan(self, *, mode: str, dp_shard: bool, impl: str,
                             block_l: int, block_b: int, has_weights: bool,
                             dedup: bool, front_end_resolved: str):
        """Build the shard_map + jit closure for one interact signature."""
        axes, mesh = self.axes, self.mesh
        dp, tp = axes.dp, axes.tp
        if not dp_shard:
            dp = ()
        idx_spec = P(dp or None, None, None)
        x_spec = P(dp or None, None)
        out_spec = P(dp or None, None)
        w_specs = (idx_spec,) if has_weights else ()

        def block(cold, hot, scales, p2s, p2slot, idx, x, *w):
            wloc = w[0] if w else None
            if front_end_resolved == "fused":
                return self._interact_block_fused(
                    cold, hot, scales, p2s, p2slot, idx, x, wloc,
                    impl=impl, block_l=block_l, block_b=block_b,
                    dedup=dedup)
            if front_end_resolved == "fused_tp":
                return self._interact_block_fused_tp(
                    cold, hot, scales, p2s, p2slot, idx, x, wloc,
                    impl=impl, block_l=block_l, block_b=block_b,
                    dedup=dedup)
            pooled = self._lookup_block(cold, hot, scales, p2s, p2slot,
                                        idx, wloc, mode=mode,
                                        combine="psum", impl=impl,
                                        block_l=block_l, dedup=dedup)
            feats = jnp.concatenate([x[:, None, :], pooled], axis=1)
            from repro.kernels import ops as kernel_ops
            return kernel_ops.dot_interaction(feats, impl=impl,
                                              block_b=block_b)

        f = shard_map(
            block, mesh=mesh,
            in_specs=(P(tp), P(), P(), P(), P(), idx_spec, x_spec) + w_specs,
            out_specs=out_spec, check_vma=False)

        def traced(*args):
            self._trace_count += 1
            return f(*args)

        return jax.jit(traced)

    def _interact_block_fused(self, cold, hot, scales, p2s, p2slot, idx, x,
                              weights, *, impl: str, block_l: int,
                              block_b: int, dedup: bool):
        """Per-device fused front-end block (tp == 1 by resolution): locate
        each entry's tier + local row, then run the single-kernel SLS ->
        interaction datapath.  Mirrors :meth:`_lookup_block`'s address math
        exactly, so the masks/rows/scales the fused kernel sees are the
        ones the split accumulates would have seen."""
        c, axes = self.cfg, self.axes
        ps = c.page_size
        page = idx // ps
        offset = idx % ps
        shard = p2s[page]
        local_row = p2slot[page] * ps + offset                 # (b, G, L)
        owned = shard == jax.lax.axis_index(axes.tp)
        is_hot = shard == HOT_SHARD
        scale = scales[page] if self.quantized else None
        return sls_ops.fused_front_end_dense(
            cold, hot, x, local_row, owned, is_hot, weights=weights,
            scales=scale, impl=impl, block_l=block_l, block_b=block_b,
            dedup=dedup, out_dtype=jnp.float32)

    def _interact_block_fused_tp(self, cold, hot, scales, p2s, p2slot, idx,
                                 x, weights, *, impl: str, block_l: int,
                                 block_b: int, dedup: bool):
        """Per-device tp-aware fused front-end block: phases 1-2 pool this
        shard's owned rows into the per-tier (b, F, D) partial feature
        tiles, the small *cold* tile is psum'd across tp shards (hot is
        replicated and x must be counted once, so only cold crosses the
        fabric — the reduce-then-communicate datapath the paper argues
        for), and phase 3 resumes on the reduced tile.  Each shard
        accumulates in the same fixed l-order as the split partials and
        the psum's per-element operand order is deterministic per mesh,
        so the composition equals ``psum(cold_part) + hot_out`` -> concat
        -> interaction bit-for-bit in fp32."""
        c, axes = self.cfg, self.axes
        ps = c.page_size
        page = idx // ps
        offset = idx % ps
        shard = p2s[page]
        local_row = p2slot[page] * ps + offset                 # (b, G, L)
        owned = shard == jax.lax.axis_index(axes.tp)
        is_hot = shard == HOT_SHARD
        scale = scales[page] if self.quantized else None
        part_c, part_h = sls_ops.fused_partial_pool_dense(
            cold, hot, x, local_row, owned, is_hot, weights=weights,
            scales=scale, impl=impl, block_l=block_l, block_b=block_b,
            dedup=dedup, out_dtype=jnp.float32)
        reduced = jax.lax.psum(part_c, axes.tp)
        return sls_ops.fused_resume_dense(reduced, part_h, impl=impl,
                                          block_b=block_b)

    # ------------------------------------------------- compiled-lookup plans
    def _resolve_dedup(self, key, dedup: str, state: EngineState,
                       indices: jax.Array, dp_shard: bool = True,
                       fused_blocks: Optional[int] = None) -> bool:
        """Freeze the gather-once coalescing decision for one plan.

        Host-side, runs once per signature at plan build.  'on' only falls
        back when the worst-case *per-device* staging buffer — the dedup
        runs inside shard_map, so with ``dp_shard`` each device stages its
        ``(B/dp)*G*L`` local entries, not the full batch — exceeds the
        VMEM budget; 'auto' additionally requires the expected per-device
        duplicate factor — computed from the observe-phase page histogram
        (paper's profiler), or the engine's host copy of it when called
        under an outer trace — to clear ``dedup_auto_threshold``.  A plan
        built before the profiler has ever run (all-zero histogram) sees a
        uniform prior and resolves 'auto' off; serving primes the
        histogram before its post-warmup rebuild for exactly this reason
        (``repro.serving.prime_dedup_auto``).  The resolution record
        (requested/resolved/expected/measured factor) is reported by
        ``plan_stats()``.
        """
        if dedup == "off":
            return False
        B, G, L = indices.shape
        dp = self.axes.dp_size(self.mesh) if dp_shard else 1
        n_entries = max(B // max(dp, 1), 1) * G * L    # per-device entries
        if fused_blocks is None:
            # split-path dedup: the hot and cold accumulates are separate
            # kernel invocations, so one (n_entries, D) fp32 row staging is
            # live at a time
            staging_bytes = n_entries * self.cfg.dim * 4
        else:
            # fused-front-end dedup: one kernel holds BOTH tiers' row
            # stagings plus the two (BB*F, D) per-tier feature accumulators
            # in VMEM simultaneously (kernels/sls.py
            # fused_front_end_dedup_pallas scratch list)
            b_local = max(B // max(dp, 1), 1)
            BB = max(1, min(fused_blocks, b_local))
            while b_local % BB:
                BB //= 2
            staging_bytes = (2 * n_entries * self.cfg.dim * 4
                             + 2 * BB * (G + 1) * self.cfg.dim * 4)
        capacity_ok = staging_bytes <= self.dedup_staging_bytes
        counts = state.counts
        if isinstance(counts, jax.core.Tracer):
            counts = self._host_counts
        expected = (None if counts is None
                    else self._expected_dup_factor(np.asarray(counts),
                                                   n_entries))
        measured = None
        if not any(isinstance(x, jax.core.Tracer)
                   for x in (indices, state.page_to_shard, state.page_to_slot)):
            measured = self.dedup_factor(state, indices)["factor"]
        if dedup == "on":
            resolved = capacity_ok
        else:   # auto: best available duplicate-factor evidence vs threshold.
            # The analytic page-histogram expectation is blind to row-level
            # skew scattered across pages, so a measured replay (the plan-
            # building batch when concrete, or the serving prime hint when
            # building under a trace) can overrule it upward.
            signals = [x for x in (expected, measured, self.dedup_auto_hint)
                       if x is not None]
            resolved = (capacity_ok and bool(signals)
                        and max(signals) >= self.dedup_auto_threshold)
        self._dedup_plans[key] = {
            "requested": dedup, "resolved": bool(resolved),
            "capacity_ok": bool(capacity_ok),
            "expected_factor": None if expected is None else float(expected),
            "measured_factor": measured,
            "hint_factor": self.dedup_auto_hint,
        }
        return bool(resolved)

    def _expected_dup_factor(self, counts: np.ndarray, n_entries: int
                             ) -> float:
        """Analytic expected duplicate factor for ``n_entries`` draws from
        the row distribution implied by the page-access histogram (uniform
        within a page): ``n / E[unique]`` with
        ``E[unique] = sum_r 1 - (1 - p_r)^n``.  Callers pass the
        *per-device* entry count (the dedup scope) — the per-shard factor
        the kernel realizes tracks it (EXPERIMENTS.md §Duplicate-access
        coalescing compares the two).  An all-zero histogram (profiler
        never ran) means a uniform prior over all rows — essentially
        duplicate-free at realistic vocab sizes."""
        c = np.asarray(counts, np.float64)
        ps = self.cfg.page_size
        tot = c.sum()
        if tot <= 0:
            p = np.full(1, 1.0 / max(self.cfg.padded_rows, 1))
            rows_per_p = np.full(1, float(self.cfg.padded_rows))
        else:
            p = c / (tot * ps)
            rows_per_p = np.full_like(c, float(ps))
        e_unique = float((rows_per_p * -np.expm1(
            n_entries * np.log1p(-np.minimum(p, 1 - 1e-12)))).sum())
        return n_entries / max(e_unique, 1.0)

    def dedup_factor(self, state: EngineState, indices,
                     weights=None) -> dict:
        """Measured (realized) duplicate-access factor of one batch.

        Host-side replay of exactly what the dedup'd datapath gathers:
        per (dp-group, shard) unique owned local rows in the cold tier,
        plus per dp-group unique hot-tier rows.  Returns entries (counting
        weight!=0 only, so serving pad entries don't skew it), unique_cold /
        unique_hot / unique_rows, and ``factor = entries / unique_rows`` —
        the bytes-moved reduction the coalescing buys on this batch.
        """
        c = self.cfg
        idx = np.asarray(indices)
        B = idx.shape[0]
        dp = min(max(1, self.axes.dp_size(self.mesh)), max(B, 1))
        mask = np.ones(idx.shape, bool)
        if weights is not None:
            mask = np.asarray(weights) != 0
        p2s = np.asarray(state.page_to_shard)
        p2slot = np.asarray(state.page_to_slot)
        ps = c.page_size
        entries = 0
        unique_cold = 0
        unique_hot = 0
        # array_split folds a non-divisible remainder into the groups
        # instead of silently dropping trailing rows from the ledger
        splits = np.array_split(np.arange(B), dp)
        for rows in splits:
            gi = idx[rows].reshape(-1)
            gm = mask[rows].reshape(-1)
            gi = gi[gm]
            entries += gi.size
            # mirror the device datapath's clamp semantics: XLA gathers
            # clip out-of-range ids, so the host replay must too (the probe
            # must never crash on traffic the engine itself would serve)
            page = np.clip(gi // ps, 0, c.num_pages - 1)
            shard = p2s[page]
            local = p2slot[page] * ps + gi % ps
            for s in range(c.n_shards):
                unique_cold += int(np.unique(local[shard == s]).size)
            unique_hot += int(np.unique(local[shard == HOT_SHARD]).size)
        unique_rows = unique_cold + unique_hot
        return {"entries": int(entries), "unique_cold": unique_cold,
                "unique_hot": unique_hot, "unique_rows": unique_rows,
                "factor": entries / max(unique_rows, 1)}

    def _build_lookup_plan(self, *, mode: str, combine: str, dp_shard: bool,
                           impl: str, block_l: int, has_weights: bool,
                           dedup: bool = False, tiers: str = "all"):
        """Build the shard_map + jit closure for one lookup signature."""
        axes, mesh = self.axes, self.mesh
        dp, tp = axes.dp, axes.tp
        if not dp_shard:
            dp = ()
        idx_spec = P(dp and dp or None, None, None) if dp else P(None, None, None)
        w_specs = (idx_spec,) if has_weights else ()
        if combine == "psum":
            out_spec = idx_spec
        else:
            out_spec = P((dp + (tp,)) if dp else tp, None, None)

        def block(cold, hot, scales, p2s, p2slot, idx, *w):
            wloc = w[0] if w else None
            return self._lookup_block(cold, hot, scales, p2s, p2slot, idx,
                                      wloc, mode=mode, combine=combine,
                                      impl=impl, block_l=block_l,
                                      dedup=dedup, tiers=tiers)

        f = shard_map(
            block, mesh=mesh,
            in_specs=(P(tp), P(), P(), P(), P(), idx_spec) + w_specs,
            out_specs=out_spec, check_vma=False)

        def traced(*args):
            # python side effect fires once per jit trace — the probe behind
            # plan_stats()['traces'] and the retrace tests/bench counters
            self._trace_count += 1
            return f(*args)

        return jax.jit(traced)

    def plan_stats(self) -> dict:
        """Compiled-plan cache stats: plans built, jit traces, lookup calls.

        When any plan was built with the gather-once coalescing knob
        requested (``dedup`` in {'auto', 'on'}), the dict additionally
        carries a ``"dedup"`` entry: one record per such plan with the
        requested knob, the frozen resolution (on/off after the capacity
        and — for 'auto' — histogram-threshold checks), the analytic
        ``expected_factor`` at decision time, and the ``measured_factor``
        realized on the plan-building batch (None when the plan was built
        under an outer trace).  The key is omitted entirely while no
        dedup-requesting plan exists, so ``dedup='off'`` callers see the
        exact legacy shape."""
        out = {"plans": len(self._plans), "traces": self._trace_count,
               "calls": self._plan_calls}
        if self._dedup_plans:
            out["dedup"] = {self._dedup_key_label(k): dict(v)
                            for k, v in self._dedup_plans.items()}
        if self._fe_plans:
            out["front_end"] = {self._dedup_key_label(k): dict(v)
                                for k, v in self._fe_plans.items()}
        return out

    @staticmethod
    def _dedup_key_label(key) -> str:
        """Compact human-readable label for a lookup- or interact-plan
        cache key — includes every key field that can distinguish two
        plans, so no two records ever collide in the
        ``plan_stats()['dedup']`` / ``['front_end']`` dicts."""
        if key[0] == "interact":
            (_, mode, combine, dp_shard, impl, blocks, storage, dedup,
             front_end, shape, _idx_dtype, weights_info) = key
            blk = ("" if blocks is None
                   else f"/bl{blocks[0]}bb{blocks[1]}")
            head, fe, tiers = "interact:", f"/fe={front_end}", "all"
        else:
            (_, mode, combine, dp_shard, impl, block_l, storage, dedup,
             tiers, shape, _idx_dtype, weights_info) = key
            blk = f"/bl{block_l}" if block_l is not None else ""
            head, fe = "", ""
        return (f"{head}{mode}/{combine}/{impl}" + blk
                + ("" if dp_shard else "/nodp")
                + f"/{storage}/dedup={dedup}" + fe
                + ("" if tiers == "all" else f"/{tiers}")
                + f"/idx={'x'.join(map(str, shape))}"
                + ("+w" if weights_info is not None else ""))

    def reset_plan_stats(self, clear_plans: bool = False) -> None:
        """Zero the trace/call counters; keeps compiled plans warm unless
        ``clear_plans`` (clearing forces a retrace of every signature —
        and also drops the per-plan dedup resolution records, which are
        re-frozen when the signatures rebuild)."""
        if clear_plans:
            self._plans.clear()
            self._dedup_plans.clear()
            self._fe_plans.clear()
        self._trace_count = 0
        self._plan_calls = 0

    def _lookup_block(self, cold, hot, scales, p2s, p2slot, idx, weights, *,
                      mode: str, combine: str, impl: str = "jnp",
                      block_l: int = 8, dedup: bool = False,
                      tiers: str = "all"):
        """Per-device block: the fabric-switch Process Core."""
        c, axes = self.cfg, self.axes
        tp = axes.tp
        b, G, L = idx.shape
        nbags = b * G
        bags = idx.reshape(nbags, L)
        wbags = None if weights is None else weights.reshape(nbags, L)

        ps = c.page_size
        page = bags // ps
        offset = bags % ps
        shard = p2s[page]
        local_row = p2slot[page] * ps + offset                  # (nbags, L)
        my = jax.lax.axis_index(tp)
        owned = shard == my
        is_hot = shard == HOT_SHARD
        # per-entry dequant scales (page-aligned addressing: the scale of an
        # entry is its *global page's* scale) — an O(bags*L) scalar gather;
        # the (rows, D)-sized fp32 cold table is never materialized
        scale_be = scales[page] if self.quantized else None     # (nbags, L)

        # ---- hot tier: replicated, zero-communication ----
        # dedup applies here too: hot hits are local-HBM reads, and under
        # zipfian traffic the hot tier is where duplicates concentrate
        hot_out = sls_ops.masked_partial_sls_dense(
            hot, local_row, is_hot, wbags, impl=impl,
            block_l=block_l, dedup=dedup)                       # (nbags, D)

        if tiers == "hot_only":
            # brown-out rung: serve the replicated hot tier only — cold
            # entries are masked to exact zeros by ``is_hot`` above and the
            # faulted/congested cross-shard path is never touched (zero
            # collectives).  Scores change (cold contributions zero-fill),
            # which is why this datapath is an explicit opt-in per plan.
            if combine == "psum":
                return hot_out.reshape(b, G, -1)
            tp_size = axes.tp_size(self.mesh)
            if nbags % tp_size:
                raise ValueError(f"bags ({nbags}) must divide tp ({tp_size}) "
                                 "for psum_scatter combine")
            out = jax.lax.dynamic_slice_in_dim(
                hot_out, my * (nbags // tp_size), nbags // tp_size, 0)
            return out.reshape(b // tp_size, G, -1)

        # ---- cold tier ----
        if mode == "pond":
            # raw rows cross the interconnect (communicate-then-reduce):
            # there is no pooling near the data in this baseline, so the
            # kernel only serves the hot tier here.  Coalescing does not
            # apply either — the baseline's semantics ship one row per
            # pooling entry, so only the hot tier above dedups in pond mode.
            seg = jnp.repeat(jnp.arange(nbags, dtype=jnp.int32), L)
            rows = sls_ops.masked_gather_rows(
                cold, local_row.reshape(-1), owned.reshape(-1))
            if self.quantized:
                # dequant after the (int8) gather, before rows hit the wire:
                # pond still ships fp32 rows (the baseline's semantics), the
                # *memory* interface moved 1-byte elements
                rows = quant.dequantize_rows(
                    rows, scale_be.reshape(-1)[:, None])
            if wbags is not None:
                rows = rows * wbags.reshape(-1)[:, None].astype(rows.dtype)
            rows = jax.lax.psum(rows, tp)                        # (b*G*L, D)!
            cold_out = jax.ops.segment_sum(rows, seg, num_segments=nbags)
            out = cold_out + hot_out
            if combine == "psum_scatter":
                tp_size = axes.tp_size(self.mesh)
                if b % tp_size:
                    raise ValueError(
                        f"per-device batch ({b}) must divide tp ({tp_size}) "
                        "for psum_scatter combine in pond mode")
                out = jax.lax.dynamic_slice_in_dim(
                    out.reshape(b, G, -1), my * (b // tp_size), b // tp_size, 0)
                return out
            return out.reshape(b, G, -1)

        # pifs / beacon: partial SLS near the data, pooled partials only
        cold_part = sls_ops.masked_partial_sls_dense(
            cold, local_row, owned, wbags, impl=impl,
            block_l=block_l, scales=scale_be,
            out_dtype=jnp.float32 if self.quantized else None,
            dedup=dedup)                                         # (nbags, D)
        if combine == "psum":
            cold_sum = jax.lax.psum(cold_part, tp)
            return (cold_sum + hot_out).reshape(b, G, -1)
        # psum_scatter over the bag axis: each tp shard keeps its bag slice
        tp_size = axes.tp_size(self.mesh)
        if nbags % tp_size:
            raise ValueError(f"bags ({nbags}) must divide tp ({tp_size}) "
                             "for psum_scatter combine")
        cold_sc = jax.lax.psum_scatter(cold_part, tp, scatter_dimension=0,
                                       tiled=True)               # (nbags/tp, D)
        hot_slice = jax.lax.dynamic_slice_in_dim(
            hot_out, my * (nbags // tp_size), nbags // tp_size, 0)
        out = cold_sc + hot_slice
        return out.reshape(b // tp_size, G, -1)

    # ---------------------------------------------------------------- observe
    def observe(self, state: EngineState, indices: jax.Array,
                weights: Optional[jax.Array] = None) -> EngineState:
        """Update the replicated page-access histogram (paper's profiler).

        Optional ``weights`` (same shape as ``indices``) gate what counts:
        an entry contributes 1 iff its weight is non-zero.  The serving
        batcher passes its SLS pad weights here so bucket padding (weight-0
        entries, replicated pad rows) never skews the hotness ranking."""
        c, axes = self.cfg, self.axes
        dp = axes.dp
        key = ("observe", tuple(indices.shape),
               jnp.dtype(indices.dtype).name, weights is not None)
        f = self._plans.get(key)
        if f is None:
            idx_spec = P(dp, None, None) if dp else P(None, None, None)
            w_specs = (idx_spec,) if weights is not None else ()

            def block(counts, idx, *w):
                page = idx.reshape(-1) // c.page_size
                inc = (jnp.where(w[0].reshape(-1) != 0, 1.0, 0.0) if w
                       else 1.0)
                local = jnp.zeros_like(counts).at[page].add(inc)
                if dp:
                    local = jax.lax.psum(local, dp)
                return counts + local

            f = jax.jit(shard_map(block, mesh=self.mesh,
                                  in_specs=(P(), idx_spec) + w_specs,
                                  out_specs=P(), check_vma=False))
            self._plans[key] = f
        args = (state.counts, indices)
        if weights is not None:
            args = args + (weights,)
        new_counts = f(*args)
        if not isinstance(new_counts, jax.core.Tracer):
            # host copy for dedup='auto' plan resolution under outer traces
            self._host_counts = np.asarray(new_counts)
        return dataclasses.replace(state, counts=new_counts)

    # ------------------------------------------------------- plan + migration
    def plan_and_migrate(self, state: EngineState) -> Tuple[EngineState, dict]:
        """Host-side plan (hotness + spreading), then pure-gather migration."""
        counts = np.asarray(jax.device_get(state.counts))
        self._host_counts = counts
        new_table, stats = plan(self.cfg, state.page_table, counts, self.planner)
        new_state = self.migrate(state, new_table)
        return new_state, stats

    def migrate(self, state: EngineState, new_table: PageTable,
                count_decay: float = 0.5) -> EngineState:
        """Execute a placement change: cache-line-granular gather (IV-B4).

        ``storage='int8'`` uses a typed gather: cold->cold moves int8 codes
        verbatim (scales are global per-page metadata and never move),
        cold->hot promotion dequantizes the page into the fp32 hot tier,
        and hot->cold demotion re-quantizes with the page's *carried* scale
        — which recovers the original codes bit-for-bit when the hot values
        came from an earlier promotion, so lookups are placement-invariant
        exactly in the quantized domain (property-tested).

        ``count_decay`` scales the access histogram after the move (the
        planner's EWMA).  Maintenance migrations that are not replans —
        the update subsystem's requant-demotions — pass 1.0 so demoting a
        drifted page never perturbs the hotness ranking the next real
        replan sees.
        """
        c = self.cfg
        cold_src, hot_src = placement_gather_indices(
            c, state.page_table, new_table)

        if self.quantized:
            new_cold, new_hot = self._migrate_quantized(
                state, new_table, cold_src, hot_src)
        else:
            # the gather plan is shape-stable across migrations — build once
            # so the periodic replans of a live serving loop never recompile.
            # The gather runs inside shard_map with an *explicit* all-gather
            # of the cold shards: arbitrary cross-shard page moves need the
            # full source table, and letting GSPMD infer the collective is
            # unsound here — it compiles per input sharding, and the
            # second migration (whose inputs arrive tp-sharded from the
            # first) silently corrupted the store.
            if self._migrate_plan is None:
                tp = self.axes.tp

                def block(cold, hot, cs, hs):
                    full = jax.lax.all_gather(cold, tp, axis=0, tiled=True)
                    comb = jnp.concatenate([full, hot], axis=0)
                    return (jnp.take(comb, cs, axis=0),
                            jnp.take(comb, hs, axis=0))

                self._migrate_plan = jax.jit(shard_map(
                    block, mesh=self.mesh,
                    in_specs=(P(tp), P(), P(tp), P()),
                    out_specs=(P(tp), P()), check_vma=False))

            new_cold, new_hot = self._migrate_plan(
                state.cold, state.hot,
                jnp.asarray(cold_src.astype(np.int32)),
                jnp.asarray(hot_src.astype(np.int32)))
        return EngineState(
            cold=new_cold, hot=new_hot, page_scales=state.page_scales,
            page_to_shard=jnp.asarray(np.asarray(new_table.page_to_shard), jnp.int32),
            page_to_slot=jnp.asarray(np.asarray(new_table.page_to_slot), jnp.int32),
            counts=state.counts * count_decay)  # decay after replan (EWMA)

    def _migrate_quantized(self, state: EngineState, new_table: PageTable,
                           cold_src: np.ndarray, hot_src: np.ndarray):
        """Typed migration for the int8 cold tier (same gather structure as
        the fp32 path, but the hot tier is bridged through quantize/dequant
        at the tier boundary instead of a mixed-dtype concat)."""
        c = self.cfg
        ps, C = c.page_size, c.cold_rows_total
        old = state.page_table
        pages = np.arange(c.num_pages, dtype=np.int64)

        def hot_slot_pages(table: PageTable) -> np.ndarray:
            """Per hot *row*: the global page occupying that hot slot (0 for
            empty slots — their content is unused)."""
            shard = np.asarray(table.page_to_shard)
            slot = np.asarray(table.page_to_slot)
            per_slot = np.zeros(c.hot_pages, dtype=np.int64)
            m = shard == HOT_SHARD
            per_slot[slot[m]] = pages[m]
            return np.repeat(per_slot, ps)                      # (hot_rows,)

        from_hot = hot_src >= C
        args = (jnp.asarray(cold_src.astype(np.int32)),
                jnp.asarray(np.where(from_hot, 0, hot_src).astype(np.int32)),
                jnp.asarray(np.where(from_hot, hot_src - C, 0).astype(np.int32)),
                jnp.asarray(from_hot),
                jnp.asarray(hot_slot_pages(old).astype(np.int32)),
                jnp.asarray(hot_slot_pages(new_table).astype(np.int32)))

        if self._migrate_plan is None:
            tp = self.axes.tp

            def block(cold, hot, scales, cs, hs_cold, hs_hot, hs_from_hot,
                      old_hot_page, new_hot_page):
                # explicit all-gather (see the fp32 path for why GSPMD must
                # not infer this); int8 codes make it 1/4 the fp32 bytes
                full = jax.lax.all_gather(cold, tp, axis=0, tiled=True)
                # demotions: re-quantize the (small) hot tier with each
                # row's carried page scale; rows whose page stays hot are
                # computed-but-unused (static shapes beat a data-dependent
                # gather).  A previously promoted page holds exactly
                # q * scale, so round(q * scale / scale) == q: lossless.
                hot_q = quant.quantize_rows(hot, scales[old_hot_page][:, None])
                new_cold = jnp.take(jnp.concatenate([full, hot_q], axis=0),
                                    cs, axis=0)
                # promotions: dequantize cold codes into the fp32 hot tier
                promoted = quant.dequantize_rows(
                    jnp.take(full, hs_cold, axis=0),
                    scales[new_hot_page][:, None])
                stayed = jnp.take(hot, hs_hot, axis=0)
                new_hot = jnp.where(hs_from_hot[:, None], stayed, promoted)
                return new_cold, new_hot

            self._migrate_plan = jax.jit(shard_map(
                block, mesh=self.mesh,
                in_specs=(P(tp), P(), P(), P(tp), P(), P(), P(), P(), P()),
                out_specs=(P(tp), P()), check_vma=False))

        return self._migrate_plan(state.cold, state.hot, state.page_scales,
                                  *args)

    # ------------------------------------------------------ streaming updates
    def apply_deltas(self, state: EngineState, rows: jax.Array,
                     deltas: jax.Array) -> EngineState:
        """Apply a batch of per-row additive deltas to the live tables.

        ``rows``: (U,) int32 global row ids, ``repro.core.updates.PAD_ROW``
        (= -1) for pad entries; rows must be *unique* (callers coalesce
        duplicates host-side — scatter-add ordering over duplicate targets
        is unspecified, and WAL replay must be bit-identical).  ``deltas``:
        (U, D) float32.

        Tier semantics: a row resident in the replicated hot tier gets an
        exact fp32 add; an fp32 cold row likewise; an int8 cold row is
        updated *in the quantized domain* — dequantize with the page's
        carried scale, add, re-quantize with the same scale — so the code
        stays on the page's grid and a later migration still moves it
        verbatim.  (Hot rows updated in fp32 drift off their page's grid;
        that drift is what the requant-demote scheduler tracks.)  Pad
        entries and rows gathered by non-owning shards are routed to an
        out-of-bounds scatter target and dropped, so every device mutates
        exactly the rows it owns and replicas stay identical — no
        ``x + 0.0`` writes that could flip a ``-0.0``.

        One compiled plan per (storage, U) signature, through the same
        traced-counter wrapper as lookups: steady-state streaming updates
        cause zero retraces and the retrace gates cover them.
        """
        if rows.ndim != 1 or deltas.ndim != 2 or deltas.shape[0] != rows.shape[0]:
            raise ValueError(
                f"rows must be (U,), deltas (U, D); got {rows.shape} / "
                f"{deltas.shape}")
        if deltas.shape[1] != self.cfg.dim:
            raise ValueError(f"delta dim {deltas.shape[1]} != table dim "
                             f"{self.cfg.dim}")
        if not isinstance(rows, jax.core.Tracer):
            r = np.asarray(rows)
            if (r >= self.cfg.padded_rows).any():
                bad = int(r[r >= self.cfg.padded_rows][0])
                raise ValueError(
                    f"apply_deltas: row id {bad} outside the padded address "
                    f"space [0, {self.cfg.padded_rows})")
        key = ("update", self.cfg.storage, int(rows.shape[0]),
               jnp.dtype(rows.dtype).name, jnp.dtype(deltas.dtype).name)
        plan = self._plans.get(key)
        if plan is None:
            plan = self._build_update_plan()
            self._plans[key] = plan
        self._plan_calls += 1
        new_cold, new_hot = plan(state.cold, state.hot, state.page_scales,
                                 state.page_to_shard, state.page_to_slot,
                                 rows, deltas)
        return dataclasses.replace(state, cold=new_cold, hot=new_hot)

    def _build_update_plan(self):
        """shard_map + jit closure for one apply_deltas signature."""
        axes, mesh = self.axes, self.mesh
        tp = axes.tp
        c = self.cfg

        def block(cold, hot, scales, p2s, p2slot, rows, deltas):
            ps = c.page_size
            valid = rows >= 0
            r = jnp.where(valid, rows, 0)
            page = r // ps
            offset = r % ps
            shard = p2s[page]
            local = p2slot[page] * ps + offset                  # (U,)
            my = jax.lax.axis_index(tp)
            is_hot = valid & (shard == HOT_SHARD)
            owned = valid & (shard == my)
            # hot tier is replicated: every device applies the identical
            # scatter-add; non-hot entries target row hot_rows (OOB, drop)
            hot_tgt = jnp.where(is_hot, local, hot.shape[0])
            new_hot = hot.at[hot_tgt].add(deltas.astype(hot.dtype),
                                          mode="drop")
            cold_tgt = jnp.where(owned, local, cold.shape[0])
            if self.quantized:
                # quantized-domain read-modify-write with the carried
                # scale: gathered codes for unowned entries are garbage
                # but their scatter target is OOB, so they drop out
                scale = scales[page][:, None]                   # (U, 1)
                q_old = jnp.take(cold, jnp.minimum(local, cold.shape[0] - 1),
                                 axis=0)
                v = quant.dequantize_rows(q_old, scale) + deltas
                # a zero carried scale (never emitted by quant.page_scales,
                # but representable in a hand-built or restored state) has
                # no quantized domain to write into: dividing by it would
                # turn the codes into ±127 or NaN casts — keep the old
                # codes instead
                safe = jnp.where(scale > 0, scale, 1.0)
                q_new = jnp.where(scale > 0,
                                  quant.quantize_rows(v, safe), q_old)
                new_cold = cold.at[cold_tgt].set(q_new, mode="drop")
            else:
                new_cold = cold.at[cold_tgt].add(
                    deltas.astype(cold.dtype), mode="drop")
            return new_cold, new_hot

        f = shard_map(block, mesh=mesh,
                      in_specs=(P(tp), P(), P(), P(), P(), P(), P()),
                      out_specs=(P(tp), P()), check_vma=False)

        def traced(*args):
            self._trace_count += 1
            return f(*args)

        return jax.jit(traced)

    def requant_hot_pages(self, state: EngineState, pages: jax.Array
                          ) -> EngineState:
        """Snap listed hot-resident pages back onto their carried-scale
        quantized grid, in place (no migration).

        ``pages``: (K,) int32 global page ids, -1 for pads.  Each listed
        page's hot rows are replaced by ``dequantize(quantize(x, s), s)``
        with the page's carried scale — exactly the value a demote-then-
        promote round trip through the int8 cold tier would produce, in
        one replicated scatter.  This is the "fused" form of requant-
        demote for pages that should *stay* hot: after a snap, a later
        planner demotion is bit-exact again (the idempotency property),
        no matter how much the page drifted under streaming updates.

        No-op for fp32 storage (there is no quantized domain to snap to).
        Entries for pages not currently hot-resident are dropped.  One
        compiled plan per K, through the traced counter."""
        if not self.quantized:
            return state
        if pages.ndim != 1:
            raise ValueError(f"pages must be (K,); got {pages.shape}")
        key = ("requant", int(pages.shape[0]),
               jnp.dtype(pages.dtype).name)
        plan = self._plans.get(key)
        if plan is None:
            plan = self._build_requant_plan()
            self._plans[key] = plan
        self._plan_calls += 1
        new_hot = plan(state.hot, state.page_scales, state.page_to_shard,
                       state.page_to_slot, pages)
        return dataclasses.replace(state, hot=new_hot)

    def _build_requant_plan(self):
        c = self.cfg

        def block(hot, scales, p2s, p2slot, pages):
            ps = c.page_size
            valid = pages >= 0
            pg = jnp.where(valid, pages, 0)
            is_hot = valid & (p2s[pg] == HOT_SHARD)
            rows = (p2slot[pg][:, None] * ps
                    + jnp.arange(ps, dtype=pages.dtype)[None, :])   # (K, ps)
            rows_flat = rows.reshape(-1)
            take = jnp.take(hot, jnp.minimum(rows_flat, hot.shape[0] - 1),
                            axis=0)                                 # (K*ps, D)
            s = jnp.repeat(scales[pg], ps)[:, None]
            snapped = quant.dequantize_rows(quant.quantize_rows(take, s), s)
            tgt = jnp.where(jnp.repeat(is_hot, ps), rows_flat, hot.shape[0])
            return hot.at[tgt].set(snapped, mode="drop")

        f = shard_map(block, mesh=self.mesh,
                      in_specs=(P(), P(), P(), P(), P()),
                      out_specs=P(), check_vma=False)

        def traced(*args):
            self._trace_count += 1
            return f(*args)

        return jax.jit(traced)

    def page_checksums(self, state: EngineState, pages: jax.Array
                       ) -> jax.Array:
        """Per-page Fletcher-pair checksums over native-domain content.

        ``pages``: (K,) int32 global page ids, -1 for pads.  Returns
        (K, 2) uint32 ``[s1, s2]`` per page (zeros for pads) — the
        definition shared bit-for-bit with the numpy twin in
        ``repro.core.integrity.page_checksum_host``: uint32 wraparound
        sums over the page's rows reinterpreted as unsigned lanes (int8
        codes -> uint8, fp32 values -> IEEE bit patterns) plus the page
        scale's fp32 bits, with a 1-based position weight on ``s2``.

        Each tp shard computes both tier candidates for every listed
        page; exactly one shard contributes per page (the owning shard
        for cold pages, shard 0 for the replicated hot tier) and a psum
        collects the replicated result.  One compiled plan per K,
        through the traced counter — callers chunk every request through
        a single fixed K so steady-state scrubbing never retraces.
        """
        if pages.ndim != 1:
            raise ValueError(f"pages must be (K,); got {pages.shape}")
        key = ("checksum", self.cfg.storage, int(pages.shape[0]),
               jnp.dtype(pages.dtype).name)
        plan = self._plans.get(key)
        if plan is None:
            plan = self._build_checksum_plan()
            self._plans[key] = plan
        self._plan_calls += 1
        return plan(state.cold, state.hot, state.page_scales,
                    state.page_to_shard, state.page_to_slot, pages)

    def _build_checksum_plan(self):
        axes, mesh = self.axes, self.mesh
        tp = axes.tp
        c = self.cfg

        def lanes_of(rows_flat):
            # (K*ps, D) native rows -> (K, N) uint32 lane stream
            if rows_flat.dtype == jnp.int8:
                u = jax.lax.bitcast_convert_type(rows_flat, jnp.uint8)
                return u.astype(jnp.uint32)
            return jax.lax.bitcast_convert_type(
                rows_flat.astype(jnp.float32), jnp.uint32)

        def fold(lanes, scale_bits):
            # lanes (K, N) uint32, scale_bits (K,) uint32 -> (K, 2) uint32
            n = lanes.shape[1]
            w = jnp.arange(1, n + 1, dtype=jnp.uint32)[None, :]
            s1 = lanes.sum(axis=1, dtype=jnp.uint32) + scale_bits
            s2 = ((lanes * w).sum(axis=1, dtype=jnp.uint32)
                  + scale_bits * jnp.uint32(n + 1))
            return jnp.stack([s1, s2], axis=1)

        def block(cold, hot, scales, p2s, p2slot, pages):
            ps = c.page_size
            k = pages.shape[0]
            valid = pages >= 0
            pg = jnp.where(valid, pages, 0)
            shard = p2s[pg]
            is_hot = shard == HOT_SHARD
            my = jax.lax.axis_index(tp)
            rows = (p2slot[pg][:, None] * ps
                    + jnp.arange(ps, dtype=pages.dtype)[None, :])  # (K, ps)
            rows_flat = rows.reshape(-1)
            # gather both tier candidates (index-clamped: non-resident
            # gathers read garbage but are masked out of the psum)
            hot_rows = jnp.take(hot,
                                jnp.minimum(rows_flat, hot.shape[0] - 1),
                                axis=0)
            cold_rows = jnp.take(cold,
                                 jnp.minimum(rows_flat, cold.shape[0] - 1),
                                 axis=0)
            sb = jax.lax.bitcast_convert_type(
                scales[pg].astype(jnp.float32), jnp.uint32)
            cs_hot = fold(lanes_of(hot_rows).reshape(k, -1), sb)
            cs_cold = fold(lanes_of(cold_rows).reshape(k, -1), sb)
            cs = jnp.where(is_hot[:, None], cs_hot, cs_cold)
            # exactly one contributor per valid page: the owning shard
            # for cold pages, shard 0 for the replicated hot tier
            contrib = valid & jnp.where(is_hot, my == 0, shard == my)
            cs = cs * contrib[:, None].astype(jnp.uint32)
            return jax.lax.psum(cs, tp)

        f = shard_map(block, mesh=mesh,
                      in_specs=(P(tp), P(), P(), P(), P(), P()),
                      out_specs=P(), check_vma=False)

        def traced(*args):
            self._trace_count += 1
            return f(*args)

        return jax.jit(traced)

    def write_page(self, state: EngineState, page, cold_rows: jax.Array,
                   hot_rows: jax.Array, scale) -> EngineState:
        """Surgically overwrite ONE page's resident rows and scale (the
        repair path: page content fetched from a snapshot + WAL tail).

        ``page``: a scalar global page id (or -1: compile-only no-op —
        every scatter target lands out of bounds and drops, leaving the
        state bit-untouched, which is what warmup uses).  ``cold_rows``:
        (page_size, D) in the cold tier's native dtype, ``hot_rows``:
        (page_size, D) fp32, ``scale``: the page's carried scale.  Only
        the payload matching the page's *current* tier lands (the other
        tier's scatter drops); callers pass zeros for the unused one.
        One compiled plan per storage mode, through the traced counter.
        """
        key = ("page_write", self.cfg.storage)
        plan = self._plans.get(key)
        if plan is None:
            plan = self._build_page_write_plan()
            self._plans[key] = plan
        self._plan_calls += 1
        pg = jnp.asarray(np.asarray(page, np.int32).reshape(1))
        sc = jnp.asarray(np.asarray(scale, np.float32).reshape(1))
        new_cold, new_hot, new_scales = plan(
            state.cold, state.hot, state.page_scales, state.page_to_shard,
            state.page_to_slot, pg,
            jnp.asarray(cold_rows, self.cold_dtype),
            jnp.asarray(hot_rows, jnp.float32), sc)
        return dataclasses.replace(state, cold=new_cold, hot=new_hot,
                                   page_scales=new_scales)

    def _build_page_write_plan(self):
        axes, mesh = self.axes, self.mesh
        tp = axes.tp
        c = self.cfg

        def block(cold, hot, scales, p2s, p2slot, page, pc, ph, sc):
            ps = c.page_size
            pg0 = page[0]
            valid = pg0 >= 0
            pg = jnp.where(valid, pg0, 0)
            shard = p2s[pg]
            is_hot = shard == HOT_SHARD
            my = jax.lax.axis_index(tp)
            rows = p2slot[pg] * ps + jnp.arange(ps, dtype=jnp.int32)
            # hot tier is replicated: every device writes the identical
            # rows (or drops, for cold/pad pages)
            hot_tgt = jnp.where(valid & is_hot, rows, hot.shape[0])
            new_hot = hot.at[hot_tgt].set(ph.astype(hot.dtype), mode="drop")
            cold_tgt = jnp.where(valid & (shard == my), rows, cold.shape[0])
            new_cold = cold.at[cold_tgt].set(pc.astype(cold.dtype),
                                             mode="drop")
            sc_tgt = jnp.where(valid, pg, scales.shape[0])
            new_scales = scales.at[sc_tgt].set(sc[0], mode="drop")
            return new_cold, new_hot, new_scales

        f = shard_map(block, mesh=mesh,
                      in_specs=(P(tp), P(), P(), P(), P(), P(), P(), P(),
                                P()),
                      out_specs=(P(tp), P(), P()), check_vma=False)

        def traced(*args):
            self._trace_count += 1
            return f(*args)

        return jax.jit(traced)


class ServeBinding:
    """The serving subsystem's seam onto the engine.

    ``repro.serving`` never touches engine internals: it drives this
    quadruple of (engine, mutable state, model params, jitted serve step).
    ``execute`` runs one bucket-shaped micro-batch and blocks until the
    device is done; ``observe``/``replan`` fold the paper's live page
    management (§IV-B4: profile -> re-plan -> pure-gather migration) into
    the serving cadence — lookups are placement-invariant, so a replan
    between micro-batches never perturbs in-flight numerics; and
    ``plan_stats`` exposes the compiled-plan cache contract the batcher's
    bucket set is built around (one signature per bucket, zero steady-state
    retraces once warmed).

    Robustness seams (all opt-in, all off by default):

      * ``steps`` — named serve-step *variants* (the brown-out ladder's
        quality rungs: split front end, dedup off, hot-tier-only, ...);
        ``set_mode`` switches between them without retracing once each
        variant's buckets are warmed, because every variant is its own
        jitted executable over the same input signatures.
      * ``validate_ids`` — host-side strict OOB check on the batch's index
        stream *before* it enters the jitted step (the device gather would
        clamp silently).
      * ``scrub_scores`` — NaN/Inf score scrub with per-batch poisoned-row
        accounting: a corrupted store (or injected NaN features) degrades
        to zero-scored rows instead of shipping NaN downstream, and the
        poison counters give the recovery controller its signal.
      * ``attach_checkpointer``/``restore`` — mid-serving state recovery:
        reload the EngineState from the last committed checkpoint between
        micro-batches (the observe/replan seam).  State shapes/dtypes are
        unchanged, so a restore never retraces the serve step.
      * ``attach_remesher``/``remesh`` — mid-serving *elastic* recovery
        from a lost tp shard: quiesce, pick a survivor mesh
        (``runtime/elastic.scale_plan``), re-mesh the EngineState in the
        quantized domain (codes + carried per-page scales move verbatim),
        and rebuild every jitted serve-step variant against the new shard
        count.  The caller (the serving runtime) re-warms the rebuilt
        variants and resumes; steady-state trace counts accumulated before
        the swap carry across it, so ``plan_stats()`` stays a whole-run
        ledger.
    """

    def __init__(self, engine: PIFSEmbeddingEngine, state: EngineState,
                 params, step, idx_key: Optional[str] = "indices",
                 track_dedup: bool = True,
                 steps: Optional[dict] = None,
                 validate_ids: bool = False,
                 scrub_scores: bool = False):
        self.engine = engine
        self.state = state
        self.params = params
        self.step = step                   # (params, state, batch) -> scores
        self.idx_key = idx_key             # batch entry feeding the profiler
        self.replans = 0
        # per-bucket duplicate-access accounting, fed by observe() on the
        # maintenance path (never the timed service path): bucket index
        # shape -> accumulated entries / unique rows over observed batches.
        # The probe is a host-side numpy replay — tens of microseconds per
        # observed batch at serving shapes; ``track_dedup=False`` disables
        # it for deployments that do not want the maintenance-path cost.
        self.track_dedup = track_dedup
        self.dedup_stats: dict = {}
        # named serve-step variants (brown-out rungs); "full" is the
        # configured-quality step and always present
        self.steps = dict(steps or {})
        self.steps.setdefault("full", step)
        self.active = "full"
        self.validate_ids = validate_ids
        self.scrub_scores = scrub_scores
        # poisoned-score accounting (scrub_scores): totals + last batch
        self.poisoned_rows = 0
        self.poisoned_batches = 0
        self.last_poisoned = 0
        # mid-serving recovery
        self.checkpointer = None
        self.ckpt_step = 0
        self.restores = 0
        # silent-corruption detection: per-page checksum ledger, kept
        # incrementally consistent by every mutation path below (see
        # repro.core.integrity); None = integrity checking disarmed
        self.integrity = None
        # streaming updates: write-ahead log + fixed apply capacity (one
        # plan signature) + applied-batch sequence number.  The WAL is the
        # delta counterpart of the checkpointer: every applied batch is
        # logged *before* it touches the device, snapshots record the
        # sequence point and truncate, and restore() replays the suffix.
        self.wal = None
        self.update_capacity = 256
        self.update_seq = 0          # seq of the last applied delta batch
        self.updates_applied = 0     # total unique rows applied
        # elastic re-mesh (mid-serving tp-shard-loss recovery): the
        # rebinder rebuilds the jitted serve-step variants for a new
        # engine/mesh pair (only loadgen knows model families, so it owns
        # the callable); prefer_tp parameterizes the survivor-mesh policy
        self._rebind = None          # (engine, mesh) -> (step, steps|None)
        self.prefer_tp = 4
        self.remeshes = 0
        self.remesh_events: list = []
        self._carried_traces = 0     # pre-remesh steady traces (see remesh)

    # ------------------------------------------------------------ variants
    def modes(self) -> tuple:
        """The available serve-step variant labels ('full' first)."""
        rest = [k for k in self.steps if k != "full"]
        return ("full",) + tuple(rest)

    def set_mode(self, label: str) -> None:
        """Switch the active serve-step variant (a brown-out ladder rung).

        Unknown labels fall back to 'full' — model families that lack a
        given degraded datapath (e.g. Rec configs have no DLRM front end)
        simply keep serving at the nearest quality they have."""
        self.active = label if label in self.steps else "full"

    def execute(self, batch: dict):
        if self.validate_ids and self.idx_key and self.idx_key in batch:
            # the serve step is jitted: the OOB check must see the concrete
            # host batch, before tracing swallows it
            self.engine._check_ids(np.asarray(batch[self.idx_key]))
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        out = self.steps[self.active](self.params, self.state, jb)
        jax.block_until_ready(out)
        if self.scrub_scores:
            scores = np.asarray(out)
            finite = np.isfinite(scores)
            self.last_poisoned = int(scores.size - finite.sum())
            if self.last_poisoned:
                self.poisoned_rows += self.last_poisoned
                self.poisoned_batches += 1
                out = jnp.where(jnp.asarray(finite), out,
                                jnp.zeros_like(out))
            return out
        self.last_poisoned = 0
        return out

    # ------------------------------------------------------------ integrity
    def attach_integrity(self, ledger=None, chunk: int = 64) -> None:
        """Arm the per-page checksum ledger over the live state.

        Builds a fully-populated ``repro.core.integrity``
        ``PageChecksumLedger`` (or adopts the one passed in).  From this
        point every mutation path — :meth:`apply_deltas`, :meth:`replan`
        migrations, :meth:`requant_hot_pages`, :meth:`remesh` — keeps the
        ledger consistent, so any divergence a scrub sweep finds is
        silent corruption by construction."""
        from repro.core.integrity import PageChecksumLedger
        if ledger is None:
            ledger = PageChecksumLedger.build(self.engine, self.state,
                                              chunk=chunk)
        self.integrity = ledger

    # ------------------------------------------------------------ recovery
    def attach_checkpointer(self, checkpointer, save_now: bool = True
                            ) -> None:
        """Wire a ``repro.checkpoint.Checkpointer`` for mid-serving state
        recovery; ``save_now`` commits the current (healthy) EngineState
        synchronously so ``restore`` always has a baseline."""
        self.checkpointer = checkpointer
        if save_now:
            self.snapshot()

    def snapshot(self) -> None:
        """Commit the current EngineState (blocking — callers sit on the
        maintenance path, never the timed service path).

        With a WAL attached the snapshot manifest records the last applied
        update sequence number, then the WAL truncates: every logged delta
        is already inside the committed state, so the log restarts empty
        and restore-time replay never double-applies.

        The manifest's ``extra`` additionally records the writing engine's
        mesh shape, shard count, and cold-tier storage mode; ``restore``
        validates them so a mismatched-mesh (or mismatched-storage)
        restore fails loudly with a pointer at the elastic path instead of
        silently mis-placing shards."""
        if self.checkpointer is None:
            raise RuntimeError("no checkpointer attached")
        self.ckpt_step += 1
        extra = {"update_seq": self.update_seq,
                 "mesh": {str(a): int(s)
                          for a, s in self.engine.mesh.shape.items()},
                 "n_shards": int(self.engine.cfg.n_shards),
                 "storage": self.engine.cfg.storage}
        if self.integrity is not None:
            # snapshot-time ledger: page repair verifies the rows it reads
            # back out of this snapshot against these entries, so a rotted
            # snapshot fails loudly instead of being written into the store
            extra["page_checksums"] = self.integrity.export()
        self.checkpointer.save(self.ckpt_step, self.state, blocking=True,
                               extra=extra)
        if self.wal is not None:
            self.wal.truncate()

    def _check_restore_extra(self, extra: dict) -> None:
        """Manifest mesh/storage guard: a checkpoint written under a
        different shard count cannot be restored in place — the cold tier's
        physical layout is a function of ``n_shards`` and the page table
        maps pages to shard ids, so a silent restore would mis-place every
        shard.  Fail loudly and name the elastic route instead.  (The
        generic per-leaf dtype/shape guard in the checkpointer would also
        trip, but with an opaque shape diff; this check explains *why* and
        *what to do*.)  Pre-metadata manifests (no ``n_shards`` key)
        validate vacuously."""
        snap_shards = extra.get("n_shards")
        if (snap_shards is not None
                and int(snap_shards) != int(self.engine.cfg.n_shards)):
            raise ValueError(
                f"checkpoint was written with n_shards={snap_shards} "
                f"(mesh {extra.get('mesh')}), but this engine has "
                f"n_shards={self.engine.cfg.n_shards} (mesh "
                f"{ {str(a): int(s) for a, s in self.engine.mesh.shape.items()} }"
                "): an in-place restore would silently mis-place shards. "
                "Route through the elastic path instead — restore on an "
                "engine matching the snapshot's mesh, then re-mesh via "
                "ServeBinding.remesh() / repro.runtime.elastic."
                "remesh_engine().")
        snap_storage = extra.get("storage")
        if (snap_storage is not None
                and snap_storage != self.engine.cfg.storage):
            raise ValueError(
                f"checkpoint was written with storage={snap_storage!r} but "
                f"this engine uses storage={self.engine.cfg.storage!r}: "
                "int8 codes and fp32 rows are not interchangeable — "
                "rebuild the engine with the snapshot's storage mode.")

    def restore(self) -> None:
        """Reload EngineState from the latest committed checkpoint (the
        mid-serving heal path, run between micro-batches on the
        observe/replan seam).  Restored leaves have identical shapes,
        dtypes, and shardings, so no serve-step plan ever retraces; the
        checkpointer's per-leaf CRC check makes an on-disk corruption fail
        loudly here rather than serve garbage.

        With a WAL attached, every delta batch logged *after* the
        restored snapshot's sequence point is replayed through the same
        coalesce + fixed-capacity apply path that ran live, so the healed
        state is bit-identical to the uninterrupted one — a mid-serving
        restore loses no updates."""
        if self.checkpointer is None:
            raise RuntimeError("no checkpointer attached")
        self._check_restore_extra(self.checkpointer.extra())
        self.state = self.checkpointer.restore(
            self.state, shardings=self.engine.state_shardings())
        self.restores += 1
        if self.integrity is not None:
            # adopt the snapshot-time ledger (it describes exactly the
            # state just loaded); the WAL replay below routes through
            # apply_deltas, which keeps it consistent from here on.  A
            # pre-ledger snapshot forces a full rebuild instead.
            rec = self.checkpointer.extra().get("page_checksums")
            if rec is not None:
                self.integrity.load(rec)
            else:
                self.integrity.note_pages(
                    self.state,
                    np.arange(self.engine.cfg.num_pages, dtype=np.int64))
        if self.wal is not None:
            snap_seq = int(self.checkpointer.extra().get("update_seq", 0))
            self.update_seq = snap_seq
            self.replay_wal(after_seq=snap_seq)

    # ----------------------------------------------------- elastic re-mesh
    def attach_remesher(self, rebind, prefer_tp: int = 4) -> None:
        """Arm mid-serving elastic recovery.

        ``rebind(engine, mesh) -> (step, steps|None)`` rebuilds the jitted
        serve-step callable(s) for a re-meshed engine — only the model
        binder (``serving.loadgen.bind_model``) knows the model family, so
        it owns this closure.  ``prefer_tp`` parameterizes the
        survivor-mesh policy (``runtime/elastic.scale_plan``)."""
        self._rebind = rebind
        self.prefer_tp = int(prefer_tp)

    @property
    def can_remesh(self) -> bool:
        return self._rebind is not None

    def remesh(self, lost_shard=None, new_mesh=None, heal: bool = False,
               batch_granule: int = 0) -> dict:
        """Mid-serving elastic recovery from a lost tp shard.

        Maintenance-seam call (between micro-batches, like observe/replan
        — its wall time is recovery, never service time).  The sequence:

          1. *Quiesce*: block on the in-flight EngineState so no device
             work straddles the swap.
          2. Optionally *heal* first: reload the last committed checkpoint
             and replay the WAL tail **on the old mesh** (the snapshot was
             written under the old placement; ``_check_restore_extra``
             enforces exactly this ordering).
          3. Pick the survivor mesh: one tp shard is gone, so
             ``dp * (tp - 1)`` devices survive; ``scale_plan(survivors,
             prefer_tp, batch_granule)`` chooses the new (dp, tp) split
             unless the caller pins ``new_mesh`` explicitly —
             ``batch_granule`` (the gcd of the batcher's bucket batch
             sizes, supplied by the serving runtime) keeps dp a divisor
             of every micro-batch the rebuilt step must shard.
          4. Re-mesh the EngineState in the quantized domain
             (``runtime/elastic.remesh_engine``: int8 codes and carried
             per-page scales move verbatim — bit-stable, no requantize).
          5. Rebuild every jitted serve-step variant via the attached
             rebinder; the caller re-warms them (warmup traces are not
             steady-state) and resumes.
          6. If a checkpointer is attached, commit a post-remesh baseline
             snapshot — the old-mesh checkpoint can no longer restore in
             place, and the snapshot truncates the already-replayed WAL.

        Steady-state trace counts accumulated before the swap move into a
        carried ledger so ``plan_stats()['traces']`` stays a whole-run
        zero-retrace measure across the re-mesh.  Returns the event record
        (also appended to ``remesh_events``)."""
        if self._rebind is None:
            raise RuntimeError(
                "no rebinder attached — call attach_remesher() (or "
                "bind_model(elastic=True)) before remesh()")
        # deferred: elastic imports this module at its top level
        from repro.runtime.elastic import remesh_engine, scale_plan
        from repro.distributed.sharding import make_mesh
        old_engine = self.engine
        # 1. quiesce: nothing may straddle the placement swap
        jax.block_until_ready((self.state.cold, self.state.hot))
        if heal:
            # 2. heal on the *old* mesh: checkpoint + WAL tail were written
            # under the old placement, and restore validates exactly that
            self.restore()
        if new_mesh is None:
            old_tp = old_engine.axes.tp_size(old_engine.mesh)
            old_dp = old_engine.axes.dp_size(old_engine.mesh)
            if old_tp < 2:
                raise RuntimeError(
                    f"cannot drop a tp shard from mesh "
                    f"{dict(old_engine.mesh.shape)}: tp={old_tp} has no "
                    "survivor — shard loss at tp=1 is total loss")
            survivors = old_dp * (old_tp - 1)
            shape, names = scale_plan(survivors, prefer_tp=self.prefer_tp,
                                      batch_granule=batch_granule)
            new_mesh = make_mesh(shape, names)
        old_p2s = (np.asarray(self.state.page_to_shard)
                   if self.integrity is not None else None)
        new_engine, new_state = remesh_engine(
            old_engine, new_mesh, self.state)
        # pre-swap steady traces move to the carried ledger (the new
        # engine's counter starts at zero and the caller's post-warm
        # reset only clears engine-level counts)
        self._carried_traces += old_engine._trace_count
        self.engine = new_engine
        self.state = new_state
        if self.integrity is not None:
            # page geometry is shard-count-invariant, so the checksum
            # ledger survives the re-mesh verbatim — only pages the
            # re-planned placement flipped across tiers need refreshing
            self.integrity.rebind(new_engine)
            self.integrity.note_tier_changes(
                self.state, old_p2s, np.asarray(self.state.page_to_shard))
        step, steps = self._rebind(new_engine, new_mesh)
        self.steps = dict(steps or {})
        self.steps.setdefault("full", step)
        self.step = self.steps["full"]
        if self.active not in self.steps:
            self.active = "full"
        if self.checkpointer is not None:
            # 6. new baseline: the pre-remesh checkpoint is now
            # mesh-mismatched (restore would refuse it) and any WAL tail
            # was replayed in step 2 — snapshot commits + truncates
            self.snapshot()
        event = {"from_mesh": dict(old_engine.mesh.shape),
                 "to_mesh": dict(new_mesh.shape),
                 "lost_shard": lost_shard,
                 "n_shards": int(new_engine.cfg.n_shards),
                 "healed": bool(heal)}
        self.remeshes += 1
        self.remesh_events.append(event)
        return event

    # ----------------------------------------------------- streaming updates
    def attach_wal(self, wal) -> None:
        """Wire a ``repro.checkpoint.WriteAheadLog``: every delta batch
        applied through :meth:`apply_deltas` is appended (write-ahead)
        before it touches the device, :meth:`snapshot` truncates, and
        :meth:`restore` replays the suffix past the snapshot's sequence
        point."""
        self.wal = wal

    def apply_deltas(self, rows, deltas, log: bool = True) -> int:
        """Apply one streaming delta batch to the live EngineState.

        Maintenance-path call (between micro-batches, like observe/replan):
        blocks until the device is done so the wall time is charged where
        the runtime measures it.  Host-side the batch is coalesced
        (duplicate rows summed deterministically), logged to the WAL if
        one is attached, then applied in fixed-``update_capacity`` chunks
        so the engine sees exactly one plan signature.  Returns the number
        of unique rows applied."""
        from repro.core import updates as upd
        rows, deltas = upd.coalesce_deltas(rows, deltas)
        if rows.size == 0:
            return 0
        if log:
            self.update_seq += 1
            if self.wal is not None:
                self.wal.append(self.update_seq, rows, deltas)
        for r_chunk, d_chunk in upd.chunk_delta_batch(
                rows, deltas, self.update_capacity):
            new = self.engine.apply_deltas(
                self.state, jnp.asarray(r_chunk), jnp.asarray(d_chunk))
            jax.block_until_ready((new.cold, new.hot))
            self.state = new
        self.updates_applied += int(rows.size)
        if self.integrity is not None:
            # every page a delta landed in gets its ledger entry refreshed
            # from the post-apply state (maintenance-path device work, one
            # fixed-chunk checksum signature — no retraces)
            self.integrity.note_rows(self.state, rows)
        return int(rows.size)

    def replay_wal(self, after_seq: int = 0) -> int:
        """Re-apply WAL records with seq > ``after_seq`` (restore path).

        Replayed batches are not re-logged; they go through the identical
        coalesce/chunk/apply path as the live stream, so the replayed
        state matches the live one bit-for-bit.  Returns the number of
        batches replayed."""
        if self.wal is None:
            raise RuntimeError("no WAL attached")
        n = 0
        for seq, rows, deltas in self.wal.replay():
            if seq <= after_seq:
                continue
            self.apply_deltas(rows, deltas, log=False)
            self.update_seq = max(self.update_seq, int(seq))
            n += 1
        return n

    def observe(self, batch: dict) -> None:
        if self.idx_key and self.idx_key in batch:
            w = batch.get("weights")
            new = self.engine.observe(
                self.state, jnp.asarray(batch[self.idx_key]),
                weights=None if w is None else jnp.asarray(w))
            # block here so the profiler update is charged to maintenance,
            # not leaked into the next micro-batch's measured service time
            jax.block_until_ready(new.counts)
            self.state = new
            if not self.track_dedup:
                return
            # dedup probe rides the same maintenance cadence: the measured
            # per-bucket duplicate factor makes serving-side bytes wins
            # attributable without touching the timed service path
            d = self.engine.dedup_factor(
                self.state, batch[self.idx_key], weights=w)
            key = tuple(np.asarray(batch[self.idx_key]).shape)
            rec = self.dedup_stats.setdefault(
                key, {"batches": 0, "entries": 0, "unique_rows": 0})
            rec["batches"] += 1
            rec["entries"] += d["entries"]
            rec["unique_rows"] += d["unique_rows"]

    def dedup_report(self) -> dict:
        """Measured per-bucket duplicate-access factors (from the observe
        cadence): ``{bucket_shape_str: {batches, entries, unique_rows,
        factor}}`` — ``factor`` is the bytes-moved reduction a dedup'd
        datapath realizes on that bucket's traffic."""
        out = {}
        for shape, rec in self.dedup_stats.items():
            out["x".join(map(str, shape))] = {
                **rec,
                "factor": rec["entries"] / max(rec["unique_rows"], 1)}
        return out

    def requant_hot_pages(self, pages) -> int:
        """Snap listed hot pages onto their carried-scale grid
        (maintenance-path wrapper around the engine op: blocks, notes the
        ledger, and WAL-fences — see :meth:`replan` for why).  Returns
        the number of non-pad pages listed."""
        pages = np.asarray(pages, np.int32).ravel()
        new = self.engine.requant_hot_pages(self.state, jnp.asarray(pages))
        jax.block_until_ready(new.hot)
        self.state = new
        valid = pages[pages >= 0]
        if self.integrity is not None and valid.size:
            self.integrity.note_pages(self.state, valid)
            if (self.engine.quantized and self.wal is not None
                    and self.checkpointer is not None):
                # a requant snap mutates pages outside the WAL: fence with
                # a snapshot so page repair never replays across it
                self.snapshot()
        return int(valid.size)

    def replan(self) -> dict:
        old_p2s = (np.asarray(self.state.page_to_shard)
                   if self.integrity is not None else None)
        new, stats = self.engine.plan_and_migrate(self.state)
        jax.block_until_ready((new.cold, new.hot))   # same: no timing leak
        self.state = new
        self.replans += 1
        if self.integrity is not None:
            # pages that flipped tier changed native-domain content
            # (promote/demote through the carried scale): refresh them
            flipped = self.integrity.note_tier_changes(
                self.state, old_p2s, np.asarray(self.state.page_to_shard))
            if (flipped.size and self.engine.quantized
                    and self.wal is not None
                    and self.checkpointer is not None):
                # WAL fence: quantized-domain RMW (cold) and fp32 adds
                # (hot) do not commute through a tier flip, so a WAL tail
                # spanning one cannot be replayed bit-exactly onto a
                # snapshot page.  Committing a fresh snapshot (which
                # truncates the WAL) pins every future page repair to a
                # post-flip baseline.
                self.snapshot()
        return stats

    def plan_stats(self) -> dict:
        """Engine plan-cache stats plus the carried trace ledger: traces
        counted on pre-remesh engines accumulate here, so the zero-
        steady-state-retrace contract is measured across the whole run,
        re-meshes included."""
        out = self.engine.plan_stats()
        out["traces"] = out["traces"] + self._carried_traces
        return out

    def reset_plan_stats(self) -> None:
        self.engine.reset_plan_stats()
        self._carried_traces = 0


def engine_for_tables(vocab_sizes, dim, mesh, hot_fraction=0.05,
                      page_bytes=4096, dtype=jnp.float32,
                      storage: str = "fp32", dedup: str = "off",
                      axes: Optional[MeshAxes] = None,
                      planner: Optional[PlannerConfig] = None,
                      ) -> Tuple[PIFSEmbeddingEngine, np.ndarray]:
    """Stack multiple tables into one engine address space.

    Returns (engine, offsets) where offsets[t] is added to table-t indices.
    Page alignment: each table starts on a page boundary, so pages never
    straddle tables.  ``storage='int8'`` selects the quantized cold tier
    (per-page scales, fused dequant in the SLS datapath); note an int8 page
    of the same ``page_bytes`` holds 4x the rows.  ``dedup`` sets the
    engine-wide default for gather-once duplicate coalescing
    (off/auto/on — see ``PIFSEmbeddingEngine.lookup``).
    """
    axes = axes or axes_for(mesh)
    n_shards = axes.tp_size(mesh)
    itemsize = jnp.dtype(dtype).itemsize
    cfg0 = PagingConfig(total_rows=1, dim=dim, n_shards=n_shards,
                        page_bytes=page_bytes, itemsize=itemsize,
                        hot_fraction=hot_fraction, storage=storage)
    ps = cfg0.page_size
    offsets = []
    total = 0
    for v in vocab_sizes:
        offsets.append(total)
        total += -(-v // ps) * ps  # round table size up to page boundary
    cfg = dataclasses.replace(cfg0, total_rows=total)
    # model index math downcasts global row ids to int32 (device-side
    # gathers), and the cold tier's flat address space is even larger than
    # the padded rows (headroom over-provisioning: cold_pos = shard *
    # rows_per_shard + local_row in to_dense/migration) — past this bound
    # either cast silently truncates and lookups read the wrong rows, so
    # fail at construction instead.
    largest = max(cfg.padded_rows, cfg.cold_rows_total)
    if largest > np.iinfo(np.int32).max:
        raise ValueError(
            f"table address space ({total} padded rows, "
            f"{cfg.cold_rows_total} cold-tier rows incl. headroom) exceeds "
            f"int32 range ({np.iinfo(np.int32).max}); row indices are "
            "int32 on device — shard the tables across engines or reduce "
            "the padded vocab sizes")
    return (PIFSEmbeddingEngine(cfg, mesh, axes=axes, planner=planner,
                                dtype=dtype, dedup=dedup),
            np.asarray(offsets, dtype=np.int64))
