"""Per-page checksum ledger: silent-corruption detection for the store.

The serving stack already catches corruption that *announces itself* —
``validate_ids`` rejects out-of-bounds indices before they index anything,
and ``scrub_scores`` zeroes non-finite outputs — but a bit flip that yields
a finite wrong embedding sails through both.  This module closes that gap
with an end-to-end integrity invariant:

  * every page of the live store (int8 codes + its carried fp32 scale, or
    fp32 values) has a host-side checksum computed over its *native-domain*
    bits — the exact bytes resident in the current tier;
  * the ledger is updated incrementally on every legitimate mutation path
    (``apply_deltas`` chunks, replan migrations, ``requant_hot_pages``
    snaps, requant-demotes, elastic re-meshes), so at any quiescent point
    ``ledger == recompute(store)`` holds bit-for-bit;
  * anything that mutates a page *without* going through a mutation path —
    a cosmic-ray flip, a bad DMA, a buggy kernel — breaks the invariant
    and is caught by the scrub sweep (``serving/scrub.py``).

Checksum definition (shared by the jitted device reduction in
``PIFSEmbeddingEngine.page_checksums`` and the numpy twin here): a
Fletcher-style pair in uint32 wraparound arithmetic over the page's lane
stream.  Lanes are the page's rows reinterpreted as unsigned integers
(int8 codes -> uint8 -> uint32; fp32 values -> their IEEE-754 bit patterns
as uint32) followed by the page scale's fp32 bit pattern:

    s1 = (sum_i lane_i            + scale_bits)           mod 2^32
    s2 = (sum_i lane_i * (i + 1)  + scale_bits * (N + 1)) mod 2^32

with ``N = page_size * dim`` lanes, stored host-side as the uint64
``(s2 << 32) | s1``.  The position-weighted ``s2`` term makes swapped or
shifted rows detectable, not just changed sums.  All arithmetic is exact
integer wraparound, so the numpy fold is *guaranteed* bit-identical to the
device reduction — no float-order caveats — which is what lets page repair
verify a snapshot page read on the host against the ledger recorded at
snapshot time.

Tier semantics: a page's checksum covers its current-tier content.  Moves
that carry content verbatim (cold page to another cold slot/shard, hot
page to another hot slot, any page across an elastic re-mesh without a
tier change) leave the checksum untouched — that is why the ledger
survives a re-mesh verbatim (page geometry is shard-count-invariant).
Tier *flips* change the native-domain content deterministically
(promote = dequantize with the carried scale, demote = requantize with
it), so flipped pages are recomputed at the flip site.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.paging import HOT_SHARD


def page_checksum_host(rows: np.ndarray, scale: float) -> int:
    """Numpy twin of the device per-page checksum (bit-identical).

    ``rows``: the page's (page_size, dim) content in its native dtype
    (int8 codes or float32 values); ``scale``: the page's carried fp32
    scale.  Returns the uint64 ``(s2 << 32) | s1`` as a Python int.
    """
    rows = np.ascontiguousarray(rows)
    if rows.dtype == np.int8:
        lanes = rows.view(np.uint8).astype(np.uint32).ravel()
    elif rows.dtype == np.float32:
        lanes = rows.view(np.uint32).ravel()
    else:
        raise TypeError(f"unsupported page dtype {rows.dtype}: the store "
                        "holds int8 codes or fp32 values")
    sc = int(np.asarray(scale, np.float32).view(np.uint32))
    n = int(lanes.size)
    w = np.arange(1, n + 1, dtype=np.uint32)
    # fold in python-int space mod 2^32: numpy uint32 sums already wrap,
    # the final adds must too (a numpy-scalar add would warn on overflow)
    s1 = (int(lanes.sum(dtype=np.uint32)) + sc) % (1 << 32)
    s2 = (int((lanes * w).sum(dtype=np.uint32)) + sc * (n + 1)) % (1 << 32)
    return (s2 << 32) | s1


class PageChecksumLedger:
    """Host-side per-page checksum ledger over a live EngineState.

    The ledger holds one uint64 per global page id.  Callers notify it on
    every mutation path (``note_rows`` after delta application,
    ``note_pages`` after requant snaps, ``note_tier_changes`` after any
    placement change that may flip tiers); ``verify`` recomputes a window
    of pages on device and returns the ids whose live checksum diverges
    from the ledger — silent corruption, by construction, since every
    legitimate mutation updated the ledger.

    All device recomputation goes through one fixed window size
    (``chunk``, -1-padded), so the engine sees exactly one checksum plan
    signature and steady-state scrubbing causes zero retraces.
    """

    def __init__(self, engine, chunk: int = 64):
        self.engine = engine
        self.chunk = int(chunk)
        self.checksums = np.zeros(engine.cfg.num_pages, np.uint64)

    @classmethod
    def build(cls, engine, state, chunk: int = 64) -> "PageChecksumLedger":
        """Ledger for ``state`` with every page's checksum populated."""
        ledger = cls(engine, chunk=chunk)
        ledger.note_pages(state,
                          np.arange(engine.cfg.num_pages, dtype=np.int64))
        return ledger

    # -------------------------------------------------------------- device
    def compute(self, state, pages) -> np.ndarray:
        """Recompute checksums for ``pages`` on device -> uint64 array.

        Chunks through the single fixed-``chunk`` plan signature; pad
        entries (-1) contribute zeros and are sliced off.
        """
        pages = np.asarray(pages, np.int32).ravel()
        out = np.zeros(pages.size, np.uint64)
        for i in range(0, pages.size, self.chunk):
            win = pages[i:i + self.chunk]
            pad = np.full(self.chunk, -1, np.int32)
            pad[:win.size] = win
            cs = np.asarray(self.engine.page_checksums(state,
                                                       jnp.asarray(pad)))
            s1 = cs[:win.size, 0].astype(np.uint64)
            s2 = cs[:win.size, 1].astype(np.uint64)
            out[i:i + win.size] = (s2 << np.uint64(32)) | s1
        return out

    def warmup(self, state) -> None:
        """Compile the checksum plan outside the timed path (an all-pad
        window: reads nothing, returns zeros, state untouched)."""
        pad = jnp.asarray(np.full(self.chunk, -1, np.int32))
        np.asarray(self.engine.page_checksums(state, pad))

    # --------------------------------------------------------- maintenance
    def note_pages(self, state, pages) -> None:
        """Re-record the listed pages' checksums from the live state."""
        pages = np.asarray(pages, np.int64).ravel()
        pages = pages[pages >= 0]
        if pages.size == 0:
            return
        self.checksums[pages] = self.compute(state, pages)

    def note_rows(self, state, rows) -> np.ndarray:
        """Re-record the checksums of every page touching ``rows``
        (global row ids; pads < 0 ignored).  Returns the touched pages."""
        rows = np.asarray(rows, np.int64).ravel()
        rows = rows[rows >= 0]
        if rows.size == 0:
            return rows
        pages = np.unique(rows // self.engine.cfg.page_size)
        self.note_pages(state, pages)
        return pages

    def note_tier_changes(self, state, old_p2s, new_p2s) -> np.ndarray:
        """Re-record pages whose tier flipped between two placements.

        Content moves verbatim unless the tier changed (promote/demote
        transform through the carried scale), so only flipped pages need
        recomputation — a pure slot/shard move keeps its checksum.
        Returns the flipped page ids.
        """
        old_hot = np.asarray(old_p2s) == HOT_SHARD
        new_hot = np.asarray(new_p2s) == HOT_SHARD
        flipped = np.nonzero(old_hot != new_hot)[0]
        if flipped.size:
            self.note_pages(state, flipped)
        return flipped

    def rebind(self, engine) -> None:
        """Point the ledger at a re-meshed engine.  Page geometry is
        shard-count-invariant, so the recorded checksums carry verbatim;
        the caller recomputes any tier-flipped pages via
        :meth:`note_tier_changes`."""
        if int(engine.cfg.num_pages) != self.checksums.size:
            raise ValueError(
                f"cannot rebind ledger across a page-geometry change: "
                f"{self.checksums.size} pages recorded, new engine has "
                f"{engine.cfg.num_pages}")
        self.engine = engine

    # ------------------------------------------------------------ auditing
    def verify(self, state, pages=None) -> np.ndarray:
        """Recompute ``pages`` (default: all) and return the ids whose
        live checksum diverges from the ledger."""
        if pages is None:
            pages = np.arange(self.engine.cfg.num_pages, dtype=np.int64)
        pages = np.asarray(pages, np.int64).ravel()
        pages = pages[pages >= 0]
        if pages.size == 0:
            return pages
        live = self.compute(state, pages)
        return pages[live != self.checksums[pages]]

    # -------------------------------------------------------- serialization
    def export(self) -> dict:
        """JSON-serializable form (snapshot manifest ``extra`` payload)."""
        return {"version": 1, "chunk": self.chunk,
                "checksums": [f"{int(c):016x}" for c in self.checksums]}

    def load(self, data: dict) -> None:
        """Adopt an exported ledger (snapshot-restore path)."""
        recorded = data["checksums"]
        if len(recorded) != self.checksums.size:
            raise ValueError(
                f"ledger size mismatch: {len(recorded)} recorded pages vs "
                f"{self.checksums.size} in this engine")
        self.checksums = np.array([int(c, 16) for c in recorded],
                                  dtype=np.uint64)


def fetch_snapshot_page(checkpointer, cfg, page: int,
                        step: Optional[int] = None) -> dict:
    """Read ONE page's rows (and metadata) out of a committed snapshot
    without materializing any full store leaf.

    Uses the checkpointer's partial-read API: the small page tables and
    scales load whole (CRC-checked), the big store leaf is sliced through
    a memory map.  Returns ``{page, tier, shard, slot, rows, scale,
    checksum}`` where ``checksum`` is the snapshot-time ledger entry for
    the page (None on pre-ledger snapshots) — repair verifies the read
    rows against it via :func:`page_checksum_host` before trusting them.
    """
    step = checkpointer.latest_step() if step is None else step
    if step is None:
        raise FileNotFoundError("no committed snapshot to read a page from")
    p2s = checkpointer.read_leaf("page_to_shard", step=step)
    p2slot = checkpointer.read_leaf("page_to_slot", step=step)
    scales = checkpointer.read_leaf("page_scales", step=step)
    shard, slot = int(p2s[page]), int(p2slot[page])
    ps = cfg.page_size
    if shard == HOT_SHARD:
        tier = "hot"
        rows = checkpointer.read_page("hot", slot * ps, ps, step=step)
    else:
        tier = "cold"
        rows = checkpointer.read_page(
            "cold", shard * cfg.rows_per_shard + slot * ps, ps, step=step)
    rec = checkpointer.extra(step).get("page_checksums")
    checksum = (int(rec["checksums"][page], 16)
                if rec and rec.get("checksums") else None)
    return {"page": int(page), "tier": tier, "shard": shard, "slot": slot,
            "rows": rows, "scale": float(scales[page]), "checksum": checksum}
