"""Access profiling + buffer replacement policies (paper sections IV-A4, IV-B2).

Two consumers:
  * the placement planner (hot-page selection = page-granular HTR), and
  * simlab's on-switch SRAM buffer model (row-granular HTR vs LRU vs FIFO,
    Fig. 15).

`AccessProfiler` is the paper's "address profiler [that] logs and ranks
frequently accessed row vectors".  Policies are plain-python simulation
objects (they model switch hardware state, not JAX tensors); the jnp-side
counterpart used under jit is `update_counts`.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def update_counts(counts: jax.Array, pages: jax.Array,
                  decay: float = 1.0) -> jax.Array:
    """jit-friendly page-access histogram update (scatter-add, optional EWMA)."""
    if decay != 1.0:
        counts = counts * decay
    ones = jnp.ones(pages.shape, counts.dtype)
    return counts.at[pages].add(ones)


class AccessProfiler:
    """Host-side frequency profiler with exponential decay."""

    def __init__(self, n_items: int, decay: float = 0.9):
        self.counts = np.zeros(n_items, dtype=np.float64)
        self.decay = decay

    def observe(self, items: np.ndarray) -> None:
        self.counts *= self.decay
        np.add.at(self.counts, np.asarray(items).ravel(), 1.0)

    def hottest(self, k: int) -> np.ndarray:
        k = min(k, len(self.counts))
        part = np.argpartition(-self.counts, k - 1)[:k]
        return part[np.argsort(-self.counts[part])]


class BufferPolicy:
    """Fixed-capacity cache model; returns hit/miss per access."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.hits = 0
        self.accesses = 0

    def access(self, key: int) -> bool:
        raise NotImplementedError

    def run(self, keys: Iterable[int]) -> float:
        for k in keys:
            self.accesses += 1
            if self.access(int(k)):
                self.hits += 1
        return self.hit_rate

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.accesses)


class LRUCache(BufferPolicy):
    def __init__(self, capacity: int):
        super().__init__(capacity)
        self._od: "OrderedDict[int, None]" = OrderedDict()

    def access(self, key: int) -> bool:
        if key in self._od:
            self._od.move_to_end(key)
            return True
        if len(self._od) >= self.capacity:
            self._od.popitem(last=False)
        self._od[key] = None
        return False


class FIFOCache(BufferPolicy):
    def __init__(self, capacity: int):
        super().__init__(capacity)
        self._od: "OrderedDict[int, None]" = OrderedDict()

    def access(self, key: int) -> bool:
        if key in self._od:
            return True
        if len(self._od) >= self.capacity:
            self._od.popitem(last=False)
        self._od[key] = None
        return False


class HTRCache(BufferPolicy):
    """Hottest-Recording (paper section IV-A4): an address profiler ranks rows
    by access frequency; the buffer retains the current top-`capacity`
    candidates.  Re-ranking happens every `rerank_every` accesses (the paper's
    profiler is periodic hardware logic, not per-access)."""

    def __init__(self, capacity: int, rerank_every: int = 2048, decay: float = 0.98):
        super().__init__(capacity)
        self._freq: Dict[int, float] = {}
        self._resident: set = set()
        self._since_rerank = 0
        self.rerank_every = rerank_every
        self.decay = decay

    def _rerank(self) -> None:
        top = sorted(self._freq.items(), key=lambda kv: -kv[1])[: self.capacity]
        self._resident = {k for k, _ in top}
        # decay so the profile tracks drift
        self._freq = {k: v * self.decay for k, v in self._freq.items() if v > 1e-3}

    def access(self, key: int) -> bool:
        self._freq[key] = self._freq.get(key, 0.0) + 1.0
        self._since_rerank += 1
        if self._since_rerank >= self.rerank_every:
            self._since_rerank = 0
            self._rerank()
        hit = key in self._resident
        if not hit and len(self._resident) < self.capacity:
            self._resident.add(key)
        return hit


def make_policy(name: str, capacity: int) -> BufferPolicy:
    name = name.lower()
    if name == "htr":
        return HTRCache(capacity)
    if name == "lru":
        return LRUCache(capacity)
    if name == "fifo":
        return FIFOCache(capacity)
    raise ValueError(f"unknown buffer policy {name!r}")
