"""Synthetic DLRM access-trace generators (paper section VI-C2, Fig. 12b).

The paper evaluates on Meta production traces [58] plus synthetic traces
"emulat[ing] various distribution types based on the access candidates
observed in the Meta traces": Zipfian (ZF), Normal (NoL), Uniform (Um) and
Random (Rm).  The open Meta trace files are not redistributable offline, so
this generator reproduces the distribution *families*; the Zipfian skew is
calibrated so a 512 KB HTR buffer sees the hit-rate regime the paper reports
(~42 % at 1 MB for RMC4 — see benchmarks/fig15_buffer.py).

A trace is a sequence of SLS requests: for each (batch sample, table) bag,
`pooling` row ids drawn from the table's id space under the distribution.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

# rng stream tags: every random decision is keyed (seed, tag, counter), so
# each stream (init / per-batch draw / per-batch drift / per-request serve)
# is deterministic under TraceConfig.seed independent of call order: batch
# k's drift remap is a pure function of (seed, k), and serve-request draws
# never consume batch-stream randomness.  The one intentional coupling is
# the hot-set permutation itself — serve_requests(drift_every > 0) churns
# the same permutation the batch stream reads (shared popularity drift),
# so mixing the two streams on one generator shares that state by design.
_INIT_TAG = 0x11A0
_BATCH_TAG = 0x11A1
_DRIFT_TAG = 0x11A2
_SERVE_TAG = 0x11A3
_SERVE_DRIFT_TAG = 0x11A4


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    n_rows: int                  # rows per table
    n_tables: int = 8
    pooling: int = 8             # lookups per bag (paper: "8 per batch")
    batch: int = 1024
    distribution: str = "zipfian"  # zipfian | normal | uniform | random
    zipf_alpha: float = 1.1      # calibrated to Meta-trace-like skew
    normal_sigma_frac: float = 0.05
    # hot-set churn per batch: production popularity drifts (this is why the
    # paper's cold_age_threshold / periodic reclassification exists); each
    # batch remaps this fraction of the hottest ranks to fresh rows
    drift_per_batch: float = 0.25
    drift_window: int = 65536    # ranks eligible to churn
    seed: int = 0


class TraceGenerator:
    """Stateful host-side generator: each call yields (batch, tables, pooling)
    int64 row ids (table-local)."""

    def __init__(self, cfg: TraceConfig):
        self.cfg = cfg
        init_rng = np.random.default_rng([cfg.seed, _INIT_TAG])
        if cfg.distribution == "zipfian":
            # fixed preference permutation per table: hot ids are scattered
            # across the address space (like hashed ids in production)
            self._perm = np.stack([
                init_rng.permutation(cfg.n_rows)
                for _ in range(cfg.n_tables)])
            ranks = np.arange(1, cfg.n_rows + 1, dtype=np.float64)
            w = ranks ** -cfg.zipf_alpha
            self._cdf = np.cumsum(w) / w.sum()
        elif cfg.distribution == "normal":
            self._centers = init_rng.integers(0, cfg.n_rows, cfg.n_tables)
        self._n_batches = 0     # drift schedule position (batch stream)
        self._n_serve = 0       # serve-request stream position
        self._serve_pos = 0     # serve-stream uniform sweep cursor (ids)

    def _draw(self, table: int, n: int, rng: np.random.Generator,
              pos: int = 0) -> np.ndarray:
        c = self.cfg
        if c.distribution == "uniform":
            # perfectly balanced round-robin over the id space: a
            # contiguous sweep continuing from the stream cursor, so
            # page-level access counts stay maximally even over any window
            # (a strided scatter aliases onto page-to-shard residue
            # classes and leaves sparse tied counts the placement LPT
            # can't balance)
            return (pos + np.arange(n, dtype=np.int64)) % c.n_rows
        if c.distribution == "random":
            return rng.integers(0, c.n_rows, n)
        if c.distribution == "normal":
            mu = self._centers[table]
            sd = max(1.0, c.n_rows * c.normal_sigma_frac)
            ids = np.rint(rng.normal(mu, sd, n)).astype(np.int64)
            return np.mod(ids, c.n_rows)
        # zipfian via inverse-CDF on the rank distribution
        u = rng.random(n)
        ranks = np.searchsorted(self._cdf, u)
        return self._perm[table][np.minimum(ranks, c.n_rows - 1)]

    def _drift(self, rng: np.random.Generator) -> None:
        """Churn the hot set: swap a fraction of hot ranks with random ranks
        (keeps each table's rank->row map a permutation)."""
        c = self.cfg
        if c.distribution != "zipfian" or c.drift_per_batch <= 0:
            return
        window = min(c.drift_window, c.n_rows)
        m = max(1, int(window * c.drift_per_batch))
        for t in range(c.n_tables):
            hot_ranks = rng.choice(window, m, replace=False)
            other_ranks = rng.integers(0, c.n_rows, m)
            p = self._perm[t]
            p[hot_ranks], p[other_ranks] = (p[other_ranks].copy(),
                                            p[hot_ranks].copy())

    def next_batch(self) -> np.ndarray:
        """(batch, n_tables, pooling) table-local row ids."""
        c = self.cfg
        rng = np.random.default_rng([c.seed, _BATCH_TAG, self._n_batches])
        pos = self._n_batches * c.batch * c.pooling   # uniform sweep cursor
        out = np.empty((c.batch, c.n_tables, c.pooling), dtype=np.int64)
        for t in range(c.n_tables):
            out[:, t, :] = self._draw(t, c.batch * c.pooling, rng,
                                      pos=pos).reshape(c.batch, c.pooling)
        self._drift(np.random.default_rng(
            [c.seed, _DRIFT_TAG, self._n_batches]))
        self._n_batches += 1
        return out

    def stream(self, n_batches: int) -> Iterator[np.ndarray]:
        for _ in range(n_batches):
            yield self.next_batch()

    def serve_requests(self, n: Optional[int] = None,
                       poolings: Optional[Sequence[int]] = None,
                       drift_every: int = 0) -> Iterator[np.ndarray]:
        """Per-request iterator for the serving load generator (the
        ``kind="serve"`` counterpart of the batch stream).

        Yields ``(n_tables, L)`` table-local row ids per request, with the
        per-request pooling ``L`` sampled uniformly from ``poolings``
        (default: the config's fixed pooling).  ``drift_every > 0`` churns
        the hot set every that many requests, mirroring the batch stream's
        popularity drift at request granularity.

        Determinism: request ``i``'s randomness is keyed ``(seed, i)`` and
        the hot-set permutation is a pure function of ``(seed, drifts
        applied so far)``, so the stream replays exactly for a given call
        sequence, and consuming serve requests never perturbs the batch
        stream (or vice versa beyond the intentional shared drift)."""
        c = self.cfg
        choices = tuple(poolings) if poolings else (c.pooling,)
        produced = 0
        while n is None or produced < n:
            i = self._n_serve
            rng = np.random.default_rng([c.seed, _SERVE_TAG, i])
            L = int(choices[rng.integers(len(choices))])
            out = np.empty((c.n_tables, L), dtype=np.int64)
            # uniform sweep cursor advances by the ids actually drawn, so
            # variable poolings leave no gaps in the round-robin coverage
            for t in range(c.n_tables):
                out[t] = self._draw(t, L, rng, pos=self._serve_pos)
            self._serve_pos += L
            self._n_serve += 1
            produced += 1
            if drift_every and self._n_serve % drift_every == 0:
                self._drift(np.random.default_rng(
                    [c.seed, _SERVE_DRIFT_TAG, i]))
            yield out


def flatten_trace(batches: np.ndarray, n_rows: int) -> np.ndarray:
    """(B, T, L) table-local -> flat global row ids (table-stacked)."""
    B, T, L = batches.shape
    offs = (np.arange(T, dtype=np.int64) * n_rows)[None, :, None]
    return (batches + offs).reshape(-1)


def make_trace(distribution: str, n_rows: int, n_tables: int = 8,
               pooling: int = 8, batch: int = 1024, n_batches: int = 16,
               seed: int = 0, **kw) -> np.ndarray:
    """Convenience: a full (n_batches, B, T, L) trace tensor."""
    gen = TraceGenerator(TraceConfig(
        n_rows=n_rows, n_tables=n_tables, pooling=pooling, batch=batch,
        distribution=distribution, seed=seed, **kw))
    return np.stack(list(gen.stream(n_batches)))
