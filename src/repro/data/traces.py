"""Synthetic DLRM access-trace generators (paper section VI-C2, Fig. 12b).

The paper evaluates on Meta production traces [58] plus synthetic traces
"emulat[ing] various distribution types based on the access candidates
observed in the Meta traces": Zipfian (ZF), Normal (NoL), Uniform (Um) and
Random (Rm).  The open Meta trace files are not redistributable offline, so
this generator reproduces the distribution *families*; the Zipfian skew is
calibrated so a 512 KB HTR buffer sees the hit-rate regime the paper reports
(~42 % at 1 MB for RMC4 — see benchmarks/fig15_buffer.py).

A trace is a sequence of SLS requests: for each (batch sample, table) bag,
`pooling` row ids drawn from the table's id space under the distribution.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    n_rows: int                  # rows per table
    n_tables: int = 8
    pooling: int = 8             # lookups per bag (paper: "8 per batch")
    batch: int = 1024
    distribution: str = "zipfian"  # zipfian | normal | uniform | random
    zipf_alpha: float = 1.1      # calibrated to Meta-trace-like skew
    normal_sigma_frac: float = 0.05
    # hot-set churn per batch: production popularity drifts (this is why the
    # paper's cold_age_threshold / periodic reclassification exists); each
    # batch remaps this fraction of the hottest ranks to fresh rows
    drift_per_batch: float = 0.25
    drift_window: int = 65536    # ranks eligible to churn
    seed: int = 0


class TraceGenerator:
    """Stateful host-side generator: each call yields (batch, tables, pooling)
    int64 row ids (table-local)."""

    def __init__(self, cfg: TraceConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        if cfg.distribution == "zipfian":
            # fixed preference permutation per table: hot ids are scattered
            # across the address space (like hashed ids in production)
            self._perm = np.stack([
                self.rng.permutation(cfg.n_rows) for _ in range(cfg.n_tables)])
            ranks = np.arange(1, cfg.n_rows + 1, dtype=np.float64)
            w = ranks ** -cfg.zipf_alpha
            self._cdf = np.cumsum(w) / w.sum()
        elif cfg.distribution == "normal":
            self._centers = self.rng.integers(0, cfg.n_rows, cfg.n_tables)

    def _draw(self, table: int, n: int) -> np.ndarray:
        c = self.cfg
        if c.distribution == "uniform":
            # perfectly balanced round-robin over the id space
            start = self.rng.integers(0, c.n_rows)
            return (start + np.arange(n, dtype=np.int64) *
                    max(1, c.n_rows // max(n, 1))) % c.n_rows
        if c.distribution == "random":
            return self.rng.integers(0, c.n_rows, n)
        if c.distribution == "normal":
            mu = self._centers[table]
            sd = max(1.0, c.n_rows * c.normal_sigma_frac)
            ids = np.rint(self.rng.normal(mu, sd, n)).astype(np.int64)
            return np.mod(ids, c.n_rows)
        # zipfian via inverse-CDF on the rank distribution
        u = self.rng.random(n)
        ranks = np.searchsorted(self._cdf, u)
        return self._perm[table][np.minimum(ranks, c.n_rows - 1)]

    def _drift(self) -> None:
        """Churn the hot set: swap a fraction of hot ranks with random ranks
        (keeps each table's rank->row map a permutation)."""
        c = self.cfg
        if c.distribution != "zipfian" or c.drift_per_batch <= 0:
            return
        window = min(c.drift_window, c.n_rows)
        m = max(1, int(window * c.drift_per_batch))
        for t in range(c.n_tables):
            hot_ranks = self.rng.choice(window, m, replace=False)
            other_ranks = self.rng.integers(0, c.n_rows, m)
            p = self._perm[t]
            p[hot_ranks], p[other_ranks] = (p[other_ranks].copy(),
                                            p[hot_ranks].copy())

    def next_batch(self) -> np.ndarray:
        """(batch, n_tables, pooling) table-local row ids."""
        c = self.cfg
        out = np.empty((c.batch, c.n_tables, c.pooling), dtype=np.int64)
        for t in range(c.n_tables):
            out[:, t, :] = self._draw(t, c.batch * c.pooling).reshape(
                c.batch, c.pooling)
        self._drift()
        return out

    def stream(self, n_batches: int) -> Iterator[np.ndarray]:
        for _ in range(n_batches):
            yield self.next_batch()


def flatten_trace(batches: np.ndarray, n_rows: int) -> np.ndarray:
    """(B, T, L) table-local -> flat global row ids (table-stacked)."""
    B, T, L = batches.shape
    offs = (np.arange(T, dtype=np.int64) * n_rows)[None, :, None]
    return (batches + offs).reshape(-1)


def make_trace(distribution: str, n_rows: int, n_tables: int = 8,
               pooling: int = 8, batch: int = 1024, n_batches: int = 16,
               seed: int = 0, **kw) -> np.ndarray:
    """Convenience: a full (n_batches, B, T, L) trace tensor."""
    gen = TraceGenerator(TraceConfig(
        n_rows=n_rows, n_tables=n_tables, pooling=pooling, batch=batch,
        distribution=distribution, seed=seed, **kw))
    return np.stack(list(gen.stream(n_batches)))
