"""Input pipeline: background-thread prefetch + device placement.

Host generators (data/synth.py, data/traces.py) produce numpy batches; this
wrapper overlaps generation with device compute via a bounded queue and
places arrays with the step's input shardings (so a (global_batch, ...)
numpy array lands directly as a dp-sharded jax.Array — no host replication).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np


class Prefetcher:
    """Wrap an iterator of numpy pytrees; prefetch `depth` batches on a
    daemon thread; optionally device_put with shardings."""

    def __init__(self, it: Iterator[Any], depth: int = 2,
                 shardings: Optional[Any] = None):
        self._it = it
        self._shardings = shardings
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._shardings is None:
            return batch
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), batch, self._shardings)

    def _worker(self):
        try:
            for batch in self._it:
                self._q.put(self._place(batch))
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def shard_batch(batch: Dict[str, np.ndarray], shardings: Dict[str, Any]
                ) -> Dict[str, jax.Array]:
    """One-shot device placement with named shardings."""
    return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}
