"""Synthetic dataset generators for every model family (offline container:
no downloads; statistics follow the public datasets each config cites).

All generators are host-side numpy and deterministic given a seed; the
pipeline wraps them into device-ready batches.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import DLRMConfig, GNNConfig, LMConfig, RecConfig
from repro.data.traces import TraceConfig, TraceGenerator


# ---------------------------------------------------------------------------
# Click / CTR data (DLRM + recsys)
# ---------------------------------------------------------------------------


def dlrm_batches(cfg: DLRMConfig, batch: int, n_batches: int,
                 distribution: str = "zipfian", seed: int = 0
                 ) -> Iterator[Dict[str, np.ndarray]]:
    """Criteo-like stream: dense gaussians + per-table zipfian multi-hot ids +
    a click label correlated with a random linear teacher (learnable)."""
    rng = np.random.default_rng(seed)
    gen = TraceGenerator(TraceConfig(
        n_rows=cfg.emb_num, n_tables=cfg.n_tables, pooling=cfg.pooling,
        batch=batch, distribution=distribution, seed=seed))
    w_teacher = rng.normal(size=cfg.n_dense)
    offs = (np.arange(cfg.n_tables, dtype=np.int64) * _padded_rows(cfg))
    for _ in range(n_batches):
        dense = rng.normal(size=(batch, cfg.n_dense)).astype(np.float32)
        idx = gen.next_batch() + offs[None, :, None]
        margin = dense @ w_teacher / np.sqrt(cfg.n_dense)
        labels = (margin + rng.normal(scale=0.5, size=batch) > 0)
        yield {"dense": dense, "indices": idx.astype(np.int32),
               "labels": labels.astype(np.int32)}


def _padded_rows(cfg: DLRMConfig, page_bytes: int = 4096,
                 storage: str = "fp32") -> int:
    """Per-table padded rows — must mirror ``engine_for_tables``' page
    rounding, including the cold-tier storage format (int8 pages of the
    same ``page_bytes`` hold 4x the rows, so the padding boundary moves)."""
    itemsize = 1 if storage == "int8" else 4
    ps = max(1, page_bytes // (cfg.emb_dim * itemsize))
    return -(-cfg.emb_num // ps) * ps


def rec_batches(cfg: RecConfig, batch: int, n_batches: int, seed: int = 0,
                kind: str = "train") -> Iterator[Dict[str, np.ndarray]]:
    """Batches shaped for repro.models.recsys.forward/loss_fn."""
    rng = np.random.default_rng(seed)
    it = cfg.interaction
    for _ in range(n_batches):
        b: Dict[str, np.ndarray] = {}
        if it in ("self-attn-seq", "transformer-seq"):
            V = cfg.vocab_sizes[0]
            # zipf-ish popularity for items
            seq = _zipf_ids(rng, V, (batch, cfg.seq_len))
            b["seq"] = seq.astype(np.int32)
            if it == "transformer-seq":
                b["dense"] = rng.normal(
                    size=(batch, cfg.n_dense)).astype(np.float32)
            if kind == "train" and it == "self-attn-seq":
                b["pos"] = np.roll(seq, -1, axis=1).astype(np.int32)
                b["neg"] = _zipf_ids(rng, V, (batch, cfg.seq_len)).astype(np.int32)
            else:
                b["target"] = _zipf_ids(rng, V, (batch,)).astype(np.int32)
                if kind == "train":
                    b["labels"] = rng.integers(0, 2, batch).astype(np.int32)
        else:
            fields = np.stack(
                [_zipf_ids(rng, v, (batch,)) for v in cfg.vocab_sizes], axis=1)
            b["fields"] = fields.astype(np.int32)
            if cfg.n_dense:
                b["dense"] = rng.normal(
                    size=(batch, cfg.n_dense)).astype(np.float32)
            if kind == "train":
                b["labels"] = rng.integers(0, 2, batch).astype(np.int32)
        yield b


def _zipf_ids(rng: np.random.Generator, vocab: int, shape: Tuple[int, ...],
              alpha: float = 1.05) -> np.ndarray:
    n = int(np.prod(shape))
    # bounded zipf via rejection-free inverse transform on a truncated tail
    u = rng.random(n)
    ids = np.floor(
        ((vocab ** (1 - alpha) - 1) * u + 1) ** (1 / (1 - alpha))) - 1
    ids = np.clip(ids.astype(np.int64), 0, vocab - 1)
    return rng.permutation(vocab)[ids].reshape(shape) if vocab <= 10_000_000 \
        else ids.reshape(shape)


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------


def lm_batches(cfg: LMConfig, batch: int, seq: int, n_batches: int,
               seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Markov-ish token stream: unigram zipf + short-range repetition, so a
    model trained a few hundred steps shows a visibly decreasing loss."""
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        toks = _zipf_ids(rng, cfg.vocab, (batch, seq + 1), alpha=1.1)
        # inject copy structure: 25% of positions repeat t-2
        rep = rng.random((batch, seq + 1)) < 0.25
        toks[:, 2:] = np.where(rep[:, 2:], toks[:, :-2], toks[:, 2:])
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------


def make_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
               seed: int = 0) -> Dict[str, np.ndarray]:
    """Power-law-ish random graph + community-correlated features/labels."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-flavoured edge sampling
    popularity = rng.zipf(1.3, n_nodes).astype(np.float64)
    popularity /= popularity.sum()
    src = rng.choice(n_nodes, n_edges, p=popularity)
    dst = rng.integers(0, n_nodes, n_edges)
    labels = rng.integers(0, n_classes, n_nodes)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats = centers[labels] + rng.normal(
        scale=1.0, size=(n_nodes, d_feat)).astype(np.float32)
    edges = np.stack([src, dst], axis=1).astype(np.int32)
    return {"feats": feats, "edges": edges,
            "labels": labels.astype(np.int32)}


def to_csr(n_nodes: int, edges: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Edge list -> CSR (indptr, indices) for the neighbor sampler."""
    src, dst = edges[:, 0], edges[:, 1]
    order = np.argsort(src, kind="stable")
    indices = dst[order].astype(np.int64)
    counts = np.bincount(src, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, indices


def molecule_batches(graph_batch: int, n_nodes: int, n_edges: int,
                     d_feat: int, n_classes: int, n_batches: int,
                     seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        feats = rng.normal(
            size=(graph_batch, n_nodes, d_feat)).astype(np.float32)
        edges = rng.integers(
            0, n_nodes, (graph_batch, n_edges, 2)).astype(np.int32)
        labels = rng.integers(0, n_classes, graph_batch).astype(np.int32)
        yield {"feats": feats, "edges": edges, "labels": labels}
