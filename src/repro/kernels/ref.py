"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sls_ref(table: jax.Array, indices: jax.Array,
            weights: Optional[jax.Array] = None,
            out_dtype=jnp.float32) -> jax.Array:
    """SparseLengthSum oracle.

    table: (V, D); indices: (B, L) int32; weights: optional (B, L).
    out[b] = sum_l w[b,l] * table[idx[b,l]]  in out_dtype accumulation.
    """
    rows = jnp.take(table, indices, axis=0).astype(out_dtype)   # (B, L, D)
    if weights is not None:
        rows = rows * weights[..., None].astype(out_dtype)
    return rows.sum(axis=1)


def masked_sls_ref(table: jax.Array, indices: jax.Array, owned: jax.Array,
                   weights: Optional[jax.Array] = None,
                   out_dtype=jnp.float32,
                   scales: Optional[jax.Array] = None) -> jax.Array:
    """Masked partial SLS oracle (the PIFS per-shard operator, dense bags).

    table: (V, D); indices/owned: (B, L); weights: optional (B, L).
    out[b] = sum_l owned[b,l] * w[b,l] * table[idx[b,l]].  Non-owned entries
    are remapped to row 0 before the gather (row 0 must exist) and zeroed by
    the mask, matching the kernel's always-resident-line trick.

    Optional ``scales`` (B, L): per-entry dequant scales for a quantized
    (e.g. int8) ``table`` — each gathered row is dequantized
    (``float(row) * scale``) before the weighted accumulate, matching the
    kernel's fused dequant (the fp32 row is never materialized table-wide).
    """
    safe = jnp.where(owned, indices, 0)
    rows = jnp.take(table, safe, axis=0).astype(out_dtype)      # (B, L, D)
    if scales is not None:
        rows = rows * scales[..., None].astype(out_dtype)
    w = owned.astype(out_dtype)
    if weights is not None:
        w = w * weights.astype(out_dtype)
    return (rows * w[..., None]).sum(axis=1)


def _fixed_order_masked_sls(table: jax.Array, indices: jax.Array,
                            owned: jax.Array,
                            weights: Optional[jax.Array] = None,
                            scales: Optional[jax.Array] = None,
                            out_dtype=jnp.float32) -> jax.Array:
    """Masked partial SLS with the kernels' **fixed l-order accumulation**
    (the ``lax.scan`` structure of :func:`masked_sls_quant_ref`, optional
    scales) — the shared tail of every oracle that must match a Pallas
    kernel bit-for-bit in fp32."""
    B, L = indices.shape
    D = table.shape[-1]
    safe = jnp.where(owned, indices, 0)
    rows = jnp.take(table, safe, axis=0).astype(out_dtype)      # (B, L, D)
    if scales is not None:
        rows = rows * scales[..., None].astype(out_dtype)
    f = owned.astype(out_dtype)
    if weights is not None:
        f = f * weights.astype(out_dtype)

    def step(carry, xs):
        rows_l, f_l = xs
        return carry + f_l[:, None] * rows_l, None

    out, _ = jax.lax.scan(step, jnp.zeros((B, D), out_dtype),
                          (rows.transpose(1, 0, 2), f.T))
    return out


def fused_front_end_ref(cold: jax.Array, hot: jax.Array, x: jax.Array,
                        rows: jax.Array, owned: jax.Array,
                        is_hot: jax.Array,
                        weights: Optional[jax.Array] = None,
                        scales: Optional[jax.Array] = None,
                        out_dtype=jnp.float32) -> jax.Array:
    """Fused DLRM front-end oracle: SLS -> features -> dot-interaction.

    cold/hot: (Vc, D) / (Vh, D) tier tables (cold may be int8 codes with
    per-entry ``scales``); rows/owned/is_hot: (B, G, L) local rows + tier
    masks; x: (B, D) bottom-MLP output (feature row 0).  Returns the
    (B, P) packed lower triangle, P = F*(F-1)/2 with F = G + 1.

    This is **exactly the split pipeline** with each tier's partial SLS in
    the kernels' fixed l-order: ``pooled = cold_partial + hot_partial``
    (that add order is the split path's ``psum(cold) + hot``), features
    concatenated, then :func:`dot_interaction_ref` — which the fused Pallas
    kernel must match **bit-for-bit in fp32** (phase 2 reproduces each
    tier's accumulate with identical operands; phase 3 is the same
    dot_general + static-gather pack as the interaction kernel)."""
    B, G, L = rows.shape
    D = cold.shape[-1]
    flat = rows.reshape(B * G, L)
    w = None if weights is None else weights.reshape(B * G, L)
    cold_p = _fixed_order_masked_sls(
        cold, flat, owned.reshape(B * G, L), w,
        None if scales is None else scales.reshape(B * G, L), out_dtype)
    hot_p = _fixed_order_masked_sls(
        hot, flat, is_hot.reshape(B * G, L), w, None, out_dtype)
    pooled = (cold_p + hot_p).reshape(B, G, D)
    feats = jnp.concatenate([x[:, None, :].astype(out_dtype), pooled],
                            axis=1)                             # (B, F, D)
    return dot_interaction_ref(feats)


def fused_partial_pool_ref(cold: jax.Array, hot: jax.Array, x: jax.Array,
                           rows: jax.Array, owned: jax.Array,
                           is_hot: jax.Array,
                           weights: Optional[jax.Array] = None,
                           scales: Optional[jax.Array] = None,
                           out_dtype=jnp.float32):
    """Partial-pool oracle: phases 1-2 of :func:`fused_front_end_ref`,
    stopped at the phase-2/3 seam for tensor-parallel execution.

    Returns the per-tier (B, F, D) partial feature tiles:

      * ``part_c`` — this shard's cold-tier fixed-l-order partial pools with
        feature row 0 all-zero (the tile a tp dispatch ``psum``s — row 0
        must not pick up x ``tp`` times), and
      * ``part_h`` — the hot-tier partial pools with ``x`` in feature row 0
        (hot is replicated across tp shards and is never reduced).

    ``fused_resume_ref(psum(part_c), part_h)`` equals
    :func:`fused_front_end_ref` of the psum'd ownership — rows 1..G are the
    identical ``cold + hot`` adds; row 0 is ``0.0 + x`` (the same exact-zero
    add the fused kernel's staging performs)."""
    B, G, L = rows.shape
    D = cold.shape[-1]
    flat = rows.reshape(B * G, L)
    w = None if weights is None else weights.reshape(B * G, L)
    cold_p = _fixed_order_masked_sls(
        cold, flat, owned.reshape(B * G, L), w,
        None if scales is None else scales.reshape(B * G, L), out_dtype)
    hot_p = _fixed_order_masked_sls(
        hot, flat, is_hot.reshape(B * G, L), w, None, out_dtype)
    zero = jnp.zeros((B, 1, D), out_dtype)
    part_c = jnp.concatenate([zero, cold_p.reshape(B, G, D)], axis=1)
    part_h = jnp.concatenate([x[:, None, :].astype(out_dtype),
                              hot_p.reshape(B, G, D)], axis=1)
    return part_c, part_h


def fused_resume_ref(part_c: jax.Array, part_h: jax.Array) -> jax.Array:
    """Phase-3 resume oracle: cold/hot add on the reduced (B, F, D) tiles
    (the split path's ``psum(cold_part) + hot_out`` operand order), then
    :func:`dot_interaction_ref`."""
    return dot_interaction_ref(part_c + part_h)


def masked_sls_quant_ref(table_q: jax.Array, indices: jax.Array,
                         owned: jax.Array, scales: jax.Array,
                         weights: Optional[jax.Array] = None,
                         out_dtype=jnp.float32) -> jax.Array:
    """Quantized masked partial SLS oracle, **fixed l-order accumulation**.

    table_q: (V, D) int8 codes; scales: (B, L) per-entry dequant scales
    (the page scale gathered per pooling entry); indices/owned/weights as
    in :func:`masked_sls_ref`.

    out[b] = sum_{l=0..L-1} f[b,l] * (scales[b,l] * float(table_q[idx]))
    with f = owned * weights, accumulated in ascending l with the same
    ``add(mul(f, mul(scale, row)))`` structure as the Pallas kernel — the
    kernel must match this **bit-for-bit in fp32** (the dequant multiply
    happens per gathered row, *after* the bytes move, before the weighted
    add; accumulation order is the kernel's fixed l order).  The running
    accumulate (:func:`_fixed_order_masked_sls`) is a ``lax.scan`` over l:
    XLA contracts its mul+add to the same FMA it emits for the kernel's
    accumulate loop — a python-unrolled add chain compiles differently and
    drifts by an ulp on weighted entries.
    """
    return _fixed_order_masked_sls(table_q, indices, owned, weights, scales,
                                   out_dtype)


def masked_sls_dedup_ref(table: jax.Array, unique_rows: jax.Array,
                         slots: jax.Array, owned: jax.Array,
                         weights: Optional[jax.Array] = None,
                         unique_scales: Optional[jax.Array] = None,
                         out_dtype=jnp.float32) -> jax.Array:
    """Gather-once dedup'd masked partial SLS oracle (staging semantics).

    table: (V, D); unique_rows: (U,) compacted row ids (sentinel-padded —
    clamped into range at the gather); slots: (B, L) staging slot per
    pooling entry; owned/weights as in :func:`masked_sls_ref`;
    unique_scales: optional (U,) per-slot dequant scales.

    Phase 1 gathers (and dequantizes) each unique row exactly once into a
    (U, D) staging buffer; phase 2 is the **same fixed l-order accumulate**
    as :func:`masked_sls_quant_ref`, reading rows through the slot
    indirection.  Because the dequant multiply sees identical operands
    whether applied per entry or per unique row, this matches
    :func:`masked_sls_ref` / :func:`masked_sls_quant_ref` (given per-entry
    ``scales[b,l] == unique_scales[slots[b,l]]``) bit-for-bit in fp32 —
    and the two-phase Pallas kernel must match it bit-for-bit too.
    """
    B, L = slots.shape
    D = table.shape[-1]
    V = table.shape[0]
    staging = jnp.take(table, jnp.minimum(unique_rows, V - 1),
                       axis=0).astype(out_dtype)                # (U, D)
    if unique_scales is not None:
        staging = staging * unique_scales[:, None].astype(out_dtype)
    rows = jnp.take(staging, slots, axis=0)                     # (B, L, D)
    f = owned.astype(out_dtype)
    if weights is not None:
        f = f * weights.astype(out_dtype)

    def step(carry, xs):
        rows_l, f_l = xs
        return carry + f_l[:, None] * rows_l, None

    out, _ = jax.lax.scan(step, jnp.zeros((B, D), out_dtype),
                          (rows.transpose(1, 0, 2), f.T))
    return out


def dot_interaction_ref(feats: jax.Array, self_interaction: bool = False
                        ) -> jax.Array:
    """DLRM pairwise-dot feature interaction oracle.

    feats: (B, F, D) — bottom-MLP output + pooled embeddings stacked.
    Returns (B, P) packed lower triangle of feats @ feats^T,
    P = F*(F-1)/2 (+F if self_interaction).
    """
    B, F, D = feats.shape
    z = jnp.einsum("bfd,bgd->bfg", feats, feats)
    i, j = jnp.tril_indices(F, k=0 if self_interaction else -1)
    return z[:, i, j]
