"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sls_ref(table: jax.Array, indices: jax.Array,
            weights: Optional[jax.Array] = None,
            out_dtype=jnp.float32) -> jax.Array:
    """SparseLengthSum oracle.

    table: (V, D); indices: (B, L) int32; weights: optional (B, L).
    out[b] = sum_l w[b,l] * table[idx[b,l]]  in out_dtype accumulation.
    """
    rows = jnp.take(table, indices, axis=0).astype(out_dtype)   # (B, L, D)
    if weights is not None:
        rows = rows * weights[..., None].astype(out_dtype)
    return rows.sum(axis=1)


def masked_sls_ref(table: jax.Array, indices: jax.Array, owned: jax.Array,
                   weights: Optional[jax.Array] = None,
                   out_dtype=jnp.float32) -> jax.Array:
    """Masked partial SLS oracle (the PIFS per-shard operator, dense bags).

    table: (V, D); indices/owned: (B, L); weights: optional (B, L).
    out[b] = sum_l owned[b,l] * w[b,l] * table[idx[b,l]].  Non-owned entries
    are remapped to row 0 before the gather (row 0 must exist) and zeroed by
    the mask, matching the kernel's always-resident-line trick.
    """
    safe = jnp.where(owned, indices, 0)
    rows = jnp.take(table, safe, axis=0).astype(out_dtype)      # (B, L, D)
    w = owned.astype(out_dtype)
    if weights is not None:
        w = w * weights.astype(out_dtype)
    return (rows * w[..., None]).sum(axis=1)


def dot_interaction_ref(feats: jax.Array, self_interaction: bool = False
                        ) -> jax.Array:
    """DLRM pairwise-dot feature interaction oracle.

    feats: (B, F, D) — bottom-MLP output + pooled embeddings stacked.
    Returns (B, P) packed lower triangle of feats @ feats^T,
    P = F*(F-1)/2 (+F if self_interaction).
    """
    B, F, D = feats.shape
    z = jnp.einsum("bfd,bgd->bfg", feats, feats)
    i, j = jnp.tril_indices(F, k=0 if self_interaction else -1)
    return z[:, i, j]
