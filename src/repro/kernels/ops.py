"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel body
runs as Python/jnp on CPU); on TPU set ``interpret=False`` (the default picks
by backend).  ``impl='jnp'`` falls back to the oracle — models use that path
for fast CPU smoke tests, while tests sweep the pallas path against ref.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.interaction import dot_interaction_pallas
from repro.kernels.sls import sls_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def sls(table: jax.Array, indices: jax.Array,
        weights: Optional[jax.Array] = None, out_dtype=jnp.float32,
        impl: str = "pallas", interpret: Optional[bool] = None) -> jax.Array:
    if impl == "jnp":
        return ref.sls_ref(table, indices, weights, out_dtype)
    if interpret is None:
        interpret = _default_interpret()
    return sls_pallas(table, indices, weights, out_dtype=out_dtype,
                      interpret=interpret)


def dot_interaction(feats: jax.Array, self_interaction: bool = False,
                    impl: str = "pallas", block_b: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    if impl == "jnp":
        return ref.dot_interaction_ref(feats, self_interaction)
    if interpret is None:
        interpret = _default_interpret()
    B = feats.shape[0]
    while B % block_b:
        block_b //= 2
    return dot_interaction_pallas(feats, self_interaction,
                                  block_b=max(block_b, 1), interpret=interpret)
