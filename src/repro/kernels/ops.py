"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel body
runs as Python/jnp on CPU); on TPU set ``interpret=False`` (the default picks
by backend).  ``impl='jnp'`` falls back to the oracle — models use that path
for fast CPU smoke tests, while tests sweep the pallas path against ref.

Lane alignment: TPU tiles are (sublane, 128); embedding dims that are not a
multiple of 128 are zero-padded here (table columns + output slice) before the
kernel sees them, so the kernel itself always works on lane-aligned rows.
Padding defaults to on for compiled TPU execution and off in interpret mode
(where alignment buys nothing); production deployments should store tables
pre-padded to avoid the per-call pad (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.interaction import dot_interaction_pallas
from repro.kernels.sls import (fused_front_end_dedup_pallas,
                               fused_front_end_pallas,
                               fused_partial_pool_dedup_pallas,
                               fused_partial_pool_pallas, fused_resume_pallas,
                               masked_sls_dedup_pallas, masked_sls_pallas,
                               sls_pallas)

LANES = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pad_to_lanes(table: jax.Array, pad_lanes: bool) -> jax.Array:
    """Zero-pad the minor (D) dim up to the 128-lane boundary."""
    D = table.shape[-1]
    if not pad_lanes or D % LANES == 0:
        return table
    return jnp.pad(table, ((0, 0), (0, LANES - D % LANES)))


def sls(table: jax.Array, indices: jax.Array,
        weights: Optional[jax.Array] = None, out_dtype=jnp.float32,
        impl: str = "pallas", interpret: Optional[bool] = None,
        block_l: int = 8, pad_lanes: Optional[bool] = None) -> jax.Array:
    """Pooled embedding lookup: indices (B, L) -> (B, D)."""
    if impl == "jnp":
        return ref.sls_ref(table, indices, weights, out_dtype)
    if interpret is None:
        interpret = _default_interpret()
    if pad_lanes is None:
        pad_lanes = not interpret
    D = table.shape[-1]
    out = sls_pallas(pad_to_lanes(table, pad_lanes), indices, weights,
                     out_dtype=out_dtype, interpret=interpret,
                     block_l=block_l)
    return out[:, :D]


def masked_sls(table: jax.Array, indices: jax.Array, owned: jax.Array,
               weights: Optional[jax.Array] = None, out_dtype=jnp.float32,
               impl: str = "pallas", interpret: Optional[bool] = None,
               block_l: int = 8, pad_lanes: Optional[bool] = None,
               scales: Optional[jax.Array] = None) -> jax.Array:
    """Masked partial SLS (the PIFS per-shard operator): (B, L) -> (B, D).

    ``scales`` (B, L, optional) dequantizes a quantized (int8) ``table``
    per gathered row inside the kernel (fused dequant; see kernels/sls.py).
    Lane padding only touches the table's D axis, so scales are unaffected.
    """
    if impl == "jnp":
        return ref.masked_sls_ref(table, indices, owned, weights, out_dtype,
                                  scales=scales)
    if interpret is None:
        interpret = _default_interpret()
    if pad_lanes is None:
        pad_lanes = not interpret
    D = table.shape[-1]
    out = masked_sls_pallas(pad_to_lanes(table, pad_lanes), indices, owned,
                            weights, scales, out_dtype=out_dtype,
                            interpret=interpret, block_l=block_l)
    return out[:, :D]


def masked_sls_dedup(table: jax.Array, plan, owned: jax.Array,
                     weights: Optional[jax.Array] = None,
                     out_dtype=jnp.float32, impl: str = "pallas",
                     interpret: Optional[bool] = None, block_l: int = 8,
                     pad_lanes: Optional[bool] = None) -> jax.Array:
    """Gather-once dedup'd masked partial SLS: each unique owned row is
    DMA'd (and dequantized) exactly once into VMEM staging, then the
    bag-tiled accumulate reads through the plan's slot indirection.

    ``plan`` is a ``core/sls.DedupPlan`` (unique_rows/slots/n_slots/
    unique_scales); build it with ``core/sls.dedup_plan``.  Lane padding
    only touches the table's D axis — the plan arrays are index-space and
    unaffected.  Bit-for-bit equal to :func:`masked_sls` in fp32 (oracle:
    ``ref.masked_sls_dedup_ref``).
    """
    if impl == "jnp":
        return ref.masked_sls_dedup_ref(
            table, plan.unique_rows, plan.slots, owned, weights,
            unique_scales=plan.unique_scales, out_dtype=out_dtype)
    if interpret is None:
        interpret = _default_interpret()
    if pad_lanes is None:
        pad_lanes = not interpret
    D = table.shape[-1]
    out = masked_sls_dedup_pallas(
        pad_to_lanes(table, pad_lanes), plan.unique_rows, plan.slots,
        owned, plan.n_slots, weights, plan.unique_scales,
        out_dtype=out_dtype, interpret=interpret, block_l=block_l)
    return out[:, :D]


def dot_interaction(feats: jax.Array, self_interaction: bool = False,
                    impl: str = "pallas", block_b: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """DLRM pairwise-dot interaction.  ``interpret=None`` defers to the
    kernel's backend detection (interpret only off-TPU); passing a bool
    threads an explicit override through to ``dot_interaction_pallas``."""
    if impl == "jnp":
        return ref.dot_interaction_ref(feats, self_interaction)
    B = feats.shape[0]
    while B % block_b:
        block_b //= 2
    return dot_interaction_pallas(feats, self_interaction,
                                  block_b=max(block_b, 1), interpret=interpret)


def fused_front_end(cold: jax.Array, hot: jax.Array, x: jax.Array,
                    rows: jax.Array, owned: jax.Array, is_hot: jax.Array,
                    weights: Optional[jax.Array] = None,
                    scales: Optional[jax.Array] = None,
                    dedup_plans=None, out_dtype=jnp.float32,
                    impl: str = "pallas", interpret: Optional[bool] = None,
                    block_l: int = 8, block_b: int = 32,
                    pad_lanes: Optional[bool] = None) -> jax.Array:
    """Fused DLRM front end: masked two-tier SLS -> dot-interaction in one
    kernel — the pooled (B, F, D) features tensor never exists in HBM.

    ``rows``/``owned``/``is_hot`` (B, G, L) are per-entry local rows + tier
    masks, ``x`` (B, D) the bottom-MLP output.  ``dedup_plans`` is an
    optional ``(cold_plan, hot_plan)`` pair of ``core/sls.DedupPlan``s
    (slots reshaped (B, G, L) by the caller) selecting the gather-once
    kernel variant.  Lane padding touches only the D axis of the three
    dense operands; the (B, P) output is D-free, so no slice-back is
    needed (zero lanes add exact +0 terms to every pairwise dot).
    Bit-for-bit equal to the split pipeline in fp32 (oracle:
    ``ref.fused_front_end_ref``).
    """
    if impl == "jnp":
        if dedup_plans is not None:
            # the coalesced gather never changes the accumulate (PR 4);
            # the jnp oracle is the per-entry formulation
            dedup_plans = None
        return ref.fused_front_end_ref(cold, hot, x, rows, owned, is_hot,
                                       weights, scales, out_dtype)
    if interpret is None:
        interpret = _default_interpret()
    if pad_lanes is None:
        pad_lanes = not interpret
    cold = pad_to_lanes(cold, pad_lanes)
    hot = pad_to_lanes(hot, pad_lanes)
    x = pad_to_lanes(x, pad_lanes)
    if dedup_plans is not None:
        cp, hp = dedup_plans
        return fused_front_end_dedup_pallas(
            cold, hot, x, cp.unique_rows, cp.slots, cp.n_slots,
            hp.unique_rows, hp.slots, hp.n_slots, owned, is_hot,
            weights, cp.unique_scales, out_dtype=out_dtype,
            interpret=interpret, block_l=block_l, block_b=block_b)
    return fused_front_end_pallas(
        cold, hot, x, rows, owned, is_hot, weights, scales,
        out_dtype=out_dtype, interpret=interpret, block_l=block_l,
        block_b=block_b)


def fused_partial_pool(cold: jax.Array, hot: jax.Array, x: jax.Array,
                       rows: jax.Array, owned: jax.Array, is_hot: jax.Array,
                       weights: Optional[jax.Array] = None,
                       scales: Optional[jax.Array] = None,
                       dedup_plans=None, out_dtype=jnp.float32,
                       impl: str = "pallas", interpret: Optional[bool] = None,
                       block_l: int = 8, block_b: int = 32,
                       pad_lanes: Optional[bool] = None):
    """Phases 1-2 of :func:`fused_front_end`, stopped at the phase-2/3 seam:
    returns the per-tier partial feature tiles ``(B, F, D)`` — cold (row 0
    zero, the tile a tp dispatch psums across shards) and hot (``x`` in
    row 0; replicated, never reduced).  ``fused_resume`` finishes the
    interaction on the reduced tile.  Lane padding is sliced back off the
    tiles so the collective ships exactly ``B*F*D`` elements.  Oracle:
    ``ref.fused_partial_pool_ref``.
    """
    if impl == "jnp":
        if dedup_plans is not None:
            dedup_plans = None
        return ref.fused_partial_pool_ref(cold, hot, x, rows, owned, is_hot,
                                          weights, scales, out_dtype)
    if interpret is None:
        interpret = _default_interpret()
    if pad_lanes is None:
        pad_lanes = not interpret
    D = cold.shape[-1]
    cold = pad_to_lanes(cold, pad_lanes)
    hot = pad_to_lanes(hot, pad_lanes)
    x = pad_to_lanes(x, pad_lanes)
    if dedup_plans is not None:
        cp, hp = dedup_plans
        part_c, part_h = fused_partial_pool_dedup_pallas(
            cold, hot, x, cp.unique_rows, cp.slots, cp.n_slots,
            hp.unique_rows, hp.slots, hp.n_slots, owned, is_hot,
            weights, cp.unique_scales, out_dtype=out_dtype,
            interpret=interpret, block_l=block_l, block_b=block_b)
    else:
        part_c, part_h = fused_partial_pool_pallas(
            cold, hot, x, rows, owned, is_hot, weights, scales,
            out_dtype=out_dtype, interpret=interpret, block_l=block_l,
            block_b=block_b)
    return part_c[:, :, :D], part_h[:, :, :D]


def fused_resume(part_c: jax.Array, part_h: jax.Array,
                 out_dtype=jnp.float32, impl: str = "pallas",
                 interpret: Optional[bool] = None, block_b: int = 32,
                 pad_lanes: Optional[bool] = None) -> jax.Array:
    """Phase 3 of the fused front end on the psum-reduced ``(B, F, D)``
    tiles: cold/hot add, dot-interaction, packed lower triangle ``(B, P)``.
    Lane padding adds exact-zero columns to both tiles (zero lanes
    contribute +0 to every pairwise dot — no slice-back needed on the
    D-free output).  Oracle: ``ref.fused_resume_ref``.
    """
    if impl == "jnp":
        return ref.fused_resume_ref(part_c, part_h)
    if interpret is None:
        interpret = _default_interpret()
    if pad_lanes is None:
        pad_lanes = not interpret
    if pad_lanes and part_c.shape[-1] % LANES:
        pad = LANES - part_c.shape[-1] % LANES
        part_c = jnp.pad(part_c, ((0, 0), (0, 0), (0, pad)))
        part_h = jnp.pad(part_h, ((0, 0), (0, 0), (0, pad)))
    return fused_resume_pallas(part_c, part_h, out_dtype=out_dtype,
                               interpret=interpret, block_b=block_b)
