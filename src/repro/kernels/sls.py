"""Pallas TPU kernel for SparseLengthSum — the operator PIFS-Rec accelerates.

TPU-native rethink of the paper's fabric-switch datapath (not a CUDA port):

  * The embedding table stays in HBM ("CXL memory pool").  Rows are streamed
    into VMEM one grid step at a time by the Pallas pipeline, with the *next*
    row's DMA overlapping the current accumulate — the hardware double-buffer
    plays the role of the paper's swap-register / out-of-order engine: row
    arrival order never stalls the accumulator.
  * Indices (and optional weights) ride in SMEM via scalar prefetch — the
    analogue of the instruction-ingress registry: the index stream must be
    resident before the table DMAs it drives can be issued
    (PrefetchScalarGridSpec.num_scalar_prefetch=1).
  * The accumulator lives in VMEM, written back once per bag (revisiting:
    out block index depends only on the bag id, so Pallas keeps it resident
    across the L inner steps — the Accumulation Configuration Register).

Blocking: table block = (1, D) — one embedding row.  D is padded to the
128-lane boundary by the caller for MXU/VPU alignment (16/32/64-dim recsys
rows pack 8/4/2 rows per 128-lane tile on real hardware; we keep the simple
1-row block and note the packing opportunity in EXPERIMENTS.md §Perf).
VMEM working set per step = (1, D) row + (1, D) accumulator + next row's
DMA buffer  ≈ 3*D*4 bytes — far below the ~16 MB/core VMEM budget, so the
pipeline depth, not capacity, is the constraint.

Ownership masking for the sharded engine: a shard that does not own a row
folds the miss into weight=0 and remaps the index to 0 — the DMA still
happens but targets a single always-resident line, mirroring how the paper's
switch drops non-local candidates without stalling (section IV-C1).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sls_kernel_w(idx_ref, w_ref, table_blk, out_ref):
    """Weighted gather-accumulate; grid = (B, L)."""
    b = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[b, l].astype(out_ref.dtype)
    out_ref[...] += w * table_blk[...].astype(out_ref.dtype)


def _sls_kernel(idx_ref, table_blk, out_ref):
    """Unweighted gather-accumulate; grid = (B, L)."""
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += table_blk[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def sls_pallas(table: jax.Array, indices: jax.Array,
               weights: Optional[jax.Array] = None,
               out_dtype=jnp.float32, interpret: bool = True) -> jax.Array:
    """SLS via pl.pallas_call. indices: (B, L) int32 -> (B, D) pooled."""
    B, L = indices.shape
    V, D = table.shape
    grid = (B, L)

    def table_map(b, l, idx_ref):
        return (idx_ref[b, l], 0)

    def out_map(b, l, idx_ref):
        return (b, 0)

    if weights is not None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),     # weights
                      pl.BlockSpec((1, D), table_map)],          # one row/step
            out_specs=pl.BlockSpec((1, D), out_map),
        )
        return pl.pallas_call(
            _sls_kernel_w, grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((B, D), out_dtype),
            interpret=interpret,
        )(indices, weights, table)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, D), table_map)],
        out_specs=pl.BlockSpec((1, D), out_map),
    )
    return pl.pallas_call(
        _sls_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), out_dtype),
        interpret=interpret,
    )(indices, table)
