"""Pallas TPU kernels for SparseLengthSum — the operator PIFS-Rec accelerates.

TPU-native rethink of the paper's fabric-switch datapath (not a CUDA port):

  * The embedding table stays in HBM ("CXL memory pool") and is *not* streamed
    by the automatic Pallas pipeline: each grid step manually DMAs the rows it
    needs into a double-buffered VMEM scratch, so the *next* row's DMA overlaps
    the current accumulate — the hardware double-buffer plays the role of the
    paper's swap-register / out-of-order engine: row arrival order never stalls
    the accumulator.
  * Indices (and optional ownership mask / weights) ride in SMEM via scalar
    prefetch — the analogue of the instruction-ingress registry: the index
    stream must be resident before the table DMAs it drives can be issued
    (PrefetchScalarGridSpec).
  * The accumulator lives in VMEM, written back once per bag (revisiting: the
    out block index depends only on the bag id, so Pallas keeps it resident
    across the inner tile steps — the Accumulation Configuration Register).

Blocking (bag-tiled): grid = (B, ceil(L / block_l)).  Each grid step owns one
*tile* of ``block_l`` pooling entries of one bag and runs a double-buffered
DMA loop over the tile's rows.  Compared with the old one-row-per-step
(B, L) grid this cuts grid-dispatch overhead by ``block_l`` and keeps the
accumulator revisit count at ``ceil(L / block_l)`` instead of ``L``.  Tail
tiles (L % block_l != 0) are masked: out-of-range entries fold into weight 0
and their DMA is clamped to the last valid entry.  D is padded to the 128-lane
boundary by ``kernels/ops.py`` when targeting real hardware (see
EXPERIMENTS.md §Perf).  VMEM working set per step = 2 scratch rows + the
(1, D) accumulator ≈ 3*D*4 bytes — far below the ~16 MB/core VMEM budget.

Ownership masking for the sharded engine (``masked_sls_pallas``): a shard
that does not own a row folds the miss into weight=0 and remaps the index to
row 0 — the DMA still happens but targets a single always-resident line,
mirroring how the paper's switch drops non-local candidates without stalling
(section IV-C1).  Semantics match ``core/sls.masked_partial_sls`` on dense
(B, L) bags.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_sls_kernel(L: int, block_l: int, has_mask: bool, has_weights: bool,
                     has_scales: bool = False):
    """Build a bag-tiled SLS kernel body for a static (L, block_l, flags)."""

    def kernel(*refs):
        # scalar-prefetch refs first (idx[, owned][, w][, scales]), then
        # table/out/scratch
        it = iter(refs)
        idx_ref = next(it)
        owned_ref = next(it) if has_mask else None
        w_ref = next(it) if has_weights else None
        s_ref = next(it) if has_scales else None
        table_ref = next(it)      # (V, D) in ANY/HBM — manually DMA'd
        out_ref = next(it)        # (1, D) accumulator block, revisited per bag
        scratch = next(it)        # (2, D) VMEM double buffer
        sem = next(it)            # (2,) DMA semaphores

        b = pl.program_id(0)
        t = pl.program_id(1)
        l0 = t * block_l

        @pl.when(t == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        def row_dma(slot, i):
            # clamp tail-tile reads into range; masked-out rows remap to the
            # always-resident line 0 (their contribution is zeroed below)
            l = jnp.minimum(l0 + i, L - 1)
            r = idx_ref[b, l]
            if has_mask:
                r = jnp.where(owned_ref[b, l] != 0, r, 0)
            return pltpu.make_async_copy(table_ref.at[r], scratch.at[slot],
                                         sem.at[slot])

        row_dma(0, 0).start()

        def body(i, carry):
            slot = i % 2

            @pl.when(i + 1 < block_l)
            def _prefetch_next():
                row_dma((i + 1) % 2, i + 1).start()

            row_dma(slot, i).wait()
            l = l0 + i
            lc = jnp.minimum(l, L - 1)
            f = (l < L).astype(out_ref.dtype)
            if has_mask:
                f = f * (owned_ref[b, lc] != 0).astype(out_ref.dtype)
            if has_weights:
                f = f * w_ref[b, lc].astype(out_ref.dtype)
            row = scratch[slot][None, :].astype(out_ref.dtype)
            if has_scales:
                # fused dequant: the int8 row is scaled to fp32 *after* its
                # (1-byte-per-element) DMA landed — an fp32 copy of the cold
                # shard never exists, only this (1, D) working row
                row = row * s_ref[b, lc].astype(out_ref.dtype)
            out_ref[...] += f * row
            return carry

        jax.lax.fori_loop(0, block_l, body, 0)

    return kernel


def _sls_call(table: jax.Array, indices: jax.Array,
              owned: Optional[jax.Array], weights: Optional[jax.Array],
              scales: Optional[jax.Array],
              out_dtype, interpret: bool, block_l: int) -> jax.Array:
    B, L = indices.shape
    V, D = table.shape
    if B == 0 or L == 0:
        return jnp.zeros((B, D), out_dtype)
    block_l = max(1, min(block_l, L))
    grid = (B, pl.cdiv(L, block_l))

    prefetch = [indices.astype(jnp.int32)]
    if owned is not None:
        prefetch.append(owned.astype(jnp.int32))
    if weights is not None:
        prefetch.append(weights)
    if scales is not None:
        prefetch.append(scales.astype(jnp.float32))

    def out_map(b, t, *prefetch_refs):
        return (b, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],   # table stays in HBM
        out_specs=pl.BlockSpec((1, D), out_map),
        scratch_shapes=[pltpu.VMEM((2, D), table.dtype),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    kernel = _make_sls_kernel(L, block_l, has_mask=owned is not None,
                              has_weights=weights is not None,
                              has_scales=scales is not None)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), out_dtype),
        interpret=interpret,
    )(*prefetch, table)


def _make_sls_dedup_kernel(L: int, block_l: int, has_weights: bool,
                           has_scales: bool):
    """Two-phase gather-once dedup'd SLS kernel body.

    Phase 1 (first grid step only): double-buffered DMA of each *unique*
    row from the HBM table into a VMEM landing pad, fused per-row dequant
    (``float(row) * scale``), store into the persistent (U, D) VMEM staging
    buffer.  The DMA loop is bounded by the *traced* live-slot count, so
    the bytes moved scale with the realized unique count, not the padded
    capacity.

    Phase 2 (every grid step): the bag-tiled fixed-l-order accumulate of
    ``_make_sls_kernel``, but each entry's row is a VMEM read from staging
    through the slot indirection — no per-entry DMA.  The accumulate sees
    the same operands in the same order as the non-dedup kernel (the
    dequant multiply moved from per-entry to per-unique-row with identical
    inputs), so the two are bit-for-bit equal in fp32.
    """

    def kernel(*refs):
        # scalar-prefetch refs first (slots, owned[, w], uniq, n[, scales]),
        # then table/out/scratch
        it = iter(refs)
        slots_ref = next(it)      # (B, L) staging slot per pooling entry
        owned_ref = next(it)      # (B, L) ownership mask
        w_ref = next(it) if has_weights else None
        uniq_ref = next(it)       # (U,) unique row ids, sentinel-padded
        n_ref = next(it)          # (1,) live staging slots
        s_ref = next(it) if has_scales else None   # (U,) dequant scales
        table_ref = next(it)      # (V, D) in ANY/HBM — manually DMA'd
        out_ref = next(it)        # (1, D) accumulator block, revisited per bag
        staging = next(it)        # (U, D) VMEM staging, persists across steps
        landing = next(it)        # (2, D) VMEM DMA double buffer
        sem = next(it)            # (2,) DMA semaphores

        b = pl.program_id(0)
        t = pl.program_id(1)
        V = table_ref.shape[0]

        @pl.when((b == 0) & (t == 0))
        def _fill_staging():
            # gather-once: each unique row crosses the memory interface
            # exactly once; duplicates are served from VMEM in phase 2.
            # At least one slot is always fetched so the sentinel-only
            # (nothing owned) case still reads initialized staging.
            n = jnp.maximum(n_ref[0], 1)

            def row_dma(u, slot):
                # clamp the sentinel (and padded slots) into range — the
                # fetched line is masked to zero contribution in phase 2
                r = jnp.minimum(uniq_ref[u], V - 1)
                return pltpu.make_async_copy(table_ref.at[r],
                                             landing.at[slot], sem.at[slot])

            row_dma(0, 0).start()

            def body(u, carry):
                slot = u % 2

                @pl.when(u + 1 < n)
                def _prefetch_next():
                    row_dma(u + 1, (u + 1) % 2).start()

                row_dma(u, slot).wait()
                row = landing[slot].astype(out_ref.dtype)
                if has_scales:
                    # fused dequant: scaled once per *unique* row, after its
                    # (1-byte-per-element) DMA landed — same operands as the
                    # non-dedup kernel's per-entry multiply
                    row = row * s_ref[u].astype(out_ref.dtype)
                staging[pl.ds(u, 1)] = row[None, :]
                return carry

            jax.lax.fori_loop(0, n, body, 0)

        @pl.when(t == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        l0 = t * block_l

        def body(i, carry):
            l = l0 + i
            lc = jnp.minimum(l, L - 1)
            f = (l < L).astype(out_ref.dtype)
            f = f * (owned_ref[b, lc] != 0).astype(out_ref.dtype)
            if has_weights:
                f = f * w_ref[b, lc].astype(out_ref.dtype)
            row = staging[slots_ref[b, lc]][None, :]   # VMEM read, no DMA
            out_ref[...] += f * row
            return carry

        jax.lax.fori_loop(0, block_l, body, 0)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("out_dtype", "interpret", "block_l"))
def masked_sls_dedup_pallas(table: jax.Array, unique_rows: jax.Array,
                            slots: jax.Array, owned: jax.Array,
                            n_slots: jax.Array,
                            weights: Optional[jax.Array] = None,
                            unique_scales: Optional[jax.Array] = None,
                            out_dtype=jnp.float32, interpret: bool = True,
                            block_l: int = 8) -> jax.Array:
    """Gather-once dedup'd masked partial SLS (oracle:
    ``kernels/ref.py:masked_sls_dedup_ref``).

    ``unique_rows (U,)`` / ``slots (B, L)`` / ``n_slots (1,)`` come from
    ``core/sls.dedup_plan`` (U = B*L capacity, sentinel-padded).  Grid and
    accumulate structure match ``masked_sls_pallas``; the table DMA happens
    once per unique row in a phase-1 prologue instead of once per pooling
    entry.  Both grid dims must execute sequentially (staging is written at
    the first step and read by all later ones) — they are "arbitrary"
    semantics, which is the Pallas TPU default and the interpret-mode
    execution order.
    """
    B, L = slots.shape
    V, D = table.shape
    if B == 0 or L == 0:
        return jnp.zeros((B, D), out_dtype)
    block_l = max(1, min(block_l, L))
    grid = (B, pl.cdiv(L, block_l))
    U = unique_rows.shape[0]

    prefetch = [slots.astype(jnp.int32), owned.astype(jnp.int32)]
    if weights is not None:
        prefetch.append(weights)
    prefetch.append(unique_rows.astype(jnp.int32))
    prefetch.append(n_slots.astype(jnp.int32).reshape(1))
    if unique_scales is not None:
        prefetch.append(unique_scales.astype(jnp.float32))

    def out_map(b, t, *prefetch_refs):
        return (b, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],   # table stays in HBM
        out_specs=pl.BlockSpec((1, D), out_map),
        scratch_shapes=[pltpu.VMEM((U, D), out_dtype),     # staging
                        pltpu.VMEM((2, D), table.dtype),   # DMA landing pad
                        pltpu.SemaphoreType.DMA((2,))],
    )
    kernel = _make_sls_dedup_kernel(L, block_l,
                                    has_weights=weights is not None,
                                    has_scales=unique_scales is not None)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), out_dtype),
        interpret=interpret,
    )(*prefetch, table)


@functools.partial(jax.jit,
                   static_argnames=("out_dtype", "interpret", "block_l"))
def sls_pallas(table: jax.Array, indices: jax.Array,
               weights: Optional[jax.Array] = None,
               out_dtype=jnp.float32, interpret: bool = True,
               block_l: int = 8) -> jax.Array:
    """SLS via pl.pallas_call. indices: (B, L) int32 -> (B, D) pooled."""
    return _sls_call(table, indices, None, weights, None, out_dtype,
                     interpret, block_l)


@functools.partial(jax.jit,
                   static_argnames=("out_dtype", "interpret", "block_l"))
def masked_sls_pallas(table: jax.Array, indices: jax.Array, owned: jax.Array,
                      weights: Optional[jax.Array] = None,
                      scales: Optional[jax.Array] = None,
                      out_dtype=jnp.float32, interpret: bool = True,
                      block_l: int = 8) -> jax.Array:
    """Masked partial SLS: out[b] = sum_l owned[b,l]*w[b,l]*table[idx[b,l]].

    The per-shard operator of the PIFS engine: ``owned`` marks the pooling
    entries whose rows live on this shard; everything else contributes zero
    (and its gather is remapped to row 0, which must exist).

    Optional ``scales`` (B, L): per-entry dequant scales for a quantized
    (int8) ``table``.  Each DMA'd row is dequantized in VMEM
    (``float(row) * scale``) right before the weighted accumulate — the
    tiered-precision store's fused-dequant datapath (oracle:
    ``kernels/ref.py:masked_sls_quant_ref``).
    """
    return _sls_call(table, indices, owned, weights, scales, out_dtype,
                     interpret, block_l)
