"""Pallas TPU kernels for SparseLengthSum — the operator PIFS-Rec accelerates.

TPU-native rethink of the paper's fabric-switch datapath (not a CUDA port):

  * The embedding table stays in HBM ("CXL memory pool") and is *not* streamed
    by the automatic Pallas pipeline: each grid step manually DMAs the rows it
    needs into a double-buffered VMEM scratch, so the *next* row's DMA overlaps
    the current accumulate — the hardware double-buffer plays the role of the
    paper's swap-register / out-of-order engine: row arrival order never stalls
    the accumulator.
  * Indices (and optional ownership mask / weights) ride in SMEM via scalar
    prefetch — the analogue of the instruction-ingress registry: the index
    stream must be resident before the table DMAs it drives can be issued
    (PrefetchScalarGridSpec).
  * The accumulator lives in VMEM, written back once per bag (revisiting: the
    out block index depends only on the bag id, so Pallas keeps it resident
    across the inner tile steps — the Accumulation Configuration Register).

Blocking (bag-tiled): grid = (B, ceil(L / block_l)).  Each grid step owns one
*tile* of ``block_l`` pooling entries of one bag and runs a double-buffered
DMA loop over the tile's rows.  Compared with the old one-row-per-step
(B, L) grid this cuts grid-dispatch overhead by ``block_l`` and keeps the
accumulator revisit count at ``ceil(L / block_l)`` instead of ``L``.  Tail
tiles (L % block_l != 0) are masked: out-of-range entries fold into weight 0
and their DMA is clamped to the last valid entry.  D is padded to the 128-lane
boundary by ``kernels/ops.py`` when targeting real hardware (see
EXPERIMENTS.md §Perf).  VMEM working set per step = 2 scratch rows + the
(1, D) accumulator ≈ 3*D*4 bytes — far below the ~16 MB/core VMEM budget.

Ownership masking for the sharded engine (``masked_sls_pallas``): a shard
that does not own a row folds the miss into weight=0 and remaps the index to
row 0 — the DMA still happens but targets a single always-resident line,
mirroring how the paper's switch drops non-local candidates without stalling
(section IV-C1).  Semantics match ``core/sls.masked_partial_sls`` on dense
(B, L) bags.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_sls_kernel(L: int, block_l: int, has_mask: bool, has_weights: bool,
                     has_scales: bool = False):
    """Build a bag-tiled SLS kernel body for a static (L, block_l, flags)."""

    def kernel(*refs):
        # scalar-prefetch refs first (idx[, owned][, w][, scales]), then
        # table/out/scratch
        it = iter(refs)
        idx_ref = next(it)
        owned_ref = next(it) if has_mask else None
        w_ref = next(it) if has_weights else None
        s_ref = next(it) if has_scales else None
        table_ref = next(it)      # (V, D) in ANY/HBM — manually DMA'd
        out_ref = next(it)        # (1, D) accumulator block, revisited per bag
        scratch = next(it)        # (2, D) VMEM double buffer
        sem = next(it)            # (2,) DMA semaphores

        b = pl.program_id(0)
        t = pl.program_id(1)
        l0 = t * block_l

        @pl.when(t == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        def row_dma(slot, i):
            # clamp tail-tile reads into range; masked-out rows remap to the
            # always-resident line 0 (their contribution is zeroed below)
            l = jnp.minimum(l0 + i, L - 1)
            r = idx_ref[b, l]
            if has_mask:
                r = jnp.where(owned_ref[b, l] != 0, r, 0)
            return pltpu.make_async_copy(table_ref.at[r], scratch.at[slot],
                                         sem.at[slot])

        row_dma(0, 0).start()

        def body(i, carry):
            slot = i % 2

            @pl.when(i + 1 < block_l)
            def _prefetch_next():
                row_dma((i + 1) % 2, i + 1).start()

            row_dma(slot, i).wait()
            l = l0 + i
            lc = jnp.minimum(l, L - 1)
            f = (l < L).astype(out_ref.dtype)
            if has_mask:
                f = f * (owned_ref[b, lc] != 0).astype(out_ref.dtype)
            if has_weights:
                f = f * w_ref[b, lc].astype(out_ref.dtype)
            row = scratch[slot][None, :].astype(out_ref.dtype)
            if has_scales:
                # fused dequant: the int8 row is scaled to fp32 *after* its
                # (1-byte-per-element) DMA landed — an fp32 copy of the cold
                # shard never exists, only this (1, D) working row
                row = row * s_ref[b, lc].astype(out_ref.dtype)
            out_ref[...] += f * row
            return carry

        jax.lax.fori_loop(0, block_l, body, 0)

    return kernel


def _sls_call(table: jax.Array, indices: jax.Array,
              owned: Optional[jax.Array], weights: Optional[jax.Array],
              scales: Optional[jax.Array],
              out_dtype, interpret: bool, block_l: int) -> jax.Array:
    B, L = indices.shape
    V, D = table.shape
    if B == 0 or L == 0:
        return jnp.zeros((B, D), out_dtype)
    block_l = max(1, min(block_l, L))
    grid = (B, pl.cdiv(L, block_l))

    prefetch = [indices.astype(jnp.int32)]
    if owned is not None:
        prefetch.append(owned.astype(jnp.int32))
    if weights is not None:
        prefetch.append(weights)
    if scales is not None:
        prefetch.append(scales.astype(jnp.float32))

    def out_map(b, t, *prefetch_refs):
        return (b, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],   # table stays in HBM
        out_specs=pl.BlockSpec((1, D), out_map),
        scratch_shapes=[pltpu.VMEM((2, D), table.dtype),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    kernel = _make_sls_kernel(L, block_l, has_mask=owned is not None,
                              has_weights=weights is not None,
                              has_scales=scales is not None)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), out_dtype),
        interpret=interpret,
    )(*prefetch, table)


def _make_sls_dedup_kernel(L: int, block_l: int, has_weights: bool,
                           has_scales: bool):
    """Two-phase gather-once dedup'd SLS kernel body.

    Phase 1 (first grid step only): double-buffered DMA of each *unique*
    row from the HBM table into a VMEM landing pad, fused per-row dequant
    (``float(row) * scale``), store into the persistent (U, D) VMEM staging
    buffer.  The DMA loop is bounded by the *traced* live-slot count, so
    the bytes moved scale with the realized unique count, not the padded
    capacity.

    Phase 2 (every grid step): the bag-tiled fixed-l-order accumulate of
    ``_make_sls_kernel``, but each entry's row is a VMEM read from staging
    through the slot indirection — no per-entry DMA.  The accumulate sees
    the same operands in the same order as the non-dedup kernel (the
    dequant multiply moved from per-entry to per-unique-row with identical
    inputs), so the two are bit-for-bit equal in fp32.
    """

    def kernel(*refs):
        # scalar-prefetch refs first (slots, owned[, w], uniq, n[, scales]),
        # then table/out/scratch
        it = iter(refs)
        slots_ref = next(it)      # (B, L) staging slot per pooling entry
        owned_ref = next(it)      # (B, L) ownership mask
        w_ref = next(it) if has_weights else None
        uniq_ref = next(it)       # (U,) unique row ids, sentinel-padded
        n_ref = next(it)          # (1,) live staging slots
        s_ref = next(it) if has_scales else None   # (U,) dequant scales
        table_ref = next(it)      # (V, D) in ANY/HBM — manually DMA'd
        out_ref = next(it)        # (1, D) accumulator block, revisited per bag
        staging = next(it)        # (U, D) VMEM staging, persists across steps
        landing = next(it)        # (2, D) VMEM DMA double buffer
        sem = next(it)            # (2,) DMA semaphores

        b = pl.program_id(0)
        t = pl.program_id(1)
        V = table_ref.shape[0]

        @pl.when((b == 0) & (t == 0))
        def _fill_staging():
            # gather-once: each unique row crosses the memory interface
            # exactly once; duplicates are served from VMEM in phase 2.
            # At least one slot is always fetched so the sentinel-only
            # (nothing owned) case still reads initialized staging.
            n = jnp.maximum(n_ref[0], 1)

            def row_dma(u, slot):
                # clamp the sentinel (and padded slots) into range — the
                # fetched line is masked to zero contribution in phase 2
                r = jnp.minimum(uniq_ref[u], V - 1)
                return pltpu.make_async_copy(table_ref.at[r],
                                             landing.at[slot], sem.at[slot])

            row_dma(0, 0).start()

            def body(u, carry):
                slot = u % 2

                @pl.when(u + 1 < n)
                def _prefetch_next():
                    row_dma(u + 1, (u + 1) % 2).start()

                row_dma(u, slot).wait()
                row = landing[slot].astype(out_ref.dtype)
                if has_scales:
                    # fused dequant: scaled once per *unique* row, after its
                    # (1-byte-per-element) DMA landed — same operands as the
                    # non-dedup kernel's per-entry multiply
                    row = row * s_ref[u].astype(out_ref.dtype)
                staging[pl.ds(u, 1)] = row[None, :]
                return carry

            jax.lax.fori_loop(0, n, body, 0)

        @pl.when(t == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        l0 = t * block_l

        def body(i, carry):
            l = l0 + i
            lc = jnp.minimum(l, L - 1)
            f = (l < L).astype(out_ref.dtype)
            f = f * (owned_ref[b, lc] != 0).astype(out_ref.dtype)
            if has_weights:
                f = f * w_ref[b, lc].astype(out_ref.dtype)
            row = staging[slots_ref[b, lc]][None, :]   # VMEM read, no DMA
            out_ref[...] += f * row
            return carry

        jax.lax.fori_loop(0, block_l, body, 0)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("out_dtype", "interpret", "block_l"))
def masked_sls_dedup_pallas(table: jax.Array, unique_rows: jax.Array,
                            slots: jax.Array, owned: jax.Array,
                            n_slots: jax.Array,
                            weights: Optional[jax.Array] = None,
                            unique_scales: Optional[jax.Array] = None,
                            out_dtype=jnp.float32, interpret: bool = True,
                            block_l: int = 8) -> jax.Array:
    """Gather-once dedup'd masked partial SLS (oracle:
    ``kernels/ref.py:masked_sls_dedup_ref``).

    ``unique_rows (U,)`` / ``slots (B, L)`` / ``n_slots (1,)`` come from
    ``core/sls.dedup_plan`` (U = B*L capacity, sentinel-padded).  Grid and
    accumulate structure match ``masked_sls_pallas``; the table DMA happens
    once per unique row in a phase-1 prologue instead of once per pooling
    entry.  Both grid dims must execute sequentially (staging is written at
    the first step and read by all later ones) — they are "arbitrary"
    semantics, which is the Pallas TPU default and the interpret-mode
    execution order.
    """
    B, L = slots.shape
    V, D = table.shape
    if B == 0 or L == 0:
        return jnp.zeros((B, D), out_dtype)
    block_l = max(1, min(block_l, L))
    grid = (B, pl.cdiv(L, block_l))
    U = unique_rows.shape[0]

    prefetch = [slots.astype(jnp.int32), owned.astype(jnp.int32)]
    if weights is not None:
        prefetch.append(weights)
    prefetch.append(unique_rows.astype(jnp.int32))
    prefetch.append(n_slots.astype(jnp.int32).reshape(1))
    if unique_scales is not None:
        prefetch.append(unique_scales.astype(jnp.float32))

    def out_map(b, t, *prefetch_refs):
        return (b, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],   # table stays in HBM
        out_specs=pl.BlockSpec((1, D), out_map),
        scratch_shapes=[pltpu.VMEM((U, D), out_dtype),     # staging
                        pltpu.VMEM((2, D), table.dtype),   # DMA landing pad
                        pltpu.SemaphoreType.DMA((2,))],
    )
    kernel = _make_sls_dedup_kernel(L, block_l,
                                    has_weights=weights is not None,
                                    has_scales=unique_scales is not None)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), out_dtype),
        interpret=interpret,
    )(*prefetch, table)


def _make_fused_front_end_kernel(L: int, block_l: int, G: int, BB: int,
                                 has_weights: bool, has_scales: bool,
                                 dedup: bool, emit: str = "interact"):
    """Fused DLRM front-end kernel body: SLS -> dot-interaction, one kernel.

    Three phases over grid ``(B // BB, G, ceil(L / block_l))``:

      * phase 1 (``dedup`` only, very first grid step): gather-once DMA of
        each unique cold/hot row into persistent ``(U, D)`` VMEM row
        staging, fused per-row dequant — identical structure to
        ``_make_sls_dedup_kernel``'s prologue, once per *tier*.
      * phase 2 (every step): the bag-tiled fixed-l-order masked accumulate,
        writing pooled rows into persistent VMEM *feature staging* laid out
        as ``(BB, F, D)`` batch-tiles (flattened ``(BB*F, D)`` scratch; one
        accumulator pair per tier so the final ``cold + hot`` add matches
        the split datapath's ``psum(cold_part) + hot_out`` bit-for-bit).
        Feature row 0 of each sample is the bottom-MLP output (``x``),
        loaded once per batch-tile.
      * phase 3 (last ``(g, t)`` step of each batch-tile): the
        dot-interaction matmul + static triangle pack of
        ``_interaction_kernel`` on the resident ``(BB, F, D)`` features.

    ``emit`` selects where the pipeline stops:

      * ``"interact"`` — the full three-phase kernel above; one ``(BB, P)``
        packed-triangle output.  ``x`` lands in *cold* staging row 0.
      * ``"tiles"`` — stop at the phase-2/3 seam for tensor-parallel
        execution: emit the per-tier partial feature tiles ``(BB, F, D)``
        (cold, hot) instead of interacting.  ``x`` lands in *hot* staging
        row 0 here — the hot tier is replicated across tp shards and is
        *not* psum'd, so ``x`` is counted exactly once; the cold tile's
        row 0 stays zero and is safe to all-reduce.  The reduced tile
        resumes phase 3 in :func:`fused_resume_pallas`.

    The pooled-features tensor never exists in HBM: the only HBM traffic is
    the row gather (phase 1/2) plus the ``(BB, D)`` x block in and the
    ``(BB, P)`` packed triangle (or the two ``(BB, F, D)`` partial tiles)
    out.
    """
    F = G + 1
    interact = emit == "interact"

    def kernel(*refs):
        it = iter(refs)
        if dedup:
            cslots_ref = next(it)   # (B, G, L) cold staging slot per entry
            hslots_ref = next(it)   # (B, G, L) hot staging slot per entry
        else:
            rows_ref = next(it)     # (B, G, L) local row per entry
        owned_ref = next(it)        # (B, G, L) cold-tier ownership mask
        hot_ref = next(it)          # (B, G, L) hot-tier membership mask
        w_ref = next(it) if has_weights else None
        if dedup:
            cuniq_ref = next(it)    # (U,) unique cold rows, sentinel-padded
            cn_ref = next(it)       # (1,) live cold staging slots
            cs_ref = next(it) if has_scales else None   # (U,) dequant scales
            huniq_ref = next(it)    # (U,) unique hot rows, sentinel-padded
            hn_ref = next(it)       # (1,) live hot staging slots
        elif has_scales:
            s_ref = next(it)        # (B, G, L) per-entry dequant scales
        if interact:
            tri_ref = next(it)      # (P,) static triangle-pack permutation
        cold_ref = next(it)         # (Vc, D) ANY/HBM — manually DMA'd
        hot_table_ref = next(it)    # (Vh, D) ANY/HBM — manually DMA'd
        x_ref = next(it)            # (BB, D) bottom-MLP block (auto-piped)
        if interact:
            out_ref = next(it)      # (BB, P) packed-triangle block
            acc_dtype = out_ref.dtype
        else:
            out_c_ref = next(it)    # (BB, F, D) cold partial feature tile
            out_h_ref = next(it)    # (BB, F, D) hot partial feature tile
            acc_dtype = out_c_ref.dtype
        if dedup:
            crows = next(it)        # (U, D) VMEM cold row staging (dequant'd)
            hrows = next(it)        # (U, D) VMEM hot row staging
        stage_c = next(it)          # (BB*F, D) VMEM cold feature staging
        stage_h = next(it)          # (BB*F, D) VMEM hot feature staging
        cland = next(it)            # (2, D) cold DMA double buffer
        hland = next(it)            # (2, D) hot DMA double buffer
        csem = next(it)             # (2,) cold DMA semaphores
        hsem = next(it)             # (2,) hot DMA semaphores

        bt = pl.program_id(0)
        g = pl.program_id(1)
        t = pl.program_id(2)
        n_tl = pl.num_programs(2)
        l0 = t * block_l

        if dedup:
            @pl.when((bt == 0) & (g == 0) & (t == 0))
            def _fill_row_staging():
                # gather-once per tier: each unique row crosses the memory
                # interface exactly once; phase 2 reads VMEM only.
                for uniq_ref, n_ref, land, sem, staging, table, sref in (
                        (cuniq_ref, cn_ref, cland, csem, crows, cold_ref,
                         cs_ref),
                        (huniq_ref, hn_ref, hland, hsem, hrows,
                         hot_table_ref, None)):
                    V = table.shape[0]
                    n = jnp.maximum(n_ref[0], 1)

                    def row_dma(u, slot, *, _t=table, _l=land, _s=sem,
                                _u=uniq_ref, _V=V):
                        r = jnp.minimum(_u[u], _V - 1)
                        return pltpu.make_async_copy(_t.at[r], _l.at[slot],
                                                     _s.at[slot])

                    row_dma(0, 0).start()

                    def body(u, carry, *, _land=land, _staging=staging,
                             _sref=sref, _n=n, _dma=row_dma):
                        slot = u % 2

                        @pl.when(u + 1 < _n)
                        def _prefetch_next():
                            _dma(u + 1, (u + 1) % 2).start()

                        _dma(u, slot).wait()
                        row = _land[slot].astype(acc_dtype)
                        if _sref is not None:
                            row = row * _sref[u].astype(acc_dtype)
                        _staging[pl.ds(u, 1)] = row[None, :]
                        return carry

                    jax.lax.fori_loop(0, n, body, 0)

        @pl.when((g == 0) & (t == 0))
        def _init_features():
            # per batch-tile: zero both accumulators, land the bottom-MLP
            # output in feature row 0 of the cold staging (the hot staging's
            # row 0 stays zero, so the phase-3 add reproduces the split
            # path's `concat([x, pooled])` exactly).  In tiles mode x rides
            # the *hot* staging instead: hot is replicated across tp shards
            # while the cold tile is psum'd, so this is the placement that
            # counts x once.
            xv = x_ref[...].astype(acc_dtype)                   # (BB, D)
            D = xv.shape[-1]
            init = jnp.zeros((BB, F, D), acc_dtype)
            with_x = init.at[:, 0, :].set(xv).reshape(BB * F, D)
            if interact:
                stage_c[...] = with_x
                stage_h[...] = jnp.zeros_like(stage_h)
            else:
                stage_c[...] = jnp.zeros_like(stage_c)
                stage_h[...] = with_x

        if not dedup:
            def entry_dma(slot, k):
                # one DMA per tier per entry; out-of-tier entries remap to
                # the always-resident line 0 of that tier's table (their
                # contribution is zeroed below) — same trick as
                # ``_make_sls_kernel``'s ownership masking
                i = k // block_l
                l = jnp.minimum(l0 + k % block_l, L - 1)
                b = bt * BB + i
                r = rows_ref[b, g, l]
                rc = jnp.where(owned_ref[b, g, l] != 0, r, 0)
                rh = jnp.where(hot_ref[b, g, l] != 0, r, 0)
                return (pltpu.make_async_copy(cold_ref.at[rc], cland.at[slot],
                                              csem.at[slot]),
                        pltpu.make_async_copy(hot_table_ref.at[rh],
                                              hland.at[slot], hsem.at[slot]))

            def start(slot, k):
                c, h = entry_dma(slot, k)
                c.start()
                h.start()

            start(0, 0)

        n_entries = BB * block_l

        def body(k, carry):
            i = k // block_l
            l = l0 + k % block_l
            lc = jnp.minimum(l, L - 1)
            b = bt * BB + i
            if not dedup:
                slot = k % 2

                @pl.when(k + 1 < n_entries)
                def _prefetch_next():
                    start((k + 1) % 2, k + 1)

                c, h = entry_dma(slot, k)
                c.wait()
                h.wait()
            f = (l < L).astype(acc_dtype)
            if has_weights:
                f = f * w_ref[b, g, lc].astype(acc_dtype)
            fc = f * (owned_ref[b, g, lc] != 0).astype(acc_dtype)
            fh = f * (hot_ref[b, g, lc] != 0).astype(acc_dtype)
            if dedup:
                row_c = crows[cslots_ref[b, g, lc]][None, :]
                row_h = hrows[hslots_ref[b, g, lc]][None, :]
            else:
                row_c = cland[slot][None, :].astype(acc_dtype)
                if has_scales:
                    row_c = row_c * s_ref[b, g, lc].astype(acc_dtype)
                row_h = hland[slot][None, :].astype(acc_dtype)
            sk = i * F + g + 1
            stage_c[pl.ds(sk, 1)] = stage_c[pl.ds(sk, 1)] + fc * row_c
            stage_h[pl.ds(sk, 1)] = stage_h[pl.ds(sk, 1)] + fh * row_h
            return carry

        jax.lax.fori_loop(0, n_entries, body, 0)

        if interact:
            @pl.when((g == G - 1) & (t == n_tl - 1))
            def _interact():
                # phase 3: dot-interaction on the resident features —
                # identical op structure to kernels/interaction.py's
                # _interaction_kernel
                D = stage_c.shape[-1]
                feats = (stage_c[...] + stage_h[...]).reshape(BB, F, D)
                z = jax.lax.dot_general(
                    feats, feats, (((2,), (2,)), ((0,), (0,))),
                    preferred_element_type=out_ref.dtype)       # (BB, F, F)
                out_ref[...] = jnp.take(z.reshape(BB, F * F), tri_ref[...],
                                        axis=1)
        else:
            @pl.when((g == G - 1) & (t == n_tl - 1))
            def _emit_tiles():
                # phase-2/3 seam: hand the per-tier partial tiles to the
                # cross-shard psum; the cold/hot add happens after the
                # reduction in the resume kernel, preserving the split
                # path's `psum(cold_part) + hot_out` operand order.
                D = stage_c.shape[-1]
                out_c_ref[...] = stage_c[...].reshape(BB, F, D)
                out_h_ref[...] = stage_h[...].reshape(BB, F, D)

    return kernel


def _fe_blocks(B: int, L: int, block_l: int, block_b: int, G: int):
    """Resolve (BB, block_l, tri, P) for a fused front-end call: the batch
    tile must divide B (largest power-of-two shrink of ``block_b`` that
    does), the pooling tile is clamped to L, and the triangle pack is the
    static lower-triangle permutation of F = G + 1 features."""
    BB = max(1, min(block_b, B))
    while B % BB:
        BB //= 2
    block_l = max(1, min(block_l, L))
    F = G + 1
    i, j = np.tril_indices(F, k=-1)
    tri = jnp.asarray(i * F + j, jnp.int32)
    return BB, block_l, tri, int(tri.shape[0])


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret",
                                             "block_l", "block_b"))
def fused_front_end_pallas(cold: jax.Array, hot: jax.Array, x: jax.Array,
                           rows: jax.Array, owned: jax.Array,
                           is_hot: jax.Array,
                           weights: Optional[jax.Array] = None,
                           scales: Optional[jax.Array] = None,
                           out_dtype=jnp.float32,
                           interpret: Optional[bool] = None,
                           block_l: int = 8, block_b: int = 32) -> jax.Array:
    """Fused SLS -> dot-interaction front end (oracle:
    ``kernels/ref.py:fused_front_end_ref``).

    rows/owned/is_hot (B, G, L): per-entry local row + tier masks (cold /
    hot; entries in neither tier contribute zero).  x (B, D): the bottom-MLP
    output, feature row 0.  Returns the (B, P) packed lower triangle of the
    (B, F, D) features' pairwise dots, F = G + 1, without ever writing the
    pooled features to HBM.  Bit-for-bit equal to the split pipeline
    (masked SLS per tier -> add -> concat -> dot-interaction) in fp32.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, G, L = rows.shape
    D = cold.shape[-1]
    BB, block_l, tri, P = _fe_blocks(B, L, block_l, block_b, G)
    if B == 0 or L == 0 or G == 0:
        return jnp.zeros((B, P), out_dtype)

    prefetch = [rows.astype(jnp.int32), owned.astype(jnp.int32),
                is_hot.astype(jnp.int32)]
    if weights is not None:
        prefetch.append(weights)
    if scales is not None:
        prefetch.append(scales.astype(jnp.float32))
    prefetch.append(tri)

    F = G + 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(B // BB, G, pl.cdiv(L, block_l)),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),    # cold stays in HBM
                  pl.BlockSpec(memory_space=pltpu.ANY),    # hot stays in HBM
                  pl.BlockSpec((BB, D), lambda bt, g, t, *p: (bt, 0))],
        out_specs=pl.BlockSpec((BB, P), lambda bt, g, t, *p: (bt, 0)),
        scratch_shapes=[pltpu.VMEM((BB * F, D), out_dtype),  # cold features
                        pltpu.VMEM((BB * F, D), out_dtype),  # hot features
                        pltpu.VMEM((2, D), cold.dtype),
                        pltpu.VMEM((2, D), hot.dtype),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    kernel = _make_fused_front_end_kernel(
        L, block_l, G, BB, has_weights=weights is not None,
        has_scales=scales is not None, dedup=False)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P), out_dtype),
        interpret=interpret,
    )(*prefetch, cold, hot, x)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret",
                                             "block_l", "block_b"))
def fused_front_end_dedup_pallas(cold: jax.Array, hot: jax.Array,
                                 x: jax.Array,
                                 c_unique: jax.Array, c_slots: jax.Array,
                                 c_n: jax.Array, h_unique: jax.Array,
                                 h_slots: jax.Array, h_n: jax.Array,
                                 owned: jax.Array, is_hot: jax.Array,
                                 weights: Optional[jax.Array] = None,
                                 c_scales: Optional[jax.Array] = None,
                                 out_dtype=jnp.float32,
                                 interpret: Optional[bool] = None,
                                 block_l: int = 8, block_b: int = 32
                                 ) -> jax.Array:
    """Gather-once dedup'd fused front end: phase 1 stages each unique
    cold/hot row once (fused dequant), phases 2-3 as
    :func:`fused_front_end_pallas` with VMEM staging reads instead of
    per-entry DMA.  ``c_*`` / ``h_*`` come from one ``core/sls.dedup_plan``
    per tier (slots reshaped to (B, G, L)); bit-for-bit equal to the
    non-dedup kernel.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, G, L = c_slots.shape
    D = cold.shape[-1]
    BB, block_l, tri, P = _fe_blocks(B, L, block_l, block_b, G)
    if B == 0 or L == 0 or G == 0:
        return jnp.zeros((B, P), out_dtype)
    U = c_unique.shape[0]

    prefetch = [c_slots.astype(jnp.int32), h_slots.astype(jnp.int32),
                owned.astype(jnp.int32), is_hot.astype(jnp.int32)]
    if weights is not None:
        prefetch.append(weights)
    prefetch.append(c_unique.astype(jnp.int32))
    prefetch.append(c_n.astype(jnp.int32).reshape(1))
    if c_scales is not None:
        prefetch.append(c_scales.astype(jnp.float32))
    prefetch.append(h_unique.astype(jnp.int32))
    prefetch.append(h_n.astype(jnp.int32).reshape(1))
    prefetch.append(tri)

    F = G + 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(B // BB, G, pl.cdiv(L, block_l)),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),    # cold stays in HBM
                  pl.BlockSpec(memory_space=pltpu.ANY),    # hot stays in HBM
                  pl.BlockSpec((BB, D), lambda bt, g, t, *p: (bt, 0))],
        out_specs=pl.BlockSpec((BB, P), lambda bt, g, t, *p: (bt, 0)),
        scratch_shapes=[pltpu.VMEM((U, D), out_dtype),     # cold row staging
                        pltpu.VMEM((U, D), out_dtype),     # hot row staging
                        pltpu.VMEM((BB * F, D), out_dtype),
                        pltpu.VMEM((BB * F, D), out_dtype),
                        pltpu.VMEM((2, D), cold.dtype),
                        pltpu.VMEM((2, D), hot.dtype),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    kernel = _make_fused_front_end_kernel(
        L, block_l, G, BB, has_weights=weights is not None,
        has_scales=c_scales is not None, dedup=True)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P), out_dtype),
        interpret=interpret,
    )(*prefetch, cold, hot, x)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret",
                                             "block_l", "block_b"))
def fused_partial_pool_pallas(cold: jax.Array, hot: jax.Array, x: jax.Array,
                              rows: jax.Array, owned: jax.Array,
                              is_hot: jax.Array,
                              weights: Optional[jax.Array] = None,
                              scales: Optional[jax.Array] = None,
                              out_dtype=jnp.float32,
                              interpret: Optional[bool] = None,
                              block_l: int = 8, block_b: int = 32):
    """Phases 1-2 of the fused front end, stopped at the phase-2/3 seam
    (oracle: ``kernels/ref.py:fused_partial_pool_ref``).

    Returns the per-tier partial feature tiles ``(B, F, D)``:

      * ``part_c`` — this shard's cold-tier partial pools, feature row 0
        all-zero (safe to ``psum`` across tp shards), and
      * ``part_h`` — the hot-tier pools with the bottom-MLP output ``x`` in
        feature row 0 (hot is replicated, never reduced).

    ``psum(part_c) + part_h`` reproduces the split datapath's
    ``psum(cold_part) + hot_out`` / ``concat([x, pooled])`` features
    bit-for-bit; :func:`fused_resume_pallas` finishes phase 3 on the
    reduced tile.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, G, L = rows.shape
    D = cold.shape[-1]
    BB, block_l, _, _ = _fe_blocks(B, L, block_l, block_b, G)
    F = G + 1
    if B == 0 or L == 0 or G == 0:
        zc = jnp.zeros((B, F, D), out_dtype)
        return zc, zc.at[:, 0, :].set(x.astype(out_dtype))

    prefetch = [rows.astype(jnp.int32), owned.astype(jnp.int32),
                is_hot.astype(jnp.int32)]
    if weights is not None:
        prefetch.append(weights)
    if scales is not None:
        prefetch.append(scales.astype(jnp.float32))

    tile_spec = pl.BlockSpec((BB, F, D), lambda bt, g, t, *p: (bt, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(B // BB, G, pl.cdiv(L, block_l)),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),    # cold stays in HBM
                  pl.BlockSpec(memory_space=pltpu.ANY),    # hot stays in HBM
                  pl.BlockSpec((BB, D), lambda bt, g, t, *p: (bt, 0))],
        out_specs=[tile_spec, tile_spec],
        scratch_shapes=[pltpu.VMEM((BB * F, D), out_dtype),  # cold features
                        pltpu.VMEM((BB * F, D), out_dtype),  # hot features
                        pltpu.VMEM((2, D), cold.dtype),
                        pltpu.VMEM((2, D), hot.dtype),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    kernel = _make_fused_front_end_kernel(
        L, block_l, G, BB, has_weights=weights is not None,
        has_scales=scales is not None, dedup=False, emit="tiles")
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, F, D), out_dtype),
                   jax.ShapeDtypeStruct((B, F, D), out_dtype)],
        interpret=interpret,
    )(*prefetch, cold, hot, x)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret",
                                             "block_l", "block_b"))
def fused_partial_pool_dedup_pallas(cold: jax.Array, hot: jax.Array,
                                    x: jax.Array,
                                    c_unique: jax.Array, c_slots: jax.Array,
                                    c_n: jax.Array, h_unique: jax.Array,
                                    h_slots: jax.Array, h_n: jax.Array,
                                    owned: jax.Array, is_hot: jax.Array,
                                    weights: Optional[jax.Array] = None,
                                    c_scales: Optional[jax.Array] = None,
                                    out_dtype=jnp.float32,
                                    interpret: Optional[bool] = None,
                                    block_l: int = 8, block_b: int = 32):
    """Gather-once dedup'd partial pool: phase 1 stages each unique cold/hot
    row once per shard (dedup staging stays per-shard — only the pooled
    tile crosses the fabric), phase 2 as :func:`fused_partial_pool_pallas`.
    Bit-for-bit equal to the non-dedup tiles.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, G, L = c_slots.shape
    D = cold.shape[-1]
    BB, block_l, _, _ = _fe_blocks(B, L, block_l, block_b, G)
    F = G + 1
    if B == 0 or L == 0 or G == 0:
        zc = jnp.zeros((B, F, D), out_dtype)
        return zc, zc.at[:, 0, :].set(x.astype(out_dtype))
    U = c_unique.shape[0]

    prefetch = [c_slots.astype(jnp.int32), h_slots.astype(jnp.int32),
                owned.astype(jnp.int32), is_hot.astype(jnp.int32)]
    if weights is not None:
        prefetch.append(weights)
    prefetch.append(c_unique.astype(jnp.int32))
    prefetch.append(c_n.astype(jnp.int32).reshape(1))
    if c_scales is not None:
        prefetch.append(c_scales.astype(jnp.float32))
    prefetch.append(h_unique.astype(jnp.int32))
    prefetch.append(h_n.astype(jnp.int32).reshape(1))

    tile_spec = pl.BlockSpec((BB, F, D), lambda bt, g, t, *p: (bt, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(B // BB, G, pl.cdiv(L, block_l)),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),    # cold stays in HBM
                  pl.BlockSpec(memory_space=pltpu.ANY),    # hot stays in HBM
                  pl.BlockSpec((BB, D), lambda bt, g, t, *p: (bt, 0))],
        out_specs=[tile_spec, tile_spec],
        scratch_shapes=[pltpu.VMEM((U, D), out_dtype),     # cold row staging
                        pltpu.VMEM((U, D), out_dtype),     # hot row staging
                        pltpu.VMEM((BB * F, D), out_dtype),
                        pltpu.VMEM((BB * F, D), out_dtype),
                        pltpu.VMEM((2, D), cold.dtype),
                        pltpu.VMEM((2, D), hot.dtype),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((2,))],
    )
    kernel = _make_fused_front_end_kernel(
        L, block_l, G, BB, has_weights=weights is not None,
        has_scales=c_scales is not None, dedup=True, emit="tiles")
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, F, D), out_dtype),
                   jax.ShapeDtypeStruct((B, F, D), out_dtype)],
        interpret=interpret,
    )(*prefetch, cold, hot, x)


def _make_fused_resume_kernel(BB: int, F: int):
    """Phase-3 resume body: cold/hot add on the *reduced* tile, then the
    dot-interaction matmul + static triangle pack — the same op sequence
    the ``emit='interact'`` kernel runs on its resident staging, so the
    tp-sharded composition stays bit-for-bit against the one-shard fusion.
    """

    def kernel(tri_ref, c_ref, h_ref, out_ref):
        D = c_ref.shape[-1]
        feats = (c_ref[...] + h_ref[...]).reshape(BB, F, D)
        z = jax.lax.dot_general(
            feats, feats, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=out_ref.dtype)               # (BB, F, F)
        out_ref[...] = jnp.take(z.reshape(BB, F * F), tri_ref[...], axis=1)

    return kernel


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret",
                                             "block_b"))
def fused_resume_pallas(part_c: jax.Array, part_h: jax.Array,
                        out_dtype=jnp.float32,
                        interpret: Optional[bool] = None,
                        block_b: int = 32) -> jax.Array:
    """Resume phase 3 on the psum-reduced ``(B, F, D)`` tiles: feats =
    part_c + part_h, dot-interaction, packed lower triangle ``(B, P)``.
    The features stay VMEM-resident on this side of the collective too —
    the tiles stream in as blocks, the interaction never round-trips a
    concat'd features tensor through HBM.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, F, D = part_c.shape
    G = F - 1
    BB, _, tri, P = _fe_blocks(B, 1, 1, block_b, G)
    if B == 0 or G == 0:
        return jnp.zeros((B, P), out_dtype)

    tile_spec = pl.BlockSpec((BB, F, D), lambda bt, *p: (bt, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B // BB,),
        in_specs=[tile_spec, tile_spec],
        out_specs=pl.BlockSpec((BB, P), lambda bt, *p: (bt, 0)),
    )
    return pl.pallas_call(
        _make_fused_resume_kernel(BB, F), grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P), out_dtype),
        interpret=interpret,
    )(tri, part_c, part_h)


@functools.partial(jax.jit,
                   static_argnames=("out_dtype", "interpret", "block_l"))
def sls_pallas(table: jax.Array, indices: jax.Array,
               weights: Optional[jax.Array] = None,
               out_dtype=jnp.float32, interpret: bool = True,
               block_l: int = 8) -> jax.Array:
    """SLS via pl.pallas_call. indices: (B, L) int32 -> (B, D) pooled."""
    return _sls_call(table, indices, None, weights, None, out_dtype,
                     interpret, block_l)


@functools.partial(jax.jit,
                   static_argnames=("out_dtype", "interpret", "block_l"))
def masked_sls_pallas(table: jax.Array, indices: jax.Array, owned: jax.Array,
                      weights: Optional[jax.Array] = None,
                      scales: Optional[jax.Array] = None,
                      out_dtype=jnp.float32, interpret: bool = True,
                      block_l: int = 8) -> jax.Array:
    """Masked partial SLS: out[b] = sum_l owned[b,l]*w[b,l]*table[idx[b,l]].

    The per-shard operator of the PIFS engine: ``owned`` marks the pooling
    entries whose rows live on this shard; everything else contributes zero
    (and its gather is remapped to row 0, which must exist).

    Optional ``scales`` (B, L): per-entry dequant scales for a quantized
    (int8) ``table``.  Each DMA'd row is dequantized in VMEM
    (``float(row) * scale``) right before the weighted accumulate — the
    tiered-precision store's fused-dequant datapath (oracle:
    ``kernels/ref.py:masked_sls_quant_ref``).
    """
    return _sls_call(table, indices, owned, weights, scales, out_dtype,
                     interpret, block_l)
