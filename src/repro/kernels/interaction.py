"""Pallas TPU kernel for DLRM pairwise-dot feature interaction.

The interaction op sits right after SLS in the DLRM pipeline (Fig. 1) and is
the only other op the paper's end-to-end model weights at scale ("non-SLS
operators", section VI-C4).  Z = X X^T per sample, packed lower triangle.

Blocking: grid over batch blocks; one (BB, F, D) activation block in VMEM per
step.  F, D are small (F <= ~40 fields, D <= 128), so a batch block of 128
keeps the MXU busy with a (F, D) x (D, F) matmul per sample batch while the
working set stays ~ BB*F*D*4 = 128*32*128*4 = 2 MB << VMEM.  The triangle
pack is a static gather on the (BB, F*F) reshape, fused into the same kernel
to avoid a round trip of the (B, F, F) tensor to HBM — that round trip is
2x the kernel's entire output traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _interaction_kernel(tri_ref, x_ref, out_ref):
    x = x_ref[...]                                      # (BB, F, D)
    z = jax.lax.dot_general(
        x, x, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=out_ref.dtype)           # (BB, F, F)
    bb, F, _ = z.shape
    flat = z.reshape(bb, F * F)
    out_ref[...] = jnp.take(flat, tri_ref[...], axis=1)


@functools.partial(jax.jit, static_argnames=("self_interaction", "block_b",
                                             "interpret"))
def dot_interaction_pallas(feats: jax.Array, self_interaction: bool = False,
                           block_b: int = 128,
                           interpret: bool | None = None) -> jax.Array:
    """feats: (B, F, D) -> (B, P) packed triangle. B must divide block_b
    (caller pads); P = F*(F-1)/2 (+F with self_interaction).

    ``interpret=None`` (the default) detects the backend once at trace
    time: compiled on TPU, interpreter elsewhere.  The old default of
    ``True`` made real-TPU callers that never threaded the knob silently
    run the interpreter; pass an explicit bool to override detection."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, F, D = feats.shape
    block_b = min(block_b, B)
    if B % block_b:
        raise ValueError(f"B={B} not divisible by block_b={block_b}")
    i, j = np.tril_indices(F, k=0 if self_interaction else -1)
    tri = jnp.asarray(i * F + j, jnp.int32)
    P = tri.shape[0]
    # tri rides in SMEM via scalar prefetch (static pack permutation)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B // block_b,),
        in_specs=[pl.BlockSpec((block_b, F, D), lambda b, tri_ref: (b, 0, 0))],
        out_specs=pl.BlockSpec((block_b, P), lambda b, tri_ref: (b, 0)),
    )
    return pl.pallas_call(
        _interaction_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, P), feats.dtype),
        interpret=interpret,
    )(tri, feats)
