"""Mesh-axis conventions and sharding helpers.

Logical axes:
  dp   : data-parallel axes — ("data",) on a single pod, ("pod", "data") on the
         multi-pod mesh (pure DP across pods).
  tp   : tensor/model-parallel axis — "model".  Embedding tables are
         row-sharded over tp ("memory devices" in the PIFS mapping).
  ep   : expert-parallel axes for MoE — the combined (dp + tp) axes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical->physical axis mapping for a given mesh."""
    dp: Tuple[str, ...]
    tp: str

    @property
    def ep(self) -> Tuple[str, ...]:
        return self.dp + (self.tp,)

    def dp_size(self, mesh: Mesh) -> int:
        return int(np.prod([mesh.shape[a] for a in self.dp]))

    def tp_size(self, mesh: Mesh) -> int:
        return int(mesh.shape[self.tp])

    def ep_size(self, mesh: Mesh) -> int:
        return self.dp_size(mesh) * self.tp_size(mesh)


def make_mesh(shape: Sequence[int], names: Sequence[str]) -> Mesh:
    """jax.make_mesh with explicit Auto axis types where the API has them.

    jax >= 0.5 wants ``axis_types`` spelled out to stay on Auto semantics;
    jax 0.4.x predates ``jax.sharding.AxisType`` (everything is Auto), so the
    kwarg is only passed when it exists.
    """
    kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(names)
    return jax.make_mesh(tuple(shape), tuple(names), **kwargs)


# ---------------------------------------------------------------------------
# shard_map compat: jax >= 0.5 exposes jax.shard_map(..., check_vma=...);
# jax 0.4.x has jax.experimental.shard_map.shard_map(..., check_rep=...).
# All repro code routes through this wrapper so both spellings work.
# ---------------------------------------------------------------------------
if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
    _shard_map_check_kwarg = "check_vma"
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _shard_map_check_kwarg = "check_rep"


def shard_map(f, mesh: Mesh, in_specs, out_specs, check_vma: bool = False):
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs,
                           **{_shard_map_check_kwarg: check_vma})


def axes_for(mesh: Mesh) -> MeshAxes:
    names = mesh.axis_names
    if "pod" in names:
        return MeshAxes(dp=("pod", "data"), tp="model")
    if "data" in names:
        return MeshAxes(dp=("data",), tp="model")
    # single-axis test meshes
    return MeshAxes(dp=(), tp=names[0])


def ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def constrain(x, mesh: Mesh, *spec):
    return jax.lax.with_sharding_constraint(x, ns(mesh, *spec))
