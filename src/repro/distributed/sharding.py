"""Mesh-axis conventions and sharding helpers.

Logical axes:
  dp   : data-parallel axes — ("data",) on a single pod, ("pod", "data") on the
         multi-pod mesh (pure DP across pods).
  tp   : tensor/model-parallel axis — "model".  Embedding tables are
         row-sharded over tp ("memory devices" in the PIFS mapping).
  ep   : expert-parallel axes for MoE — the combined (dp + tp) axes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical->physical axis mapping for a given mesh."""
    dp: Tuple[str, ...]
    tp: str

    @property
    def ep(self) -> Tuple[str, ...]:
        return self.dp + (self.tp,)

    def dp_size(self, mesh: Mesh) -> int:
        return int(np.prod([mesh.shape[a] for a in self.dp]))

    def tp_size(self, mesh: Mesh) -> int:
        return int(mesh.shape[self.tp])

    def ep_size(self, mesh: Mesh) -> int:
        return self.dp_size(mesh) * self.tp_size(mesh)


def make_mesh(shape: Sequence[int], names: Sequence[str]) -> Mesh:
    """jax.make_mesh with explicit Auto axis types (stable across jax 0.8/0.9)."""
    return jax.make_mesh(tuple(shape), tuple(names),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(names))


def axes_for(mesh: Mesh) -> MeshAxes:
    names = mesh.axis_names
    if "pod" in names:
        return MeshAxes(dp=("pod", "data"), tp="model")
    if "data" in names:
        return MeshAxes(dp=("data",), tp="model")
    # single-axis test meshes
    return MeshAxes(dp=(), tp=names[0])


def ns(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def constrain(x, mesh: Mesh, *spec):
    return jax.lax.with_sharding_constraint(x, ns(mesh, *spec))
