from repro.distributed.sharding import MeshAxes, axes_for, constrain, ns, replicated  # noqa: F401
