"""Benchmark entry point: `PYTHONPATH=src python -m benchmarks.run`.

Runs every paper-figure reproduction (simlab) and prints the scorecard of
reproduced vs paper-reported values, then the roofline table from the
dry-run artifacts (if present).
"""
from __future__ import annotations

import argparse
import time


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.3f}"
    return str(v)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fig", help="run a single figure")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    from benchmarks.paper_figs import ALL_FIGS
    figs = {args.fig: ALL_FIGS[args.fig]} if args.fig else ALL_FIGS

    for name, fn in figs.items():
        t0 = time.time()
        out = fn()
        paper = out.pop("paper", {})
        print(f"\n### {name}  ({time.time() - t0:.1f}s)")
        for k, v in out.items():
            ref = ""
            if k in paper:
                ref = f"   [paper: {_fmt(paper[k])}]"
            print(f"  {k:42s} {_fmt(v)}{ref}")
        extra = {k: v for k, v in paper.items() if k not in out}
        if extra:
            print("  (paper context: "
                  + ", ".join(f"{k}={_fmt(v)}" for k, v in extra.items())
                  + ")")

    if not args.skip_roofline:
        try:
            from benchmarks.roofline import main as roofline_main
            roofline_main()
        except Exception as e:  # dry-run artifacts may not exist yet
            print(f"\n(roofline table unavailable: {e})")


if __name__ == "__main__":
    main()
