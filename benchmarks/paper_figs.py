"""Reproduction of the paper's tables/figures via simlab.

One function per figure; each returns a dict of named results and the paper's
reported value where it exists, so `python -m benchmarks.run` prints a
reproduction scorecard.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.configs import get_config
from repro.data.traces import TraceConfig, TraceGenerator, flatten_trace
from repro.simlab.devices import HardwareParams
from repro.simlab.simulator import (ALL_SYSTEMS, SimResult, SystemConfig,
                                    e2e_speedup, make_system, pifs,
                                    simulate, sls_fraction_for)
from repro.simlab.tco import (performance_per_watt, power_area_table,
                              tco_comparison)

RMC = {name: get_config(name) for name in ("rmc1", "rmc2", "rmc3", "rmc4")}


def _trace(model, distribution="zipfian", batches=6, batch=512, seed=0):
    cfg = TraceConfig(n_rows=model.emb_num, n_tables=model.n_tables,
                      pooling=model.pooling, batch=batch,
                      distribution=distribution, seed=seed)
    g = TraceGenerator(cfg)
    arr = np.stack([g.next_batch() for _ in range(batches)])
    flat = flatten_trace(arr.reshape(-1, model.n_tables, model.pooling),
                         model.emb_num)
    return flat


def _run_all(flat, model, hw, n_devices=None, systems=ALL_SYSTEMS,
             **kw) -> Dict[str, SimResult]:
    return {name: simulate(flat, model.emb_dim, model.pooling,
                           make_system(name, hw), hw,
                           n_rows_total=model.emb_num * model.n_tables,
                           n_devices=n_devices, **kw)
            for name in systems}


def fig12a_models(hw: HardwareParams = HardwareParams()) -> Dict:
    """Latency across RMC1-4 (paper: PIFS vs Pond 3.8x avg / 3.89x RMC4,
    Pond+PM 3.5x/3.57x, BEACON 1.94x/2.03x, RecNMP 8.5%/11%)."""
    out = {}
    speedups = {s: [] for s in ALL_SYSTEMS}
    for name, model in RMC.items():
        flat = _trace(model)
        res = _run_all(flat, model, hw)
        p = res["pifs"].total_us
        for s in ALL_SYSTEMS:
            out[f"{name}/{s}_vs_pifs"] = res[s].total_us / p
            speedups[s].append(res[s].total_us / p)
    for s in ALL_SYSTEMS:
        out[f"avg/{s}_vs_pifs"] = float(np.mean(speedups[s]))
    out["paper"] = {"avg/pond_vs_pifs": 3.8, "avg/pond_pm_vs_pifs": 3.5,
                    "avg/beacon_vs_pifs": 1.94, "avg/recnmp_vs_pifs": 1.085,
                    "rmc4/pond_vs_pifs": 3.89, "rmc4/pond_pm_vs_pifs": 3.57,
                    "rmc4/beacon_vs_pifs": 2.03, "rmc4/recnmp_vs_pifs": 1.11}
    return out


def fig12b_distributions(hw: HardwareParams = HardwareParams()) -> Dict:
    """Trace distributions (paper: uniform best — 1.1x over RecNMP; zipfian
    worst — 2% over RecNMP; PIFS 2-2.2x BEACON, 3.8-3.9x Pond)."""
    model = RMC["rmc4"]
    out = {}
    for dist in ("zipfian", "normal", "uniform", "random"):
        flat = _trace(model, distribution=dist)
        res = _run_all(flat, model, hw)
        p = res["pifs"].total_us
        for s in ("pond", "pond_pm", "beacon", "recnmp"):
            out[f"{dist}/{s}_vs_pifs"] = res[s].total_us / p
    out["paper"] = {"uniform/recnmp_vs_pifs": 1.1,
                    "zipfian/recnmp_vs_pifs": 1.02}
    return out


def fig12c_scalability(hw: HardwareParams = HardwareParams()) -> Dict:
    """Memory-device scaling (paper at 16 devices: 12.5x Pond, 8.3x Pond+PM,
    1.22x RecNMP)."""
    model = RMC["rmc4"]
    flat = _trace(model)
    out = {}
    for D in (2, 4, 8, 16):
        res = _run_all(flat, model, hw, n_devices=D)
        p = res["pifs"].total_us
        out[f"x{D}/pifs_us"] = p
        for s in ("pond", "pond_pm", "recnmp"):
            out[f"x{D}/{s}_vs_pifs"] = res[s].total_us / p
    out["paper"] = {"x16/pond_vs_pifs": 12.5, "x16/pond_pm_vs_pifs": 8.3,
                    "x16/recnmp_vs_pifs": 1.22}
    return out


def fig12d_dram_size(hw: HardwareParams = HardwareParams()) -> Dict:
    """Local DRAM capacity sweep (paper: 256 GB +4%, 512 GB +6% vs 128 GB)."""
    model = RMC["rmc4"]
    flat = _trace(model)
    base_frac = hw.local_capacity_frac
    out = {}
    t0 = None
    for mult, label in ((1, "128GB"), (2, "256GB"), (4, "512GB")):
        res = simulate(flat, model.emb_dim, model.pooling,
                       pifs(hw), hw,
                       n_rows_total=model.emb_num * model.n_tables,
                       local_capacity_frac=base_frac * mult)
        if t0 is None:
            t0 = res.total_us
        out[f"{label}_speedup_vs_128GB"] = t0 / res.total_us
    out["paper"] = {"256GB_speedup_vs_128GB": 1.04,
                    "512GB_speedup_vs_128GB": 1.06}
    return out


def fig12e_ablation(hw: HardwareParams = HardwareParams()) -> Dict:
    """Mechanism ablation vs Pond (paper: +PC 26%, +OoO <=7.3%, +PM ~27%,
    +buffer +15%)."""
    model = RMC["rmc4"]
    flat = _trace(model)
    kw = dict(hw=hw, n_rows_total=model.emb_num * model.n_tables)
    rb = model.emb_dim

    def t(sys):
        return simulate(flat, rb, model.pooling, sys, **kw).total_us

    pond_t = t(make_system("pond", hw))
    variants = {
        "pond": pond_t,
        "+pc": t(pifs(hw, pc=True, pm=False, buffer_kb=0, ooo=False)),
        "+pc+ooo": t(pifs(hw, pc=True, pm=False, buffer_kb=0, ooo=True)),
        "+pc+pm": t(pifs(hw, pc=True, pm=True, buffer_kb=0, ooo=False)),
        "+pc+buffer": t(pifs(hw, pc=True, pm=False, ooo=False)),
        "full_pifs": t(pifs(hw)),
    }
    variants["full_no_ooo"] = t(pifs(hw, ooo=False))
    out = {f"{k}_speedup_vs_pond": pond_t / v for k, v in variants.items()}
    out["ooo_gain"] = variants["full_no_ooo"] / variants["full_pifs"]
    out["paper"] = {"+pc_speedup_vs_pond": 1.26, "ooo_gain_max": 1.073,
                    "+pc+pm_speedup_vs_pond": 1.27 * 1.26,
                    "+pc+buffer_speedup_vs_pond": 1.15 * 1.26}
    return out


def fig13a_migrate_threshold(hw: HardwareParams = HardwareParams()) -> Dict:
    """Embedding-migration threshold sweep (paper: best at 35%, ~14% latency
    reduction; page-block migration cost 1.67%->10% from 10%->50%)."""
    model = RMC["rmc4"]
    flat = _trace(model)
    rb = model.emb_dim
    out = {}
    # threshold sweep is realized through the planner's spread aggressiveness:
    # we model low/high thresholds as page-block vs line migration cost and
    # spreading on/off (the simulator's PM includes spreading)
    res_line = simulate(flat, rb, model.pooling,
                        pifs(hw, migration_granularity="line"), hw,
                        n_rows_total=model.emb_num * model.n_tables)
    res_page = simulate(flat, rb, model.pooling,
                        pifs(hw, migration_granularity="page"), hw,
                        n_rows_total=model.emb_num * model.n_tables)
    res_nopm = simulate(flat, rb, model.pooling, pifs(hw, pm=False), hw,
                        n_rows_total=model.emb_num * model.n_tables)
    out["pm_latency_reduction"] = res_nopm.total_us / res_line.total_us
    out["line_vs_page_migration_cost"] = (
        res_page.migration_cost_us / max(res_line.migration_cost_us, 1e-9))
    out["migration_cost_frac_line"] = (res_line.migration_cost_us
                                       / res_line.total_us)
    out["paper"] = {"pm_latency_reduction": 1.14,
                    "line_vs_page_migration_cost": 5.1,
                    "migration_cost_frac_line": 0.02}
    return out


def fig13b_access_balance(hw: HardwareParams = HardwareParams()) -> Dict:
    """Std-dev of device access frequency before/after migration
    (paper: 20.6 -> 7.8)."""
    model = RMC["rmc4"]
    flat = _trace(model)
    rb = model.emb_dim
    kw = dict(hw=hw, n_rows_total=model.emb_num * model.n_tables)
    before = simulate(flat, rb, model.pooling, pifs(hw, pm=False), **kw)
    after = simulate(flat, rb, model.pooling, pifs(hw), **kw)

    def std_norm(loads):
        m = loads.mean()
        return float(loads.std() / max(m, 1e-9) * 20.6 / 0.35)  # scaled units

    out = {"imbalance_before": before.device_imbalance,
           "imbalance_after": after.device_imbalance,
           "std_before": float(before.device_loads.std() / 1e6),
           "std_after": float(after.device_loads.std() / 1e6)}
    out["paper"] = {"std_ratio": 20.6 / 7.8}
    out["std_ratio"] = out["std_before"] / max(out["std_after"], 1e-9)
    return out


def fig14_multihost(hw: HardwareParams = HardwareParams()) -> Dict:
    """End-to-end speedup vs hosts/batch (paper RMC4: 1.9-4.7x from 2->8
    hosts, growing with batch via the SLS-fraction weighting)."""
    model = RMC["rmc4"]
    out = {}
    for hosts in (2, 4, 8):
        batch = 256 * hosts
        flat = _trace(model, batch=batch, batches=4)
        res = _run_all(flat, model, hw, systems=("pond", "pifs"))
        sls_sp = res["pond"].total_us / res["pifs"].total_us
        f = sls_fraction_for(model, batch, hw)
        out[f"hosts{hosts}/sls_fraction"] = f
        out[f"hosts{hosts}/e2e_speedup"] = e2e_speedup(sls_sp, f)
    out["paper"] = {"hosts2_to_8_range": (1.9, 4.7)}
    return out


def fig13c_multiswitch(hw: HardwareParams = HardwareParams()) -> Dict:
    """Multi-switch scaling via instruction forwarding (paper: 2->32 switches
    improves latency 1.8-20.8x for the largest batch).

    Each switch adds its own device pool + PC; cross-switch partials add a
    100 ns hop (paper's assumption).  Modeled as n_switches independent
    shards of the trace with per-switch resources + the forwarding hop."""
    model = RMC["rmc4"]
    flat = _trace(model, batch=2048, batches=4)
    rb = model.emb_dim
    out = {}
    base = None
    for n_sw in (1, 2, 4, 8, 16, 32):
        shard = flat[: len(flat) // n_sw]
        hw_sw = dataclasses.replace(hw, pc_GBs=hw.pc_GBs)
        res = simulate(shard, rb, model.pooling, pifs(hw_sw), hw_sw,
                       n_rows_total=model.emb_num * model.n_tables)
        total = res.total_us + 0.1 * (n_sw > 1)  # +100ns forwarding hop
        if base is None:
            base = total
        out[f"x{n_sw}_speedup"] = base / total
    out["paper"] = {"x32_range": (1.8, 20.8)}
    return out


def fig15_buffer(hw: HardwareParams = HardwareParams()) -> Dict:
    """On-switch buffer policy x capacity (paper: HTR 7.6-14.8% gain
    64KB->512KB on RMC4; 1MB degrades, hit ratio 41.9%)."""
    model = RMC["rmc4"]
    flat = _trace(model)
    rb = model.emb_dim
    kw = dict(hw=hw, n_rows_total=model.emb_num * model.n_tables)
    base = simulate(flat, rb, model.pooling, pifs(hw, buffer_kb=0), **kw)
    out = {"no_buffer_us": base.total_us}
    for pol in ("htr", "lru", "fifo"):
        for kb in (64, 128, 256, 512, 1024):
            r = simulate(flat, rb, model.pooling,
                         pifs(hw, buffer_kb=kb, buffer_policy=pol), **kw)
            out[f"{pol}/{kb}KB_speedup"] = base.total_us / r.total_us
            if pol == "htr":
                out[f"htr/{kb}KB_hit"] = r.buffer_hit_rate
    out["paper"] = {"htr/512KB_speedup_range": (1.076, 1.148),
                    "htr/1MB_hit": 0.419}
    return out


def fig16_18_tco(hw: HardwareParams = HardwareParams()) -> Dict:
    """TCO + power/area + PPW (paper: RMC1 3.38x, RMC4 1-GPU 2.53x; power
    2.7x vs RecNMP, area 2.02x; PPW 1.22->1.61x)."""
    out = {}
    for name in ("rmc1", "rmc4"):
        t = tco_comparison(RMC[name])
        out[f"{name}/mem_gb"] = t["mem_gb"]
        for k in ("ratio_x1", "ratio_x2", "ratio_x4"):
            out[f"{name}/{k}"] = t[k]
    pa = power_area_table()
    out["power_ratio_vs_recnmp"] = pa["power_ratio"]
    out["area_ratio_vs_recnmp"] = pa["area_ratio"]
    out["ppw_small"] = performance_per_watt(0.1)
    out["ppw_large"] = performance_per_watt(1.0)
    out["paper"] = {"rmc1_matched_throughput": 3.38, "rmc4/ratio_x1": 2.53,
                    "power_ratio_vs_recnmp": 2.7,
                    "area_ratio_vs_recnmp": 2.02,
                    "ppw_range": (1.22, 1.61)}
    return out


import dataclasses  # noqa: E402  (used by fig13c)

ALL_FIGS = {
    "fig12a_models": fig12a_models,
    "fig12b_distributions": fig12b_distributions,
    "fig12c_scalability": fig12c_scalability,
    "fig12d_dram_size": fig12d_dram_size,
    "fig12e_ablation": fig12e_ablation,
    "fig13a_migrate_threshold": fig13a_migrate_threshold,
    "fig13b_access_balance": fig13b_access_balance,
    "fig13c_multiswitch": fig13c_multiswitch,
    "fig14_multihost": fig14_multihost,
    "fig15_buffer": fig15_buffer,
    "fig16_18_tco": fig16_18_tco,
}
