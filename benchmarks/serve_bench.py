"""Serving benchmark: deadline-aware dynamic batcher vs the fixed-batch
baseline at equal offered load, on a real PIFSEmbeddingEngine.

The paper's headline claim is *online-inference latency under concurrent
production-style access streams*; this bench measures the quantities that
regime is judged by — p50/p99/p99.9 latency, sustained QPS, SLO-violation
rate, batch occupancy — for two batching policies over the same engine,
the same compiled serve step, and the **same arrival stream** (same seed):

  * ``dynamic`` — the deadline-aware shape-bucket micro-batcher
    (repro.serving.batcher.DynamicBatcher), and
  * ``fixed``   — the old serve-loop policy (wait for a full fixed batch).

Offered load is calibrated against the measured capacity of the largest
bucket (``frac * B_max / service(B_max)``), so the comparison is at an
apples-to-apples utilization on any host.  Each run sweeps load regimes;
hard gates:

  * zero steady-state retraces (``engine.plan_stats()`` delta stays 0
    across every shape bucket after warmup, for both policies, in every
    regime);
  * **trough** regime (sub-saturation, where fixed-batch fill time
    dominates the tail): dynamic p99 < fixed p99 at equal offered load —
    the structural win of deadline-aware flushing;
  * **sustained** regime (both policies serve full buckets; the tail
    difference there is measurement noise): dynamic must sustain >= 80 %
    of the offered QPS.

``--faults`` switches to the fault-injection regimes instead (straggler,
transient executor failures, corrupted data/store, a forced brown-out
burst), each run under the retry/circuit-breaker/degradation-ladder
controller with gates on availability, zero steady-state retraces, and
recorded degradation/recovery transitions (EXPERIMENTS.md §Serving fault
tolerance).

``--mesh-faults`` switches to the degraded-mesh regime: a tp shard is
deterministically killed mid-serving (``shard_loss`` fault class); the
degradation controller attributes the consecutive same-shard failures,
escalates past the brown-out ladder to the ``remesh`` recovery action,
and the runtime re-meshes the engine onto the survivors on the
maintenance seam (quiesce -> export -> re-plan -> re-pack -> rebuild +
re-warm the jitted serve-step variants) and re-attempts the stranded
micro-batch.  Hard gates per config (fp32/split and int8+dedup+fused):
exactly one recorded re-mesh, availability >= 0.99, bounded MTTR
(recorded in the artifact; wall time is compile-dominated on CPU
containers), zero steady-state retraces across the whole run — the
pre-loss *and* post-recovery steady states share one gate, read before
any probe executes — and post-recovery probe scores bit-identical to a
fresh engine packed onto the same survivor mesh (fused configs
additionally assert the front end re-resolved ``fused_tp`` at the new
tp).

``--updates`` switches to the streaming-embedding-update regime: the same
offered load served twice — once clean, once with a WAL-logged trainer
delta stream drained between micro-batches on the background-maintenance
seam (same accounting model as observe/replan).  Hard gates: updates
never blow the service tail (measured p99 regression < 10 % vs the clean
run at equal offered load), every drain's wall cost fits inside one SLO
budget, zero steady-state retraces in both runs, staleness p99 bounded,
and a mid-serving corrupt -> restore -> WAL-replay probe whose state AND
lookups are bit-identical to the pre-corruption engine (EXPERIMENTS.md
§Online embedding updates).

``--scrub`` switches to the silent-corruption regime: the same offered
load served twice — once clean, once with deterministic *finite* bit
flips seeded into live store pages (``bit_flip`` fault class — the case
the NaN score scrub structurally misses) while a ``ScrubController``
audits a rotating page window against the per-page checksum ledger on
the maintenance seam and repairs divergent pages surgically (snapshot
page slice + filtered WAL replay).  Hard gates: every flipped page
detected within one full sweep of the store, repaired pages == detected
pages with bounded per-page MTTR, availability >= 0.99, measured p99
within 10 % of the no-scrub leg at equal offered load, zero
steady-state retraces in both legs, and the post-run store leaves AND
probe scores bitwise identical to the never-corrupted engine
(EXPERIMENTS.md §Silent-corruption scrubbing).

The policy-comparison section also runs a fused front-end leg on a
(4, 2) dp x tp mesh (DLRM archs): ``front_end='fused'`` — resolved
``fused_tp`` by the engine (partial-pool per shard, psum the (B, F, d)
cold tile, resume; asserted via ``plan_stats()['front_end']``) — served
against the ``front_end='split'`` control on the same arrival stream,
gated on zero steady-state retraces in both runs and probe-batch scores
bit-equal between the bindings.

Writes ``BENCH_serve.json`` (schema 7); schema documented in
EXPERIMENTS.md §Serving.

Service times are real measured device executions (interpret-mode caveat
from BENCH_sls applies to pallas impl on CPU); arrivals/queueing run on
the virtual clock, which is what makes tail-latency comparisons meaningful
on CPU containers.

Usage: ``PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
[--impl pallas] [--out BENCH_serve.json]``
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint.checkpointer import Checkpointer  # noqa: E402
from repro.checkpoint.wal import WriteAheadLog  # noqa: E402
from repro.configs import get_config, reduced  # noqa: E402
from repro.distributed.sharding import make_mesh  # noqa: E402
from repro.runtime.fault_tolerance import StragglerWatchdog  # noqa: E402
from repro.serving import (ArrivalConfig, BatcherConfig,  # noqa: E402
                           BindingExecutor, BreakerConfig, Bucket,
                           DegradationController,
                           DynamicBatcher, FaultConfig,
                           FaultInjectingExecutor, FixedBatcher,
                           LadderConfig, LoadConfig, OpenLoopSource,
                           RetryPolicy, RuntimeConfig, ScrubConfig,
                           ScrubController, ServiceModel,
                           ServingRuntime,
                           StreamingUpdater, UpdateConfig, bind_model,
                           corrupt_store, dummy_request_factory,
                           flip_store_bits, make_padder, prime_dedup_auto,
                           request_stream, update_stream)


def run_policy(binding, cfg, batcher, load, runtime_cfg, updater=None) -> dict:
    """One (policy, arrival-stream) serving run over a warmed binding."""
    runtime = ServingRuntime(BindingExecutor(binding), batcher,
                             make_padder(cfg), runtime_cfg, updater=updater)
    runtime.warmup(dummy_request_factory(cfg, storage=load.storage))
    # ^ no-op cost once plans warm
    reqs = request_stream(cfg, load)
    if load.dedup == "auto" and prime_dedup_auto(binding, reqs):
        # 'auto' freezes per bucket at plan build — rebuild the buckets
        # against a histogram primed with the live stream's prefix
        runtime.warmup(dummy_request_factory(cfg, storage=load.storage))
    if updater is not None:
        updater.warmup()   # compile the apply plan before steady state
    binding.reset_plan_stats()
    warm_replans = binding.replans
    binding.dedup_stats.clear()
    summary = runtime.run(OpenLoopSource(reqs))
    stats = binding.plan_stats()
    summary["steady_traces"] = stats["traces"]
    summary["replans"] = binding.replans - warm_replans
    # measured per-bucket duplicate factor (observe-cadence probe): makes
    # serving wins attributable in bytes, not just p50
    summary["dedup_factors"] = binding.dedup_report()
    return summary


# ---------------------------------------------------------------------------
# Fault regimes (--faults): chaos-hardened serving
# ---------------------------------------------------------------------------

# one regime per injected fault class (ISSUE: straggler, transient executor
# failure, corrupted store, OOB/NaN data) plus a forced brown-out burst that
# exercises the breaker + degradation ladder end to end.  Availability gates
# apply to the chaos classes (rare, retryable faults: healthy traffic must
# see >= 0.99); the brownout burst deliberately fails batches past the retry
# budget, so its gate is the *recorded recovery*, not availability.
FAULT_REGIMES = [
    # scheduled steps guarantee each class actually fires at smoke scale
    # (~10-20 micro-batches); the chaos probabilities ride on top so the
    # longer full-size runs also see unscheduled faults
    dict(label="straggler", avail_gate=0.99,
         faults=dict(straggler_at=(4, 12), straggler_prob=0.05,
                     straggler_factor=8.0, stall_at=(1,), stall_prob=0.1,
                     stall_s=0.01)),
    dict(label="transient", avail_gate=0.99,
         faults=dict(transient_at=(6,), transient_prob=0.05)),
    dict(label="corrupt_data", avail_gate=0.99,
         faults=dict(corrupt_oob_at=(3, 9), corrupt_oob_prob=0.05,
                     corrupt_nan_at=(5, 11), corrupt_nan_prob=0.05)),
    dict(label="corrupt_store", avail_gate=0.99, corrupt_store=True,
         faults=dict()),
    # forced failure burst past the retry budget: this regime exists to
    # exercise the breaker + ladder end to end (its gate is the recorded
    # degradation AND recovery, not availability — the burst deliberately
    # fails whole batches); 2x requests so recovery completes in-run
    dict(label="brownout", avail_gate=0.50, gate_transitions=True,
         n_mult=2, faults=dict(transient_at=(5,), transient_runs=6)),
]


def _warm_all_rungs(binding, cfg, bat_cfg, runtime_cfg, svc_model, storage):
    """Warm every ladder-rung variant over every bucket so mid-serving rung
    switches stay retrace-free (the same contract the plain bench gates)."""
    warm_rt = ServingRuntime(BindingExecutor(binding), DynamicBatcher(bat_cfg),
                             make_padder(cfg), runtime_cfg,
                             service_model=svc_model)
    factory = dummy_request_factory(cfg, storage=storage)
    for rung in binding.modes():
        binding.set_mode(rung)
        warm_rt.warmup(factory)
    binding.set_mode("full")


def run_fault_regime(binding, cfg, bat_cfg, load, runtime_cfg, svc_model,
                     regime: dict, ckpt_dir: str) -> dict:
    """One fault class: fresh controller + fault wrapper over the warmed
    binding, full open-loop run, degradation report attached."""
    binding.set_mode("full")          # fresh ladder per fault class
    fault_cfg = FaultConfig(seed=13, **regime["faults"])
    ctrl = DegradationController(
        binding=binding,
        # short virtual-time cooldown + eager step-up so trip/recovery both
        # complete within a smoke-sized run (hysteresis band stays wide)
        breaker=BreakerConfig(trip_after=5, cooldown_s=0.02),
        ladder=LadderConfig(min_dwell_batches=4, step_up_at=0.15,
                            poison_restore_after=2))
    fex = FaultInjectingExecutor(BindingExecutor(binding), fault_cfg,
                                 idx_key=binding.idx_key)
    # per-batch service-time watchdog feeding the controller: a straggling
    # shard walks the ladder down before it ever fails outright.  The 4x
    # threshold sits safely above shared-host jitter and safely below the
    # 8x injected straggler factor.
    watchdog = StragglerWatchdog(threshold=4.0)
    runtime = ServingRuntime(fex, DynamicBatcher(bat_cfg), make_padder(cfg),
                             runtime_cfg, service_model=svc_model,
                             controller=ctrl, watchdog=watchdog)
    reqs = request_stream(cfg, load)
    if regime.get("corrupt_store"):
        # promote hot pages with the live stream's prefix (a corrupted hot
        # tier nobody reads poisons nothing), snapshot the healthy state,
        # then scribble NaNs the restore path must heal
        dp = max(1, binding.engine.axes.dp_size(binding.engine.mesh))
        for r in reqs[:16]:
            idx = np.asarray(r.features[binding.idx_key])
            binding.observe({binding.idx_key:
                             np.broadcast_to(idx[None], (dp,) + idx.shape)})
        binding.replan()
        binding.attach_checkpointer(Checkpointer(ckpt_dir), save_now=True)
        # explicit mode="nan": this regime heals through the NaN score
        # scrub -> poison-restore path; finite flips are --scrub's job
        corrupt_store(binding, frac=0.5, seed=3, mode="nan")
    elif binding.checkpointer is None:
        binding.attach_checkpointer(Checkpointer(ckpt_dir), save_now=True)
    binding.reset_plan_stats()
    base_poisoned = binding.poisoned_batches
    summary = runtime.run(OpenLoopSource(reqs))
    summary["steady_traces"] = binding.plan_stats()["traces"]
    summary["faults_fired"] = fex.report()
    summary["poisoned_batches"] = binding.poisoned_batches - base_poisoned
    return summary


def run_fault_section(binding, cfg, bat_cfg, runtime_cfg, svc_model,
                      n_requests, capacity_qps, slo_ms, storage, dedup,
                      ckpt_dir) -> dict:
    runs: dict = {}
    for regime in FAULT_REGIMES:
        arrival = ArrivalConfig(rate_qps=0.3 * capacity_qps,
                                process="poisson", seed=7)
        load = LoadConfig(n_requests=n_requests * regime.get("n_mult", 1),
                          arrival=arrival, slo_ms=slo_ms, seed=7,
                          storage=storage, dedup=dedup)
        r = run_fault_regime(binding, cfg, bat_cfg, load, runtime_cfg,
                             svc_model, regime, ckpt_dir)
        deg = r["degradation"]
        label = regime["label"]
        print(f"[{label:13s}] avail={r['availability']:.4f} "
              f"goodput={r['goodput_qps']:7.1f} qps "
              f"p99={r['p99_ms']:8.2f} served={r['served']} "
              f"failed={r['failed']} retries={r['retries']} "
              f"rung={deg['rung']} transitions={deg['n_transitions']} "
              f"trips={deg['breaker_trips']} restores={deg['restores']} "
              f"wd_trips={r['watchdog']['trips']} "
              f"fired={r['faults_fired']} "
              f"steady_traces={r['steady_traces']}")
        # ---- gates ----
        if r["steady_traces"]:
            raise AssertionError(
                f"plan cache failed under faults: steady-state retrace in "
                f"{label}")
        if r["availability"] < regime["avail_gate"]:
            raise AssertionError(
                f"availability gate failed in {label}: "
                f"{r['availability']:.4f} < {regime['avail_gate']}")
        if regime.get("gate_transitions") and deg["n_transitions"] < 2:
            raise AssertionError(
                f"{label}: expected degradation AND recovery transitions, "
                f"recorded {deg['transitions']}")
        if label == "transient" and not r["retries"]:
            raise AssertionError("transient regime exercised no retries")
        if label == "straggler" and not r["watchdog"]["trips"]:
            raise AssertionError(
                "straggler regime: the 8x injected stragglers never "
                "tripped the service-time watchdog")
        if label == "corrupt_data" and not r["poisoned_batches"]:
            raise AssertionError(
                "corrupt_data regime: NaN injection never reached the "
                "score scrub")
        if label == "corrupt_store" and not deg["restores"]:
            raise AssertionError(
                "corrupt_store regime: poisoned store never triggered a "
                "checkpoint restore")
        runs[label] = {"avail_gate": regime["avail_gate"], **r}
    return runs


# ---------------------------------------------------------------------------
# Degraded-mesh regime (--mesh-faults): survive shard loss via elastic remesh
# ---------------------------------------------------------------------------

# one run per serving configuration: the plain control and the full
# feature stack (int8 cold tier + gather-once dedup + fused front end) —
# the re-mesh must carry quantized pages verbatim, re-prime dedup, and
# re-resolve fused_tp at the survivor tp, all mid-serving
MESH_FAULT_CONFIGS = [
    dict(label="fp32_split", storage="fp32", dedup="off", front_end="split"),
    dict(label="int8_fused", storage="int8", dedup="on", front_end="fused"),
]


def run_mesh_fault_config(cfg, args, conf: dict, n_requests: int,
                          prefer_tp: int) -> dict:
    """One shard-loss -> elastic-remesh serving run, fully gated.

    Starts on a (2, 4) dp x tp mesh, kills the highest tp shard at
    attempt 2, and requires the runtime to detect (same-shard streak),
    re-mesh onto the survivors (tp 4 -> 2 under ``prefer_tp=2`` with the
    bucket-granule constraint), re-warm, and finish the offered load with
    availability intact and zero steady-state retraces.  The retrace gate
    is read *before* the bit-exactness probe: probe batches are fresh jit
    signatures, and sampling the counter after them would conflate probe
    traces with steady-state ones."""
    fe = conf["front_end"] if hasattr(cfg, "n_tables") else "split"
    mesh = make_mesh((2, 4), ("data", "model"))
    bat_cfg = BatcherConfig(batch_sizes=(8, 16), poolings=(cfg.pooling,))
    runtime_cfg = RuntimeConfig(observe_every=4, replan_every=32)
    with mesh:
        binding = bind_model(cfg, mesh, mode=args.mode, impl=args.impl,
                             block_l=args.block_l, storage=conf["storage"],
                             dedup=conf["dedup"], front_end=fe,
                             degraded_variants=True, scrub_scores=True,
                             elastic=True, prefer_tp=prefer_tp)
        ctrl = DegradationController(
            binding=binding,
            retry=RetryPolicy(max_attempts=3),
            # trip_after > retry budget x remesh_after: the breaker must
            # not fail-fast the stranded batch before attribution
            # escalates to remesh
            breaker=BreakerConfig(trip_after=6, cooldown_s=0.02),
            ladder=LadderConfig(min_dwell_batches=4, remesh_after=3))
        inner = BindingExecutor(binding)
        fex = FaultInjectingExecutor(
            inner, FaultConfig(seed=13, shard_loss_at=(2,)),
            idx_key=binding.idx_key)
        watchdog = StragglerWatchdog(threshold=4.0, warmup=4)
        runtime = ServingRuntime(inner, DynamicBatcher(bat_cfg),
                                 make_padder(cfg), runtime_cfg,
                                 controller=ctrl, watchdog=watchdog)
        factory = dummy_request_factory(cfg, storage=conf["storage"])
        # warm every ladder rung over every bucket through the *clean*
        # executor (fault schedules index live attempts only), then arm
        # the fault wrapper
        for rung in binding.modes():
            binding.set_mode(rung)
            runtime.warmup(factory)
        binding.set_mode("full")
        padder = make_padder(cfg)
        big = Bucket(bat_cfg.batch_sizes[-1], bat_cfg.poolings[-1])
        cal = padder([factory(i, big.pooling)
                      for i in range(big.batch)], big)
        svc = float(np.median([inner.run_batch(big, cal)
                               for _ in range(5)]))
        capacity_qps = big.batch / svc
        slo_ms = args.slo_ms or 5.0 * svc * 1e3
        runtime.executor = fex
        binding.reset_plan_stats()
        load = LoadConfig(
            n_requests=n_requests,
            arrival=ArrivalConfig(rate_qps=0.3 * capacity_qps,
                                  process="poisson", seed=7),
            slo_ms=slo_ms, seed=7, storage=conf["storage"],
            dedup=conf["dedup"], front_end=fe)
        summary = runtime.run(OpenLoopSource(request_stream(cfg, load)))

        # ---- gates (retrace gate FIRST — before any probe executes) ----
        label = conf["label"]
        steady_traces = binding.plan_stats()["traces"]
        if steady_traces:
            raise AssertionError(
                f"[{label}] plan cache failed across the re-mesh: "
                f"{steady_traces} steady-state retraces (the carried-trace "
                f"ledger spans both sides of the recovery)")
        rec = summary.get("remesh")
        if binding.remeshes != 1 or rec is None:
            raise AssertionError(
                f"[{label}] expected exactly one elastic re-mesh, recorded "
                f"{binding.remeshes} (remesh record: {rec})")
        if summary["availability"] < 0.99:
            raise AssertionError(
                f"[{label}] availability gate failed across shard loss: "
                f"{summary['availability']:.4f} < 0.99")
        # MTTR = maintenance-seam wall time of the recovery (quiesce +
        # export/re-plan/re-pack + rebuild & re-warm every serve-step
        # variant).  On CPU containers the re-warm recompiles dominate, so
        # the bound is deliberately loose: generous in SLO multiples,
        # floored at 60 s wall.
        mttr_bound = max(100.0 * slo_ms * 1e-3, 60.0)
        if not (0.0 < rec["mttr_s"] < mttr_bound):
            raise AssertionError(
                f"[{label}] MTTR unbounded: {rec['mttr_s']:.2f} s "
                f">= {mttr_bound:.1f} s")
        new_shape = dict(binding.engine.mesh.shape)
        if new_shape.get("model") != 2 or rec["to_mesh"] != new_shape:
            raise AssertionError(
                f"[{label}] survivor mesh mismatch: engine on {new_shape}, "
                f"record says {rec['to_mesh']} (expected model=2)")
        if fe == "fused":
            recs = [r for r in
                    binding.engine.plan_stats().get("front_end", {}).values()
                    if r["requested"] == "fused"]
            if not recs or any(r["resolved"] != "fused_tp" or r["tp"] != 2
                               for r in recs):
                raise AssertionError(
                    f"[{label}] front end did not re-resolve fused_tp at "
                    f"the survivor tp: "
                    f"{[(r['resolved'], r['tp']) for r in recs]}")

        # ---- bit-exactness probe: recovered engine vs a fresh engine
        # packed onto the *same* survivor mesh from the same logical
        # (codes, values, scales) triple and the same page table
        codes, values, scales = binding.engine.export_state(binding.state)
        fresh = bind_model(cfg, binding.engine.mesh, mode=args.mode,
                           impl=args.impl, block_l=args.block_l,
                           storage=conf["storage"], dedup=conf["dedup"],
                           front_end=fe)
        fresh.params = binding.params
        fresh.state = fresh.engine.pack_state(
            codes, values, scales, table=binding.state.page_table,
            counts=np.asarray(jax.device_get(binding.state.counts)))
        for bucket in (Bucket(b, cfg.pooling)
                       for b in bat_cfg.batch_sizes):
            probe = padder([factory(i, bucket.pooling)
                            for i in range(bucket.batch)], bucket)
            a = np.asarray(jax.device_get(binding.execute(probe)))
            b = np.asarray(jax.device_get(fresh.execute(probe)))
            if not np.array_equal(a, b):
                raise AssertionError(
                    f"[{label}] post-recovery scores diverge from a fresh "
                    f"engine on the degraded mesh at bucket {bucket}")

    deg = summary["degradation"]
    print(f"[{label:11s}] avail={summary['availability']:.4f} "
          f"served={summary['served']} failed={summary['failed']} "
          f"remeshes={binding.remeshes} "
          f"mttr={rec['mttr_s']:.2f}s at_batch={rec['at_batch']} "
          f"{rec['from_mesh']} -> {rec['to_mesh']} "
          f"lost_shard={rec['lost_shard']} "
          f"steady_traces={steady_traces} "
          f"fired={fex.report()['shard_loss']} "
          f"rung={deg['rung']} probe=bit-identical")
    summary.pop("latency_hist", None)
    summary.pop("dedup_factors", None)
    return {
        "label": label, "storage": conf["storage"], "dedup": conf["dedup"],
        "front_end": fe, "prefer_tp": prefer_tp,
        "capacity_qps": capacity_qps, "slo_ms": slo_ms,
        "offered_qps": 0.3 * capacity_qps,
        "steady_traces": steady_traces,
        "mttr_bound_s": mttr_bound,
        "faults_fired": fex.report(),
        "probe_bit_identical": True,
        "run": summary,
    }


def run_mesh_fault_section(cfg, args, n_requests: int,
                           prefer_tp: int) -> dict:
    return {c["label"]: run_mesh_fault_config(cfg, args, c, n_requests,
                                              prefer_tp)
            for c in MESH_FAULT_CONFIGS}


# ---------------------------------------------------------------------------
# Streaming-update regime (--updates): serving-concurrent embedding updates
# ---------------------------------------------------------------------------


def _state_leaves(binding):
    st = binding.state
    return [np.asarray(jax.device_get(x))
            for x in (st.cold, st.hot, st.page_scales,
                      st.page_to_shard, st.page_to_slot)]


def run_update_section(binding, cfg, bat_cfg, runtime_cfg, n_requests,
                       capacity_qps, slo_ms, svc_max, storage, dedup,
                       update_batch, ckpt_dir) -> dict:
    """Clean run vs updates run at the same offered load, then the
    mid-serving recovery probe.

    Updates drain on the maintenance seam under the same background-
    stream model as observe/replan (``account_maintenance=False``, the
    bench-wide convention — on CPU containers the ~ms jit-dispatch floor
    of a single apply would otherwise swamp the virtual-clock tail).
    The cost is still gated twice: the measured service p99 of the
    updates run must stay within 10 % of the clean run at equal offered
    load (dispatch interference is real wall time in both runs), and
    every drain's wall cost must fit inside one SLO budget — the slack a
    real deployment has between micro-batches."""
    rt_cfg = runtime_cfg
    arrival = ArrivalConfig(rate_qps=0.3 * capacity_qps, process="poisson",
                            seed=7)
    load = LoadConfig(n_requests=n_requests, arrival=arrival, slo_ms=slo_ms,
                      seed=7, storage=storage, dedup=dedup)
    base = run_policy(binding, cfg, DynamicBatcher(bat_cfg), load, rt_cfg)

    # trainer stream: one update_batch roughly every two service times —
    # a stiff but sub-saturating delta rate relative to engine capacity
    update_qps = 0.5 * update_batch / svc_max
    upd_load = dataclasses.replace(load, update_qps=update_qps,
                                   update_batch=update_batch)
    wal = WriteAheadLog(os.path.join(ckpt_dir, "updates.wal"))
    ucfg = UpdateConfig(capacity=2 * update_batch, drift_threshold=0.0,
                        max_demotions=4)
    updater = StreamingUpdater(binding, update_stream(cfg, upd_load), ucfg,
                               wal=wal)
    if binding.checkpointer is None:
        binding.attach_checkpointer(Checkpointer(ckpt_dir), save_now=True)
    upd = run_policy(binding, cfg, DynamicBatcher(bat_cfg), upd_load, rt_cfg,
                     updater=updater)
    upd["updates"] = updater.report()

    print(f"[updates   ] base   p99={base['p99_ms']:8.2f} "
          f"qps={base['qps']:8.1f} steady_traces={base['steady_traces']}")
    st = upd.get("staleness", {})
    print(f"[updates   ] stream p99={upd['p99_ms']:8.2f} "
          f"qps={upd['qps']:8.1f} steady_traces={upd['steady_traces']} "
          f"applied={upd['updates']['applied_batches']}/"
          f"{upd['updates']['generated_batches']} batches "
          f"stale_rows_p99={st.get('rows_behind_p99', 0.0):.1f} "
          f"stale_s_p99={st.get('seconds_behind_p99', 0.0):.4f}")

    # ---- gates: the update stream must be invisible to the service tail
    for name, r in (("base", base), ("updates", upd)):
        if r["steady_traces"]:
            raise AssertionError(
                f"plan cache failed under updates: steady-state retrace "
                f"in the {name} run")
    if not upd["updates"]["applied_batches"]:
        raise AssertionError("update regime applied no delta batches")
    p99_gate = 1.10 * base["p99_ms"]
    if upd["p99_ms"] >= p99_gate:
        raise AssertionError(
            f"updates blew the service tail: p99 {upd['p99_ms']:.2f} ms "
            f">= 1.10 x clean-run p99 ({base['p99_ms']:.2f} ms) at equal "
            f"offered load")
    # per-drain cost must fit in one SLO budget (the inter-batch slack a
    # real deployment hides background maintenance in)
    drain_s = upd["maintenance_s"].get("updates", 0.0)
    drain_calls = upd["maintenance_calls"].get("updates", 0)
    drain_mean_s = drain_s / drain_calls if drain_calls else 0.0
    if drain_mean_s >= slo_ms * 1e-3:
        raise AssertionError(
            f"update drains do not fit the maintenance slack: mean "
            f"{drain_mean_s * 1e3:.2f} ms per drain >= slo {slo_ms:.1f} ms")
    # staleness SLO: the stream must never fall more than ~4 SLO budgets
    # behind (seconds), nor hold more unapplied rows than the stream can
    # emit in that window (+2 batches of draining slack)
    slo_s = slo_ms * 1e-3
    if not st:
        raise AssertionError("update run recorded no staleness samples")
    if st["seconds_behind_p99"] > 4.0 * slo_s:
        raise AssertionError(
            f"staleness SLO failed: seconds_behind_p99 "
            f"{st['seconds_behind_p99']:.4f} > {4.0 * slo_s:.4f}")
    rows_bound = update_qps * 4.0 * slo_s + 2.0 * update_batch
    if st["rows_behind_p99"] > rows_bound:
        raise AssertionError(
            f"staleness SLO failed: rows_behind_p99 "
            f"{st['rows_behind_p99']:.1f} > {rows_bound:.1f}")

    # ---- recovery probe: drain the tail of the stream, force one
    # requant-demote scan (drift_threshold=0 guarantees candidates exist
    # when any traffic-cold hot page drifted; the demote fences itself
    # with a WAL-truncating snapshot), apply what remains, then corrupt
    # the store and restore: snapshot + WAL replay must reproduce the
    # live state bit-for-bit, lookups included
    updater.drain()
    demoted = updater.requant_demote()
    # the demote fenced with a snapshot (truncating the WAL) — land one
    # more logged delta batch past it, so restore must actually *replay*
    # rather than just reload the snapshot
    rng = np.random.default_rng(11)
    tail_rows = rng.integers(0, binding.engine.cfg.total_rows,
                             size=update_batch).astype(np.int64)
    tail_deltas = (1e-3 * rng.standard_normal(
        (update_batch, binding.engine.cfg.dim))).astype(np.float32)
    binding.apply_deltas(tail_rows, tail_deltas)
    if not len(wal):
        raise AssertionError("recovery probe expected a non-empty WAL")
    factory = dummy_request_factory(cfg, storage=storage)
    probe_bucket = Bucket(bat_cfg.batch_sizes[-1], bat_cfg.poolings[-1])
    probe = make_padder(cfg)(
        [factory(i, probe_bucket.pooling)
         for i in range(probe_bucket.batch)], probe_bucket)
    before_scores = np.asarray(jax.device_get(binding.execute(probe)))
    before_leaves = _state_leaves(binding)
    corrupt_store(binding, frac=0.5, seed=5, mode="nan")
    binding.restore()
    after_leaves = _state_leaves(binding)
    after_scores = np.asarray(jax.device_get(binding.execute(probe)))
    leaves_ok = all(a.dtype == b.dtype and (a == b).all()
                    for a, b in zip(before_leaves, after_leaves))
    scores_ok = (before_scores == after_scores).all()
    print(f"[updates   ] recovery demoted={demoted} "
          f"wal_replayed_state_identical={bool(leaves_ok)} "
          f"lookups_identical={bool(scores_ok)}")
    if not leaves_ok:
        raise AssertionError(
            "mid-serving restore + WAL replay did not reproduce the "
            "engine state bit-for-bit")
    if not scores_ok:
        raise AssertionError(
            "mid-serving restore + WAL replay changed lookup results")

    return {
        "offered_qps": 0.3 * capacity_qps,
        "update_qps": update_qps,
        "update_batch": update_batch,
        "p99_gate_ms": p99_gate,
        "drain_mean_ms": drain_mean_s * 1e3,
        "staleness_rows_bound": rows_bound,
        "staleness_seconds_bound": 4.0 * slo_s,
        "demoted_pages_post_run": demoted,
        "recovery_bit_identical": bool(leaves_ok and scores_ok),
        "base": base,
        "updates": upd,
    }


# ---------------------------------------------------------------------------
# Silent-corruption regime (--scrub): checksum scrubbing + page-level repair
# ---------------------------------------------------------------------------


def run_scrub_section(binding, cfg, bat_cfg, runtime_cfg, n_requests,
                      capacity_qps, slo_ms, storage, dedup, pages_per_cycle,
                      ckpt_dir) -> dict:
    """Clean leg vs bit-flip + scrub leg at the same offered load.

    Both legs run with observe/replan disabled: the whole point is that
    the *only* store mutations in the treated leg are the injected flips
    and the scrubber's repairs, so the post-run store must be bitwise
    identical to the never-corrupted truth captured after the clean leg.
    Hot pages are promoted and a WAL-logged delta tail is landed *before*
    the legs, so repairs exercise both tiers and must actually replay
    WAL records past the snapshot rather than just reload it."""
    rt_cfg = dataclasses.replace(runtime_cfg, observe_every=0,
                                 replan_every=0)
    arrival = ArrivalConfig(rate_qps=0.3 * capacity_qps, process="poisson",
                            seed=7)
    load = LoadConfig(n_requests=n_requests, arrival=arrival, slo_ms=slo_ms,
                      seed=7, storage=storage, dedup=dedup)
    reqs = request_stream(cfg, load)

    # ---- arm the store: hot tier, ledger, snapshot (+ledger), WAL tail
    dp = max(1, binding.engine.axes.dp_size(binding.engine.mesh))
    for r in reqs[:16]:
        idx = np.asarray(r.features[binding.idx_key])
        binding.observe({binding.idx_key:
                         np.broadcast_to(idx[None], (dp,) + idx.shape)})
    binding.replan()
    binding.attach_integrity()
    binding.attach_wal(WriteAheadLog(os.path.join(ckpt_dir, "scrub.wal")))
    binding.attach_checkpointer(Checkpointer(ckpt_dir), save_now=True)
    # a logged delta batch past the snapshot: every repair below must
    # replay it (filtered to the repaired page) to reach the live state
    rng = np.random.default_rng(17)
    n_tail = binding.update_capacity
    tail_rows = rng.integers(0, binding.engine.cfg.total_rows,
                             size=n_tail).astype(np.int64)
    tail_deltas = (1e-3 * rng.standard_normal(
        (n_tail, binding.engine.cfg.dim))).astype(np.float32)
    binding.apply_deltas(tail_rows, tail_deltas)
    if not len(binding.wal):
        raise AssertionError("scrub regime expected a non-empty WAL")

    # ---- clean leg, then the never-corrupted truth
    base = run_policy(binding, cfg, DynamicBatcher(bat_cfg), load, rt_cfg)
    factory = dummy_request_factory(cfg, storage=storage)
    probe_bucket = Bucket(bat_cfg.batch_sizes[-1], bat_cfg.poolings[-1])
    probe = make_padder(cfg)(
        [factory(i, probe_bucket.pooling)
         for i in range(probe_bucket.batch)], probe_bucket)
    truth_scores = np.asarray(jax.device_get(binding.execute(probe)))
    truth_leaves = _state_leaves(binding)

    # ---- treated leg: seeded finite flips + scrubbing repairs
    flip_at = (2, 5)
    ctrl = DegradationController(binding=binding,
                                 ladder=LadderConfig(min_dwell_batches=4))
    inner = BindingExecutor(binding)
    fex = FaultInjectingExecutor(
        inner, FaultConfig(seed=13, bit_flip_at=flip_at, bit_flip_rows=2,
                           bit_flip_tier="both"),
        idx_key=binding.idx_key)
    scrub = ScrubController(
        binding, ScrubConfig(pages_per_cycle=pages_per_cycle),
        controller=ctrl)
    runtime = ServingRuntime(inner, DynamicBatcher(bat_cfg),
                             make_padder(cfg), rt_cfg, controller=ctrl,
                             scrubber=scrub)
    # warm through the clean executor (fault schedules index live
    # attempts only), compile the scrub/repair plans, then arm the flips
    runtime.warmup(factory)
    scrub.warmup()
    # the first serve step over device_put-committed state arrays is a
    # fresh executable signature on some backends (observed for the int8
    # cold tier), per bucket: absorb those one-time recompiles outside
    # the timed leg with a self-inverse double flip — same seed XORs the
    # same bits twice, so the store stays bit-identical while the arrays
    # round-trip through the injector's exact write-back path — then one
    # execute per bucket signature
    for _ in range(2):
        flip_store_bits(binding, n_rows=2, seed=29, tier="both")
    padder = make_padder(cfg)
    for bs in bat_cfg.batch_sizes:
        for pl in bat_cfg.poolings:
            wb = Bucket(bs, pl)
            wbatch = padder([factory(i, wb.pooling)
                             for i in range(wb.batch)], wb)
            jax.block_until_ready(binding.execute(wbatch))
    runtime.executor = fex
    binding.reset_plan_stats()
    treated = runtime.run(OpenLoopSource(request_stream(cfg, load)))
    # retrace gate read BEFORE any probe executes (probe batches reuse
    # warmed signatures, but the discipline matches the other sections)
    treated["steady_traces"] = binding.plan_stats()["traces"]
    rep = treated["scrub_run"]

    print(f"[scrub     ] base    p99={base['p99_ms']:8.2f} "
          f"qps={base['qps']:8.1f} steady_traces={base['steady_traces']}")
    print(f"[scrub     ] treated p99={treated['p99_ms']:8.2f} "
          f"qps={treated['qps']:8.1f} "
          f"steady_traces={treated['steady_traces']} "
          f"avail={treated['availability']:.4f} "
          f"cycles={rep['cycles']} sweep={rep['sweep_cycles']} "
          f"flips={fex.bit_flip_events} "
          f"detected={rep['pages_detected']} "
          f"repaired={rep['pages_repaired']} "
          f"mttr_max={rep.get('repair_mttr_max_s', 0.0):.4f}s "
          f"corruption_trips={ctrl.corruption_trips}")

    # ---- gates ----
    for name, r in (("base", base), ("treated", treated)):
        if r["steady_traces"]:
            raise AssertionError(
                f"plan cache failed under scrubbing: steady-state retrace "
                f"in the {name} leg")
    if len(fex.bit_flip_events) != len(flip_at):
        raise AssertionError(
            f"bit_flip schedule under-fired: {fex.bit_flip_events} "
            f"(expected one event per step in {flip_at})")
    flipped = sorted({int(p) for e in fex.bit_flip_events
                      for p in e["pages"]})
    # detection within one full sweep of the flip (+1 cycle slack for the
    # attempt-index/cycle-index offset: the flip lands mid-batch, the
    # audit runs on that batch's maintenance turn at the earliest)
    sweep = rep["sweep_cycles"]
    for e in fex.bit_flip_events:
        for p in e["pages"]:
            cyc = rep["detections"].get(int(p))
            if cyc is None:
                raise AssertionError(
                    f"page {p} flipped at step {e['step']} was never "
                    f"detected ({rep['cycles']} cycles run)")
            if cyc > e["step"] + sweep + 1:
                raise AssertionError(
                    f"detection latency gate failed: page {p} flipped at "
                    f"step {e['step']} detected at cycle {cyc} > one full "
                    f"sweep ({sweep} cycles) later")
    if rep["pages_repaired"] < rep["pages_detected"] or rep["quarantined"]:
        raise AssertionError(
            f"repair gate failed: detected={rep['pages_detected']} "
            f"repaired={rep['pages_repaired']} "
            f"still_quarantined={rep['quarantined']}")
    if not ctrl.corruption_trips:
        raise AssertionError(
            "detections never reached the degradation controller "
            "(on_corruption)")
    if treated["availability"] < 0.99:
        raise AssertionError(
            f"availability gate failed under scrubbing: "
            f"{treated['availability']:.4f} < 0.99")
    p99_gate = 1.10 * base["p99_ms"]
    if treated["p99_ms"] >= p99_gate:
        raise AssertionError(
            f"scrubbing blew the service tail: p99 "
            f"{treated['p99_ms']:.2f} ms >= 1.10 x clean-leg p99 "
            f"({base['p99_ms']:.2f} ms) at equal offered load")
    # per-page repair MTTR: snapshot slice + filtered WAL replay over warm
    # plans — bounded loosely in SLO multiples (floored for CPU hosts
    # where jit dispatch dominates), same convention as the mesh MTTR
    mttr_bound = max(100.0 * slo_ms * 1e-3, 60.0)
    for r in rep["repairs"]:
        if not (0.0 < r["mttr_s"] < mttr_bound):
            raise AssertionError(
                f"repair MTTR unbounded: page {r['page']} took "
                f"{r['mttr_s']:.3f} s >= {mttr_bound:.1f} s")
    if "scrub" not in treated["maintenance_s"]:
        raise AssertionError(
            "scrub wall time missing from maintenance accounting")

    # ---- bitwise truth: repaired store == never-corrupted store
    after_leaves = _state_leaves(binding)
    after_scores = np.asarray(jax.device_get(binding.execute(probe)))
    leaves_ok = all(a.dtype == b.dtype and (a == b).all()
                    for a, b in zip(truth_leaves, after_leaves))
    scores_ok = (truth_scores == after_scores).all()
    print(f"[scrub     ] repaired_state_identical={bool(leaves_ok)} "
          f"lookups_identical={bool(scores_ok)}")
    if not leaves_ok:
        raise AssertionError(
            "scrub repairs did not reproduce the never-corrupted store "
            "bit-for-bit")
    if not scores_ok:
        raise AssertionError("scrub repairs changed lookup results")

    treated.pop("latency_hist", None)
    treated.pop("dedup_factors", None)
    base.pop("latency_hist", None)
    base.pop("dedup_factors", None)
    return {
        "offered_qps": 0.3 * capacity_qps,
        "pages_per_cycle": pages_per_cycle,
        "sweep_cycles": sweep,
        "flip_at": list(flip_at),
        "flip_events": list(fex.bit_flip_events),
        "flipped_pages": flipped,
        "p99_gate_ms": p99_gate,
        "mttr_bound_s": mttr_bound,
        "corruption_trips": ctrl.corruption_trips,
        "repaired_bit_identical": bool(leaves_ok and scores_ok),
        "base": base,
        "treated": treated,
    }


def run_front_end_leg(cfg, args, bat_cfg, runtime_cfg, offered_qps, slo_ms,
                      max_wait_ms, n_requests, batch_sizes, poolings) -> dict:
    """Fused front end under tensor parallelism, end to end.

    Serves the same offered-load stream through two bindings on a (4, 2)
    dp x tp mesh — ``front_end='fused'`` (which the engine resolves
    ``fused_tp``: partial-pool per shard, psum the (B, F, d) cold tile,
    resume) and the ``front_end='split'`` control.  Hard gates: the fused
    binding's plans actually resolved ``fused_tp`` (a silent fallback to
    split would time the wrong datapath), zero steady-state retraces in
    both runs, and probe-batch scores bit-equal between the bindings."""
    mesh = make_mesh((4, 2), ("data", "model"))
    leg = {"mesh": {"data": 4, "model": 2}}
    with mesh:
        bindings = {
            fe: bind_model(cfg, mesh, mode=args.mode, impl=args.impl,
                           block_l=args.block_l, storage=args.storage,
                           dedup=args.dedup, front_end=fe)
            for fe in ("split", "fused")}
        # bit-equality probe: identical padded batches through both steps
        factory = dummy_request_factory(cfg, storage=args.storage)
        padder = make_padder(cfg)
        for bucket in (Bucket(batch_sizes[0], poolings[0]),
                       Bucket(batch_sizes[-1], poolings[-1])):
            batch = padder([factory(i, bucket.pooling)
                            for i in range(bucket.batch)], bucket)
            a = np.asarray(bindings["split"].execute(batch))
            b = np.asarray(bindings["fused"].execute(batch))
            if not np.array_equal(a, b):
                raise AssertionError(
                    f"fused_tp scores diverge from the split control on "
                    f"bucket {bucket}")
        for fe, binding in bindings.items():
            load = LoadConfig(
                n_requests=n_requests,
                arrival=ArrivalConfig(rate_qps=offered_qps,
                                      process="poisson", seed=11),
                slo_ms=slo_ms, poolings=(), seed=11,
                storage=args.storage, dedup=args.dedup, front_end=fe)
            dyn_cfg = dataclasses.replace(bat_cfg, max_wait_ms=max_wait_ms)
            r = run_policy(binding, cfg, DynamicBatcher(dyn_cfg), load,
                           runtime_cfg)
            if r["steady_traces"]:
                raise AssertionError(
                    f"plan cache failed: steady-state retrace in the "
                    f"front-end leg (front_end={fe})")
            recs = [rec for rec in
                    binding.engine.plan_stats().get("front_end", {}).values()
                    if rec["requested"] == fe]
            want = "fused_tp" if fe == "fused" else "split"
            if fe == "fused" and (
                    not recs
                    or any(rec["resolved"] != want for rec in recs)):
                # the split control composes lookup + interaction as
                # separate ops (no lookup_interact plan, no record); the
                # fused binding must have resolved every plan fused_tp
                raise AssertionError(
                    f"front-end leg resolution: requested={fe} expected "
                    f"{want!r}, got {[rec['resolved'] for rec in recs]}")
            r.pop("latency_hist", None)
            r.pop("dedup_factors", None)
            r["resolved"] = want
            leg[fe] = r
            print(f"[front-end] {fe:5s} -> {want:8s} "
                  f"qps={r['qps']:8.1f} p50={r['p50_ms']:7.2f} "
                  f"p99={r['p99_ms']:8.2f} "
                  f"steady_traces={r['steady_traces']}")
    leg["scores_bit_equal"] = True
    return leg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--arch", default="rmc1")
    ap.add_argument("--requests", type=int, default=800)
    ap.add_argument("--load-frac", type=float, default=0.5,
                    help="sustained-regime offered load as a fraction of "
                         "measured capacity")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="SLO budget; 0 = auto (5x largest-bucket service)")
    ap.add_argument("--mode", default="pifs",
                    choices=["pifs", "pond", "beacon"])
    ap.add_argument("--impl", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--block-l", type=int, default=8)
    ap.add_argument("--storage", default="fp32", choices=["fp32", "int8"],
                    help="engine cold-tier storage dtype (reported in the "
                         "run header so BENCH_serve.json entries stay "
                         "comparable across storage modes)")
    ap.add_argument("--dedup", default="off", choices=["off", "auto", "on"],
                    help="gather-once duplicate coalescing in the SLS "
                         "datapath (bit-exact; reported per bucket so "
                         "serving wins are attributable in bytes)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI configuration (fewer requests/buckets)")
    ap.add_argument("--faults", action="store_true",
                    help="run the fault-injection regimes (straggler, "
                         "transient, corrupt data/store, forced brown-out) "
                         "instead of the policy-comparison regimes")
    ap.add_argument("--updates", action="store_true",
                    help="run the streaming-embedding-update regime (clean "
                         "vs update-stream runs at equal offered load, "
                         "staleness SLOs, WAL-replay recovery probe) "
                         "instead of the policy-comparison regimes")
    ap.add_argument("--update-batch", type=int, default=32,
                    help="rows per trainer-emitted delta batch (--updates)")
    ap.add_argument("--mesh-faults", action="store_true",
                    help="run the degraded-mesh regime (kill a tp shard "
                         "mid-serving, gate on elastic re-mesh recovery: "
                         "availability, bounded MTTR, zero retraces, "
                         "bit-exact post-recovery scores) instead of the "
                         "policy-comparison regimes")
    ap.add_argument("--prefer-tp", type=int, default=2,
                    help="survivor-mesh tp preference for the elastic "
                         "re-mesh policy (--mesh-faults; "
                         "repro.runtime.elastic.scale_plan)")
    ap.add_argument("--scrub", action="store_true",
                    help="run the silent-corruption regime (clean vs "
                         "bit-flip + checksum-scrub legs at equal offered "
                         "load, page-granular snapshot/WAL repair, bitwise "
                         "post-repair equality) instead of the "
                         "policy-comparison regimes")
    ap.add_argument("--scrub-pages-per-cycle", type=int, default=8,
                    help="pages audited per maintenance turn (--scrub; "
                         "full sweep every ceil(num_pages / K) cycles)")
    args = ap.parse_args()
    if sum((args.faults, args.updates, args.mesh_faults, args.scrub)) > 1:
        ap.error("--faults, --updates, --mesh-faults, and --scrub are "
                 "mutually exclusive sections")

    cfg = reduced(get_config(args.arch))

    if args.mesh_faults:
        # the section builds its own per-config meshes/bindings (the
        # whole point is that the mesh changes mid-run); --storage/--dedup
        # are superseded by the per-config matrix
        n_requests = 96 if args.smoke else 192
        print(f"serve bench: arch={args.arch} mode={args.mode} "
              f"impl={args.impl} section=mesh_faults "
              f"prefer_tp={args.prefer_tp}")
        runs = run_mesh_fault_section(cfg, args, n_requests, args.prefer_tp)
        out = {
            "bench": "serve",
            "schema": 7,
            "section": "mesh_faults",
            "backend": jax.default_backend(),
            "interpret_mode": jax.default_backend() != "tpu",
            "jax_version": jax.__version__,
            "platform": platform.platform(),
            "mesh": {"data": 2, "model": 4},
            "arch": args.arch, "mode": args.mode, "impl": args.impl,
            "block_l": args.block_l, "prefer_tp": args.prefer_tp,
            "n_requests": n_requests,
            "mesh_fault_runs": runs,
        }
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"\nwrote {args.out}")
        return
    mesh = make_mesh((2, 4), ("data", "model"))

    # Regimes: the tail-latency gate applies where the policies differ
    # *structurally* (sub-saturation load, where fixed-batch fill time
    # dominates the tail); at sustained load both policies serve full
    # buckets and the comparison is noise — there we gate throughput.
    if args.smoke:
        batch_sizes, poolings = (8, 16), (cfg.pooling,)
        n_requests = 160
        regimes = [
            dict(label="trough", process="poisson", frac=0.12,
                 gate_p99=True, gate_qps=False),
            # 0.4, not 0.5: shared CI runners execute slower than the
            # calibration pass, and the 0.8 sustain gate needs headroom
            dict(label="sustained", process="poisson", frac=0.4,
                 gate_p99=False, gate_qps=True),
        ]
    else:
        batch_sizes = (8, 16, 32)
        poolings = tuple(sorted({max(1, cfg.pooling // 2), cfg.pooling}))
        n_requests = args.requests
        regimes = [
            dict(label="trough", process="poisson", frac=0.12,
                 gate_p99=True, gate_qps=False),
            dict(label="sustained", process="poisson", frac=args.load_frac,
                 gate_p99=False, gate_qps=True),
            dict(label="bursty", process="bursty", frac=0.4,
                 gate_p99=False, gate_qps=False),
        ]

    print(f"serve bench: arch={args.arch} mode={args.mode} impl={args.impl} "
          f"storage={args.storage} (cold tier "
          f"{'int8+page-scales' if args.storage == 'int8' else 'fp32'}) "
          f"dedup={args.dedup}")
    binding = bind_model(cfg, mesh, mode=args.mode, impl=args.impl,
                         block_l=args.block_l, storage=args.storage,
                         dedup=args.dedup,
                         # fault runs need the ladder's serve-step variants
                         # and the NaN/Inf score scrub armed
                         degraded_variants=args.faults,
                         scrub_scores=args.faults)
    bat_cfg = BatcherConfig(batch_sizes=batch_sizes, poolings=poolings)
    fixed_bucket = Bucket(batch_sizes[-1], poolings[-1])
    runtime_cfg = RuntimeConfig(observe_every=4, replan_every=32)

    with mesh:
        # calibrate: warm all buckets once, read the largest bucket's
        # steady service time off the service model
        calib = ServingRuntime(BindingExecutor(binding),
                               DynamicBatcher(bat_cfg), make_padder(cfg),
                               runtime_cfg)
        warm = calib.warmup(dummy_request_factory(cfg, storage=args.storage))
        # calibrate the largest bucket's service time as a median over
        # several steady executions (a single sample is too noisy on
        # shared CPU hosts to anchor offered load on)
        factory = dummy_request_factory(cfg, storage=args.storage)
        cal_batch = make_padder(cfg)(
            [factory(i, fixed_bucket.pooling)
             for i in range(fixed_bucket.batch)], fixed_bucket)
        ex = BindingExecutor(binding)
        svc_max = float(np.median(
            [ex.run_batch(fixed_bucket, cal_batch) for _ in range(5)]))
        calib.service_model.update(fixed_bucket, svc_max)
        capacity_qps = fixed_bucket.batch / svc_max
        # auto SLO at 5 service times: both the dynamic deadline-bound tail
        # (~slo) and the fixed-batch fill tail (~svc/frac) scale with the
        # measured service time, so the trough-regime comparison is robust
        # to calibration error
        slo_ms = args.slo_ms or 5.0 * svc_max * 1e3
        # coalescing-wait cap: ~1.5 service times (waiting longer than that
        # buys occupancy the latency budget can't afford), never more than
        # half the SLO budget
        max_wait_ms = min(slo_ms / 2, max(2.0, 1.5 * svc_max * 1e3))
        print(f"capacity ~{capacity_qps:.0f} qps "
              f"(service({fixed_bucket.batch}x{fixed_bucket.pooling}) = "
              f"{svc_max * 1e3:.2f} ms), slo {slo_ms:.1f} ms, "
              f"coalesce cap {max_wait_ms:.1f} ms")

        if args.faults:
            import tempfile
            bat_cfg_f = dataclasses.replace(bat_cfg, max_wait_ms=max_wait_ms)
            _warm_all_rungs(binding, cfg, bat_cfg_f, runtime_cfg,
                            calib.service_model, args.storage)
            runs = run_fault_section(
                binding, cfg, bat_cfg_f, runtime_cfg, calib.service_model,
                n_requests, capacity_qps, slo_ms, args.storage, args.dedup,
                tempfile.mkdtemp(prefix="serve_bench_ckpt_"))
            out = {
                "bench": "serve",
                "schema": 7,
                "section": "faults",
                "backend": jax.default_backend(),
                "interpret_mode": jax.default_backend() != "tpu",
                "jax_version": jax.__version__,
                "platform": platform.platform(),
                "mesh": {"data": 2, "model": 4},
                "arch": args.arch, "mode": args.mode, "impl": args.impl,
                "block_l": args.block_l, "storage": args.storage,
                "dedup": args.dedup,
                "capacity_qps": capacity_qps, "slo_ms": slo_ms,
                "n_requests": n_requests,
                "fault_runs": {k: {kk: vv for kk, vv in v.items()
                                   if kk != "latency_hist"}
                               for k, v in runs.items()},
            }
            with open(args.out, "w") as f:
                json.dump(out, f, indent=2)
            print(f"\nwrote {args.out}")
            return

        if args.updates:
            import tempfile
            bat_cfg_u = dataclasses.replace(bat_cfg, max_wait_ms=max_wait_ms)
            section = run_update_section(
                binding, cfg, bat_cfg_u, runtime_cfg, n_requests,
                capacity_qps, slo_ms, svc_max, args.storage, args.dedup,
                args.update_batch,
                tempfile.mkdtemp(prefix="serve_bench_upd_"))
            for leg in ("base", "updates"):
                section[leg] = {k: v for k, v in section[leg].items()
                                if k != "latency_hist"}
            out = {
                "bench": "serve",
                "schema": 7,
                "section": "updates",
                "backend": jax.default_backend(),
                "interpret_mode": jax.default_backend() != "tpu",
                "jax_version": jax.__version__,
                "platform": platform.platform(),
                "mesh": {"data": 2, "model": 4},
                "arch": args.arch, "mode": args.mode, "impl": args.impl,
                "block_l": args.block_l, "storage": args.storage,
                "dedup": args.dedup,
                "capacity_qps": capacity_qps, "slo_ms": slo_ms,
                "n_requests": n_requests,
                "update_run": section,
            }
            with open(args.out, "w") as f:
                json.dump(out, f, indent=2)
            print(f"\nwrote {args.out}")
            return

        if args.scrub:
            import tempfile
            bat_cfg_s = dataclasses.replace(bat_cfg, max_wait_ms=max_wait_ms)
            section = run_scrub_section(
                binding, cfg, bat_cfg_s, runtime_cfg, n_requests,
                capacity_qps, slo_ms, args.storage, args.dedup,
                args.scrub_pages_per_cycle,
                tempfile.mkdtemp(prefix="serve_bench_scrub_"))
            out = {
                "bench": "serve",
                "schema": 7,
                "section": "scrub",
                "backend": jax.default_backend(),
                "interpret_mode": jax.default_backend() != "tpu",
                "jax_version": jax.__version__,
                "platform": platform.platform(),
                "mesh": {"data": 2, "model": 4},
                "arch": args.arch, "mode": args.mode, "impl": args.impl,
                "block_l": args.block_l, "storage": args.storage,
                "dedup": args.dedup,
                "capacity_qps": capacity_qps, "slo_ms": slo_ms,
                "n_requests": n_requests,
                "scrub_run": section,
            }
            with open(args.out, "w") as f:
                json.dump(out, f, indent=2)
            print(f"\nwrote {args.out}")
            return

        runs = {}
        for regime in regimes:
            offered_qps = regime["frac"] * capacity_qps
            arrival = ArrivalConfig(
                rate_qps=offered_qps, process=regime["process"], seed=7,
                burst_factor=4.0, mean_burst_s=0.05)
            load = LoadConfig(
                n_requests=n_requests, arrival=arrival, slo_ms=slo_ms,
                poolings=poolings if len(poolings) > 1 else (),
                seed=7, storage=args.storage, dedup=args.dedup)
            dyn_cfg = dataclasses.replace(bat_cfg, max_wait_ms=max_wait_ms)
            dyn = run_policy(binding, cfg, DynamicBatcher(dyn_cfg), load,
                             runtime_cfg)
            fix = run_policy(binding, cfg,
                             FixedBatcher(fixed_bucket.batch,
                                          fixed_bucket.pooling),
                             load, runtime_cfg)
            label = regime["label"]
            for name, r in (("dynamic", dyn), ("fixed", fix)):
                print(f"[{label:9s}] {name:8s} "
                      f"offered={offered_qps:7.1f} qps={r['qps']:8.1f} "
                      f"p50={r['p50_ms']:7.2f} p99={r['p99_ms']:8.2f} "
                      f"p99.9={r['p99.9_ms']:8.2f} "
                      f"slo_viol={r['slo_violation_rate']:.3f} "
                      f"occ={r['batch_occupancy_mean']:.2f} "
                      f"steady_traces={r['steady_traces']}")
                for bucket, rec in r.get("dedup_factors", {}).items():
                    print(f"            dedup[{bucket}] "
                          f"factor={rec['factor']:.2f} "
                          f"({rec['entries']} entries -> "
                          f"{rec['unique_rows']} unique)")
                if r["steady_traces"]:
                    raise AssertionError(
                        f"plan cache failed: steady-state retrace in "
                        f"{name}/{label} serving run")
            if regime["gate_p99"] and dyn["p99_ms"] >= fix["p99_ms"]:
                raise AssertionError(
                    f"dynamic batcher p99 ({dyn['p99_ms']:.2f} ms) not "
                    f"below fixed-batch p99 ({fix['p99_ms']:.2f} ms) in "
                    f"the {label} regime at {offered_qps:.0f} qps")
            if regime["gate_qps"] and dyn["qps"] < 0.8 * offered_qps:
                raise AssertionError(
                    f"dynamic batcher did not sustain offered load in "
                    f"{label}: {dyn['qps']:.1f} qps vs {offered_qps:.1f}")
            runs[label] = {"process": regime["process"],
                           "offered_qps": offered_qps,
                           "gate_p99": regime["gate_p99"],
                           "gate_qps": regime["gate_qps"],
                           "dynamic": dyn, "fixed": fix}

    # fused front end under tp (DLRM only: Rec configs have no
    # dot-interaction stage, so the knob is a no-op for them)
    front_end_leg = None
    if hasattr(cfg, "n_tables"):
        front_end_leg = run_front_end_leg(
            cfg, args, bat_cfg, runtime_cfg, 0.3 * capacity_qps, slo_ms,
            max_wait_ms, min(n_requests, 120), batch_sizes, poolings)

    out = {
        "bench": "serve",
        "schema": 7,
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "jax_version": jax.__version__,
        "platform": platform.platform(),
        "mesh": {"data": 2, "model": 4},
        "arch": args.arch,
        "mode": args.mode,
        "impl": args.impl,
        "block_l": args.block_l,
        "storage": args.storage,
        "dedup": args.dedup,
        "batch_sizes": list(batch_sizes),
        "poolings": list(poolings),
        "warmup_service_s": warm,
        "capacity_qps": capacity_qps,
        "slo_ms": slo_ms,
        "max_wait_ms": max_wait_ms,
        "n_requests": n_requests,
        "runs": runs,
        "front_end_leg": front_end_leg,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
