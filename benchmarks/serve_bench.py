"""Serving benchmark: deadline-aware dynamic batcher vs the fixed-batch
baseline at equal offered load, on a real PIFSEmbeddingEngine.

The paper's headline claim is *online-inference latency under concurrent
production-style access streams*; this bench measures the quantities that
regime is judged by — p50/p99/p99.9 latency, sustained QPS, SLO-violation
rate, batch occupancy — for two batching policies over the same engine,
the same compiled serve step, and the **same arrival stream** (same seed):

  * ``dynamic`` — the deadline-aware shape-bucket micro-batcher
    (repro.serving.batcher.DynamicBatcher), and
  * ``fixed``   — the old serve-loop policy (wait for a full fixed batch).

Offered load is calibrated against the measured capacity of the largest
bucket (``frac * B_max / service(B_max)``), so the comparison is at an
apples-to-apples utilization on any host.  Each run sweeps load regimes;
hard gates:

  * zero steady-state retraces (``engine.plan_stats()`` delta stays 0
    across every shape bucket after warmup, for both policies, in every
    regime);
  * **trough** regime (sub-saturation, where fixed-batch fill time
    dominates the tail): dynamic p99 < fixed p99 at equal offered load —
    the structural win of deadline-aware flushing;
  * **sustained** regime (both policies serve full buckets; the tail
    difference there is measurement noise): dynamic must sustain >= 80 %
    of the offered QPS.

Writes ``BENCH_serve.json``; schema documented in EXPERIMENTS.md §Serving.

Service times are real measured device executions (interpret-mode caveat
from BENCH_sls applies to pallas impl on CPU); arrivals/queueing run on
the virtual clock, which is what makes tail-latency comparisons meaningful
on CPU containers.

Usage: ``PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
[--impl pallas] [--out BENCH_serve.json]``
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.distributed.sharding import make_mesh  # noqa: E402
from repro.serving import (ArrivalConfig, BatcherConfig,  # noqa: E402
                           BindingExecutor, Bucket, DynamicBatcher,
                           FixedBatcher, LoadConfig, OpenLoopSource,
                           RuntimeConfig, ServingRuntime, bind_model,
                           dummy_request_factory, make_padder,
                           prime_dedup_auto, request_stream)


def run_policy(binding, cfg, batcher, load, runtime_cfg) -> dict:
    """One (policy, arrival-stream) serving run over a warmed binding."""
    runtime = ServingRuntime(BindingExecutor(binding), batcher,
                             make_padder(cfg), runtime_cfg)
    runtime.warmup(dummy_request_factory(cfg, storage=load.storage))
    # ^ no-op cost once plans warm
    reqs = request_stream(cfg, load)
    if load.dedup == "auto" and prime_dedup_auto(binding, reqs):
        # 'auto' freezes per bucket at plan build — rebuild the buckets
        # against a histogram primed with the live stream's prefix
        runtime.warmup(dummy_request_factory(cfg, storage=load.storage))
    binding.reset_plan_stats()
    warm_replans = binding.replans
    binding.dedup_stats.clear()
    summary = runtime.run(OpenLoopSource(reqs))
    stats = binding.plan_stats()
    summary["steady_traces"] = stats["traces"]
    summary["replans"] = binding.replans - warm_replans
    # measured per-bucket duplicate factor (observe-cadence probe): makes
    # serving wins attributable in bytes, not just p50
    summary["dedup_factors"] = binding.dedup_report()
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--arch", default="rmc1")
    ap.add_argument("--requests", type=int, default=800)
    ap.add_argument("--load-frac", type=float, default=0.5,
                    help="sustained-regime offered load as a fraction of "
                         "measured capacity")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="SLO budget; 0 = auto (5x largest-bucket service)")
    ap.add_argument("--mode", default="pifs",
                    choices=["pifs", "pond", "beacon"])
    ap.add_argument("--impl", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--block-l", type=int, default=8)
    ap.add_argument("--storage", default="fp32", choices=["fp32", "int8"],
                    help="engine cold-tier storage dtype (reported in the "
                         "run header so BENCH_serve.json entries stay "
                         "comparable across storage modes)")
    ap.add_argument("--dedup", default="off", choices=["off", "auto", "on"],
                    help="gather-once duplicate coalescing in the SLS "
                         "datapath (bit-exact; reported per bucket so "
                         "serving wins are attributable in bytes)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI configuration (fewer requests/buckets)")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    mesh = make_mesh((2, 4), ("data", "model"))

    # Regimes: the tail-latency gate applies where the policies differ
    # *structurally* (sub-saturation load, where fixed-batch fill time
    # dominates the tail); at sustained load both policies serve full
    # buckets and the comparison is noise — there we gate throughput.
    if args.smoke:
        batch_sizes, poolings = (8, 16), (cfg.pooling,)
        n_requests = 160
        regimes = [
            dict(label="trough", process="poisson", frac=0.12,
                 gate_p99=True, gate_qps=False),
            # 0.4, not 0.5: shared CI runners execute slower than the
            # calibration pass, and the 0.8 sustain gate needs headroom
            dict(label="sustained", process="poisson", frac=0.4,
                 gate_p99=False, gate_qps=True),
        ]
    else:
        batch_sizes = (8, 16, 32)
        poolings = tuple(sorted({max(1, cfg.pooling // 2), cfg.pooling}))
        n_requests = args.requests
        regimes = [
            dict(label="trough", process="poisson", frac=0.12,
                 gate_p99=True, gate_qps=False),
            dict(label="sustained", process="poisson", frac=args.load_frac,
                 gate_p99=False, gate_qps=True),
            dict(label="bursty", process="bursty", frac=0.4,
                 gate_p99=False, gate_qps=False),
        ]

    print(f"serve bench: arch={args.arch} mode={args.mode} impl={args.impl} "
          f"storage={args.storage} (cold tier "
          f"{'int8+page-scales' if args.storage == 'int8' else 'fp32'}) "
          f"dedup={args.dedup}")
    binding = bind_model(cfg, mesh, mode=args.mode, impl=args.impl,
                         block_l=args.block_l, storage=args.storage,
                         dedup=args.dedup)
    bat_cfg = BatcherConfig(batch_sizes=batch_sizes, poolings=poolings)
    fixed_bucket = Bucket(batch_sizes[-1], poolings[-1])
    runtime_cfg = RuntimeConfig(observe_every=4, replan_every=32)

    with mesh:
        # calibrate: warm all buckets once, read the largest bucket's
        # steady service time off the service model
        calib = ServingRuntime(BindingExecutor(binding),
                               DynamicBatcher(bat_cfg), make_padder(cfg),
                               runtime_cfg)
        warm = calib.warmup(dummy_request_factory(cfg, storage=args.storage))
        # calibrate the largest bucket's service time as a median over
        # several steady executions (a single sample is too noisy on
        # shared CPU hosts to anchor offered load on)
        factory = dummy_request_factory(cfg, storage=args.storage)
        cal_batch = make_padder(cfg)(
            [factory(i, fixed_bucket.pooling)
             for i in range(fixed_bucket.batch)], fixed_bucket)
        ex = BindingExecutor(binding)
        svc_max = float(np.median(
            [ex.run_batch(fixed_bucket, cal_batch) for _ in range(5)]))
        calib.service_model.update(fixed_bucket, svc_max)
        capacity_qps = fixed_bucket.batch / svc_max
        # auto SLO at 5 service times: both the dynamic deadline-bound tail
        # (~slo) and the fixed-batch fill tail (~svc/frac) scale with the
        # measured service time, so the trough-regime comparison is robust
        # to calibration error
        slo_ms = args.slo_ms or 5.0 * svc_max * 1e3
        # coalescing-wait cap: ~1.5 service times (waiting longer than that
        # buys occupancy the latency budget can't afford), never more than
        # half the SLO budget
        max_wait_ms = min(slo_ms / 2, max(2.0, 1.5 * svc_max * 1e3))
        print(f"capacity ~{capacity_qps:.0f} qps "
              f"(service({fixed_bucket.batch}x{fixed_bucket.pooling}) = "
              f"{svc_max * 1e3:.2f} ms), slo {slo_ms:.1f} ms, "
              f"coalesce cap {max_wait_ms:.1f} ms")

        runs: dict = {}
        for regime in regimes:
            offered_qps = regime["frac"] * capacity_qps
            arrival = ArrivalConfig(
                rate_qps=offered_qps, process=regime["process"], seed=7,
                burst_factor=4.0, mean_burst_s=0.05)
            load = LoadConfig(
                n_requests=n_requests, arrival=arrival, slo_ms=slo_ms,
                poolings=poolings if len(poolings) > 1 else (),
                seed=7, storage=args.storage, dedup=args.dedup)
            dyn_cfg = dataclasses.replace(bat_cfg, max_wait_ms=max_wait_ms)
            dyn = run_policy(binding, cfg, DynamicBatcher(dyn_cfg), load,
                             runtime_cfg)
            fix = run_policy(binding, cfg,
                             FixedBatcher(fixed_bucket.batch,
                                          fixed_bucket.pooling),
                             load, runtime_cfg)
            label = regime["label"]
            for name, r in (("dynamic", dyn), ("fixed", fix)):
                print(f"[{label:9s}] {name:8s} "
                      f"offered={offered_qps:7.1f} qps={r['qps']:8.1f} "
                      f"p50={r['p50_ms']:7.2f} p99={r['p99_ms']:8.2f} "
                      f"p99.9={r['p99.9_ms']:8.2f} "
                      f"slo_viol={r['slo_violation_rate']:.3f} "
                      f"occ={r['batch_occupancy_mean']:.2f} "
                      f"steady_traces={r['steady_traces']}")
                for bucket, rec in r.get("dedup_factors", {}).items():
                    print(f"            dedup[{bucket}] "
                          f"factor={rec['factor']:.2f} "
                          f"({rec['entries']} entries -> "
                          f"{rec['unique_rows']} unique)")
                if r["steady_traces"]:
                    raise AssertionError(
                        f"plan cache failed: steady-state retrace in "
                        f"{name}/{label} serving run")
            if regime["gate_p99"] and dyn["p99_ms"] >= fix["p99_ms"]:
                raise AssertionError(
                    f"dynamic batcher p99 ({dyn['p99_ms']:.2f} ms) not "
                    f"below fixed-batch p99 ({fix['p99_ms']:.2f} ms) in "
                    f"the {label} regime at {offered_qps:.0f} qps")
            if regime["gate_qps"] and dyn["qps"] < 0.8 * offered_qps:
                raise AssertionError(
                    f"dynamic batcher did not sustain offered load in "
                    f"{label}: {dyn['qps']:.1f} qps vs {offered_qps:.1f}")
            runs[label] = {"process": regime["process"],
                           "offered_qps": offered_qps,
                           "gate_p99": regime["gate_p99"],
                           "gate_qps": regime["gate_qps"],
                           "dynamic": dyn, "fixed": fix}

    out = {
        "bench": "serve",
        "schema": 2,
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "jax_version": jax.__version__,
        "platform": platform.platform(),
        "mesh": {"data": 2, "model": 4},
        "arch": args.arch,
        "mode": args.mode,
        "impl": args.impl,
        "block_l": args.block_l,
        "storage": args.storage,
        "dedup": args.dedup,
        "batch_sizes": list(batch_sizes),
        "poolings": list(poolings),
        "warmup_service_s": warm,
        "capacity_qps": capacity_qps,
        "slo_ms": slo_ms,
        "max_wait_ms": max_wait_ms,
        "n_requests": n_requests,
        "runs": runs,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
