"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Prints the per-(arch x shape x mesh) three-term roofline with the dominant
bottleneck and the MODEL/HLO useful-flops ratio — the §Roofline deliverable.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_records(mesh: Optional[str] = None) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        d = json.load(open(f))
        if mesh and d.get("mesh") != mesh:
            continue
        recs.append(d)
    return recs


def table(mesh: str = "pod") -> str:
    rows = []
    hdr = (f"{'arch':22s} {'shape':14s} {'fit':4s} {'GB':>5s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'dominant':>10s} {'useful':>7s}")
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for d in load_records(mesh):
        if d.get("skipped"):
            rows.append(f"{d['arch']:22s} {d['shape']:14s} SKIP "
                        f"(sub-quadratic-only shape)")
            continue
        if not d.get("ok"):
            rows.append(f"{d['arch']:22s} {d['shape']:14s} FAIL")
            continue
        r = d["roofline"]
        m = d["memory"]
        gb = (m["argument_bytes"] + m["temp_bytes"]) / 1e9
        corr = r.get("bf16_cpu_upcast_correction", 1.0)
        gb_eq = gb * (corr if corr < 1 else 1.0)
        fit = "ok" if gb_eq < 16 else "OOM"
        rows.append(
            f"{d['arch']:22s} {d['shape']:14s} {fit:4s} {gb_eq:5.1f} "
            f"{r['compute_s']:10.3e} {r['memory_s']:10.3e} "
            f"{r['collective_s']:10.3e} {r['dominant'][:10]:>10s} "
            f"{r['useful_flops_ratio']:7.3f}")
    return "\n".join(rows)


def main() -> None:
    for mesh in ("pod", "multipod"):
        print(f"\n=== Roofline ({mesh}: "
              f"{'256' if mesh == 'pod' else '512'} chips) ===")
        print(table(mesh))


if __name__ == "__main__":
    main()
